"""Paper experiments E1-E8 (one function per paper figure/table).

Scale note: the paper's cluster is 40 nodes x 24 cores = 960 cores; its task
counts are 4.6k-23.4k. We run the same task counts with the same worker x
thread topology; task compute is virtual time, store ops are measured (see
simkit). Where the container is the limit (one CPU), counts are optionally
scaled by ``scale`` with proportional workloads — ratios, not absolute
seconds, are the reproduction target.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.simkit import SimResult, run_centralized, run_chaos, \
    run_distributed, run_replica_lag, run_shard_failover, run_sharded, \
    run_wire_ship
from repro.configs import risers_workflow as RW

PAPER_ACCESS_LATENCY_S = 0.010   # MySQL Cluster over GbE under 936-thread
                                 # concurrency (calibrated to Fig. 11's
                                 # short-task saturation; see EXPERIMENTS)
PAPER_MASTER_RTT_S = 0.010       # Chiron: MPI hop + PostgreSQL transaction


def exp1_strong_scaling(scale: float = 0.1) -> List[Dict]:
    """Fig. 9a: fixed 13k-task workload, 120->960 cores, threads sweep."""
    n_tasks = int(13_000 * scale)
    rows = []
    base: Dict[int, float] = {}
    for threads in (12, 24, 48):
        for nodes in (5, 10, 20, 40):
            r = run_distributed(nodes, threads, n_tasks, 60.0)
            key = threads
            if nodes == 5:
                base[key] = r.makespan_s
            linear = base[key] * 5 / nodes
            rows.append({
                "exp": "e1", "nodes": nodes, "cores": nodes * 24,
                "threads": threads, "makespan_s": round(r.makespan_s, 2),
                "linear_s": round(linear, 2),
                "efficiency": round(linear / r.makespan_s, 3),
            })
    return rows


def exp2_weak_scaling(scale: float = 0.1) -> List[Dict]:
    """Fig. 9b: workload grows with cores (6k/12k/23.4k on 10/20/39 nodes)."""
    rows = []
    base = None
    for nodes, n_tasks in ((10, 6_000), (20, 12_000), (39, 23_400)):
        r = run_distributed(nodes, 24, int(n_tasks * scale), 60.0)
        if base is None:
            base = r.makespan_s
        rows.append({
            "exp": "e2", "nodes": nodes, "cores": nodes * 24,
            "tasks": int(n_tasks * scale),
            "makespan_s": round(r.makespan_s, 2),
            "vs_linear": round(r.makespan_s / base - 1.0, 3),
        })
    return rows


def exp3_workload_tasks(scale: float = 0.1) -> List[Dict]:
    """Fig. 10a: fixed duration (5s / 60s), varying #tasks, 39 nodes."""
    rows = []
    for mode, lat in (("paper", PAPER_ACCESS_LATENCY_S), ("adapted", 0.0)):
        for dur in (5.0, 60.0):
            base = None
            for n_tasks in RW.EXP3_TASK_COUNTS:
                n = int(n_tasks * scale)
                r = run_distributed(39, 24, n, dur, access_latency_s=lat)
                if base is None:
                    base = (r.makespan_s, n)
                linear = base[0] * n / base[1]
                rows.append({
                    "exp": "e3", "mode": mode, "task_dur_s": dur, "tasks": n,
                    "makespan_s": round(r.makespan_s, 2),
                    "linear_s": round(linear, 2),
                    "gap": round(r.makespan_s / linear - 1.0, 4),
                })
    return rows


def exp4_workload_duration(scale: float = 0.1) -> List[Dict]:
    """Fig. 10b: fixed #tasks (4.6k / 23.4k), varying duration."""
    rows = []
    for mode, lat in (("paper", PAPER_ACCESS_LATENCY_S), ("adapted", 0.0)):
        for n_tasks in RW.EXP4_TASK_COUNTS:
            n = int(n_tasks * scale)
            base = None
            for dur in sorted(RW.EXP4_DURATIONS, reverse=True):
                r = run_distributed(39, 24, n, dur, access_latency_s=lat)
                if base is None:
                    base = (r.makespan_s, dur)
                linear = base[0] * dur / base[1]
                rows.append({
                    "exp": "e4", "mode": mode, "tasks": n, "task_dur_s": dur,
                    "makespan_s": round(r.makespan_s, 2),
                    "linear_s": round(linear, 2),
                    "gap": round(r.makespan_s / max(linear, 1e-9) - 1.0, 4),
                })
    return rows


def exp5_dbms_overhead(scale: float = 0.1) -> List[Dict]:
    """Fig. 11: DBMS access time vs total, 23.4k tasks, dur 1..60s.

    Two regimes per duration: "paper" charges the calibrated per-access
    latency of the paper's stack; "adapted" charges only our measured
    in-memory store ops (the TPU adaptation's real overhead).
    """
    rows = []
    n = int(RW.EXP5_TASKS * scale)
    for dur in RW.EXP5_DURATIONS:
        for mode, lat in (("paper", PAPER_ACCESS_LATENCY_S), ("adapted", 0.0)):
            r = run_distributed(39, 24, n, dur, access_latency_s=lat)
            rows.append({
                "exp": "e5", "mode": mode, "task_dur_s": dur,
                "dbms_max_node_s": round(r.dbms_time_s, 4),
                "dbms_total_s": round(r.dbms_total_s, 4),
                "total_s": round(r.makespan_s, 2),
                "dbms_frac": round(
                    r.dbms_time_s * 39 / max(r.makespan_s * 39, 1e-9), 4),
            })
    return rows


def exp6_access_breakdown(scale: float = 0.1) -> List[Dict]:
    """Fig. 12: time share per DBMS access kind (10s workload)."""
    n = int(RW.EXP5_TASKS * scale)
    r = run_distributed(39, 24, n, 10.0, activities=3, steer_every_s=0.0)
    total = sum(r.op_time.values())
    return [{
        "exp": "e6", "op": k,
        "time_s": round(v, 4),
        "share": round(v / total, 4),
        "count": r.op_count[k],
    } for k, v in sorted(r.op_time.items(), key=lambda kv: -kv[1])]


def exp7_steering_overhead(scale: float = 0.1) -> List[Dict]:
    """Fig. 13 at 10x the seed task count: wall time with vs without
    15s-interval steering sweeps. Sweeps execute against store SNAPSHOTS on
    an analyst thread, truly concurrent with the workers' claim loop — the
    HTAP interference this experiment quantifies."""
    n = int(RW.EXP5_TASKS * scale * 10)
    r0 = run_distributed(39, 24, n, 5.0, steer_every_s=0.0,
                         access_latency_s=PAPER_ACCESS_LATENCY_S)
    r1 = run_distributed(39, 24, n, 5.0, steer_every_s=15.0,
                         access_latency_s=PAPER_ACCESS_LATENCY_S)
    return [{
        "exp": "e7", "steering": s, "tasks": n,
        "makespan_s": round(r.makespan_s, 2),
        "overhead": round(r.makespan_s / r0.makespan_s - 1.0, 4),
        "queries_run": r.op_count.get("steering(Q1..Q7)", 0),
        "steer_wall_s": round(r.op_time.get("steering(Q1..Q7)", 0.0), 4),
    } for s, r in (("off", r0), ("on", r1))]


def exp8_centralized_vs_distributed(scale: float = 0.1) -> List[Dict]:
    """Fig. 14: Chiron (centralized) vs d-Chiron (SchalaDB) on 39 nodes."""
    rows = []
    for name, n_tasks, dur in RW.EXP8_WORKLOADS:
        n = int(n_tasks * scale)
        for mode, lat, rtt in (("paper", PAPER_ACCESS_LATENCY_S,
                                PAPER_MASTER_RTT_S),
                               ("adapted", 0.0, 0.0)):
            rd = run_distributed(39, 24, n, dur, access_latency_s=lat)
            rc = run_centralized(39, 24, n, dur, request_overhead_s=rtt)
            rows.append({
                "exp": "e8", "mode": mode, "workload": name, "tasks": n,
                "task_dur_s": dur,
                "distributed_s": round(rd.makespan_s, 2),
                "centralized_s": round(rc.makespan_s, 2),
                "speedup": round(rc.makespan_s / max(rd.makespan_s, 1e-9), 2),
                "central_sched_s": round(rc.dbms_time_s, 3),
                "distrib_sched_s": round(rd.dbms_total_s, 3),
                "central_msgs": rc.messages,
            })
    return rows


def exp_replica_lag(scale: float = 1.0) -> List[Dict]:
    """Replica catch-up: delta-shipped txn-log replay vs full-copy baseline.

    The paper's availability story (§3.2, one replica per partition fed by
    the transaction log; tens-of-MB metadata at 100k tasks) demands sync
    cost proportional to the DELTA, not the store. Both arms run the same
    deterministic workload (claims, finishes, fails, requeue, resize, Q8
    patches, prunes, expansions) with the same sync cadence; the delta arm
    additionally verifies that the caught-up replica is bit-identical to a
    primary snapshot at the same version and that a full steering sweep on
    it returns identical results — FAILING the benchmark otherwise (this is
    the enforced acceptance criterion, not a soft metric).
    """
    n = max(int(4_000 * scale), 200)
    rows: List[Dict] = []
    arms: Dict[str, Dict] = {}
    for mode in ("delta", "full"):
        for workers in (8, 39):
            r = run_replica_lag(workers, n, mode=mode, sync_every=64)
            arms[(mode, workers)] = r
            rows.append({"exp": "e_replica_lag", "mode": mode,
                         "workers": workers, **{
                             k: (round(v, 5) if isinstance(v, float) else v)
                             for k, v in r.items() if k != "mode"}})
    for workers in (8, 39):
        d, f = arms[("delta", workers)], arms[("full", workers)]
        if not (d.get("cols_equal") and d.get("sweep_equal")):
            raise AssertionError(
                f"replica catch-up diverged from primary at W={workers}: "
                f"cols_equal={d.get('cols_equal')} "
                f"sweep_equal={d.get('sweep_equal')}")
        if d.get("log_truncated_records", 0) <= 0:
            raise AssertionError(
                f"delta arm at W={workers} never truncated its txn log — "
                "the parity check must run against a replica that synced "
                "across at least one TxnLog.truncate")
        rows.append({
            "exp": "e_replica_lag", "mode": "speedup", "workers": workers,
            # what would cross the NIC: the codec's exact encoded frame
            # bytes, not the payload_nbytes estimate (kept alongside)
            "bytes_ratio_full_over_delta": round(
                f["bytes_shipped"]
                / max(d["encoded_bytes_shipped"], 1), 2),
            "payload_ratio_full_over_delta": round(
                f["bytes_shipped"] / max(d["bytes_shipped"], 1), 2),
            "encoded_over_payload": d["encoded_over_payload"],
            "sync_wall_ratio": round(
                f["sync_wall_s"] / max(d["sync_wall_s"], 1e-9), 2),
            "delta_bytes_per_record": round(
                d["bytes_shipped"] / max(d["log_records"], 1), 1),
        })
    return rows


def exp_wire_ship(scale: float = 1.0) -> List[Dict]:
    """Cross-process wire shipping: encode + ship + decode + replay for real.

    Runs :func:`benchmarks.simkit.run_wire_ship`: replica OS processes fed
    wire-encoded txn-log deltas over the configured transport — pipe by
    default, TCP when ``REPRO_WIRE_TRANSPORT=tcp`` (the CI socket-loopback
    leg) — with the drill replica at the executor's sync cadence, the bulk
    replica in one sustained catch-up, and a 3-member ReplicaGroup fan-out
    drill. HARD-FAILS unless the drill replica (a) lives in a DIFFERENT
    process, (b) synced across at least one ``TxnLog.truncate``, (c)
    produces a Q1-Q7 sweep and store columns bit-identical to a primary
    ``snapshot_view()`` at the same version, and (d) requeues every RUNNING
    row on remote ``promote()`` — plus the fabric criteria: every fan-out
    member sweeps bit-identically after one broadcast sync, and after
    killing the leader ``promote()`` elects the highest-acked survivor and
    leaves no RUNNING row. The acceptance criteria of the wire layer,
    enforced on every run, not reported as soft metrics.
    """
    n = max(int(4_000 * scale), 200)
    rows: List[Dict] = []
    for workers in (8, 39):
        r = run_wire_ship(workers, n, sync_every=64)
        if r["remote_pid"] == r["parent_pid"]:
            raise AssertionError(
                f"wire ship at W={workers} never crossed a process "
                f"boundary: replica pid == parent pid {r['parent_pid']}")
        if not (r["cols_equal"] and r["sweep_equal"]
                and r["bulk_cols_equal"]):
            raise AssertionError(
                f"shipped replica diverged from primary at W={workers}: "
                f"cols_equal={r['cols_equal']} "
                f"sweep_equal={r['sweep_equal']} "
                f"bulk_cols_equal={r['bulk_cols_equal']}")
        if r["log_truncated_records"] <= 0:
            raise AssertionError(
                f"wire drill at W={workers} never truncated its txn log — "
                "the parity check must run against a replica that shipped "
                "across at least one TxnLog.truncate")
        if not r["recovered_no_running"]:
            raise AssertionError(
                f"remote promote() at W={workers} left RUNNING rows in the "
                "recovered store")
        if not r["fanout_sweep_equal"]:
            raise AssertionError(
                f"fan-out at W={workers}: a ReplicaGroup member's sweep "
                "diverged from the primary after broadcast sync")
        if not (r["fanout_elected_highest_acked"]
                and r["fanout_promote_no_running"]):
            raise AssertionError(
                f"fan-out failover at W={workers}: "
                f"elected_highest_acked={r['fanout_elected_highest_acked']} "
                f"promote_no_running={r['fanout_promote_no_running']}")
        rows.append({"exp": "e_wire_ship", "workers": workers, **{
            k: (round(v, 5) if isinstance(v, float) else v)
            for k, v in r.items()}})
    return rows


def exp_sharded(scale: float = 1.0) -> List[Dict]:
    """Sharded multi-primary scale-out behind the ShardRouter.

    Runs :func:`benchmarks.simkit.run_sharded` at N=4 shards x 8 workers.
    HARD-FAILS unless (a) every per-worker claim set and the scatter-gather
    Q1-Q7 sweep are bit-identical to a single 32-worker primary oracle at
    the same version vector (and the sweep re-merged over the per-shard
    REPLICA snapshots still matches), (b) each shard's DeltaReplicator is
    column-bit-identical across at least one log truncation, and (c)
    cross-shard stealing moves a non-empty batch, conserves the live
    task-id multiset, leaves the drained shard claimable, and keeps every
    shard's replica at bit-parity (the steal is ordinary logged traffic).
    The steering fan-out phase (d) scatters the FULL Q1-Q7 sweep through
    per-shard replica PROCESSES (``sweep_partials`` remotely,
    ``merge_partials`` on the router) and HARD-FAILS unless the remote
    merged result is bit-identical to the local ``run_all`` and to the
    single-primary oracle at the same pinned version vector (across a
    per-shard log truncate), and the concurrent scatter equals the serial
    loop. The weak-scaling ``scaleup`` and ``steer_fanout_speedup``
    numbers themselves are gated in ``scripts/bench_trajectory.py``
    (``--min-sharded-scaleup`` / ``--min-steer-fanout-speedup``), not
    here — the smoke scale is too small for stable wall-clock ratios.
    """
    n = max(int(4_000 * scale), 200)
    thr = max(int(20_000 * scale), 2_000)
    r = run_sharded(4, 8, n, thr_tasks=thr, sync_every=64)
    if not r["claim_parity"]:
        raise AssertionError(
            "sharded claim sets diverged from the single-primary oracle "
            "(shard-local partition (tid % L) no longer composes to the "
            "oracle's global partition tid % W)")
    if not (r["sweep_equal"] and r["replica_sweep_equal"]):
        raise AssertionError(
            f"scatter-gather sweep diverged from the oracle at version "
            f"vector {r['version_vector']} (oracle v{r['oracle_version']}):"
            f" sweep_equal={r['sweep_equal']} "
            f"replica_sweep_equal={r['replica_sweep_equal']}")
    if not r["replica_cols_equal"]:
        raise AssertionError("a per-shard DeltaReplicator lost column "
                             "bit-parity with its primary")
    if not r["log_truncated_all_shards"]:
        raise AssertionError(
            "a shard never truncated its txn log — the replica parity "
            "check must cross at least one compaction per shard")
    if r["steal_moved"] <= 0 or r["steal_claimable"] <= 0:
        raise AssertionError(
            f"cross-shard stealing moved {r['steal_moved']} tasks and the "
            f"drained shard claimed {r['steal_claimable']} afterwards — "
            "the rebalance path is dead")
    if not r["steal_conserved"]:
        raise AssertionError(
            "cross-shard stealing did not conserve the live task-id "
            "multiset (a task was lost or duplicated in flight)")
    if not r["steal_replica_parity"]:
        raise AssertionError(
            "a shard replica diverged after the steal — the victim prune "
            "or thief insert is not replaying as ordinary logged traffic")
    if not (r["steer_remote_sweep_equal"] and r["steer_remote_matches_local"]):
        raise AssertionError(
            f"remote merged sweep diverged at version vector "
            f"{r['steer_version_vector']}: vs_oracle="
            f"{r['steer_remote_sweep_equal']} "
            f"vs_local_run_all={r['steer_remote_matches_local']} — the "
            "shipped partial aggregation is not bit-identical")
    if not r["steer_scatter_equal"]:
        raise AssertionError(
            "concurrent remote scatter returned a different merged sweep "
            "than the serial shard loop")
    if not r["steer_log_truncated"]:
        raise AssertionError(
            "the steering fan-out drill never truncated a shard log — the "
            "remote parity check must cross a per-shard compaction")
    return [{"exp": "e_sharded", **{
        k: (round(v, 5) if isinstance(v, float) else v)
        for k, v in r.items()}}]


def exp_chaos(scale: float = 1.0) -> List[Dict]:
    """Chaos kill-drill: silent worker death + replica process kill.

    Runs :func:`benchmarks.simkit.run_chaos`: >=2 randomly chosen workers
    go silent mid-run (no requeue call, no goodbye — their claim leases
    just expire) and the shipped replica process is killed outright, on
    both a single primary and a sharded router. HARD-FAILS unless (a) at
    least 2 workers and 1 replica actually died with claims stranded, (b)
    the live task-id set is conserved through reap/steal/respawn, (c)
    every task drains to FINISHED on the survivors, (d) the reaper — not
    any explicit failure notification — recovered the stranded claims, and
    (e) the respawned replica and every per-shard replica are
    column-bit-identical to their primaries across at least one log
    truncation. ``recovery_s`` (kill instant -> last task drained) is
    gated in ``scripts/bench_trajectory.py`` via ``--max-recovery-s``.
    """
    n = max(int(2_000 * scale), 160)
    r = run_chaos(8, n, kill_workers=2, sync_every=16)
    if len(r["workers_killed"]) < 2 or r["replicas_killed"] < 1:
        raise AssertionError(
            f"chaos drill under-killed: workers={r['workers_killed']} "
            f"replicas={r['replicas_killed']} — the drill must take down "
            ">=2 workers and >=1 replica process")
    if r["stranded_claims"] <= 0 or r["reaped"] <= 0:
        raise AssertionError(
            f"the kill stranded {r['stranded_claims']} claims and the "
            f"reaper requeued {r['reaped']} — dead workers held nothing, "
            "the drill proved nothing")
    if not r["conserved"]:
        raise AssertionError(
            "chaos drill lost or duplicated task ids on the single "
            "primary (lease reap + steal must conserve the live set)")
    if not r["drained"]:
        raise AssertionError(
            f"tasks failed to drain after the kill: {r['finished']}/"
            f"{r['tasks']} finished — stranded claims were not recovered")
    if r["replica_respawns"] < 2:
        raise AssertionError(
            f"replica respawned {r['replica_respawns'] - 1} times — the "
            "kill never forced a snapshot respawn")
    if not r["replica_cols_equal"] or r["log_truncated_records"] <= 0:
        raise AssertionError(
            f"respawned replica parity failed: cols_equal="
            f"{r['replica_cols_equal']} truncated="
            f"{r['log_truncated_records']} (must be bit-identical across "
            ">=1 truncate)")
    if not (r["sharded_conserved"] and r["sharded_drained"]):
        raise AssertionError(
            f"sharded chaos failed: conserved={r['sharded_conserved']} "
            f"drained={r['sharded_drained']} "
            f"({r['sharded_finished']}/{r['tasks']} finished)")
    if r["sharded_reaped"] <= 0:
        raise AssertionError(
            "sharded drill reaped nothing — the router never swept the "
            "dead workers' expired leases")
    if not (r["sharded_replica_parity"] and r["sharded_log_truncated"]):
        raise AssertionError(
            f"per-shard replica parity failed after the sharded kill: "
            f"parity={r['sharded_replica_parity']} "
            f"truncated_all={r['sharded_log_truncated']}")
    if r["resize_reaped"] <= 0:
        raise AssertionError(
            "the resize-kill phase reaped nothing — no claim was in "
            "flight when the pool shrank, the race was never exercised")
    if not r["resize_rehash_ok"]:
        raise AssertionError(
            f"reaped rows landed OUTSIDE the post-resize partition map "
            f"[0, {r['resize_to']}) — reap_expired is rehashing on a "
            "stale worker count")
    if not r["resize_no_ghost_beats"]:
        raise AssertionError(
            "HeartbeatMonitor kept beats/dead entries for workers removed "
            "by the resize — ghost beats would re-trigger requeue_worker "
            "on every sweep")
    if not (r["resize_conserved"] and r["resize_drained"]):
        raise AssertionError(
            f"kill-during-resize lost work: conserved="
            f"{r['resize_conserved']} drained={r['resize_drained']}")
    return [{"exp": "e_chaos", **{
        k: (round(v, 5) if isinstance(v, float) else v)
        for k, v in r.items()}}]


def exp_shard_failover(scale: float = 1.0) -> List[Dict]:
    """Shard-primary failover: kill two shard primaries mid-run (PR 9).

    Runs :func:`benchmarks.simkit.run_shard_failover` on a 3x2 router with
    per-shard delta replicas and supervision: shard 0's primary dies with
    its in-flight claims mid-run, shard 1's a few rounds later, each
    promoted via ``ShardRouter.promote_shard`` after a multi-round dead
    window. HARD-FAILS unless (a) the live task-id set is conserved across
    both failovers and every task drains, (b) the surviving shards' claim
    loops never drop to zero during a dead window, (c) every claim round
    and the post-recovery merged Q1-Q7 sweep are bit-identical to a
    single-primary oracle at the recovered version vector, (d) each
    promote actually drained unsynced WAL records (the replica was
    behind), re-armed a replica that replays to column bit-parity, and
    bumped the shard's supervisor generation, and (e) sharded checkpoints
    cut before the kill and after the promote both restore at exactly
    their persisted version vectors with bit-identical sweeps and a
    claimable router. ``failover_wall_s`` (first kill -> drain) is gated
    in ``scripts/bench_trajectory.py`` via ``--max-shard-failover-s``.
    """
    n = max(int(2_000 * scale), 160)
    r = run_shard_failover(3, 2, n, sync_every=32)
    if not r["claim_parity"]:
        raise AssertionError(
            "claim sets diverged from the single-primary oracle across "
            "the failovers — a promoted shard is not claiming the same "
            "lowest-READY rows as the pre-kill primary would")
    if not (r["conserved"] and r["drained"]):
        raise AssertionError(
            f"failover lost work: conserved={r['conserved']} "
            f"drained={r['drained']} ({r['finished']}/{r['tasks']} "
            "finished) — a committed transaction vanished in a promote")
    if r["survivor_min_claims"] <= 0:
        raise AssertionError(
            "surviving shards' claim throughput dropped to zero during a "
            "dead window — a single shard failure stalled the others")
    if r["promotes"] < 2 or r["promote_log_lag"] <= 0:
        raise AssertionError(
            f"promotes={r['promotes']} with combined log lag "
            f"{r['promote_log_lag']} — the drill must promote twice and "
            "actually drain an unsynced WAL tail at least once")
    if not r["sweep_equal"]:
        raise AssertionError(
            f"post-recovery merged Q1-Q7 sweep diverged from the oracle "
            f"at version vector {r['version_vector']}")
    if not r["replica_cols_equal"]:
        raise AssertionError(
            "a re-armed (post-promote) replica lost column bit-parity "
            "with its promoted primary")
    if not r["supervision_ok"]:
        raise AssertionError(
            f"per-shard supervision failed over wrong: generations="
            f"{r['supervisor_generations']} (killed shards must bump)")
    if not (r["ckpt_vector_match"] and r["ckpt_sweep_equal"]
            and r["ckpt_pre_kill_sweep_equal"] and r["ckpt_state_equal"]):
        raise AssertionError(
            f"sharded checkpoint restore broke atomicity: vector_match="
            f"{r['ckpt_vector_match']} sweep={r['ckpt_sweep_equal']} "
            f"pre_kill_sweep={r['ckpt_pre_kill_sweep_equal']} "
            f"state={r['ckpt_state_equal']}")
    if r["ckpt_resumed_claims"] <= 0:
        raise AssertionError(
            "the restored router could not claim — a resumed sharded run "
            "would stall immediately")
    return [{"exp": "e_shard_failover", **{
        k: (round(v, 5) if isinstance(v, float) else v)
        for k, v in r.items()}}]


def exp_replay_throughput(scale: float = 1.0) -> List[Dict]:
    """Txn-log replay: batched (segment-coalesced) vs record-at-a-time.

    Builds a claims/finishes-heavy log — the op mix the paper's Experiment 6
    shows dominating DBMS time — of ~100k records at scale 1.0 (one bulk
    insert, one claim record per task, one finish record per task), then
    replays it from genesis onto fresh stores with ``replay_reference`` (the
    seed record-at-a-time oracle) and ``replay`` (consecutive same-op runs
    coalesced into one vectorized update each). HARD-FAILS unless both
    replicas are bit-identical to each other AND to the primary store —
    the speedup only counts if the batched path is exactly equivalent.
    """
    from repro.core.replication import replay, replay_reference
    from repro.core.store import ColumnStore
    from repro.core.workqueue import WorkQueue

    target = max(int(100_000 * scale), 2_000)
    n_tasks = target // 2
    W = 64
    wq = WorkQueue(num_workers=W, capacity=2 * n_tasks)
    wq.add_tasks(0, n_tasks)
    claimed = [wq.claim(r % W, k=1, now=float(r)) for r in range(n_tasks)]
    for r, rows in enumerate(claimed):
        if len(rows):
            wq.finish(rows, now=float(r) + 0.5,
                      domain_out=np.full((len(rows), 3), 0.5))
    records = wq.log.tail(0)

    def replay_onto_fresh(fn):
        store = ColumnStore(wq.store.schema, capacity=2 * n_tasks)
        t0 = time.perf_counter()
        n = fn(store, records)
        return store, (time.perf_counter() - t0), n

    ref_store, ref_s, n_ref = replay_onto_fresh(replay_reference)
    bat_store, bat_s, n_bat = replay_onto_fresh(replay)
    for name in wq.store.cols:
        if not (np.array_equal(ref_store.col(name), bat_store.col(name),
                               equal_nan=True)
                and np.array_equal(wq.store.col(name), bat_store.col(name),
                                   equal_nan=True)):
            raise AssertionError(
                f"batched replay diverged from the record-at-a-time oracle "
                f"or the primary on column {name!r}")
    if not (ref_store.version == bat_store.version == wq.store.version):
        raise AssertionError("replayed store versions diverged")
    speedup = ref_s / max(bat_s, 1e-9)
    return [
        {"exp": "replay_throughput", "impl": "record_at_a_time",
         "records": len(records), "wall_ms": round(ref_s * 1e3, 2),
         "us_per_record": round(ref_s / max(n_ref, 1) * 1e6, 3)},
        {"exp": "replay_throughput", "impl": "batched",
         "records": len(records), "wall_ms": round(bat_s * 1e3, 2),
         "us_per_record": round(bat_s / max(n_bat, 1) * 1e6, 3)},
        {"exp": "replay_throughput", "impl": "speedup",
         "records": len(records), "speedup": round(speedup, 2),
         "replica_equal": True},
    ]


def exp_steering_sweep(scale: float = 1.0) -> List[Dict]:
    """Steering-sweep latency on a large mixed-status store.

    One full Q1-Q7 ``run_all`` sweep against a pinned snapshot of a
    ~100k-row store (scale 1.0) with FINISHED / RUNNING / READY / FAILED
    rows across 3 activities — the loop-free segment-reduced sweep path
    whose latency the bench-trajectory gate records and bounds.
    """
    from repro.core.steering import SteeringEngine
    from repro.core.workqueue import WorkQueue

    n = max(int(100_000 * scale), 2_000)
    W = 39
    per_act = n // 3
    rng = np.random.default_rng(0)
    wq = WorkQueue(num_workers=W, capacity=2 * n)
    for a in range(3):
        wq.add_tasks(a, per_act, domain_in=rng.uniform(0, 1, (per_act, 3)),
                     parent_task=(None if a == 0 else
                                  np.arange(per_act) + (a - 1) * per_act),
                     now=0.0)
    now = 0.0
    for r in range(6):                 # claim/finish/fail churn -> mixed mix
        out = wq.claim_all(k=max(per_act // (6 * W), 1), now=now)
        rows = np.concatenate([v for v in out.values() if len(v)]) \
            if any(len(v) for v in out.values()) else np.empty(0, np.int64)
        if not len(rows):
            break
        n_fail = len(rows) // 10
        if n_fail:
            wq.fail(rows[:n_fail], now=now + 0.2)
        keep = rows[n_fail:]
        fin = keep[: max(2 * len(keep) // 3, 1)]
        if len(fin):
            wq.finish(fin, now=now + 1.0,
                      domain_out=rng.normal(0.5, 0.3, (len(fin), 3)))
        now += 30.0                    # spreads start times across horizons
    steer = SteeringEngine(wq)
    steer.run_all(now)                 # warm-up (snapshot + caches)
    reps = 5
    t0 = time.perf_counter()
    for _ in range(reps):
        steer.run_all(now)
    ms = (time.perf_counter() - t0) / reps * 1e3
    return [{"exp": "steering_sweep", "rows": int(wq.store.n_rows),
             "workers": W, "ms_per_sweep": round(ms, 2),
             "tasks_finished": int(wq.counts()["FINISHED"])}]


def exp_kernel_claim(scale: float = 1.0) -> List[Dict]:
    """Claim hot-path microbench, host AND device.

    Host: the vectorized claim_all fast-path vs the seed O(n·W) loop
    (claim_all_reference) on a 100k-task store — the ≥5x speedup gate —
    at k=1 (the stable worker-sort path) AND k=4 (the segmented
    argpartition path, the heavy-tail batched-claim shape).
    Device: the wq_claim op's jnp oracle latency vs store size (kernel
    semantics, what the TPU path executes).
    """
    import jax
    import jax.numpy as jnp
    from repro.core.workqueue import WorkQueue
    from repro.kernels.wq_claim.ref import wq_claim_ref
    rows: List[Dict] = []

    # ---- host path: vectorized vs seed loop at 100k tasks ----------------
    n_host = max(1024, int(100_000 * scale))
    rounds = 3
    host_us: Dict[tuple, float] = {}
    for k in (1, 4):
        for w in (64, 936):
            for impl in ("seed_loop", "vectorized"):
                wq = WorkQueue(num_workers=w, capacity=2 * n_host)
                wq.add_tasks(0, n_host)
                claim = (wq.claim_all_reference if impl == "seed_loop"
                         else wq.claim_all)
                t0 = time.perf_counter()
                claimed = 0
                for r in range(rounds):
                    out = claim(k=k, now=float(r))
                    claimed += sum(len(v) for v in out.values())
                us = (time.perf_counter() - t0) / rounds * 1e6
                host_us[(k, w, impl)] = us
                rows.append({"exp": "claim_kernel", "path": "host",
                             "impl": impl, "k": k,
                             "rows": n_host, "workers": w,
                             "us_per_claim_all": round(us, 1),
                             "tasks_claimed": claimed})
    for k in (1, 4):
        for w in (64, 936):
            rows.append({
                "exp": "claim_kernel", "path": "host", "impl": "speedup",
                "k": k, "rows": n_host, "workers": w,
                "speedup": round(host_us[(k, w, "seed_loop")]
                                 / max(host_us[(k, w, "vectorized")],
                                       1e-9), 2)})

    # ---- device path: wq_claim op latency vs store size ------------------
    rng = np.random.default_rng(0)
    for n in (1 << 12, 1 << 15, 1 << 18):
        for w in (64, 936):
            status = jnp.asarray(
                rng.choice([0, 2, 3, 4], n, p=[.1, .5, .2, .2]).astype(
                    np.int32))
            worker = jnp.asarray(rng.integers(0, w, n).astype(np.int32))
            fn = jax.jit(lambda s, wk: wq_claim_ref(s, wk, num_workers=w,
                                                    k=1))
            fn(status, worker)[0].block_until_ready()
            t0 = time.perf_counter()
            reps = 20
            for _ in range(reps):
                out = fn(status, worker)
            out[0].block_until_ready()
            us = (time.perf_counter() - t0) / reps * 1e6
            rows.append({"exp": "claim_kernel", "path": "device",
                         "rows": n, "workers": w,
                         "us_per_claim_all": round(us, 1),
                         "us_per_task": round(us / max(w, 1), 3)})
    return rows
