"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (per harness contract) and
writes the full records to results/bench/*.json.

``--scale`` scales the paper's task counts (default 0.1 => 1.3k-2.3k tasks
per run; the paper's ratios are scale-invariant here because store-op cost
is measured at true partition sizes).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time


def main() -> None:
    root = pathlib.Path(__file__).resolve().parent.parent
    sys.path.insert(0, str(root))          # the benchmarks package itself
    sys.path.insert(0, str(root / "src"))
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--out", default="results/bench")
    ap.add_argument("--only", default="")
    ap.add_argument("--min-claim-speedup", type=float, default=0.0,
                    help="exit nonzero unless the claim_kernel host "
                         "speedup (vectorized vs seed loop) meets this "
                         "floor — the CI regression gate")
    args = ap.parse_args()

    from benchmarks import experiments as E

    runs = {
        "e1_strong_scaling": lambda: E.exp1_strong_scaling(args.scale),
        "e2_weak_scaling": lambda: E.exp2_weak_scaling(args.scale),
        "e3_workload_tasks": lambda: E.exp3_workload_tasks(args.scale),
        "e4_workload_duration": lambda: E.exp4_workload_duration(args.scale),
        "e5_dbms_overhead": lambda: E.exp5_dbms_overhead(args.scale),
        "e6_access_breakdown": lambda: E.exp6_access_breakdown(args.scale),
        "e7_steering_overhead": lambda: E.exp7_steering_overhead(args.scale),
        "e8_centralized_vs_distributed":
            lambda: E.exp8_centralized_vs_distributed(args.scale),
        "e_replica_lag": lambda: E.exp_replica_lag(args.scale),
        "e_wire_ship": lambda: E.exp_wire_ship(args.scale),
        "claim_kernel": lambda: E.exp_kernel_claim(args.scale),
        "replay_throughput": lambda: E.exp_replay_throughput(args.scale),
        "steering_sweep": lambda: E.exp_steering_sweep(args.scale),
    }
    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    only = [t for t in args.only.split(",") if t]
    print("name,us_per_call,derived")
    for name, fn in runs.items():
        if only and not any(t in name for t in only):
            continue
        t0 = time.perf_counter()
        rows = fn()
        dt_us = (time.perf_counter() - t0) * 1e6
        (out_dir / f"{name}.json").write_text(json.dumps(rows, indent=1))
        derived = _headline(name, rows)
        print(f"{name},{dt_us / max(len(rows), 1):.1f},{derived}")
        if name == "claim_kernel" and args.min_claim_speedup > 0:
            spd = min(r["speedup"] for r in rows
                      if r.get("impl") == "speedup")
            if spd < args.min_claim_speedup:
                print(f"FAIL: claim host speedup {spd}x < "
                      f"{args.min_claim_speedup}x gate", file=sys.stderr)
                sys.exit(1)


def _headline(name: str, rows) -> str:
    try:
        if name.startswith("e1"):
            best = max(r["efficiency"] for r in rows if r["nodes"] == 40)
            return f"efficiency@960cores={best}"
        if name.startswith("e2"):
            return f"vs_linear@39nodes={rows[-1]['vs_linear']}"
        if name.startswith("e3"):
            worst = max(r["gap"] for r in rows)
            return f"max_gap={worst}"
        if name.startswith("e4"):
            worst = max(r["gap"] for r in rows)
            return f"max_gap={worst}"
        if name.startswith("e5"):
            fr = {(r["mode"], r["task_dur_s"]): r["dbms_frac"] for r in rows}
            return (f"paper@1s={fr.get(('paper',1.0))};"
                    f"paper@60s={fr.get(('paper',60.0))};"
                    f"adapted@1s={fr.get(('adapted',1.0))}")
        if name.startswith("e6"):
            top = rows[0]
            return f"top_op={top['op']}:{top['share']}"
        if name.startswith("e7"):
            return f"steering_overhead={rows[-1]['overhead']}"
        if name.startswith("e8"):
            p = max(r["speedup"] for r in rows if r["mode"] == "paper")
            a = max(r["speedup"] for r in rows if r["mode"] == "adapted")
            return f"paper_speedup={p}x;adapted={a}x"
        if name == "e_replica_lag":
            sp = [r for r in rows if r["mode"] == "speedup"]
            br = min(r["bytes_ratio_full_over_delta"] for r in sp)
            eq = all(r.get("sweep_equal", True) for r in rows
                     if r["mode"] == "delta")
            return f"full/delta_bytes_min={br}x;sweep_equal={eq}"
        if name == "e_wire_ship":
            mbps = min(r["ship_mbps_bulk"] for r in rows)
            ratio = max(r["encoded_bytes_ratio"] for r in rows)
            eq = all(r["cols_equal"] and r["sweep_equal"] for r in rows)
            return (f"ship_mbps_bulk_min={mbps};encoded/payload={ratio};"
                    f"remote_parity={eq}")
        if name == "claim_kernel":
            spd = min(r["speedup"] for r in rows if r.get("impl") == "speedup")
            dev = min(r["us_per_task"] for r in rows if "us_per_task" in r)
            return f"host_speedup_min={spd}x;device_us_per_task_min={dev}"
        if name == "replay_throughput":
            spd = next(r["speedup"] for r in rows if r["impl"] == "speedup")
            return f"batched_vs_record_speedup={spd}x"
        if name == "steering_sweep":
            return f"ms_per_sweep={rows[0]['ms_per_sweep']}@" \
                   f"{rows[0]['rows']}rows"
    except Exception as e:  # noqa: BLE001
        return f"err:{e}"
    return ""


if __name__ == "__main__":
    main()
