"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (per harness contract) and
writes the full records to results/bench/*.json.

``--scale`` scales the paper's task counts (default 0.1 => 1.3k-2.3k tasks
per run; the paper's ratios are scale-invariant here because store-op cost
is measured at true partition sizes).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time


# what each registered experiment measures — what `--list` prints and
# `--only` accepts (substring match); kept in lockstep with `runs` below
# (main() fails loudly if the two ever drift)
DESCRIPTIONS = {
    "e1_strong_scaling": "Fig 9a: fixed 13k tasks, 120->960 cores, "
                         "threads sweep (makespan efficiency)",
    "e2_weak_scaling": "Fig 9b: workload grows with cores "
                       "(6k/12k/23.4k tasks on 10/20/39 nodes)",
    "e3_workload_tasks": "Fig 10a: fixed duration, varying #tasks, "
                         "paper vs adapted access latency",
    "e4_workload_duration": "Fig 10b: fixed #tasks, varying duration, "
                            "paper vs adapted access latency",
    "e5_dbms_overhead": "Fig 11: DBMS access time vs total makespan "
                        "across task durations",
    "e6_access_breakdown": "Fig 12: time share per DBMS access kind "
                           "(claims/finishes dominate)",
    "e7_steering_overhead": "Fig 13 at 10x tasks: makespan with vs "
                            "without concurrent snapshot steering sweeps",
    "e8_centralized_vs_distributed": "Fig 14: Chiron (one master) vs "
                                     "d-Chiron (partitioned WQ) makespan",
    "e_replica_lag": "delta txn-log replay vs full-copy replica sync "
                     "(encoded wire bytes; parity across a truncate)",
    "e_wire_ship": "cross-process replicas over pipe/TCP: pipelined "
                   "bulk + incremental ship throughput, varint "
                   "compression, concurrent 3-replica fan-out parity + "
                   "leader-kill promote (all hard-checked)",
    "e_sharded": "N-shard multi-primary router: scatter-gather Q1-Q7 "
                 "parity vs a single-primary oracle, cross-shard steal "
                 "conservation + per-shard replica parity (hard-checked), "
                 "weak-scaling claim throughput (the "
                 "--min-sharded-scaleup gate), concurrent remote steering "
                 "scatter with per-shard partial sweeps in replica "
                 "processes (bit-checked; the --min-steer-fanout-speedup "
                 "gate)",
    "e_chaos": "kill-drill: >=2 workers go silent + replica process "
               "killed mid-run (one batch DURING a pool resize); lease "
               "reap + steal + snapshot respawn must conserve the "
               "task-id set, drain every task and keep replica "
               "bit-parity (the --max-recovery-s gate)",
    "e_shard_failover": "shard-primary failover: two shard primaries "
                        "killed mid-run with claims in flight; promote "
                        "must drain the WAL tail, keep survivors "
                        "claiming, restore checkpoints at the exact "
                        "version vector and stay sweep-bit-identical to "
                        "a single-primary oracle (the "
                        "--max-shard-failover-s gate)",
    "claim_kernel": "claim_all fast-path vs seed loop at k=1/k=4 "
                    "(the >=5x gate) + device wq_claim op latency",
    "replay_throughput": "batched hot-plane txn-log replay vs "
                         "record-at-a-time (the >=10x gate, bit-parity)",
    "steering_sweep": "full Q1-Q7 sweep latency on a ~100k-row snapshot "
                      "(the --max-sweep-ms gate)",
}


def main() -> None:
    root = pathlib.Path(__file__).resolve().parent.parent
    sys.path.insert(0, str(root))          # the benchmarks package itself
    sys.path.insert(0, str(root / "src"))
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--out", default="results/bench")
    ap.add_argument("--only", default="")
    ap.add_argument("--list", action="store_true",
                    help="print every registered experiment with its "
                         "one-line description (what --only accepts) and "
                         "exit")
    ap.add_argument("--min-claim-speedup", type=float, default=0.0,
                    help="exit nonzero unless the claim_kernel host "
                         "speedup (vectorized vs seed loop) meets this "
                         "floor — the CI regression gate")
    args = ap.parse_args()

    if args.list:
        for name, desc in DESCRIPTIONS.items():
            print(f"{name:32s} {desc}")
        return

    from benchmarks import experiments as E

    runs = {
        "e1_strong_scaling": lambda: E.exp1_strong_scaling(args.scale),
        "e2_weak_scaling": lambda: E.exp2_weak_scaling(args.scale),
        "e3_workload_tasks": lambda: E.exp3_workload_tasks(args.scale),
        "e4_workload_duration": lambda: E.exp4_workload_duration(args.scale),
        "e5_dbms_overhead": lambda: E.exp5_dbms_overhead(args.scale),
        "e6_access_breakdown": lambda: E.exp6_access_breakdown(args.scale),
        "e7_steering_overhead": lambda: E.exp7_steering_overhead(args.scale),
        "e8_centralized_vs_distributed":
            lambda: E.exp8_centralized_vs_distributed(args.scale),
        "e_replica_lag": lambda: E.exp_replica_lag(args.scale),
        "e_wire_ship": lambda: E.exp_wire_ship(args.scale),
        "e_sharded": lambda: E.exp_sharded(args.scale),
        "e_chaos": lambda: E.exp_chaos(args.scale),
        "e_shard_failover": lambda: E.exp_shard_failover(args.scale),
        "claim_kernel": lambda: E.exp_kernel_claim(args.scale),
        "replay_throughput": lambda: E.exp_replay_throughput(args.scale),
        "steering_sweep": lambda: E.exp_steering_sweep(args.scale),
    }
    missing = set(runs) ^ set(DESCRIPTIONS)
    if missing:                            # keep --list honest forever
        raise RuntimeError(f"experiments without (or with stale) "
                           f"descriptions: {sorted(missing)}")
    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    only = [t for t in args.only.split(",") if t]
    print("name,us_per_call,derived")
    for name, fn in runs.items():
        if only and not any(t in name for t in only):
            continue
        t0 = time.perf_counter()
        rows = fn()
        dt_us = (time.perf_counter() - t0) * 1e6
        (out_dir / f"{name}.json").write_text(json.dumps(rows, indent=1))
        derived = _headline(name, rows)
        print(f"{name},{dt_us / max(len(rows), 1):.1f},{derived}")
        if name == "claim_kernel" and args.min_claim_speedup > 0:
            spd = min(r["speedup"] for r in rows
                      if r.get("impl") == "speedup")
            if spd < args.min_claim_speedup:
                print(f"FAIL: claim host speedup {spd}x < "
                      f"{args.min_claim_speedup}x gate", file=sys.stderr)
                sys.exit(1)


def _headline(name: str, rows) -> str:
    try:
        if name.startswith("e1"):
            best = max(r["efficiency"] for r in rows if r["nodes"] == 40)
            return f"efficiency@960cores={best}"
        if name.startswith("e2"):
            return f"vs_linear@39nodes={rows[-1]['vs_linear']}"
        if name.startswith("e3"):
            worst = max(r["gap"] for r in rows)
            return f"max_gap={worst}"
        if name.startswith("e4"):
            worst = max(r["gap"] for r in rows)
            return f"max_gap={worst}"
        if name.startswith("e5"):
            fr = {(r["mode"], r["task_dur_s"]): r["dbms_frac"] for r in rows}
            return (f"paper@1s={fr.get(('paper',1.0))};"
                    f"paper@60s={fr.get(('paper',60.0))};"
                    f"adapted@1s={fr.get(('adapted',1.0))}")
        if name.startswith("e6"):
            top = rows[0]
            return f"top_op={top['op']}:{top['share']}"
        if name.startswith("e7"):
            return f"steering_overhead={rows[-1]['overhead']}"
        if name.startswith("e8"):
            p = max(r["speedup"] for r in rows if r["mode"] == "paper")
            a = max(r["speedup"] for r in rows if r["mode"] == "adapted")
            return f"paper_speedup={p}x;adapted={a}x"
        if name == "e_replica_lag":
            sp = [r for r in rows if r["mode"] == "speedup"]
            br = min(r["bytes_ratio_full_over_delta"] for r in sp)
            eq = all(r.get("sweep_equal", True) for r in rows
                     if r["mode"] == "delta")
            return f"full/delta_bytes_min={br}x;sweep_equal={eq}"
        if name == "e_wire_ship":
            mbps = min(r["ship_mbps_bulk"] for r in rows)
            inc = min(r["ship_mbps"] for r in rows)
            comp = min(r["compression_ratio"] for r in rows)
            eq = all(r["cols_equal"] and r["sweep_equal"]
                     and r["fanout_sweep_equal"] for r in rows)
            tr = rows[0]["transport"]
            return (f"ship_mbps_bulk_min={mbps};ship_mbps_inc_min={inc};"
                    f"compression={comp}x;"
                    f"transport={tr};remote+fanout_parity={eq}")
        if name == "e_sharded":
            r = rows[0]
            return (f"scaleup={r['scaleup']}x@{r['shards']}shards;"
                    f"sweep_equal={r['sweep_equal']};"
                    f"steal_moved={r['steal_moved']};"
                    f"steal_conserved={r['steal_conserved']};"
                    f"steer_fanout={r['steer_fanout_speedup']}x;"
                    f"steer_remote_parity={r['steer_remote_sweep_equal']}")
        if name == "e_chaos":
            r = rows[0]
            return (f"recovery_s={r['recovery_s']};"
                    f"conserved={r['conserved']};drained={r['drained']};"
                    f"reaped={r['reaped']};"
                    f"respawns={r['replica_respawns']}")
        if name == "e_shard_failover":
            r = rows[0]
            return (f"failover_wall_s={r['failover_wall_s']};"
                    f"promote_s_max={r['promote_s_max']};"
                    f"survivor_min_claims={r['survivor_min_claims']};"
                    f"conserved={r['conserved']};"
                    f"sweep_equal={r['sweep_equal']};"
                    f"ckpt_vector_match={r['ckpt_vector_match']}")
        if name == "claim_kernel":
            spd = min(r["speedup"] for r in rows if r.get("impl") == "speedup")
            dev = min(r["us_per_task"] for r in rows if "us_per_task" in r)
            return f"host_speedup_min={spd}x;device_us_per_task_min={dev}"
        if name == "replay_throughput":
            spd = next(r["speedup"] for r in rows if r["impl"] == "speedup")
            return f"batched_vs_record_speedup={spd}x"
        if name == "steering_sweep":
            return f"ms_per_sweep={rows[0]['ms_per_sweep']}@" \
                   f"{rows[0]['rows']}rows"
    except Exception as e:  # noqa: BLE001
        return f"err:{e}"
    return ""


if __name__ == "__main__":
    main()
