"""Benchmark engine: event-driven workflow simulation over the REAL store.

Methodology (EXPERIMENTS.md §Benchmarks): scheduler/store operations are
MEASURED (wall time of the real ColumnStore/WorkQueue ops at true partition
sizes); task *compute* advances a virtual clock (the paper itself uses
synthetic workloads with configured durations — its tasks are external
simulations we have no reason to re-run). Wall-clock results are therefore
"simulated seconds" composed of measured scheduling latency + virtual task
time, with worker/thread parallelism modeled exactly like the paper's
cluster: W workers x T threads each.

The paper's experiments map 1:1 (see DESIGN.md §8).
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import heapq
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.configs.risers_workflow import WorkflowConfig
from repro.core.centralized import CentralizedMaster
from repro.core.replication import DeltaReplicator, FullCopyReplica, \
    ShippedDeltaReplicator
from repro.core.schema import Status
from repro.core.steering import SteeringEngine
from repro.core.supervisor import Supervisor
from repro.core.workqueue import WorkQueue


@dataclasses.dataclass
class SimResult:
    makespan_s: float               # simulated wall time
    dbms_time_s: float              # max per-node accumulated DBMS time
    dbms_total_s: float             # sum of all DBMS access time
    op_time: Dict[str, float]       # measured time by op kind
    op_count: Dict[str, int]
    tasks_done: int
    messages: int = 0


def run_distributed(num_workers: int, threads: int, num_tasks: int,
                    mean_dur_s: float, *, activities: int = 1,
                    seed: int = 0, steer_every_s: float = 0.0,
                    batch_claim: int = 1,
                    access_latency_s: float = 0.0) -> SimResult:
    """d-Chiron-style run: partitioned WQ, workers pull from own partition.

    ``access_latency_s`` reproduces the PAPER's hardware regime: per-access
    wall latency of MySQL Cluster over Gigabit Ethernet under 936-thread
    concurrency (the paper's Fig. 11 shows DBMS time ~ total wall for <=3 s
    tasks on 23.4k tasks; that implies ~10 ms effective latency per access —
    we use 12 ms, see EXPERIMENTS §Benchmarks). With the default 0.0 the sim
    charges only OUR measured in-memory store op times — i.e., the
    TPU-adapted system — which removes that bottleneck entirely.
    """
    rng = np.random.default_rng(seed)
    wf = WorkflowConfig(activities=tuple(f"a{i}" for i in range(activities)))
    wq = WorkQueue(num_workers=num_workers,
                   capacity=max(1 << 16, 2 * num_tasks * activities))
    sup = Supervisor(wq, wf)
    per_act = num_tasks // activities
    sup.seed(per_act, duration_s=mean_dur_s, rng=rng)
    steer = SteeringEngine(wq)

    op_time: Dict[str, float] = {}
    op_count: Dict[str, int] = {}
    dbms_by_worker = np.zeros(num_workers)

    def timed(kind: str, fn, worker: Optional[int] = None):
        t0 = time.perf_counter()
        out = fn()
        # access multiplicity mirrors the paper's Fig. 12 op inventory:
        # claim = getREADYtasks + updateToRUNNING (2 round trips);
        # finish = updateToFINISHED + store outputs + getFileFields (3)
        mult = {"getREADYtasks+toRUNNING": 2, "updateToFINISHED": 3}.get(kind, 1)
        dt = time.perf_counter() - t0 + access_latency_s * mult
        op_time[kind] = op_time.get(kind, 0.0) + dt
        op_count[kind] = op_count.get(kind, 0) + 1
        if worker is not None:
            dbms_by_worker[worker] += dt
        else:
            dbms_by_worker[:] = dbms_by_worker + dt / num_workers
        return dt, out

    # steering runs on a separate analyst thread against store SNAPSHOTS —
    # truly concurrent with the claim/finish loop below (HTAP: the sweep
    # reads one committed version while workers mutate the live arrays).
    # ONE sweep in flight at a time: like a real analyst session, a sweep
    # due while the previous one still runs is skipped — this also bounds
    # the COW column generations pinned by queued snapshots to one
    steer_pool = concurrent.futures.ThreadPoolExecutor(
        max_workers=1, thread_name_prefix="steering")
    steer_futs: List[concurrent.futures.Future] = []

    def steer_sweep(view, at_clock: float) -> float:
        t0 = time.perf_counter()
        steer.run_all(at_clock, view)
        return time.perf_counter() - t0

    def steer_account(dt: float) -> None:
        op_time["steering(Q1..Q7)"] = \
            op_time.get("steering(Q1..Q7)", 0.0) + dt
        op_count["steering(Q1..Q7)"] = \
            op_count.get("steering(Q1..Q7)", 0) + 1
        dbms_by_worker[:] = dbms_by_worker + dt / num_workers

    # event loop: (finish_time, worker, row)
    clock = 0.0
    events: List[Tuple[float, int, int]] = []
    free_threads = {w: threads for w in range(num_workers)}
    done = 0
    next_steer = steer_every_s if steer_every_s else np.inf

    def try_fill(w: int):
        nonlocal clock
        while free_threads[w] > 0:
            t_claim, rows = timed("getREADYtasks+toRUNNING",
                                  lambda: wq.claim(w,
                                                   k=min(batch_claim,
                                                         free_threads[w]),
                                                   now=clock,
                                                   allow_steal=True),
                                  worker=w)
            if len(rows) == 0:
                return
            for row in rows:
                dur = float(wq.store.col("duration_est")[row]) or \
                    rng.exponential(mean_dur_s)
                # CPU oversubscription: threads beyond the 24 cores/node
                # time-share (the paper's 48-thread curve degrades this way)
                if threads > 24:
                    dur *= (threads / 24.0) * 1.08   # + contention
                # the claim access blocks the thread before the task starts
                heapq.heappush(events, (clock + t_claim + dur, w, int(row)))
                free_threads[w] -= 1

    for w in range(num_workers):
        try_fill(w)

    while events:
        clock, w, row = heapq.heappop(events)
        out = rng.normal(0.5, 0.3, (1, 3))
        t_fin, _ = timed("updateToFINISHED",
                         lambda: wq.finish(np.asarray([row]), now=clock,
                                           domain_out=out), worker=w)
        clock += t_fin                    # completion access blocks the thread
        free_threads[w] += 1
        done += 1
        if activities > 1 and done % num_workers == 0:
            # batched expansion: the supervisor inserts dependents in bulk,
            # off the workers' claim path (paper Fig. 2: supervisor is not a
            # proxy between workers and their tasks)
            timed("supervisor.expand", lambda: sup.expand(now=clock))
        if clock >= next_steer:
            while steer_futs and steer_futs[0].done():   # harvest finished
                steer_account(steer_futs.pop(0).result())
            if not steer_futs:
                # snapshot at this commit point; the sweep itself runs on
                # the analyst thread, does NOT block workers (paper Exp. 7)
                steer_futs.append(steer_pool.submit(
                    steer_sweep, wq.store.snapshot_view(), clock))
            next_steer += steer_every_s
        try_fill(w)
        if not events:
            # supervisor may have inserted new READY tasks
            for w2 in range(num_workers):
                try_fill(w2)

    for f in steer_futs:                      # drain the analyst thread;
        steer_account(f.result())             # charge measured sweep time
    steer_pool.shutdown()

    dbms_total = float(dbms_by_worker.sum())
    return SimResult(
        makespan_s=clock,
        dbms_time_s=float(dbms_by_worker.max()),
        dbms_total_s=dbms_total,
        op_time=op_time, op_count=op_count, tasks_done=done)


def _sweep_fingerprint(res: Dict) -> str:
    """Canonical form of a run_all result for cross-store equality checks."""
    import json
    return json.dumps(res, sort_keys=True, default=str)


def run_replica_lag(num_workers: int, num_tasks: int,
                    mean_dur_s: float = 1.0, *, activities: int = 3,
                    sync_every: int = 64, seed: int = 0,
                    mode: str = "delta") -> Dict:
    """Replication catch-up drill: a full workflow (claims, finishes, fails,
    requeue, resize, steering patches/prunes, expansions) runs on the
    primary while a replica syncs every ``sync_every`` log records.

    ``mode="delta"`` uses :class:`DeltaReplicator` (txn-log tail replay);
    ``mode="full"`` uses :class:`FullCopyReplica` (the pre-delta baseline
    that deep-copies the whole store each sync). Both arms run the identical
    deterministic workload, so sync bytes and sync wall time are directly
    comparable — delta cost tracks the log delta, full-copy cost tracks
    store size.

    The drill also exercises log COMPACTION under replication: after every
    sync the consumed prefix is truncated (``WorkQueue.compact_log`` — a
    no-op in full mode, where no consumer registers), so the delta replica
    provably syncs ACROSS at least one ``TxnLog.truncate`` and the final
    bit-parity check certifies compaction never corrupts catch-up while
    ``log_retained`` stays bounded by the sync cadence.

    For the delta arm the drill also PROVES catch-up correctness: at the
    end it pins a primary ``snapshot_view()``, syncs the replica to exactly
    that version, and checks (a) every store column is bit-identical and
    (b) a full Q1-Q7 steering sweep returns identical results on both
    stores (the acceptance criterion of the replication subsystem).
    """
    rng = np.random.default_rng(seed)
    wf = WorkflowConfig(activities=tuple(f"a{i}" for i in range(activities)))
    wq = WorkQueue(num_workers=num_workers,
                   capacity=max(1 << 14, 2 * num_tasks * activities))
    sup = Supervisor(wq, wf)
    sup.seed(max(num_tasks // activities, 1), duration_s=mean_dur_s, rng=rng)
    steer = SteeringEngine(wq)
    rep = (DeltaReplicator(wq, sync_every=sync_every) if mode == "delta"
           else FullCopyReplica(wq, sync_every=sync_every))

    sync_wall_s = 0.0
    lags_at_sync: List[int] = []
    syncs = 0

    max_retained = 0

    def maybe_sync():
        nonlocal sync_wall_s, syncs, max_retained
        if rep.lag() >= sync_every:
            lags_at_sync.append(rep.lag())
            t0 = time.perf_counter()
            rep.sync()
            sync_wall_s += time.perf_counter() - t0
            syncs += 1
            wq.compact_log()        # drop the prefix the replica just acked
        max_retained = max(max_retained, wq.log.n_retained)

    clock = 0.0
    rounds = 0
    while rounds < 10_000:
        out = wq.claim_all(k=1, now=clock)
        rows = np.concatenate([v for v in out.values() if len(v)]) \
            if any(len(v) for v in out.values()) else np.empty(0, np.int64)
        if len(rows) == 0:
            if sup.expand(now=clock) == 0:
                break
            rounds += 1
            continue
        # a slice of claims fails (retry path), the rest finish with
        # provenance outputs — both ops ship through the log
        n_fail = len(rows) // 8 if rounds % 5 == 2 else 0
        if n_fail:
            wq.fail(rows[:n_fail], now=clock + 0.5)
            rows = rows[n_fail:]
        if rounds == 3:
            victim = num_workers - 1                 # node loss: its RUNNING
            wid = wq.store.col("worker_id")[rows]    # claims requeue+rehash
            wq.requeue_worker(victim)
            rows = rows[wid != victim]
        if len(rows):
            wq.finish(rows, now=clock + 1.0,
                      domain_out=rng.normal(0.5, 0.3, (len(rows), 3)))
        if rounds == 4:
            steer.q8_patch_ready(0, "in0", 9.5,      # user steering (Q8)
                                 predicate=lambda v: v > 0.8)
        if rounds == 6:
            steer.prune("in1", 0.0, 0.02)            # data reduction
        if rounds == 8 and num_workers > 2:
            wq.resize(num_workers - 1)               # elastic re-hash
        sup.expand(now=clock)
        maybe_sync()
        clock += mean_dur_s
        rounds += 1

    # final catch-up from whatever lag remains (crash-recovery cost)
    final_lag = rep.lag()
    t0 = time.perf_counter()
    rep.sync()
    catchup_s = time.perf_counter() - t0
    syncs += 1
    wq.compact_log()   # delta mode: guarantees >=1 truncate before parity

    bytes_shipped = (rep.delta_bytes if mode == "delta" else rep.copy_bytes)
    # what would ACTUALLY cross a NIC: the wire codec's exact frame bytes
    # (tracked transactionally with the applied offset); the payload_nbytes
    # figure above is the in-memory cost model those frames replace
    encoded = int(getattr(rep, "encoded_bytes", 0))
    res: Dict = {
        "mode": mode, "rounds": rounds, "store_rows": int(wq.store.n_rows),
        "log_records": len(wq.log), "sync_count": syncs,
        "sync_every": sync_every,
        "encoded_bytes_shipped": encoded,
        "encoded_over_payload": round(encoded / max(bytes_shipped, 1), 4)
        if mode == "delta" else None,
        "mean_lag_at_sync": float(np.mean(lags_at_sync)) if lags_at_sync
        else 0.0,
        "final_lag": int(final_lag),
        "sync_wall_s": sync_wall_s, "catchup_s": catchup_s,
        "bytes_shipped": int(bytes_shipped),
        "full_copy_row_bytes": int(wq.store.row_nbytes()
                                   * wq.store.n_rows),
        "tasks_finished": int(wq.counts()["FINISHED"]),
        "log_truncated_records": int(wq.log.base),
        "log_max_retained": int(max(max_retained, wq.log.n_retained)),
    }
    if mode == "delta":
        # --- catch-up correctness: replica at v == primary snapshot at v,
        # with the replica having synced across the truncations above ---
        view = wq.store.snapshot_view()
        rep.sync(upto_version=view.version)
        cols_equal = all(
            np.array_equal(view.col(n), rep.store.col(n), equal_nan=True)
            for n in wq.store.cols)
        sweep_primary = steer.run_all(clock, view=view)
        sweep_replica = steer.run_all(clock, view=rep.snapshot_view())
        res["cols_equal"] = bool(cols_equal)
        res["sweep_equal"] = (_sweep_fingerprint(sweep_primary)
                              == _sweep_fingerprint(sweep_replica))
        res["replica_version"] = int(rep.store.version)
        res["primary_version"] = int(view.version)
    return res


def run_wire_ship(num_workers: int, num_tasks: int,
                  mean_dur_s: float = 1.0, *, activities: int = 3,
                  sync_every: int = 64, seed: int = 0,
                  transport: Optional[str] = None,
                  fanout: int = 3) -> Dict:
    """Cross-process delta shipping drill: the wire layer measured for real.

    Two :class:`ShippedDeltaReplicator`\\ s — each a separate OS process fed
    wire-encoded frames over the configured transport (``"pipe"`` or
    ``"tcp"``; default from ``REPRO_WIRE_TRANSPORT``, which is how CI runs
    the socket path) — ride one deterministic workflow (the same op mix as
    :func:`run_replica_lag`):

    * the DRILL replica syncs every ``sync_every`` records (the executor's
      steady-state cadence) and, after a mid-run ``TxnLog.truncate``, keeps
      syncing ACROSS the compaction — at the end its REMOTE Q1-Q7 sweep
      and its fetched store columns are hard-checked bit-identical to a
      primary ``snapshot_view()`` at the same version, and its ``promote()``
      exercises remote failover (no RUNNING rows may survive);
    * the BULK log (claims/finishes-heavy — the op mix the paper's
      Experiment 6 shows dominating: long same-op runs, big contiguous hot
      frames) is caught up by TWO arms. The lockstep arm ships it in one
      synchronous request/reply — its byte accounting is hard-checked
      against the analytic codec oracle, and its remote columns against
      the primary. The PIPELINED arm stages, encodes and ships the same
      log through the background shipper with a bounded unacked window —
      encode overlaps the remote's decode+replay, which is where the
      ``ship_mbps_bulk`` the trajectory gate bounds now comes from
      (measured END-TO-END: enqueue to last ack, on negotiated/compressed
      wire bytes, best of three independent consumers — the machine is
      shared, and a one-shot wall can triple under load;
      ``ship_mbps_bulk_sync`` keeps the lockstep number).
      ``compression_ratio`` compares the bulk log's hot-frame bytes under
      the raw codec vs the negotiated one (cold pickles are byte-identical
      either way and excluded; ``compression_ratio_total`` keeps them in).

    After the cadenced loop an INCREMENTAL BURST isolates the tiny-delta
    regime that collapsed under the old blocking path: per-iteration
    claim+finish deltas of a few records, synced every iteration through
    (a) the pipelined drill replica — timing ONLY the producer-visible
    cost, i.e. the ``sync()`` enqueues plus the final ``flush()`` drain,
    which is exactly what an executor tick pays — and (b) a blocking
    comparison consumer that eats a full request/reply round trip per
    sync.  ``ship_mbps`` (the gated incremental number) is the burst
    bytes over the pipelined producer-visible wall;
    ``ship_mbps_incremental_sync`` is the same bytes over the blocking
    arm's wall.  ``inc_messages`` vs ``inc_syncs`` shows the shipper's
    queue coalescing tiny deltas into fewer wire messages.

    A third phase exercises the FABRIC: a ``fanout``-member
    :class:`ReplicaGroup` rides a fresh workload — every member must sweep
    bit-identically to the primary after one broadcast sync
    (``fanout_sweep_equal``).  The broadcast now fans out CONCURRENTLY
    over a thread pool, so its wall (``fanout_lag_ms``) tracks the
    slowest member (``fanout_member_max_ms``), not the serial sum
    (``fanout_member_sum_ms`` — what the old member-by-member loop paid);
    the straggler spread keeps its own row (``fanout_spread_ms``).
    Failover is drilled by advancing one member ahead (the leader),
    killing its process, and checking ``promote()`` elects the
    highest-acked SURVIVOR (``fanout_elected_highest_acked``) and
    requeues every RUNNING row.

    ``encoded_bytes`` are the exact frame bytes that crossed the wire;
    ``payload_bytes`` is the in-memory ``payload_nbytes`` cost model those
    frames replace — their ratio is what the NIC would actually see.
    """
    import os

    from repro.core import wire
    from repro.core.replication import ReplicaGroup

    if fanout < 2:
        raise ValueError("the fan-out drill kills the leader and checks "
                         "the survivor election — it needs fanout >= 2")
    rng = np.random.default_rng(seed)
    wf = WorkflowConfig(activities=tuple(f"a{i}" for i in range(activities)))
    wq = WorkQueue(num_workers=num_workers,
                   capacity=max(1 << 14, 2 * num_tasks * activities))
    sup = Supervisor(wq, wf)
    sup.seed(max(num_tasks // activities, 1), duration_s=mean_dur_s, rng=rng)
    steer = SteeringEngine(wq)
    rep = ShippedDeltaReplicator(wq, sync_every=sync_every,
                                 transport=transport, pipelined=True)

    clock = 0.0
    rounds = 0
    while rounds < 10_000:
        out = wq.claim_all(k=1, now=clock)
        rows = np.concatenate([v for v in out.values() if len(v)]) \
            if any(len(v) for v in out.values()) else np.empty(0, np.int64)
        if len(rows) == 0:
            if sup.expand(now=clock) == 0:
                break
            rounds += 1
            continue
        n_fail = len(rows) // 8 if rounds % 5 == 2 else 0
        if n_fail:
            wq.fail(rows[:n_fail], now=clock + 0.5)
            rows = rows[n_fail:]
        if rounds == 3:
            victim = num_workers - 1
            wid = wq.store.col("worker_id")[rows]
            wq.requeue_worker(victim)
            rows = rows[wid != victim]
        if len(rows):
            wq.finish(rows, now=clock + 1.0,
                      domain_out=rng.normal(0.5, 0.3, (len(rows), 3)))
        if rounds == 4:
            steer.q8_patch_ready(0, "in0", 9.5,
                                 predicate=lambda v: v > 0.8)
        if rounds == 6:
            steer.prune("in1", 0.0, 0.02)
        if rounds == 8 and num_workers > 2:
            wq.resize(num_workers - 1)
        sup.expand(now=clock)
        if rep.maybe_sync():
            wq.compact_log()     # drop the prefix the replica just acked
        clock += mean_dur_s
        rounds += 1

    # ---- incremental burst: tiny per-tick deltas, every tick synced -----
    # The regime that collapsed under the blocking path: a claim_all plus
    # a finish per iteration (two log records, a few hundred bytes), each
    # followed by sync().  The pipelined arm is timed on what the PRODUCER
    # pays — the sync() enqueues and one final flush(); the shipper's
    # encode/send/ack overlaps the next iteration's claim work.  The
    # blocking arm pays a full round trip per sync.
    inc_iters = 40
    wq.add_tasks(0, inc_iters * num_workers,
                 domain_in=rng.uniform(0, 1, (inc_iters * num_workers, 3)),
                 now=clock)
    rep.sync()
    rep.flush()      # the seeding record is drained BEFORE the clock starts
    inc_sync_rep = ShippedDeltaReplicator(wq, sync_every=1 << 62,
                                          transport=transport)
    inc_b0, inc_m0 = rep.encoded_bytes, rep.messages_sent
    inc_wall_p = 0.0
    inc_wall_s = 0.0
    for _ in range(inc_iters):
        out = wq.claim_all(k=1, now=clock)
        rows = np.concatenate([v for v in out.values() if len(v)]) \
            if any(len(v) for v in out.values()) else np.empty(0, np.int64)
        if len(rows):
            wq.finish(rows, now=clock + 0.5,
                      domain_out=rng.normal(0.5, 0.3, (len(rows), 3)))
        t0 = time.perf_counter()
        rep.sync()
        inc_wall_p += time.perf_counter() - t0
        t0 = time.perf_counter()
        inc_sync_rep.sync()
        inc_wall_s += time.perf_counter() - t0
        clock += mean_dur_s
    t0 = time.perf_counter()
    rep.flush()
    inc_wall_p += time.perf_counter() - t0
    inc_bytes = rep.encoded_bytes - inc_b0
    inc_messages = rep.messages_sent - inc_m0
    inc_sync_rep.close()

    # ---- bulk one-shot catch-up: sustained wire throughput --------------
    # A separate claims/finishes-heavy log (one bulk insert, one claim
    # record per task, one finish record per task — consecutive same-op
    # records, so the codec ships a handful of large contiguous hot
    # frames): the multi-host shape the wire layer exists for.
    n_bulk = max(num_tasks, 500)
    wq_b = WorkQueue(num_workers=num_workers, capacity=2 * n_bulk)
    bulk = ShippedDeltaReplicator(wq_b, sync_every=1 << 62,
                                  transport=transport)
    bulk_ps = [ShippedDeltaReplicator(wq_b, sync_every=1 << 62,
                                      transport=transport, pipelined=True)
               for _ in range(3)]
    wq_b.add_tasks(0, n_bulk, domain_in=rng.uniform(0, 1, (n_bulk, 3)))
    claimed = [wq_b.claim(r % num_workers, k=1, now=float(r))
               for r in range(n_bulk)]
    for r, brow in enumerate(claimed):
        if len(brow):
            wq_b.finish(brow, now=float(r) + 0.5,
                        domain_out=rng.normal(0.5, 0.3, (len(brow), 3)))
    # compression accounting on the exact records the bulk sync will ship:
    # hot-frame bytes raw vs negotiated codec (cold pickles are identical
    # across codecs — the ratio the varint planes actually deliver)
    bulk_recs = wq_b.log.tail(0)
    enc_raw = wire.frames_nbytes_detail(bulk_recs, "raw")
    enc_neg = wire.frames_nbytes_detail(bulk_recs, bulk.codec)
    bulk.sync()
    bulk_bytes = bulk.encoded_bytes
    if bulk_bytes != enc_neg["total"]:
        raise AssertionError(
            f"bulk encoded-bytes accounting diverged from the codec "
            f"oracle: shipped {bulk_bytes}, sized {enc_neg['total']}")
    bulk_wall = bulk.encode_wall_s + bulk.ship_wall_s
    bulk_records = bulk.records_applied
    bulk_state = bulk.fetch_remote_state()
    bulk_cols_equal = all(
        np.array_equal(wq_b.store.col(n), bulk_state["snapshot"]["cols"][n],
                       equal_nan=True)
        for n in wq_b.store.cols)
    bulk.close()

    # Pipelined arms: same log, background shipper — encode of chunk k+1
    # overlaps the remote's decode+replay of chunk k, with a bounded
    # unacked window.  Measured END-TO-END (enqueue .. last ack), which
    # is the number a workflow producer actually waits for.  Three
    # independent consumers ship the identical span and the best wall
    # wins: the box is shared, and one-shot walls swing 2-3x under load.
    bulk_p_wall = float("inf")
    bulk_p_bytes = bulk_p_msgs = 0
    for bp in bulk_ps:
        t0 = time.perf_counter()
        bp.sync()
        bp.flush()
        wall = time.perf_counter() - t0
        if wall < bulk_p_wall:
            bulk_p_wall = wall
            bulk_p_bytes = bp.encoded_bytes
            bulk_p_msgs = bp.messages_sent
    bulk_p_state = bulk_ps[-1].fetch_remote_state()
    bulk_cols_equal = bulk_cols_equal and all(
        np.array_equal(wq_b.store.col(n),
                       bulk_p_state["snapshot"]["cols"][n], equal_nan=True)
        for n in wq_b.store.cols)
    for bp in bulk_ps:
        bp.close()

    # ---- compact, then keep shipping ACROSS the truncation --------------
    rep.sync()
    rep.flush()          # acks harvested -> the consumer floor advances
    truncated = wq.compact_log()
    wq.add_tasks(0, max(num_workers, 8),
                 domain_in=rng.uniform(0, 1, (max(num_workers, 8), 3)),
                 now=clock)
    out = wq.claim_all(k=1, now=clock)
    rows = np.concatenate([v for v in out.values() if len(v)]) \
        if any(len(v) for v in out.values()) else np.empty(0, np.int64)
    if len(rows):
        wq.finish(rows, now=clock + 1.0,
                  domain_out=rng.normal(0.5, 0.3, (len(rows), 3)))
    rep.sync()

    # ---- parity against a primary snapshot at the same version ----------
    view = wq.store.snapshot_view()
    rep.sync(upto_version=view.version)
    sweep_primary = steer.run_all(clock, view=view)
    sweep_remote = rep.remote_sweep(clock)
    state = rep.fetch_remote_state()
    cols_equal = all(
        np.array_equal(view.col(n), state["snapshot"]["cols"][n],
                       equal_nan=True)
        for n in wq.store.cols)
    remote_pid = state["pid"]
    drill_bytes = rep.encoded_bytes
    drill_wall = rep.encode_wall_s + rep.ship_wall_s

    # ---- fan-out: N replicas per partition, broadcast + election ---------
    # A fresh workload rides an N-member ReplicaGroup: one broadcast sync,
    # then every member's REMOTE sweep must match the primary bit-exactly.
    # Failover drill: the leader (synced ahead of the others) is killed and
    # promote() must elect the highest-acked SURVIVOR.
    n_fan = max(min(num_tasks, 400), 4 * num_workers)
    wq_f = WorkQueue(num_workers=num_workers, capacity=4 * n_fan)
    steer_f = SteeringEngine(wq_f)
    grp = ReplicaGroup(wq_f, n_replicas=fanout, sync_every=sync_every,
                       transport=transport, pipelined=True)
    wq_f.add_tasks(0, n_fan, domain_in=rng.uniform(0, 1, (n_fan, 3)))
    out = wq_f.claim_all(k=1, now=0.0)
    rows_f = np.concatenate([v for v in out.values() if len(v)])
    wq_f.finish(rows_f[len(rows_f) // 2:], now=1.0,
                domain_out=rng.normal(0.5, 0.3,
                                      (len(rows_f) - len(rows_f) // 2, 3)))
    view_f = wq_f.store.snapshot_view()
    grp.sync(upto_version=view_f.version)
    fan_ref = _sweep_fingerprint(steer_f.run_all(2.0, view=view_f))
    fanout_sweep_equal = all(
        _sweep_fingerprint(m.remote_sweep(2.0)) == fan_ref
        for m in grp.members)
    fanout_lag_ms = grp.fanout_lag_s() * 1e3      # broadcast wall
    member_walls = list(grp.last_sync_wall_s)
    fanout_member_max_ms = max(member_walls) * 1e3
    fanout_member_sum_ms = sum(member_walls) * 1e3
    fanout_spread_ms = grp.member_spread_s() * 1e3
    # leader = member 0, synced past everyone else, then killed.  The
    # members are pipelined, so flush() to turn enqueues into acks
    # before comparing offsets.
    wq_f.add_tasks(0, num_workers, now=3.0)
    grp.members[0].sync()
    grp.members[1].sync()
    wq_f.add_tasks(0, num_workers, now=4.0)
    grp.members[0].sync()
    for m in (grp.members[0], grp.members[1]):
        m.flush()
    leader = grp.members[0]
    leader.process.kill()
    leader.process.join()
    elected = grp.elect()
    fanout_elected_highest_acked = (
        elected is not leader
        and elected.offset == max(m.offset for m in grp.members
                                  if m is not leader))
    wq_fp = grp.promote()
    fanout_promote_no_running = bool(
        (wq_fp.store.col("status") != int(Status.RUNNING)).all())

    res: Dict = {
        "rounds": rounds, "store_rows": int(wq.store.n_rows),
        "log_records": len(wq.log),
        "records_shipped": int(rep.records_applied),
        "sync_count": int(rep.sync_count), "sync_every": sync_every,
        "encoded_bytes": int(drill_bytes),
        "payload_bytes": int(rep.delta_bytes),
        "encoded_bytes_ratio": round(
            drill_bytes / max(rep.delta_bytes, 1), 4),
        "encode_wall_s": round(rep.encode_wall_s, 5),
        "ship_wall_s": round(rep.ship_wall_s, 5),
        "ship_mbps": round(inc_bytes / max(inc_wall_p, 1e-9) / 1e6, 2),
        "ship_mbps_drill_wire": round(
            drill_bytes / max(drill_wall, 1e-9) / 1e6, 2),
        "ship_mbps_incremental_sync": round(
            inc_bytes / max(inc_wall_s, 1e-9) / 1e6, 2),
        "inc_bytes": int(inc_bytes),
        "inc_syncs": int(inc_iters),
        "inc_messages": int(inc_messages),
        "drill_messages_sent": int(rep.messages_sent),
        "bulk_records": int(bulk_records),
        "bulk_encoded_bytes": int(bulk_bytes),
        "bulk_cols_equal": bool(bulk_cols_equal),
        "ship_mbps_bulk": round(
            bulk_p_bytes / max(bulk_p_wall, 1e-9) / 1e6, 2),
        "ship_mbps_bulk_sync": round(
            bulk_bytes / max(bulk_wall, 1e-9) / 1e6, 2),
        "bulk_pipeline_messages": int(bulk_p_msgs),
        "transport": rep.transport, "codec": rep.codec,
        "compression_ratio": round(
            enc_raw["hot"] / max(enc_neg["hot"], 1), 4),
        "compression_ratio_total": round(
            enc_raw["total"] / max(enc_neg["total"], 1), 4),
        "fanout_n": int(fanout),
        "fanout_sweep_equal": bool(fanout_sweep_equal),
        "fanout_lag_ms": round(fanout_lag_ms, 3),
        "fanout_member_max_ms": round(fanout_member_max_ms, 3),
        "fanout_member_sum_ms": round(fanout_member_sum_ms, 3),
        "fanout_spread_ms": round(fanout_spread_ms, 3),
        "fanout_elected_highest_acked": bool(fanout_elected_highest_acked),
        "fanout_promote_no_running": bool(fanout_promote_no_running),
        "log_truncated_records": int(wq.log.base),
        "compact_dropped": int(truncated),
        "parent_pid": int(os.getpid()), "remote_pid": int(remote_pid),
        "replica_spawns": int(rep.spawn_count),
        "cols_equal": bool(cols_equal),
        "sweep_equal": (_sweep_fingerprint(sweep_primary)
                        == _sweep_fingerprint(sweep_remote)),
        "replica_version": int(state["snapshot"]["version"]),
        "primary_version": int(view.version),
        "tasks_finished": int(wq.counts()["FINISHED"]),
    }
    # ---- remote failover: promote() must requeue RUNNING rows there -----
    wq2 = rep.promote()
    res["recovered_rows"] = int(wq2.store.n_rows)
    res["recovered_no_running"] = bool(
        (wq2.store.col("status") != int(Status.RUNNING)).all())
    return res


def run_sharded(num_shards: int, workers_per_shard: int, num_tasks: int,
                *, activities: int = 3, sync_every: int = 64,
                thr_tasks: Optional[int] = None, thr_k: int = 4,
                repeats: int = 2, seed: int = 0) -> Dict:
    """Sharded multi-primary drill (ShardRouter), four phases:

    **A. Oracle parity.** The identical deterministic workload (inserts
    with provenance chains, claims, retries, finishes, a Q8 patch, a
    steering prune) runs on an N-shard router AND on a single W-worker
    primary. Because shard ``(tid % W) // L`` + local partition ``tid % L``
    compose to the oracle's global partition ``tid % W``, every per-worker
    claim set must match id-for-id, and the router's scatter-gather
    Q1-Q7 sweep — pinned at a version vector cut after the drill — must be
    bit-identical to the oracle's single-snapshot sweep (all times are
    dyadic so merged partial sums reassociate exactly). Each shard also
    feeds its own ``DeltaReplicator`` across log compactions; the merged
    sweep is re-run over the REPLICA snapshot vector and per-shard replica
    columns are compared bit-for-bit.

    **B. Cross-shard stealing.** Shard 0 is drained, a fresh batch tops up
    the siblings, and ``rebalance`` pulls half the richest sibling's READY
    backlog over the transport. Checked: the live task-id multiset is
    conserved, the drained shard can claim again, and every shard's
    replica still replays to bit-parity (the steal is a logged prune + a
    normal logged insert — no new record type).

    **C. Weak-scaling claim throughput.** Fixed per-shard load (``thr_tasks``
    tasks on ``workers_per_shard`` partitions): a 1-shard router vs an
    N-shard router, claim-drained with ``claim_all(k=thr_k)``. Shards are
    independent primaries (disjoint stores/logs), so per-shard walls are
    measured separately and the N-shard wall is the MAX over shards — the
    makespan of N data nodes claiming in parallel, the same node-parallel
    accounting the rest of simkit uses. ``scaleup`` = aggregate sharded
    throughput / single-primary throughput (the ``--min-sharded-scaleup``
    CI gate); best-of-``repeats`` per arm.

    **D. Parallel steering plane (remote scatter).** A fresh router with
    SHIPPED replicas (one OS process per shard; pipe transport by
    default, TCP under ``REPRO_WIRE_TRANSPORT=tcp``) runs a
    provenance-chained workload mirrored on a single-primary oracle
    across a mid-drill log truncation, then bulk-loads filler rows so
    per-shard sweeps carry real reduction work. The remote merged Q1-Q7
    sweep (``sweep_partials`` inside each replica process,
    ``merge_partials`` on the router) is hard-checked bit-identical to
    the local ``run_all`` AND to the oracle at the same pinned version
    vector, concurrent scatter == serial loop, and the serial-vs-
    concurrent scatter walls are timed under the paper's modeled
    per-shard data-node RPC latency (``steer_rpc_delay_s``, slept inside
    each replica process — the ``run_baseline`` ``access_latency_s``
    regime) — ``steer_fanout_speedup`` feeds the
    ``--min-steer-fanout-speedup`` CI gate, with per-shard walls and the
    straggler spread recorded alongside.
    """
    from repro.core.sharding_router import ShardRouter

    S, L = num_shards, workers_per_shard
    W = S * L
    cap = max(1 << 14, 4 * num_tasks)
    router = ShardRouter(S, L, capacity=cap, replicate="delta",
                         sync_every=sync_every)
    oracle = WorkQueue(num_workers=W, capacity=cap)
    osteer = SteeringEngine(oracle)

    # ---------------------------------------------------- phase A: parity
    def dom_in(ids: np.ndarray) -> np.ndarray:
        h = (ids * 2654435761) % (1 << 10)
        return np.stack([(h % 977) / 976.0, ((h * 3) % 911) / 910.0,
                         ((h * 7) % 1013) / 1012.0], 1)

    def dom_out(ids: np.ndarray) -> np.ndarray:
        # dyadic denominators: exact floats, so out0-threshold tests and
        # merged segment sums are bit-stable
        return np.stack([(ids % 7) / 8.0, (ids % 5) / 4.0,
                         (ids % 3) / 2.0], 1)

    per_act = max(num_tasks // activities, 2 * W)
    prev = None
    for a in range(activities):
        ids = np.arange(a * per_act, (a + 1) * per_act, dtype=np.int64)
        kw = dict(domain_in=dom_in(ids), duration_est=1.0, now=0.0)
        if prev is not None:
            kw["parent_task"] = prev          # provenance chain for Q7
        rid = router.add_tasks(a, per_act, **kw)
        oid = oracle.add_tasks(a, per_act, **kw)
        assert np.array_equal(rid, ids) and np.array_equal(oid, ids)
        prev = ids

    def shard_rows(ids: np.ndarray) -> List[Tuple[int, np.ndarray]]:
        """Map global task ids to (shard, rows). Valid in phase A only:
        no steal has run yet, so shard task_id columns are ascending."""
        out = []
        owner = router.shard_of(ids)
        for s in range(S):
            m = owner == s
            if not m.any():
                continue
            tid = router.shards[s].wq.store.col("task_id")
            pos = np.searchsorted(tid, ids[m])
            assert np.array_equal(tid[pos], ids[m])
            out.append((s, pos))
        return out

    claim_parity = True
    clock = 1.0
    rounds = 0
    while rounds < 32:
        rc = router.claim_all(k=2, now=clock, steal=False)
        oc = oracle.claim_all(k=2, now=clock, steal=False)
        r_ids = {g: np.sort(router.shards[s].wq.store.col("task_id")[rows])
                 for g, (s, rows) in rc.items() if len(rows)}
        o_ids = {g: np.sort(oracle.store.col("task_id")[rows])
                 for g, rows in oc.items() if len(rows)}
        claim_parity &= set(r_ids) == set(o_ids) and all(
            np.array_equal(r_ids[g], o_ids[g]) for g in r_ids)
        if not o_ids:
            break
        all_ids = np.sort(np.concatenate(list(o_ids.values())))
        fail_ids = all_ids[::7] if rounds % 3 == 2 else all_ids[:0]
        fin = np.setdiff1d(all_ids, fail_ids)
        fa, fb = fin[fin % 2 == 0], fin[fin % 2 == 1]
        # oracle rows == task ids (single contiguous insertion order)
        if len(fail_ids):
            oracle.fail(fail_ids, now=clock + 0.25)
            for s, pos in shard_rows(fail_ids):
                router.shards[s].wq.fail(pos, now=clock + 0.25)
        for ids_, dt in ((fa, 1.0), (fb, 1.5)):   # two dyadic durations:
            if not len(ids_):                     # Q6/Q7 means non-trivial
                continue
            oracle.finish(ids_, now=clock + dt, domain_out=dom_out(ids_))
            for s, pos in shard_rows(ids_):
                tid = router.shards[s].wq.store.col("task_id")[pos]
                router.shards[s].wq.finish(pos, now=clock + dt,
                                           domain_out=dom_out(tid))
        if rounds == 4:                           # user steering (Q8):
            osteer.q8_patch_ready(0, "in0", 9.5,  # value predicate selects
                                  predicate=lambda v: v > 0.8)
            for sh in router.shards:              # the same tasks per shard
                SteeringEngine(sh.wq).q8_patch_ready(
                    0, "in0", 9.5, predicate=lambda v: v > 0.8)
        if rounds == 6:                           # data reduction
            osteer.prune("in1", 0.0, 0.02)
            for sh in router.shards:
                SteeringEngine(sh.wq).prune("in1", 0.0, 0.02)
        for sh in router.shards:                  # replicate + compact
            sh.replicator.maybe_sync()            # mid-drill, so catch-up
        router.compact()                          # crosses truncations
        clock += 2.0
        rounds += 1

    views = router.snapshot_vector()
    oview = oracle.store.snapshot_view()
    merged = ShardRouter.comparable(router.run_all(clock, views=views))
    onorm = ShardRouter.oracle_normalize(
        osteer.run_all(clock, view=oview), oview)
    sweep_equal = _sweep_fingerprint(merged) == _sweep_fingerprint(onorm)

    # replicas: catch up to the pinned vector, compare bit-for-bit, then
    # run the merged sweep OVER THE REPLICA SNAPSHOTS
    replica_cols_equal = True
    for s, sh in enumerate(router.shards):
        sh.replicator.sync(upto_version=views[s].version)
        replica_cols_equal &= all(
            np.array_equal(views[s].col(n), sh.replicator.store.col(n),
                           equal_nan=True)
            for n in sh.wq.store.cols)
    rep_views = tuple(sh.replicator.snapshot_view()
                      for sh in router.shards)
    merged_rep = ShardRouter.comparable(router.run_all(clock,
                                                       views=rep_views))
    replica_sweep_equal = (_sweep_fingerprint(merged_rep)
                           == _sweep_fingerprint(onorm))
    router.sync_replicas()
    router.compact()
    log_truncated = all(sh.wq.log.base > 0 for sh in router.shards)

    # --------------------------------------------- phase B: work stealing
    topup = router.add_tasks(
        0, 8 * W, domain_in=dom_in(np.arange(8 * W)),
        duration_est=1.0, now=clock)
    assert len(topup) == 8 * W
    sh0 = router.shards[0]
    while sh0.wq.ready_counts().sum() > 0:        # drain shard 0 dry
        got = sh0.wq.claim_all(k=64, now=clock)
        rows = np.concatenate([v for v in got.values() if len(v)])
        if not len(rows):
            break
        sh0.wq.finish(rows, now=clock + 1.0)
        clock += 2.0
    live_before = router.live_task_ids()
    steal_moved = router.rebalance(now=clock)
    steal_conserved = np.array_equal(live_before, router.live_task_ids())
    got = sh0.wq.claim_all(k=4, now=clock + 2.0)
    steal_claimable = int(sum(len(v) for v in got.values()))
    router.sync_replicas()                        # steal is ordinary logged
    steal_replica_parity = True                   # ops: replicas stay equal
    for sh in router.shards:
        v = sh.wq.store.snapshot_view()
        sh.replicator.sync(upto_version=v.version)
        steal_replica_parity &= all(
            np.array_equal(v.col(n), sh.replicator.store.col(n),
                           equal_nan=True)
            for n in sh.wq.store.cols)
    router.check_invariants()
    oracle.check_invariants()
    steal_wire_bytes = int(router.steal_stats.wire_bytes)
    router.close()

    # ------------------------------------- phase C: weak-scaling throughput
    T = thr_tasks if thr_tasks is not None else max(4 * num_tasks, 2000)

    def claim_drain_wall(wq: WorkQueue) -> Tuple[float, int]:
        wall, claimed, t = 0.0, 0, 0.0
        while True:
            t0 = time.perf_counter()
            out = wq.claim_all(k=thr_k, now=t)
            wall += time.perf_counter() - t0
            rows = np.concatenate([v for v in out.values() if len(v)]) \
                if any(len(v) for v in out.values()) \
                else np.empty(0, np.int64)
            if not len(rows):
                break
            claimed += len(rows)
            wq.finish(rows, now=t + 1.0)          # untimed: claim path only
            t += 2.0
        return wall, claimed

    def arm(n_shards: int) -> Tuple[float, float]:
        """(aggregate claim throughput, max per-shard wall)."""
        r = ShardRouter(n_shards, L, capacity=max(1 << 14, 2 * T))
        r.add_tasks(0, n_shards * T, duration_est=1.0, now=0.0)
        walls, claimed = [], 0
        for sh in r.shards:
            w, c = claim_drain_wall(sh.wq)
            walls.append(w)
            claimed += c
        r.close()
        assert claimed == n_shards * T, (claimed, n_shards * T)
        wall = max(walls)
        return claimed / wall, wall

    thr_1 = thr_S = 0.0
    wall_1 = wall_S = float("inf")
    for _ in range(max(repeats, 1)):
        t1, w1 = arm(1)
        tS, wS = arm(S)
        if t1 > thr_1:
            thr_1, wall_1 = t1, w1
        if tS > thr_S:
            thr_S, wall_S = tS, wS

    # ------------------- phase D: parallel steering plane (remote scatter)
    # The paper's analyst plane is distributed: every shard is a data NODE
    # whose replica lives in its own OS process. Rebuild the router with
    # SHIPPED replicas (pipe transport by default, TCP under
    # REPRO_WIRE_TRANSPORT=tcp), drive a provenance-chained workload
    # mirrored on a single-primary oracle ACROSS a mid-drill log
    # truncation, then bulk-load filler rows so the per-shard sweeps carry
    # real reduction work. Hard-checked: the remote merged Q1-Q7 sweep
    # (sweep_partials inside each replica process, merge_partials here) is
    # bit-identical to the local run_all AND to the oracle at the same
    # version vector, and the concurrent scatter equals the serial loop.
    # Timed: serial-vs-concurrent scatter walls under the paper's modeled
    # per-shard data-node RPC latency (steer_rpc_delay_s, slept inside
    # each replica process — run_baseline's access_latency_s regime:
    # remote shards answer over a NIC, and only a concurrent scatter can
    # overlap those round trips). Best-of-``repeats``; per-shard walls and
    # the straggler spread ride along.
    steer_fill = max(2 * T, 4 * W)
    steer_rtt_s = 0.01
    n_chain = activities * per_act
    router2 = ShardRouter(
        S, L, capacity=max(1 << 14, 2 * (n_chain + steer_fill) // S),
        replicate="shipped", sync_every=sync_every)
    oracle2 = WorkQueue(num_workers=W,
                        capacity=max(1 << 14, 2 * (n_chain + steer_fill)))
    osteer2 = SteeringEngine(oracle2)
    prev = None
    for a in range(activities):
        ids = np.arange(a * per_act, (a + 1) * per_act, dtype=np.int64)
        kw = dict(domain_in=dom_in(ids), duration_est=1.0, now=0.0)
        if prev is not None:
            kw["parent_task"] = prev
        router2.add_tasks(a, per_act, **kw)
        oracle2.add_tasks(a, per_act, **kw)
        prev = ids

    def shard_rows2(ids: np.ndarray) -> List[Tuple[int, np.ndarray]]:
        out = []
        owner = router2.shard_of(ids)
        for s in range(S):
            m = owner == s
            if not m.any():
                continue
            tid = router2.shards[s].wq.store.col("task_id")
            pos = np.searchsorted(tid, ids[m])
            assert np.array_equal(tid[pos], ids[m])
            out.append((s, pos))
        return out

    clock2 = 1.0
    for rnd in range(12):
        rc = router2.claim_all(k=2, now=clock2, steal=False)
        oc = oracle2.claim_all(k=2, now=clock2, steal=False)
        o_ids = {g: np.sort(oracle2.store.col("task_id")[rows])
                 for g, rows in oc.items() if len(rows)}
        del rc
        if not o_ids:
            break
        all_ids = np.sort(np.concatenate(list(o_ids.values())))
        fail_ids = all_ids[::7] if rnd % 3 == 2 else all_ids[:0]
        fin = np.setdiff1d(all_ids, fail_ids)
        fa, fb = fin[fin % 2 == 0], fin[fin % 2 == 1]
        if len(fail_ids):
            oracle2.fail(fail_ids, now=clock2 + 0.25)
            for s, pos in shard_rows2(fail_ids):
                router2.shards[s].wq.fail(pos, now=clock2 + 0.25)
        for ids_, dt in ((fa, 1.0), (fb, 1.5)):
            if not len(ids_):
                continue
            oracle2.finish(ids_, now=clock2 + dt, domain_out=dom_out(ids_))
            for s, pos in shard_rows2(ids_):
                tid = router2.shards[s].wq.store.col("task_id")[pos]
                router2.shards[s].wq.finish(pos, now=clock2 + dt,
                                            domain_out=dom_out(tid))
        if rnd == 3:
            osteer2.q8_patch_ready(0, "in0", 9.5,
                                   predicate=lambda v: v > 0.8)
            for sh in router2.shards:
                SteeringEngine(sh.wq).q8_patch_ready(
                    0, "in0", 9.5, predicate=lambda v: v > 0.8)
        if rnd == 5:
            osteer2.prune("in1", 0.0, 0.02)
            for sh in router2.shards:
                SteeringEngine(sh.wq).prune("in1", 0.0, 0.02)
        router2.sync_replicas()       # acks advance the consumer floor...
        router2.compact()             # ...so the catch-up crosses truncates
        clock2 += 2.0
    steer_log_truncated = all(sh.wq.log.base > 0 for sh in router2.shards)

    fill_ids = np.arange(n_chain, n_chain + steer_fill, dtype=np.int64)
    router2.add_tasks(0, steer_fill, domain_in=dom_in(fill_ids),
                      duration_est=1.0, now=clock2)
    oracle2.add_tasks(0, steer_fill, domain_in=dom_in(fill_ids),
                      duration_est=1.0, now=clock2)

    vec2 = router2.sync_replicas()
    views2 = router2.snapshot_vector()
    oview2 = oracle2.store.snapshot_view()
    t0 = time.perf_counter()
    res_conc = router2.remote_sweep(clock2, versions=vec2, sync=False)
    steer_conc_raw = time.perf_counter() - t0
    t0 = time.perf_counter()
    res_serial = router2.remote_sweep(clock2, versions=vec2, sync=False,
                                      concurrent_scatter=False)
    steer_serial_raw = time.perf_counter() - t0
    local2 = router2.run_all(clock2, views=views2)
    onorm2 = ShardRouter.oracle_normalize(
        osteer2.run_all(clock2, view=oview2), oview2)
    steer_remote_matches_local = (_sweep_fingerprint(res_conc)
                                  == _sweep_fingerprint(local2))
    steer_remote_sweep_equal = (
        _sweep_fingerprint(ShardRouter.comparable(res_conc))
        == _sweep_fingerprint(onorm2))
    steer_scatter_equal = (_sweep_fingerprint(res_conc)
                           == _sweep_fingerprint(res_serial))

    rtt = [steer_rtt_s] * S
    steer_conc = steer_serial = float("inf")
    steer_walls: List[float] = []
    steer_spread = 0.0
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        router2.remote_sweep(clock2, versions=vec2, sync=False,
                             shard_delay_s=rtt)
        wc = time.perf_counter() - t0
        if wc < steer_conc:
            steer_conc = wc
            steer_walls = [round(w, 5) for w in router2.last_scatter_wall_s]
            steer_spread = router2.scatter_spread_s()
        t0 = time.perf_counter()
        router2.remote_sweep(clock2, versions=vec2, sync=False,
                             concurrent_scatter=False, shard_delay_s=rtt)
        steer_serial = min(steer_serial, time.perf_counter() - t0)
    router2.close()

    return {
        "shards": S, "workers_per_shard": L, "global_workers": W,
        "parity_rounds": rounds,
        "claim_parity": bool(claim_parity),
        "sweep_equal": bool(sweep_equal),
        "replica_cols_equal": bool(replica_cols_equal),
        "replica_sweep_equal": bool(replica_sweep_equal),
        "log_truncated_all_shards": bool(log_truncated),
        "version_vector": [int(v.version) for v in views],
        "oracle_version": int(oview.version),
        "steal_moved": int(steal_moved),
        "steal_conserved": bool(steal_conserved),
        "steal_claimable": steal_claimable,
        "steal_wire_bytes": steal_wire_bytes,
        "steal_replica_parity": bool(steal_replica_parity),
        "thr_tasks_per_shard": int(T), "claim_k": int(thr_k),
        "claims_per_s_single": round(thr_1, 1),
        "claims_per_s_sharded": round(thr_S, 1),
        "claim_wall_single_s": round(wall_1, 4),
        "claim_wall_sharded_max_s": round(wall_S, 4),
        "scaleup": round(thr_S / thr_1, 2) if thr_1 else 0.0,
        "steer_rows": int(n_chain + steer_fill),
        "steer_rpc_delay_s": steer_rtt_s,
        "steer_serial_wall_s": round(steer_serial, 5),
        "steer_concurrent_wall_s": round(steer_conc, 5),
        "steer_fanout_speedup": round(steer_serial / steer_conc, 2)
        if steer_conc else 0.0,
        "steer_shard_walls_s": steer_walls,
        "steer_spread_s": round(steer_spread, 5),
        "steer_serial_raw_wall_s": round(steer_serial_raw, 5),
        "steer_concurrent_raw_wall_s": round(steer_conc_raw, 5),
        "steer_remote_sweep_equal": bool(steer_remote_sweep_equal),
        "steer_remote_matches_local": bool(steer_remote_matches_local),
        "steer_scatter_equal": bool(steer_scatter_equal),
        "steer_log_truncated": bool(steer_log_truncated),
        "steer_version_vector": [int(v) for v in vec2],
    }


def run_chaos(num_workers: int, num_tasks: int, *, lease_s: float = 4.0,
              kill_workers: int = 2, max_trials: int = 6,
              sync_every: int = 16, seed: int = 0,
              transport: Optional[str] = None,
              shards: int = 2, workers_per_shard: int = 4) -> Dict:
    """Kill-drill for the lease-based recovery path (PR 8), three phases.

    **A. Single primary + shipped replica.** ``num_workers`` workers run
    per-worker ``claim(w, ..., allow_steal=True)`` loops against one
    WorkQueue with a short claim lease, renewing leases on held rows and
    shipping every record (claims with their lease stamps, renewals,
    reaps) to a :class:`ShippedDeltaReplicator` in another OS process.
    Mid-run, ``kill_workers`` randomly chosen workers go silent (they stop
    claiming, finishing and heartbeating — their RUNNING rows strand with
    live leases) AND the replica process is ``kill()``-ed outright. No
    component is told anything: the leases simply expire, the reaper
    (running at the steering-tick cadence) requeues the stranded rows in
    one masked transition, the survivors STEAL them, and the next sync
    respawns the replica from a snapshot. The drill then hard-checks, at a
    pinned version and across at least one log truncation, that the
    respawned replica's columns are bit-identical to the primary.

    **B. Sharded.** The same silent-worker chaos on a
    ``shards x workers_per_shard`` :class:`ShardRouter` with per-shard
    delta replicas: ``router.reap_expired`` requeues per shard, the reaped
    backlog re-enters the per-shard READY counts so ``rebalance`` treats
    it as ordinary stealable work, and per-shard replica parity is
    re-checked across compactions.

    **C. Kill DURING a resize (reaper x rehash race).** Workers go silent
    holding live leases at the same tick the pool shrink-``resize``s under
    them: their RUNNING rows keep pre-resize worker ids that no longer name
    a partition. The lease reaper must land the requeued rows on the
    POST-resize partition map (``reap_expired`` rehashes at today's
    ``num_workers``) and the :class:`HeartbeatMonitor` must resync to the
    new pool with no ghost beats — a stale beat entry for a removed worker
    would re-trigger ``requeue_worker`` on every sweep forever.

    Returned dict carries the conservation / drain / parity verdicts
    (``exp_chaos`` raises on any False) plus ``recovery_s`` — wall time
    from the kill instant to the last task draining — which
    ``scripts/bench_trajectory.py`` gates with ``--max-recovery-s``.
    """
    from repro.core.sharding_router import ShardRouter
    from repro.runtime.fault import HeartbeatMonitor

    rng = np.random.default_rng(seed)

    # ---------------- phase A: single primary + shipped replica ----------
    wq = WorkQueue(num_workers=num_workers,
                   capacity=max(1 << 12, 2 * num_tasks), lease_s=lease_s)
    rep = ShippedDeltaReplicator(wq, sync_every=sync_every,
                                 transport=transport)
    wq.add_tasks(0, num_tasks,
                 domain_in=rng.uniform(0, 1, (num_tasks, 3)), now=0.0)
    ids_before = np.sort(wq.store.col("task_id")[
        wq.store.col("status") != int(Status.EMPTY)])

    live = set(range(num_workers))
    pending: Dict[int, np.ndarray] = {w: np.empty(0, np.int64)
                                      for w in range(num_workers)}
    kill_tick = 4
    killed: List[int] = []
    stranded = 0
    reaped = 0
    t_kill = 0.0
    tick = 0
    while tick < 10_000:
        clock = float(tick)
        for w in sorted(live):
            if tick % 3 == 1 and len(pending[w]):
                # a held row's heartbeat — ships a lease_renew record
                wq.renew_leases(pending[w], now=clock)
            if len(pending[w]):
                wq.finish(pending[w], now=clock,
                          domain_out=rng.normal(
                              0.5, 0.3, (len(pending[w]), 3)))
            pending[w] = wq.claim(w, k=2, now=clock, allow_steal=True)
        if tick == kill_tick:
            killed = sorted(rng.choice(num_workers, size=kill_workers,
                                       replace=False).tolist())
            live -= set(killed)            # silent death: no requeue call
            stranded = int(sum(len(pending[w]) for w in killed))
            rep.process.kill()             # the replica dies with them
            rep.process.join()
            t_kill = time.perf_counter()
        # the steering-tick lease sweep: expired claims requeue in one
        # masked transition, survivors steal them next tick
        if tick >= kill_tick:
            reaped += wq.reap_expired(now=clock, max_trials=max_trials)
        if rep.maybe_sync():               # first post-kill sync respawns
            wq.compact_log()
        if int(wq.counts()["FINISHED"]) == num_tasks:
            break
        tick += 1
    recovery_s = time.perf_counter() - t_kill
    counts = wq.counts()
    ids_after = np.sort(wq.store.col("task_id")[
        wq.store.col("status") != int(Status.EMPTY)])
    wq.check_invariants()

    rep.sync()
    wq.compact_log()
    view = wq.store.snapshot_view()
    rep.sync(upto_version=view.version)
    state = rep.fetch_remote_state()
    cols_equal = all(
        np.array_equal(view.col(n), state["snapshot"]["cols"][n],
                       equal_nan=True)
        for n in wq.store.cols)
    respawns = int(rep.spawn_count)
    log_truncated = int(wq.log.base)
    rep.close()

    # ------------------------- phase B: sharded ---------------------------
    S, L = shards, workers_per_shard
    router = ShardRouter(S, L, capacity=max(1 << 12, 2 * num_tasks),
                         replicate="delta", sync_every=sync_every,
                         lease_s=lease_s)
    router.add_tasks(0, num_tasks, now=0.0)
    s_before = router.live_task_ids()
    s_live = set(range(S * L))
    s_pending: Dict[int, np.ndarray] = {g: np.empty(0, np.int64)
                                        for g in range(S * L)}
    s_killed = sorted(rng.choice(S * L, size=kill_workers,
                                 replace=False).tolist())
    s_reaped = 0
    s_stolen = 0
    t_kill_b = 0.0
    tick = 0
    while tick < 10_000:
        clock = float(tick)
        for g in sorted(s_live):
            s, l = g // L, g % L
            swq = router.shards[s].wq
            if len(s_pending[g]):
                swq.finish(s_pending[g], now=clock)
            s_pending[g] = swq.claim(l, k=2, now=clock, allow_steal=True)
        if tick == kill_tick:
            s_live -= set(s_killed)
            t_kill_b = time.perf_counter()
        if tick >= kill_tick:
            s_reaped += router.reap_expired(now=clock,
                                            max_trials=max_trials)
            # reaped rows re-entered per-shard READY counts: a starved
            # shard now steals them as perfectly ordinary backlog
            s_stolen += router.rebalance(now=clock)
        router.sync_replicas()
        router.compact()
        done = sum(int(sh.wq.counts()["FINISHED"])
                   for sh in router.shards)
        if done == num_tasks:
            break
        tick += 1
    s_recovery_s = time.perf_counter() - t_kill_b
    s_done = sum(int(sh.wq.counts()["FINISHED"]) for sh in router.shards)
    s_running = sum(int(sh.wq.counts()["RUNNING"])
                    for sh in router.shards)
    s_conserved = np.array_equal(s_before, router.live_task_ids())
    router.check_invariants()
    s_parity = True
    s_truncated = True
    for sh in router.shards:
        v = sh.wq.store.snapshot_view()
        sh.replicator.sync(upto_version=v.version)
        s_parity &= all(
            np.array_equal(v.col(n), sh.replicator.store.col(n),
                           equal_nan=True)
            for n in sh.wq.store.cols)
        s_truncated &= sh.wq.log.base > 0
    router.close()

    # -------------- phase C: kill DURING a resize (reaper x rehash race) --
    W0, W1 = num_workers, max(2, num_workers // 2)
    wq2 = WorkQueue(num_workers=W0, capacity=max(1 << 12, 2 * num_tasks),
                    lease_s=lease_s)
    mon = HeartbeatMonitor(wq2, timeout_s=lease_s, now=0.0)
    wq2.add_tasks(0, num_tasks, now=0.0)
    r_before = np.sort(wq2.store.col("task_id")[
        wq2.store.col("status") != int(Status.EMPTY)])
    r_live = set(range(W0))
    r_pending: Dict[int, np.ndarray] = {w: np.empty(0, np.int64)
                                        for w in range(W0)}
    # worker 0 always survives, so the shrunken pool can drain the backlog
    r_killed = sorted(rng.choice(np.arange(1, W0),
                                 size=min(kill_workers, W0 - 1),
                                 replace=False).tolist())
    resize_reaped = 0
    rehash_ok = True
    tick = 0
    while tick < 10_000:
        clock = float(tick)
        for w in sorted(r_live):
            if w >= wq2.num_workers:
                continue               # partition removed by the shrink:
            if len(r_pending[w]):      # decommissioned workers stop; their
                wq2.finish(r_pending[w], now=clock)  # held rows strand too
            mon.beat(w, now=clock)
            r_pending[w] = wq2.claim(w, k=4, now=clock, allow_steal=True)
        if tick == kill_tick:
            r_live -= set(r_killed)    # silent death, leases still live...
            wq2.resize(W1)             # ...and the map changes under them
        if tick > kill_tick:
            n = wq2.reap_expired(now=clock, max_trials=max_trials)
            resize_reaped += n
            if n:                      # reaped rows must land IN the new map
                st_c = wq2.store.col("status")
                rw = wq2.store.col("worker_id")[st_c == int(Status.READY)]
                rehash_ok &= bool(((rw >= 0) & (rw < W1)).all())
        mon.sweep(now=clock)           # auto-resyncs to the resized pool
        if int(wq2.counts()["FINISHED"]) == num_tasks:
            break
        tick += 1
    r_counts = wq2.counts()
    r_after = np.sort(wq2.store.col("task_id")[
        wq2.store.col("status") != int(Status.EMPTY)])
    ghost_free = (len(mon.beats) == W1
                  and all(w < W1 for w in mon.beats)
                  and all(w < W1 for w in mon.dead))

    return {
        "workers": num_workers, "tasks": num_tasks, "lease_s": lease_s,
        "workers_killed": killed, "replicas_killed": 1,
        "stranded_claims": stranded,
        "reaped": int(reaped),
        "recovery_s": round(recovery_s, 4),
        "conserved": bool(np.array_equal(ids_before, ids_after)),
        "drained": bool(counts["FINISHED"] == num_tasks
                        and counts["RUNNING"] == 0
                        and counts["READY"] == 0),
        "finished": int(counts["FINISHED"]),
        "replica_respawns": respawns,
        "replica_cols_equal": bool(cols_equal),
        "log_truncated_records": log_truncated,
        "shards": S, "workers_per_shard": L,
        "sharded_workers_killed": s_killed,
        "sharded_reaped": int(s_reaped),
        "sharded_stolen": int(s_stolen),
        "sharded_recovery_s": round(s_recovery_s, 4),
        "sharded_conserved": bool(s_conserved),
        "sharded_drained": bool(s_done == num_tasks and s_running == 0),
        "sharded_finished": int(s_done),
        "sharded_replica_parity": bool(s_parity),
        "sharded_log_truncated": bool(s_truncated),
        "resize_from": int(W0), "resize_to": int(W1),
        "resize_killed": r_killed,
        "resize_reaped": int(resize_reaped),
        "resize_rehash_ok": bool(rehash_ok),
        "resize_no_ghost_beats": bool(ghost_free),
        "resize_conserved": bool(np.array_equal(r_before, r_after)),
        "resize_drained": bool(r_counts["FINISHED"] == num_tasks
                               and r_counts["RUNNING"] == 0
                               and r_counts["READY"] == 0),
    }


def run_shard_failover(num_shards: int, workers_per_shard: int,
                       num_tasks: int, *, activities: int = 3,
                       sync_every: int = 32, seed: int = 0) -> Dict:
    """Shard-primary failover drill (PR 9): kill two primaries mid-run.

    An ``S x L`` :class:`ShardRouter` (per-shard delta replicas, per-shard
    Supervisor + SecondarySupervisor) runs the deterministic lockstep
    workload of :func:`run_sharded` against a single ``W``-worker oracle.
    Mid-run, shard 0's primary dies WITH its in-flight claims (its workers
    held them); a few rounds later so does shard 1's. For each kill:

    * **Dead window.** The failed shard stops serving; the surviving
      shards' claim loops must keep returning work every round
      (``survivor_min_claims`` > 0 — no global stall) and must stay
      id-for-id equal to the oracle claiming with only the surviving
      global workers.
    * **Promote.** ``router.promote_shard`` elects the replica, drains the
      surviving log tail (``promote_log_lag`` records how many
      unsynced records the WAL drain recovered — the replica was BEHIND),
      requeues the dead primary's RUNNING rows, re-arms a fresh replicator
      and promotes the shadow supervisor (generation bump). The oracle
      mirrors only the status flip, so every later claim round and the
      final merged Q1-Q7 sweep must stay bit-identical.

    A sharded checkpoint is cut BEFORE the first kill and another AFTER
    the first promote; both must restore (``Checkpointer.restore`` ->
    ``ShardRouter.from_checkpoint``) at exactly their persisted version
    vectors with bit-identical merged sweeps, and the restored router must
    serve claims — the ``shards > 1`` checkpoint exclusion is gone.

    Hard verdicts returned (``exp_shard_failover`` raises on any False):
    conservation of the live task-id set across both failovers, full
    drain, claim parity, final + checkpoint sweep parity, re-armed replica
    column parity, supervisor generations. ``failover_wall_s`` (first kill
    -> drain) is gated by ``--max-shard-failover-s`` in
    ``scripts/bench_trajectory.py``.
    """
    import shutil
    import tempfile

    from repro.checkpoint.checkpointer import Checkpointer
    from repro.core.sharding_router import ShardRouter

    S, L = num_shards, workers_per_shard
    W = S * L
    cap = max(1 << 14, 8 * num_tasks)
    router = ShardRouter(S, L, capacity=cap, replicate="delta",
                         sync_every=sync_every)
    router.attach_supervision(
        WorkflowConfig(name="failover-drill", activities=("a0",)))
    oracle = WorkQueue(num_workers=W, capacity=cap)
    osteer = SteeringEngine(oracle)

    def dom_in(ids: np.ndarray) -> np.ndarray:
        h = (ids * 2654435761) % (1 << 10)
        return np.stack([(h % 977) / 976.0, ((h * 3) % 911) / 910.0,
                         ((h * 7) % 1013) / 1012.0], 1)

    def dom_out(ids: np.ndarray) -> np.ndarray:
        return np.stack([(ids % 7) / 8.0, (ids % 5) / 4.0,
                         (ids % 3) / 2.0], 1)

    # enough backlog that BOTH kill/promote windows happen mid-claim-storm
    per_act = max(num_tasks // activities, 16 * W)
    prev = None
    for a in range(activities):
        ids = np.arange(a * per_act, (a + 1) * per_act, dtype=np.int64)
        kw = dict(domain_in=dom_in(ids), duration_est=1.0, now=0.0)
        if prev is not None:
            kw["parent_task"] = prev              # provenance chain for Q7
        rid = router.add_tasks(a, per_act, **kw)
        oid = oracle.add_tasks(a, per_act, **kw)
        assert np.array_equal(rid, ids) and np.array_equal(oid, ids)
        prev = ids
    total = activities * per_act
    ids_all = np.arange(total, dtype=np.int64)

    def shard_rows(ids: np.ndarray) -> List[Tuple[int, np.ndarray]]:
        # valid throughout: this drill never steals, and a promoted store
        # replays the primary's log, so per-shard row order is preserved
        out = []
        owner = router.shard_of(ids)
        for s in range(S):
            m = owner == s
            if not m.any():
                continue
            tid = router.shards[s].wq.store.col("task_id")
            pos = np.searchsorted(tid, ids[m])
            assert np.array_equal(tid[pos], ids[m])
            out.append((s, pos))
        return out

    # schedule (round -> event); each kill strands that shard's claims of
    # the SAME round — the workers die holding them — and is promoted
    # after a multi-round dead window
    CKPT1, KILL1, PROM1, CKPT2, KILL2, PROM2 = 3, 5, 8, 10, 12, 15
    kills = [(KILL1, 0, PROM1), (KILL2, 1, PROM2)]
    ckpt_root = tempfile.mkdtemp(prefix="shard_failover_ckpt_")
    ckpt = Checkpointer(ckpt_root, keep=3, async_write=True)
    vecs: Dict[int, List[int]] = {}
    fps: Dict[int, str] = {}
    ck_clock: Dict[int, float] = {}

    clock = 1.0
    rounds = 0
    claim_parity = True
    conserved = True
    survivor_min: Optional[int] = None
    survivor_min_rate: Optional[float] = None
    promote_s: List[float] = []
    promote_lag = 0
    t_kill1 = 0.0
    while rounds < 400:
        dead = [s for s in range(S) if not router.shards[s].alive]
        t0 = time.perf_counter()
        rc = router.claim_all(k=2, now=clock, steal=False)
        claim_dt = time.perf_counter() - t0
        r_ids = {g: np.sort(router.shards[s].wq.store.col("task_id")[rows])
                 for g, (s, rows) in rc.items() if len(rows)}
        if dead:
            # oracle mirror of the dead window: only the surviving global
            # workers claim (per-worker, own partition — same id choice as
            # claim_all(steal=False))
            o_ids = {}
            for g in range(W):
                if g // L in dead:
                    continue
                rows = oracle.claim(g, k=2, now=clock, allow_steal=False)
                if len(rows):
                    o_ids[g] = np.sort(oracle.store.col("task_id")[rows])
            n_sur = int(sum(len(v) for v in o_ids.values()))
            survivor_min = n_sur if survivor_min is None \
                else min(survivor_min, n_sur)
            rate = n_sur / max(claim_dt, 1e-9)
            survivor_min_rate = rate if survivor_min_rate is None \
                else min(survivor_min_rate, rate)
        else:
            oc = oracle.claim_all(k=2, now=clock, steal=False)
            o_ids = {g: np.sort(oracle.store.col("task_id")[rows])
                     for g, rows in oc.items() if len(rows)}
        claim_parity &= set(r_ids) == set(o_ids) and all(
            np.array_equal(r_ids[g], o_ids[g]) for g in r_ids)
        if not o_ids and rounds > PROM2:
            break

        kill_here = next((ks for kr, ks, _ in kills if kr == rounds), None)
        if kill_here is not None:
            # this round's claims on the doomed shard die WITH it: they
            # stay RUNNING in the (frozen) store until promote requeues them
            strand = np.concatenate(
                [v for g, v in o_ids.items() if g // L == kill_here]
                or [np.empty(0, np.int64)])
            router.fail_shard(kill_here)
            if kill_here == 0:
                t_kill1 = time.perf_counter()
        else:
            strand = np.empty(0, np.int64)
        done_ids = np.sort(np.concatenate(list(o_ids.values()))) \
            if o_ids else np.empty(0, np.int64)
        work = np.setdiff1d(done_ids, strand)
        fail_ids = work[::7] if (not dead and kill_here is None
                                 and rounds % 3 == 2) else work[:0]
        fin = np.setdiff1d(work, fail_ids)
        fa, fb = fin[fin % 2 == 0], fin[fin % 2 == 1]
        if len(fail_ids):
            oracle.fail(fail_ids, now=clock + 0.25)
            for s, pos in shard_rows(fail_ids):
                router.shards[s].wq.fail(pos, now=clock + 0.25)
        for ids_, dt in ((fa, 1.0), (fb, 1.5)):
            if not len(ids_):
                continue
            oracle.finish(ids_, now=clock + dt, domain_out=dom_out(ids_))
            for s, pos in shard_rows(ids_):
                tid = router.shards[s].wq.store.col("task_id")[pos]
                router.shards[s].wq.finish(pos, now=clock + dt,
                                           domain_out=dom_out(tid))

        prom = next(((ks, pr) for kr, ks, pr in kills if pr == rounds),
                    None)
        if prom is not None:
            ks = prom[0]
            promote_lag += int(router.shards[ks].replicator.lag())
            t0 = time.perf_counter()
            router.promote_shard(ks)
            promote_s.append(time.perf_counter() - t0)
            # oracle mirror: recover() ONLY flips the dead primary's
            # in-flight RUNNING rows back to READY (no trials bump, no
            # time stamps) — every other column already matches
            tid = oracle.store.col("task_id")
            st = oracle.store.col("status")
            rows = np.nonzero((st == int(Status.RUNNING))
                              & (((tid % W) // L) == ks))[0]
            if len(rows):
                oracle.store.update(rows, status=int(Status.READY))
                oracle.invalidate_cursors(rows)
            conserved &= bool(
                np.array_equal(ids_all, router.live_task_ids()))

        if rounds in (CKPT1, CKPT2):
            step = 1 if rounds == CKPT1 else 2
            vecs[step] = [int(v) for v in router.version_vector()]
            fps[step] = _sweep_fingerprint(ShardRouter.comparable(
                router.run_all(clock, views=router.snapshot_vector())))
            ck_clock[step] = clock
            ckpt.save(step, {"w": np.full(8, float(step), np.float32)},
                      router=router)
            ckpt.wait()

        router.sync_secondaries()
        for sh in router.shards:
            if sh.alive and sh.replicator is not None:
                sh.replicator.maybe_sync()
        router.compact()
        clock += 2.0
        rounds += 1
    failover_wall_s = time.perf_counter() - t_kill1

    conserved &= bool(np.array_equal(ids_all, router.live_task_ids()))
    o_open = int(np.isin(oracle.store.col("status"),
                         [int(Status.READY), int(Status.RUNNING),
                          int(Status.BLOCKED)]).sum())
    drained = router.tasks_left() == 0 and o_open == 0

    views = router.snapshot_vector()
    oview = oracle.store.snapshot_view()
    merged = ShardRouter.comparable(router.run_all(clock, views=views))
    onorm = ShardRouter.oracle_normalize(
        osteer.run_all(clock, view=oview), oview)
    sweep_equal = _sweep_fingerprint(merged) == _sweep_fingerprint(onorm)

    # the RE-ARMED replicators (fresh after each promote) still replay to
    # bit-parity at the pinned vector
    replica_cols_equal = True
    for s, sh in enumerate(router.shards):
        sh.replicator.sync(upto_version=views[s].version)
        replica_cols_equal &= all(
            np.array_equal(views[s].col(n), sh.replicator.store.col(n),
                           equal_nan=True)
            for n in sh.wq.store.cols)

    gens = [int(sh.supervisor.state.generation) for sh in router.shards]
    supervision_ok = (all(sh.supervisor.done() for sh in router.shards)
                      and gens[0] >= 1 and gens[1] >= 1)

    router.check_invariants()
    oracle.check_invariants()

    # restore the LATEST checkpoint (cut after the first promote): the
    # rebuilt router resumes at exactly the persisted version vector,
    # sweeps bit-identically, and serves claims again
    tmpl = {"w": np.zeros(8, np.float32)}
    step2, st2, r2 = ckpt.restore(tmpl, router_kw={"replicate": None})
    ck_vector_ok = (step2 == 2
                    and [int(v) for v in r2.version_vector()] == vecs[2])
    ck_sweep_ok = _sweep_fingerprint(ShardRouter.comparable(
        r2.run_all(ck_clock[2], views=r2.snapshot_vector()))) == fps[2]
    ck_state_ok = bool(np.array_equal(
        st2["w"], np.full(8, 2.0, np.float32)))
    got = r2.claim_all(k=1, now=ck_clock[2] + 1.0)
    ck_resumed_claims = int(sum(len(rows) for _, rows in got.values()))
    r2.close()
    # the pre-kill cut stays independently restorable (historical step)
    step1, _, r1 = ckpt.restore(tmpl, step=1,
                                router_kw={"replicate": None})
    ck_pre_ok = ([int(v) for v in r1.version_vector()] == vecs[1]
                 and _sweep_fingerprint(ShardRouter.comparable(
                     r1.run_all(ck_clock[1],
                                views=r1.snapshot_vector()))) == fps[1])
    r1.close()
    router.close()
    shutil.rmtree(ckpt_root, ignore_errors=True)

    return {
        "shards": S, "workers_per_shard": L, "global_workers": W,
        "tasks": int(total), "rounds": int(rounds),
        "kills": [ks for _, ks, _ in kills],
        "claim_parity": bool(claim_parity),
        "survivor_min_claims": int(survivor_min or 0),
        "survivor_min_claims_per_s": round(float(survivor_min_rate or 0.0),
                                           1),
        "promotes": len(promote_s),
        "promote_s_max": round(max(promote_s), 4) if promote_s else 0.0,
        "promote_log_lag": int(promote_lag),
        "failover_wall_s": round(failover_wall_s, 4),
        "conserved": bool(conserved),
        "drained": bool(drained),
        "sweep_equal": bool(sweep_equal),
        "replica_cols_equal": bool(replica_cols_equal),
        "supervisor_generations": gens,
        "supervision_ok": bool(supervision_ok),
        "ckpt_vector_match": bool(ck_vector_ok),
        "ckpt_sweep_equal": bool(ck_sweep_ok),
        "ckpt_pre_kill_sweep_equal": bool(ck_pre_ok),
        "ckpt_state_equal": bool(ck_state_ok),
        "ckpt_resumed_claims": int(ck_resumed_claims),
        "version_vector": [int(v.version) for v in views],
        "finished": int(sum(int(sh.wq.counts()["FINISHED"])
                            for sh in router.shards)),
    }


def run_centralized(num_workers: int, threads: int, num_tasks: int,
                    mean_dur_s: float, *, seed: int = 0,
                    request_overhead_s: float = 0.0) -> SimResult:
    """Chiron-style run: ONE master serializes every claim over one queue.

    The master is a serial resource: claim/finish requests queue behind each
    other (the paper's Fig. 6-B bottleneck). Simulated time accounts for the
    serialized master occupancy; op costs are measured on the real store.
    """
    rng = np.random.default_rng(seed)
    master = CentralizedMaster(capacity=max(1 << 16, 2 * num_tasks))
    master.add_tasks(0, num_tasks)
    clock = 0.0
    master_free_at = 0.0
    events: List[Tuple[float, int, int]] = []
    free_threads = {w: threads for w in range(num_workers)}
    done = 0
    op_time: Dict[str, float] = {}
    op_count: Dict[str, int] = {}

    def master_op(kind: str, fn) -> Tuple[float, object]:
        """Serialize through the master; returns (completion_time, result)."""
        nonlocal master_free_at
        t0 = time.perf_counter()
        out = fn()
        # request_overhead_s models Chiron's per-request cost: MPI round trip
        # + centralized PostgreSQL transaction (paper Fig. 6-B), serialized
        # at the single master
        dt = time.perf_counter() - t0 + request_overhead_s
        op_time[kind] = op_time.get(kind, 0.0) + dt
        op_count[kind] = op_count.get(kind, 0) + 1
        start = max(clock, master_free_at)
        master_free_at = start + dt
        return master_free_at, out

    def try_fill(w: int):
        while free_threads[w] > 0:
            t_done, rows = master_op("master.claim",
                                     lambda: master.claim(w, 1, now=clock))
            if len(rows) == 0:
                return
            dur = rng.exponential(mean_dur_s)
            heapq.heappush(events, (t_done + dur, w, int(rows[0])))
            free_threads[w] -= 1

    for w in range(num_workers):
        try_fill(w)
    while events:
        clock, w, row = heapq.heappop(events)
        master_op("master.finish",
                  lambda: master.finish(np.asarray([row]), now=clock))
        free_threads[w] += 1
        done += 1
        try_fill(w)

    return SimResult(
        makespan_s=max(clock, master_free_at),
        dbms_time_s=master.busy_s,
        dbms_total_s=master.busy_s,
        op_time=op_time, op_count=op_count, tasks_done=done,
        messages=master.total_messages)
