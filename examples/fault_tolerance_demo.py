"""Fault-tolerance drill: worker death, supervisor failover, checkpoint
resume, straggler cloning — the paper's availability story end to end.

    PYTHONPATH=src python examples/fault_tolerance_demo.py
"""
import sys
import tempfile

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import smoke_config
from repro.data.pipeline import DataConfig
from repro.runtime.executor import TrainExecutor
from repro.runtime.fault import HeartbeatMonitor


def main():
    cfg = smoke_config("qwen2-0.5b")
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, async_write=False)
        ex = TrainExecutor(cfg, num_workers=3, checkpointer=ck,
                           checkpoint_every=6,
                           data_cfg=DataConfig(vocab_size=cfg.vocab_size,
                                               seq_len=32, batch_size=4))
        mon = HeartbeatMonitor(ex.wq, timeout_s=5.0, now=0.0)
        ex.submit_steps(18)
        print("18 tasks, 3 workers, checkpoint every 6 steps")

        for i in range(4):
            ex.tick()
        print(f"[t=4] progress: {ex.wq.counts()['FINISHED']} finished")

        n = ex.fail_worker(1)
        print(f"[t=4] WORKER 1 DIES -> {n} RUNNING tasks requeued+rehashed")
        ex.promote_secondary()
        print("[t=4] SUPERVISOR DIES -> secondary promoted "
              f"(generation {ex.supervisor.state.generation})")

        ex.run()
        ck.save(ex.step, ex.state, ex.wq)
        print(f"[done] finished={ex.wq.counts()['FINISHED']}; "
              f"fail_trials recorded: "
              f"{int(ex.wq.store.col('fail_trials').sum())}")

        # crash-restart: restore from the atomic checkpoint
        step, state, wq = ck.restore(jax.device_get(ex.state))
        print(f"[restart] restored step {step}, store rows {wq.store.n_rows},"
              f" counts {wq.counts()}")
        assert wq.counts()["FINISHED"] == 18


if __name__ == "__main__":
    main()
