"""Serving: the work queue drives continuous batching.

Requests are WQ rows (the paper's tasks); decode slots claim requests from
their partitions as slots free up, token-by-token progress and outputs are
committed back to the store, and the steering engine provides live SLO
analytics over the same data.

    PYTHONPATH=src python examples/serve_continuous_batching.py
"""
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.configs import smoke_config
from repro.runtime.executor import ServeExecutor


def main():
    cfg = smoke_config("qwen2-0.5b")
    ex = ServeExecutor(cfg, slots=3, max_len=64)
    rng = np.random.default_rng(0)

    # three waves of requests with different generation budgets
    waves = [(6, 4), (4, 8), (5, 6)]
    t0 = time.time()
    all_ids = []
    for i, (n, max_new) in enumerate(waves):
        prompts = rng.integers(0, cfg.vocab_size, (n, 8)).astype(np.int32)
        ids = ex.submit(prompts, max_new=max_new)
        all_ids.extend(int(t) for t in ids)
        print(f"wave {i}: submitted {n} requests (max_new={max_new}); "
              f"queue depth: {ex.wq.counts()['READY']}")
        for _ in range(4):
            ex.step_decode()
    ex.drain()
    dt = time.time() - t0

    fin = ex.wq.counts()["FINISHED"]
    toks = sum(len(ex.wq.store.blobs[t].get("output", []))
               for t in all_ids)
    print(f"\nserved {fin} requests / {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s on CPU)")
    st = ex.wq.store
    lat = st.col("end_time")[:fin] - st.col("submit_time")[:fin]
    print(f"latency p50/p95: {np.percentile(lat,50):.2f}/"
          f"{np.percentile(lat,95):.2f}s  (from the store's exec columns)")


if __name__ == "__main__":
    main()
