"""The paper's use case, ML-shaped: a hyperparameter sweep the user STEERS.

Risers-analogue: instead of environmental-condition parameters, the sweep
members carry learning-rate scales. Mid-run the user runs a Q7-style
analysis ("which members' losses are diverging?") and a Q8-style adaptation
(prune the diverging members' remaining tasks — the paper's data reduction),
so compute is reallocated to promising members.

    PYTHONPATH=src python examples/parameter_sweep_steering.py
"""
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.configs import smoke_config
from repro.data.pipeline import DataConfig
from repro.runtime.executor import TrainExecutor


def main():
    cfg = smoke_config("qwen2-0.5b")
    ex = TrainExecutor(
        cfg, num_workers=4, base_lr=1e-3,
        data_cfg=DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                            batch_size=8))
    # 4 sweep members x 16 steps; member 3 has a divergently large lr
    sweep = {0: 1.0, 1: 2.0, 2: 4.0, 3: 64.0}
    for sid, scale in sweep.items():
        ex.submit_steps(16, lr_scale=scale, sweep_id=sid)
    print("sweep: 4 members x 16 steps; member 3 lr_scale=64 (diverges)")

    pruned = 0
    while ex.steering.q4_tasks_left() > 0:
        m = ex.tick()
        # --- user steering moment: after 12 ticks, inspect per-member loss
        if m and m.get("step") == 12 * 1:
            store = ex.wq.store
            fin = store.col("status") == 4
            losses = {}
            for sid in sweep:
                mask = fin & (store.col("in2") == sid)
                if mask.any():
                    losses[sid] = float(np.nanmean(store.col("out0")[mask]))
            print(f"\n[steering] Q7-style per-member mean loss: "
                  f"{ {k: round(v,3) for k,v in losses.items()} }")
            worst = max(losses, key=losses.get)
            pruned = ex.steering.prune("in0", sweep[worst] - 0.5,
                                       sweep[worst] + 0.5)
            print(f"[steering] Q8: pruned {pruned} remaining tasks of "
                  f"member {worst} (lr_scale={sweep[worst]})\n")
    c = ex.wq.counts()
    print(f"finished={c['FINISHED']} pruned={c['PRUNED']} "
          f"(compute saved: {c['PRUNED']}/64 tasks)")
    # provenance export
    from repro.core.provenance import prov_document
    doc = prov_document(ex.wq)
    print(f"provenance: {len(doc['activity'])} activities, "
          f"{len(doc['used'])} usage edges, W3C PROV-shaped")


if __name__ == "__main__":
    main()
