"""Quickstart: WQ-driven training of a small LM with live steering queries.

The SchalaDB work queue schedules training tasks across (simulated) workers,
captures provenance (loss / grad-norm / timing) into the same store, and the
steering engine answers the paper's Q1/Q4/Q5-style queries WHILE training.

    PYTHONPATH=src python examples/quickstart.py [--steps 60]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.configs import smoke_config
from repro.data.pipeline import DataConfig
from repro.runtime.executor import TrainExecutor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--workers", type=int, default=4)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    ex = TrainExecutor(
        cfg, num_workers=args.workers, base_lr=3e-3,
        data_cfg=DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                            batch_size=8))
    ex.submit_steps(args.steps)
    print(f"workflow: {args.steps} train tasks over {args.workers} workers "
          f"(partitioned work queue)")

    t0 = time.time()
    while ex.steering.q4_tasks_left() > 0:
        m = ex.tick()
        if m and m["step"] % 10 == 0:
            q1 = ex.steering.q1_recent_status_by_node(time.time())
            done = sum(v["finished"] for v in q1.values())
            print(f"step {m['step']:4d} loss {m['loss']:.4f} "
                  f"grad {m['grad_norm']:.3f} | Q4 left: "
                  f"{ex.steering.q4_tasks_left():3d} | Q1 finished/node: "
                  f"{ {k: v['finished'] for k, v in q1.items()} }")
    hist = ex.history
    print(f"\ndone in {time.time()-t0:.1f}s; "
          f"loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")
    mon = ex.steering.device_monitor()
    print(f"on-device monitor (HTAP mirror): {mon}")


if __name__ == "__main__":
    main()
