"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracle across
shape/dtype sweeps (+ hypothesis property tests for wq_claim)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.kernels.wq_claim.ops import wq_claim
from repro.kernels.wq_claim.ref import wq_claim_ref


@pytest.mark.parametrize("b,s,hq,hkv,dh,causal,window,dtype", [
    (1, 512, 4, 2, 64, True, 0, jnp.float32),
    (2, 256, 4, 4, 128, False, 0, jnp.float32),
    (1, 512, 2, 1, 112, True, 128, jnp.float32),   # pad 112->128 + window
    (1, 256, 4, 2, 64, True, 0, jnp.bfloat16),
])
def test_flash_attention_vs_ref(b, s, hq, hkv, dh, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, hq, dh), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, dh), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, dh), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    assert float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                 - ref.astype(jnp.float32)))) < tol


@pytest.mark.parametrize("b,smax,hq,hkv,dh,kvlen", [
    (2, 1024, 4, 2, 64, 700),
    (1, 2048, 8, 1, 128, 2048),
    (2, 1024, 4, 4, 112, 513),
])
def test_decode_attention_vs_ref(b, smax, hq, hkv, dh, kvlen):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, 1, hq, dh))
    k = jax.random.normal(ks[1], (b, smax, hkv, dh))
    v = jax.random.normal(ks[2], (b, smax, hkv, dh))
    got = decode_attention(q, k, v, kv_len=kvlen, interpret=True)
    ref = decode_attention_ref(q, k, v, kvlen)
    assert float(jnp.max(jnp.abs(got - ref))) < 2e-5


@pytest.mark.parametrize("bh,s,p,n,chunk", [
    (4, 128, 64, 32, 32), (2, 256, 64, 128, 64), (1, 64, 128, 16, 64),
])
def test_ssd_scan_vs_sequential_ref(bh, s, p, n, chunk):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (bh, s, p))
    b = jax.random.normal(ks[1], (bh, s, n)) * 0.5
    c = jax.random.normal(ks[2], (bh, s, n)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (bh, s, 1)))
    a = -jnp.exp(jax.random.normal(ks[4], (bh, 1, 1)) * 0.3)
    got = ssd_scan(x, b, c, dt, dt * a, chunk=chunk, interpret=True)
    ref = ssd_scan_ref(x, b, c, dt, dt * a)
    rel = float(jnp.max(jnp.abs(got - ref)) / jnp.max(jnp.abs(ref)))
    assert rel < 1e-4


@pytest.mark.parametrize("b,s,c", [(2, 64, 128), (1, 256, 512)])
def test_rglru_scan_vs_ref(b, s, c):
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (b, s, c))) * 0.95
    u = jax.random.normal(ks[1], (b, s, c)) * 0.3
    got = rglru_scan(a, u, interpret=True)
    ref = rglru_scan_ref(a, u)
    assert float(jnp.max(jnp.abs(got - ref))) < 1e-4


@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([512, 1000, 2048]), w=st.integers(1, 16),
       k=st.integers(1, 4), seed=st.integers(0, 5))
def test_property_wq_claim_kernel(n, w, k, seed):
    """Kernel == oracle; nobody over-claims; claims are partition-private."""
    rng = np.random.default_rng(seed)
    status = jnp.asarray(rng.choice(
        [0, 2, 3, 4], n, p=[.1, .5, .2, .2]).astype(np.int32))
    worker = jnp.asarray(rng.integers(0, w, n).astype(np.int32))
    gs, gc = wq_claim(status, worker, num_workers=w, k=k, interpret=True)
    rs, rc = wq_claim_ref(status, worker, num_workers=w, k=k)
    assert (np.asarray(gs) == np.asarray(rs)).all()
    assert (np.asarray(gc) == np.asarray(rc)).all()
    claimed = np.asarray(gc) == 1
    per_w = np.bincount(np.asarray(worker)[claimed], minlength=w)
    assert per_w.max(initial=0) <= k
    # claimed rows were READY and are now RUNNING; others untouched
    st_old, st_new = np.asarray(status), np.asarray(gs)
    assert (st_old[claimed] == 2).all()
    assert (st_new[claimed] == 3).all()
    assert (st_new[~claimed] == st_old[~claimed]).all()
