import os
import sys

# Smoke tests and benches must see the REAL single device (the dry-run sets
# its own 512-device flag in its own process) — so no XLA_FLAGS here; the
# 8-device SPMD test sets the flag in its own subprocess.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Property tests use hypothesis when available (CI installs the dev extra);
# hermetic containers without it fall back to the deterministic stub so all
# test modules still collect and run.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub
    _hypothesis_stub.install()
