import os
import sys

# Smoke tests and benches must see the REAL single device (the dry-run sets
# its own 512-device flag in its own process) — so no XLA_FLAGS here.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
