"""REQUIRED per-arch smoke tests: reduced same-family configs, one forward +
one train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, smoke_config
from repro.launch.steps import init_train_state, make_train_step
from repro.models import build_model

B, S = 2, 32


def _batch(cfg, rng):
    if cfg.family == "encdec":
        return {"frames": jax.random.normal(rng, (B, 24, cfg.d_model)) * 0.1,
                "tokens": jnp.ones((B, 16), jnp.int32),
                "labels": jnp.ones((B, 16), jnp.int32)}
    batch = {"labels": jnp.ones((B, S), jnp.int32)}
    if cfg.embed_stub:
        batch["embeds"] = jax.random.normal(rng, (B, S, cfg.d_model)) * 0.1
    else:
        batch["tokens"] = jnp.ones((B, S), jnp.int32)
    if cfg.mrope:
        batch["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    # forward
    loss, metrics = jax.jit(model.train_loss)(model.init(rng), batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), (arch, loss)

    # one full train step (grads + optimizer)
    state = init_train_state(cfg, rng)
    step = jax.jit(make_train_step(cfg))
    state2, m = step(state, batch, {"lr": jnp.float32(1e-3)})
    assert bool(jnp.isfinite(m["loss"]))
    assert bool(jnp.isfinite(m["grad_norm"])) and float(m["grad_norm"]) > 0
    # params actually changed
    delta = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(state["params"]),
                                jax.tree.leaves(state2["params"])))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    batch.pop("labels")
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, S + 8))(
        params, batch)
    assert logits.shape[-1] == cfg.vocab_size
    assert bool(jnp.isfinite(logits).all())
    nxt = jnp.argmax(logits[:, -1], -1)[:, None]
    logits2, cache2 = jax.jit(model.decode_step)(params, nxt, cache)
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits2).all())
    assert int(cache2["idx"]) == int(cache["idx"]) + 1
