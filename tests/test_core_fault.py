"""Fault tolerance: replication recovery, heartbeats, stragglers, elastic."""
import numpy as np

from repro.core import Status, WorkQueue
from repro.core.replication import ReplicaSet
from repro.core.transactions import TxnLog
from repro.runtime.elastic import ElasticController, ElasticPolicy
from repro.runtime.fault import FailureInjector, HeartbeatMonitor
from repro.runtime.straggler import SpeculativeReexec


def test_replica_recovery_returns_running_to_ready():
    wq = WorkQueue(num_workers=2)
    wq.add_tasks(0, 8)
    rep = ReplicaSet(wq, sync_every=1)
    rows = wq.claim(0, k=2)
    rep.sync()
    wq2 = rep.recover()
    st = wq2.store.col("status")
    assert (st != int(Status.RUNNING)).all()
    assert wq2.counts()["READY"] == 8       # claimed tasks restored to READY
    # new inserts get fresh ids
    ids = wq2.add_tasks(0, 2)
    assert ids.min() >= 8


def test_txn_log_records_everything():
    wq = WorkQueue(num_workers=2)
    wq.add_tasks(0, 4)
    wq.claim_all(k=1)
    rows = np.nonzero(wq.store.col("status") == int(Status.RUNNING))[0]
    wq.finish(rows, now=1.0)
    ops = [t.op for t in wq.log.records]
    assert ops == ["insert", "claim_all", "finish"]


def test_heartbeat_monitor_requeues_dead_worker():
    wq = WorkQueue(num_workers=3)
    wq.add_tasks(0, 9)
    wq.claim(1, k=3, now=0.0)
    mon = HeartbeatMonitor(wq, timeout_s=10.0, now=0.0)
    mon.beat(0, now=100.0)
    mon.beat(2, now=100.0)
    dead = mon.sweep(now=100.0)
    assert dead == [1]
    assert wq.counts()["RUNNING"] == 0
    assert (wq.store.col("worker_id")[:9] != 1).sum() == 9


def test_speculative_reexec_clones_and_reconciles():
    wq = WorkQueue(num_workers=2)
    wq.add_tasks(0, 10)
    spec = SpeculativeReexec(wq, percentile=50, min_samples=5, factor=1.5)
    # finish a population fast (duration 1s)
    for t in range(5):
        rows = wq.claim(0, k=1, now=float(t))
        wq.finish(rows, now=float(t) + 1.0)
    # one slow straggler — swept while its claim lease is still live
    # (PR 8: an alive-but-slow worker speculates; an EXPIRED lease is the
    # reaper's to requeue, covered by test_straggler_skips_expired_leases)
    slow = wq.claim(1, k=1, now=10.0)
    clones = spec.sweep(now=12.0)
    assert len(clones) == 1
    # straggler eventually finishes; clone gets pruned
    wq.finish(slow, now=101.0)
    assert spec.reconcile() == 1
    assert wq.counts()["PRUNED"] == 1


def test_elastic_controller_grows_with_backlog():
    wq = WorkQueue(num_workers=2)
    wq.add_tasks(0, 64)
    ctl = ElasticController(wq, ElasticPolicy(target_tasks_per_worker=8))
    new = ctl.maybe_resize()
    assert new == 8
    assert wq.num_workers == 8
    wq.check_invariants()


def test_failure_injector_schedule():
    inj = FailureInjector().kill_worker_at(3, 1).crash_supervisor_at(5)
    assert inj.events_at(3) == [(3, "worker", 1)]
    assert inj.events_at(5) == [(5, "supervisor", None)]
    assert inj.events_at(4) == []


def test_straggler_skips_expired_leases():
    """An EXPIRED claim lease is the reaper's to requeue — the speculative
    sweeper must not also clone it (double-recovery would race a clone
    against the reaped original)."""
    wq = WorkQueue(num_workers=2, lease_s=5.0)
    wq.add_tasks(0, 10)
    spec = SpeculativeReexec(wq, percentile=50, min_samples=5, factor=1.5)
    for t in range(5):
        rows = wq.claim(0, k=1, now=float(t))
        wq.finish(rows, now=float(t) + 1.0)
    slow = wq.claim(1, k=1, now=10.0)          # lease expires at t=15
    assert spec.sweep(now=20.0) == []          # expired: not a straggler
    assert wq.reap_expired(now=20.0) == 1      # it is the reaper's row
    assert wq.store.col("status")[slow[0]] == int(Status.READY)


def test_heartbeat_monitor_survives_resize():
    """Regression (PR 8 satellite): after ``WorkQueue.resize`` the monitor
    must drop beats of removed workers (a stale entry would re-declare a
    ghost dead on every sweep) and seed added workers at sweep time (a
    missing entry would either KeyError or insta-kill them)."""
    wq = WorkQueue(num_workers=3)
    wq.add_tasks(0, 9)
    mon = HeartbeatMonitor(wq, timeout_s=10.0, now=0.0)
    wq.resize(2)                               # shrink: worker 2 is gone
    mon.beat(0, now=100.0)
    mon.beat(1, now=100.0)
    assert mon.sweep(now=100.0) == []          # ghost worker 2 not swept
    assert set(mon.beats) == {0, 1}
    wq.resize(4)                               # grow: workers 2, 3 are new
    assert mon.sweep(now=105.0) == []          # seeded at now, not dead
    assert set(mon.beats) == {0, 1, 2, 3}
    # new workers then get the full timeout before being declared dead
    mon.beat(0, now=116.0)
    mon.beat(1, now=116.0)
    assert sorted(mon.sweep(now=116.0)) == [2, 3]
    wq.check_invariants()


def test_elastic_hysteresis_holds_small_drift():
    wq = WorkQueue(num_workers=4)
    wq.add_tasks(0, 40)                        # want = 40/8 = 5 vs cur 4
    ctl = ElasticController(wq, ElasticPolicy(target_tasks_per_worker=8,
                                              hysteresis=0.5))
    assert ctl.desired_workers() == 5
    assert ctl.maybe_resize() is None          # |5-4|/4 < 0.5: hold
    assert wq.num_workers == 4


def test_elastic_clamps_to_min_and_max():
    wq = WorkQueue(num_workers=4)
    pol = ElasticPolicy(target_tasks_per_worker=2, min_workers=2,
                        max_workers=6)
    ctl = ElasticController(wq, pol)
    assert ctl.desired_workers() == 2          # empty queue: floor, not 0
    wq.add_tasks(0, 100)                       # want = 50, ceiling is 6
    assert ctl.desired_workers() == 6
    assert ctl.maybe_resize() == 6
    assert wq.num_workers == 6


def test_elastic_counts_blocked_backlog():
    """All-BLOCKED backlog (upstream deps unresolved) is still pending work
    the pool will face — the controller must scale for it."""
    wq = WorkQueue(num_workers=1)
    wq.add_tasks(0, 32, status=Status.BLOCKED)
    ctl = ElasticController(wq, ElasticPolicy(target_tasks_per_worker=8))
    assert ctl.last_signals is None
    assert ctl.desired_workers() == 4
    assert ctl.last_signals["pending"] == 32.0
    assert ctl.maybe_resize() == 4


def test_elastic_staleness_escalation_bypasses_hysteresis():
    """Count-based target says hold, but the backlog is STALE (oldest
    pending older than max_backlog_age_s): escalate past the hysteresis
    band and grow by escalation_factor."""
    wq = WorkQueue(num_workers=4)
    wq.add_tasks(0, 32, now=0.0)               # want = 32/8 = 4 == cur
    pol = ElasticPolicy(target_tasks_per_worker=8, max_backlog_age_s=5.0,
                        escalation_factor=2.0)
    ctl = ElasticController(wq, pol)
    assert ctl.maybe_resize() is None          # no clock: pure count, hold
    assert ctl.maybe_resize(now=2.0) is None   # backlog still fresh
    assert ctl.maybe_resize(now=10.0) == 8     # stale: 4 * 2.0
    assert wq.num_workers == 8
    assert ctl.last_signals["backlog_age_s"] == 10.0
