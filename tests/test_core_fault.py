"""Fault tolerance: replication recovery, heartbeats, stragglers, elastic."""
import numpy as np

from repro.core import Status, WorkQueue
from repro.core.replication import ReplicaSet
from repro.core.transactions import TxnLog
from repro.runtime.elastic import ElasticController, ElasticPolicy
from repro.runtime.fault import FailureInjector, HeartbeatMonitor
from repro.runtime.straggler import SpeculativeReexec


def test_replica_recovery_returns_running_to_ready():
    wq = WorkQueue(num_workers=2)
    wq.add_tasks(0, 8)
    rep = ReplicaSet(wq, sync_every=1)
    rows = wq.claim(0, k=2)
    rep.sync()
    wq2 = rep.recover()
    st = wq2.store.col("status")
    assert (st != int(Status.RUNNING)).all()
    assert wq2.counts()["READY"] == 8       # claimed tasks restored to READY
    # new inserts get fresh ids
    ids = wq2.add_tasks(0, 2)
    assert ids.min() >= 8


def test_txn_log_records_everything():
    wq = WorkQueue(num_workers=2)
    wq.add_tasks(0, 4)
    wq.claim_all(k=1)
    rows = np.nonzero(wq.store.col("status") == int(Status.RUNNING))[0]
    wq.finish(rows, now=1.0)
    ops = [t.op for t in wq.log.records]
    assert ops == ["insert", "claim_all", "finish"]


def test_heartbeat_monitor_requeues_dead_worker():
    wq = WorkQueue(num_workers=3)
    wq.add_tasks(0, 9)
    wq.claim(1, k=3, now=0.0)
    mon = HeartbeatMonitor(wq, timeout_s=10.0, now=0.0)
    mon.beat(0, now=100.0)
    mon.beat(2, now=100.0)
    dead = mon.sweep(now=100.0)
    assert dead == [1]
    assert wq.counts()["RUNNING"] == 0
    assert (wq.store.col("worker_id")[:9] != 1).sum() == 9


def test_speculative_reexec_clones_and_reconciles():
    wq = WorkQueue(num_workers=2)
    wq.add_tasks(0, 10)
    spec = SpeculativeReexec(wq, percentile=50, min_samples=5, factor=1.5)
    # finish a population fast (duration 1s)
    for t in range(5):
        rows = wq.claim(0, k=1, now=float(t))
        wq.finish(rows, now=float(t) + 1.0)
    # one slow straggler
    slow = wq.claim(1, k=1, now=10.0)
    clones = spec.sweep(now=100.0)
    assert len(clones) == 1
    # straggler eventually finishes; clone gets pruned
    wq.finish(slow, now=101.0)
    assert spec.reconcile() == 1
    assert wq.counts()["PRUNED"] == 1


def test_elastic_controller_grows_with_backlog():
    wq = WorkQueue(num_workers=2)
    wq.add_tasks(0, 64)
    ctl = ElasticController(wq, ElasticPolicy(target_tasks_per_worker=8))
    new = ctl.maybe_resize()
    assert new == 8
    assert wq.num_workers == 8
    wq.check_invariants()


def test_failure_injector_schedule():
    inj = FailureInjector().kill_worker_at(3, 1).crash_supervisor_at(5)
    assert inj.events_at(3) == [(3, "worker", 1)]
    assert inj.events_at(5) == [(5, "supervisor", None)]
    assert inj.events_at(4) == []
