"""ShardRouter: sharded multi-primary scale-out (PR 7).

The invariants under test are the ones the paper's partitioned-ownership
design rests on: hash routing composes with per-shard partition assignment
to reproduce a single W-worker primary exactly (claims match id-for-id);
the scatter-gather Q1-Q7 sweep at a pinned version vector is bit-identical
to a single-primary oracle; cross-shard work stealing conserves the live
task-id multiset and stays invisible to per-shard replicas (it is ordinary
logged traffic); and the executor runs end-to-end through the router.
"""
import numpy as np
import pytest

from repro.core.schema import Status
from repro.core.sharding_router import ShardRouter
from repro.core.steering import SteeringEngine
from repro.core.workqueue import WorkQueue

S, L = 4, 4
W = S * L


def _fp(x):
    import json
    return json.dumps(x, sort_keys=True, default=str)


def _dom(ids):
    h = (ids * 2654435761) % (1 << 10)
    return np.stack([(h % 977) / 976.0, ((h * 3) % 911) / 910.0,
                     ((h * 7) % 1013) / 1012.0], 1)


def _dom_out(ids):
    # dyadic denominators: exact in float64, so merged sums are bit-stable
    return np.stack([(ids % 7) / 8.0, (ids % 5) / 4.0, (ids % 3) / 2.0], 1)


def _paired(n_per_act=40, activities=3, **router_kw):
    """Router + oracle loaded with the identical chained workflow."""
    r = ShardRouter(S, L, **router_kw)
    o = WorkQueue(num_workers=W)
    prev = None
    for a in range(activities):
        ids = np.arange(a * n_per_act, (a + 1) * n_per_act, dtype=np.int64)
        kw = dict(domain_in=_dom(ids), duration_est=1.0, now=0.0)
        if prev is not None:
            kw["parent_task"] = prev
        assert np.array_equal(r.add_tasks(a, n_per_act, **kw), ids)
        assert np.array_equal(o.add_tasks(a, n_per_act, **kw), ids)
        prev = ids
    return r, o


def _shard_rows(r, ids):
    """(shard, rows) for global ids — pre-steal, task_id cols ascending."""
    out = []
    owner = r.shard_of(ids)
    for s in range(S):
        m = owner == s
        if not m.any():
            continue
        tid = r.shards[s].wq.store.col("task_id")
        pos = np.searchsorted(tid, ids[m])
        assert np.array_equal(tid[pos], ids[m])
        out.append((s, pos))
    return out


def _drive_parity(r, o, rounds=8):
    """Identical deterministic claims/fails/finishes on both sides; returns
    the final clock. Asserts per-worker claim parity every round."""
    clock = 1.0
    for rnd in range(rounds):
        rc = r.claim_all(k=2, now=clock, steal=False)
        oc = o.claim_all(k=2, now=clock, steal=False)
        r_ids = {g: np.sort(r.shards[s].wq.store.col("task_id")[rows])
                 for g, (s, rows) in rc.items() if len(rows)}
        o_ids = {g: np.sort(o.store.col("task_id")[rows])
                 for g, rows in oc.items() if len(rows)}
        assert set(r_ids) == set(o_ids)
        for g in r_ids:
            assert np.array_equal(r_ids[g], o_ids[g]), (rnd, g)
        if not o_ids:
            break
        all_ids = np.sort(np.concatenate(list(o_ids.values())))
        fail_ids = all_ids[::7] if rnd % 3 == 2 else all_ids[:0]
        fin = np.setdiff1d(all_ids, fail_ids)
        fa, fb = fin[fin % 2 == 0], fin[fin % 2 == 1]
        if len(fail_ids):
            o.fail(fail_ids, now=clock + 0.25)    # oracle rows == ids
            for s, pos in _shard_rows(r, fail_ids):
                r.shards[s].wq.fail(pos, now=clock + 0.25)
        for ids_, dt in ((fa, 1.0), (fb, 1.5)):
            if not len(ids_):
                continue
            o.finish(ids_, now=clock + dt, domain_out=_dom_out(ids_))
            for s, pos in _shard_rows(r, ids_):
                tid = r.shards[s].wq.store.col("task_id")[pos]
                r.shards[s].wq.finish(pos, now=clock + dt,
                                      domain_out=_dom_out(tid))
        clock += 2.0
    return clock


# ------------------------------------------------------------- routing map
def test_shard_map_composes_to_global_partition():
    """shard (tid % W)//L + local partition tid % L == global tid % W —
    the identity every oracle-parity claim comparison rests on."""
    r = ShardRouter(S, L)
    ids = np.arange(1000, dtype=np.int64)
    shard = r.shard_of(ids)
    local = ids % L
    assert np.array_equal(r.global_worker(shard, local), ids % W)
    r.close()


def test_add_tasks_scatters_to_owning_shards():
    r = ShardRouter(S, L)
    ids = r.add_tasks(0, 100, now=0.0)
    for s, sh in enumerate(r.shards):
        tid = sh.wq.store.col("task_id")
        assert (r.shard_of(tid) == s).all()
        # local partition is the one the shard's own hash assigns
        assert np.array_equal(sh.wq.store.col("worker_id"), tid % L)
    assert np.array_equal(np.sort(r.live_task_ids()), ids)
    r.check_invariants()
    r.close()


def test_workqueue_add_tasks_explicit_ids_bumps_counter():
    wq = WorkQueue(num_workers=2)
    wq.add_tasks(0, 3, task_ids=np.array([5, 9, 21]))
    assert np.array_equal(wq.store.col("task_id"), [5, 9, 21])
    ids = wq.add_tasks(0, 2)                 # counter resumes past the max
    assert ids.tolist() == [22, 23]
    with pytest.raises(ValueError):
        wq.add_tasks(0, 3, task_ids=np.array([1, 2]))


# ------------------------------------------------------ claims + steering
def test_claim_and_scatter_gather_sweep_match_single_primary_oracle():
    r, o = _paired()
    clock = _drive_parity(r, o)
    extra = np.arange(120, 150, dtype=np.int64)  # open tasks: Q4/Q5/Q6
    kw = dict(domain_in=_dom(extra), duration_est=1.0, now=clock)
    assert np.array_equal(r.add_tasks(0, 30, **kw), extra)
    assert np.array_equal(o.add_tasks(0, 30, **kw), extra)
    views = r.snapshot_vector()
    oview = o.store.snapshot_view()
    merged = ShardRouter.comparable(r.run_all(clock, views=views))
    onorm = ShardRouter.oracle_normalize(
        SteeringEngine(o).run_all(clock, view=oview), oview)
    assert _fp(merged) == _fp(onorm)
    # the queries were actually exercised, not vacuously equal
    assert merged["q1"] and merged["q4"] > 0 and merged["q6"]
    assert merged["q7"], "Q7 provenance walk returned no hits"
    r.close()


def test_version_vector_pins_sweep_against_later_writes():
    r, o = _paired()
    clock = _drive_parity(r, o, rounds=4)
    views = r.snapshot_vector()
    before = ShardRouter.comparable(r.run_all(clock, views=views))
    r.add_tasks(0, 50, now=clock)            # mutate every shard afterwards
    r.claim_all(k=1, now=clock + 2.0)
    after = ShardRouter.comparable(r.run_all(clock, views=views))
    assert _fp(before) == _fp(after)         # pinned vector: same answers
    live = ShardRouter.comparable(r.run_all(clock))
    assert _fp(live) != _fp(before)          # fresh vector sees the writes
    r.close()


def test_q8_and_prune_stay_in_parity_per_shard():
    """Value-predicate steering writes (Q8 patch, data-reduction prune)
    select the same tasks on every shard as on the oracle."""
    r, o = _paired()
    osteer = SteeringEngine(o)
    osteer.q8_patch_ready(0, "in0", 9.5, predicate=lambda v: v > 0.8)
    osteer.prune("in1", 0.0, 0.05)
    for sh in r.shards:
        se = SteeringEngine(sh.wq)
        se.q8_patch_ready(0, "in0", 9.5, predicate=lambda v: v > 0.8)
        se.prune("in1", 0.0, 0.05)
    clock = _drive_parity(r, o, rounds=4)
    views = r.snapshot_vector()
    oview = o.store.snapshot_view()
    merged = ShardRouter.comparable(r.run_all(clock, views=views))
    onorm = ShardRouter.oracle_normalize(
        SteeringEngine(o).run_all(clock, view=oview), oview)
    assert _fp(merged) == _fp(onorm)
    r.close()


# ------------------------------------------------------------ replication
def test_per_shard_replicas_replay_to_parity_across_truncate():
    r, o = _paired(replicate="delta", sync_every=8)
    clock = _drive_parity(r, o, rounds=6)
    r.sync_replicas()
    r.compact()                      # every shard truncates its acked prefix
    assert all(sh.wq.log.base > 0 for sh in r.shards)
    clock = _drive_parity(r, o, rounds=2)   # keep writing ACROSS the cut
    views = r.snapshot_vector()
    for s, sh in enumerate(r.shards):
        sh.replicator.sync(upto_version=views[s].version)
        for n in sh.wq.store.cols:
            assert np.array_equal(views[s].col(n),
                                  sh.replicator.store.col(n),
                                  equal_nan=True), (s, n)
    # scatter-gather over the REPLICA snapshots == oracle sweep
    rep_views = tuple(sh.replicator.snapshot_view() for sh in r.shards)
    oview = o.store.snapshot_view()
    assert _fp(ShardRouter.comparable(r.run_all(clock, views=rep_views))) \
        == _fp(ShardRouter.oracle_normalize(
            SteeringEngine(o).run_all(clock, view=oview), oview))
    r.close()


def test_consumer_lags_namespaced_per_shard():
    r = ShardRouter(2, 2, replicate="delta")
    r.add_tasks(0, 8, now=0.0)
    lags = r.consumer_lags()
    assert len(lags) == 2
    assert all(k.startswith(("shard0:", "shard1:")) for k in lags)
    assert all(v > 0 for v in lags.values())   # nothing synced yet
    r.sync_replicas()
    assert all(v == 0 for v in r.consumer_lags().values())
    r.close()


# ---------------------------------------------------- cross-shard stealing
def test_rebalance_conserves_tasks_and_feeds_drained_shard():
    r = ShardRouter(S, L, replicate="delta")
    r.add_tasks(0, 12 * W, domain_in=_dom(np.arange(12 * W)), now=0.0)
    sh0 = r.shards[0]
    while sh0.wq.ready_counts().sum() > 0:      # drain shard 0 dry
        got = sh0.wq.claim_all(k=64, now=1.0)
        rows = np.concatenate([v for v in got.values() if len(v)])
        sh0.wq.finish(rows, now=2.0)
    live_before = r.live_task_ids()
    moved = r.rebalance(now=3.0)
    assert moved > 0
    assert np.array_equal(live_before, r.live_task_ids())  # conservation
    assert r.steal_stats.tasks == moved
    assert r.steal_stats.wire_bytes > 0         # it really crossed the wire
    # the drained shard is claimable again, under its own partition hash
    got = sh0.wq.claim_all(k=4, now=4.0)
    assert sum(len(v) for v in got.values()) > 0
    # the steal is ordinary logged traffic: replicas replay to bit-parity
    r.sync_replicas()
    for sh in r.shards:
        v = sh.wq.store.snapshot_view()
        sh.replicator.sync(upto_version=v.version)
        for n in sh.wq.store.cols:
            assert np.array_equal(v.col(n), sh.replicator.store.col(n),
                                  equal_nan=True), (sh.index, n)
    r.check_invariants()
    r.close()


def test_rebalance_noop_when_no_shard_is_drained():
    r = ShardRouter(S, L)
    r.add_tasks(0, 8 * W, now=0.0)              # every shard has backlog
    live = r.live_task_ids()
    assert r.rebalance(now=1.0) == 0
    assert np.array_equal(live, r.live_task_ids())
    r.close()


# ------------------------------------------------------------ executor
def test_train_executor_runs_sharded():
    from repro.configs import smoke_config
    from repro.data.pipeline import DataConfig
    from repro.runtime.executor import TrainExecutor
    cfg = smoke_config("qwen2-0.5b")
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, batch_size=4)
    ex = TrainExecutor(cfg, num_workers=4, shards=2, data_cfg=data,
                       steer_every=4)
    ex.submit_steps(12)
    hist = ex.run()
    ex.close()
    assert len(hist) == 12
    assert ex.router.tasks_left() == 0
    assert sum(int(sh.wq.counts()["FINISHED"])
               for sh in ex.router.shards) == 12
    assert ex.last_steering is not None          # scatter-gather sweeps ran
    assert ex.last_steering["q4"] == 0
    assert isinstance(ex.last_steering["version"], list)
    with pytest.raises(ValueError):
        TrainExecutor(cfg, num_workers=3, shards=2, data_cfg=data)
