"""Steering engine (Q1-Q8) + supervisor expansion + provenance tests."""
import numpy as np

from repro.configs.risers_workflow import DEFAULT, WorkflowConfig
from repro.core import (SecondarySupervisor, Status, SteeringEngine,
                        Supervisor, WorkQueue)
from repro.core.provenance import derivation_path, prov_document


def run_workflow(workers=4, tasks=16, activities=3, fail_worker_at=None):
    rng = np.random.default_rng(0)
    wf = WorkflowConfig(activities=tuple(f"a{i}" for i in range(activities)))
    wq = WorkQueue(num_workers=workers)
    sup = Supervisor(wq, wf)
    sup.seed(tasks, duration_s=5.0, rng=rng)
    now = 0.0
    for step in range(200):
        if sup.done():
            break
        claims = wq.claim_all(k=1, now=now)
        for w, rows in claims.items():
            if len(rows):
                wq.finish(rows, now=now + 1.0,
                          domain_out=rng.normal(0.6, 0.2, (len(rows), 3)))
        sup.expand(now=now)
        now += 1.0
    return wq, sup, now


def test_supervisor_expands_full_chain():
    wq, sup, _ = run_workflow(tasks=8, activities=3)
    act = wq.store.col("activity_id")
    st = wq.store.col("status")
    for a in range(3):
        fin = ((act == a) & (st == int(Status.FINISHED))).sum()
        assert fin == 8, (a, fin)


def test_q1_q6_queries():
    wq, sup, now = run_workflow(tasks=12, activities=2)
    steer = SteeringEngine(wq)
    q1 = steer.q1_recent_status_by_node(now, horizon=now + 10)
    assert sum(v["finished"] for v in q1.values()) == 24
    assert steer.q4_tasks_left() == 0
    assert steer.q5_worst_activity() == (-1, 0)
    # q6 requires open activities: create some
    wq.add_tasks(1, 3)
    times = steer.q6_activity_times()
    assert 1 in times and times[1][0] > 0


def test_q7_provenance_join_and_path():
    wq, sup, _ = run_workflow(tasks=10, activities=4)
    steer = SteeringEngine(wq)
    rows = steer.q7_provenance_join(act_a=0, act_b=2, thr=0.4)
    act = wq.store.col("activity_id")
    assert all(act[r] == 0 for r in rows)
    # derivation path walks back to activity 0
    tid = int(wq.store.col("task_id")[act == 3][0])
    path = derivation_path(wq, tid)
    assert len(path) == 4


def test_q8_patch_and_prune():
    wq = WorkQueue(num_workers=2)
    wq.add_tasks(0, 10, domain_in=np.linspace(0, 9, 10)[:, None]
                 * np.ones((10, 3)))
    steer = SteeringEngine(wq)
    n = steer.q8_patch_ready(0, "in0", 42.0,
                             predicate=lambda v: v > 5.0)
    assert n == 4
    npruned = steer.prune("in1", 0.0, 3.0)
    assert npruned == 4
    assert wq.counts()["PRUNED"] == 4


def test_secondary_supervisor_promotion_no_duplicates():
    rng = np.random.default_rng(1)
    wf = WorkflowConfig(activities=("a0", "a1"))
    wq = WorkQueue(num_workers=2)
    sup = Supervisor(wq, wf)
    sup.seed(6, duration_s=1.0, rng=rng)
    sec = SecondarySupervisor(sup)
    rows = np.concatenate(list(wq.claim_all(k=3).values()))
    wq.finish(rows, now=1.0, domain_out=np.ones((len(rows), 3)))
    sup.expand(now=1.0)
    sec.sync()
    sup.crash()
    sup2 = sec.promote()
    n_new = sup2.expand(now=2.0)       # must not re-expand the same tasks
    assert n_new == 0
    act = wq.store.col("activity_id")
    assert (act == 1).sum() == 6


def test_prov_document_is_w3c_shaped():
    wq, sup, _ = run_workflow(tasks=4, activities=2)
    doc = prov_document(wq)
    assert set(doc) >= {"activity", "entity", "agent", "used",
                        "wasGeneratedBy", "wasAssociatedWith",
                        "wasDerivedFrom"}
    assert len(doc["activity"]) == 8
    assert len(doc["wasDerivedFrom"]) == 4


def test_q8_and_prune_race_concurrent_claim_all():
    """Q8 patches and prunes are LIVE-store transactions; claims mutate the
    same partitions concurrently. Interleaved under the commit lock, the
    incremental ready counts (and every other invariant) must survive —
    check_invariants recounts them exactly."""
    import threading

    rng = np.random.default_rng(0)
    wq = WorkQueue(num_workers=8)
    steer = SteeringEngine(wq)
    wq.add_tasks(0, 400, domain_in=rng.uniform(0, 1, (400, 3)))
    stop = threading.Event()
    errors = []
    steered = {"patched": 0, "pruned": 0}

    def analyst():
        i = 0
        try:
            while not stop.is_set():
                steered["patched"] += steer.q8_patch_ready(
                    0, "in0", 5.0, predicate=lambda v: v > 0.6)
                steered["pruned"] += steer.prune(
                    "in1", 0.0, 0.001 * (i % 40))
                i += 1
        except Exception as e:                            # noqa: BLE001
            errors.append(e)

    t = threading.Thread(target=analyst)
    t.start()
    try:
        now = 0.0
        for r in range(40):
            out = wq.claim_all(k=2, now=now)
            rows = np.concatenate([v for v in out.values() if len(v)]) \
                if any(len(v) for v in out.values()) \
                else np.empty(0, np.int64)
            if len(rows):
                wq.finish(rows, now=now + 0.5,
                          domain_out=rng.normal(0.5, 0.3, (len(rows), 3)))
            wq.add_tasks(0, 10, domain_in=rng.uniform(0, 1, (10, 3)),
                         now=now)
            now += 1.0
    finally:
        stop.set()
        t.join()
    assert not errors, errors
    assert steered["pruned"] > 0           # the race actually happened
    wq.check_invariants()                  # ready counts == exact recount
    # conservation: every row is in exactly one state, none lost or forged
    st = wq.store.col("status")
    assert wq.store.n_rows == 400 + 40 * 10
    counts = wq.counts()
    assert sum(counts.values()) - counts["EMPTY"] == wq.store.n_rows
    # every row a prune transition ever touched must STILL be PRUNED:
    # PRUNED is terminal and claim_all only takes READY rows, so a row
    # resurrected to RUNNING here would mean a claim interleaved inside
    # the prune's read-predicate/write window (the race this test exists
    # to catch)
    pruned_rows = [r.payload["rows"] for r in wq.log.tail(0)
                   if r.op == "steer_prune"]
    assert pruned_rows                     # the race actually pruned rows
    ever_pruned = np.concatenate(pruned_rows)
    assert (st[ever_pruned] == int(Status.PRUNED)).all()
