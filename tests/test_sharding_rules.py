"""Sharding rules unit tests + an 8-device SPMD test run in a subprocess
(the device-count flag must precede jax init, so it cannot run in-process)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config, smoke_config
from repro.launch import shardrules as SR


class FakeMesh:
    """Just enough Mesh interface for spec-fitting tests."""
    def __init__(self, shape):
        self.axis_names = tuple(shape)
        self.shape = dict(shape)
        self.devices = np.empty(tuple(shape.values()), object)

    @property
    def size(self):
        return int(np.prod(list(self.shape.values())))


def test_fit_spec_drops_nondivisible_axes():
    mesh = FakeMesh({"data": 16, "model": 16})
    spec = SR.fit_spec(mesh, P("model", "data"), (49155, 1536))
    assert spec == P(None, "data")        # 49155 % 16 != 0 -> replicated dim
    spec = SR.fit_spec(mesh, P(("data", "model"), None), (256, 64))
    assert spec == P(("data", "model"), None)
    spec = SR.fit_spec(mesh, P(("data", "model"), None), (128, 64))
    assert spec == P(None, None)          # 128 % 256 != 0


def test_strategy_selection():
    assert SR.Strategy.for_arch(get_config("qwen2-0.5b")).dp_only
    assert SR.Strategy.for_arch(get_config("glm4-9b")).tp
    assert SR.Strategy.for_arch(get_config("glm4-9b")).fsdp
    st = SR.Strategy.for_arch(get_config("granite-moe-3b-a800m"))
    assert st.ep and st.tp      # TP enabled in §Perf iteration GR1
    st = SR.Strategy.for_arch(get_config("kimi-k2-1t-a32b"))
    assert st.ep and st.tp and st.fsdp


def test_kv_replication_rule():
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = SR.make_rules(get_config("glm4-9b"), SHAPES["train_4k"], mesh)
    # kv=2 not divisible by model=16 -> replicated kv, seq-sharded cache
    assert rules.table["model_kv"] is None
    assert rules.table["model_kvseq"] == "model"
    rules = SR.make_rules(get_config("seamless-m4t-large-v2"),
                          SHAPES["train_4k"], mesh)
    assert rules.table["model_kv"] is None or True   # dp-only: no tp at all


@pytest.mark.slow
def test_spmd_training_on_8_cpu_devices():
    """Real multi-device SPMD: one train step of a smoke arch on a (4,2)
    mesh must run and produce a finite loss identical-ish to 1-device."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, dataclasses, json
        from repro.configs import smoke_config, SHAPES
        from repro.launch import shardrules as SR
        from repro.launch.steps import (init_train_state, make_train_step,
                                        train_state_shardings)
        from repro.models.registry import train_input_specs
        cfg = dataclasses.replace(smoke_config("granite-moe-3b-a800m"))
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        shape = dataclasses.replace(SHAPES["train_4k"], seq_len=32,
                                    global_batch=8)
        rules = SR.make_rules(cfg, shape, mesh)
        step = make_train_step(cfg, rules)
        state = init_train_state(cfg, jax.random.PRNGKey(0))
        batch = {"tokens": jnp.ones((8, 32), jnp.int32),
                 "labels": jnp.ones((8, 32), jnp.int32)}
        with mesh:
            state_sh = train_state_shardings(cfg, rules, state)
            jitted = jax.jit(step, in_shardings=(state_sh, None, None),
                             out_shardings=(state_sh, None))
            out, metrics = jitted(state, batch, {"lr": jnp.float32(1e-3)})
        print(json.dumps({"loss": float(metrics["loss"]),
                          "devices": jax.device_count()}))
    """)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=os.path.join(
                             os.path.dirname(__file__), ".."), timeout=300)
    assert res.returncode == 0, res.stderr[-2000:]
    rec = json.loads(res.stdout.strip().splitlines()[-1])
    assert rec["devices"] == 8
    assert np.isfinite(rec["loss"])
