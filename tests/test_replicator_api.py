"""Replicator API conformance + pipelined shipper edges (PR 6).

One surface, four arms: every replicator class satisfies the same
``Replicator`` contract and is constructed through ``make_replicator``.
The pipelined shipper keeps the transactional offset/compaction-floor
semantics of the synchronous path: property-tested bit-identical replica
state, kill-mid-backlog respawn without offset loss, and close() that
drains a non-empty queue without hanging. The adaptive codec picks
varint/raw PER FRAME and stays decode-compatible with both.
"""
import inspect
import os

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import Status, SteeringEngine, WorkQueue
from repro.core import wire
from repro.core.replication import (DeltaReplicator, FullCopyReplica,
                                    ReplicaGroup, Replicator,
                                    ShippedDeltaReplicator, make_replicator,
                                    replay_reference)
from repro.core.store import ColumnStore

from test_wire import assert_stores_equal, fresh_store, mixed_workload


def drive(wq, rng, rounds=4):
    wq.add_tasks(0, 24, domain_in=rng.uniform(0, 1, (24, 3)))
    mixed_workload(wq, rng, rounds=rounds)


# ------------------------------------------------------------- conformance
STATS_KEYS = {"records_applied", "encoded_bytes", "sync_count", "lag",
              "fanout_lag_s"}

MODES = ["delta", "full", "shipped", "remote"]


@pytest.mark.parametrize("mode", MODES)
def test_replicator_conformance(mode):
    """All four arms implement the one Replicator surface: sync/maybe_sync/
    lag/flush/recover/promote/close + the uniform stats() dict."""
    rng = np.random.default_rng(11)
    wq = WorkQueue(num_workers=3)
    rep = make_replicator(wq, mode, sync_every=1,
                          replicas=2 if mode == "remote" else 1)
    assert isinstance(rep, Replicator)
    drive(wq, rng)
    assert rep.lag() > 0
    assert rep.maybe_sync() is True      # cadence helper fired (sync_every=1)
    rep.sync()
    rep.flush()
    view = wq.store.snapshot_view()
    rep.sync(upto_version=view.version)
    assert rep.lag() == 0
    s = rep.stats()
    assert STATS_KEYS <= set(s)
    assert s["lag"] == 0
    wq2 = rep.promote()
    assert isinstance(wq2, WorkQueue)
    assert (wq2.store.col("status") != int(Status.RUNNING)).all()
    rep.close()                          # promote released it; idempotent


def test_conformance_classes_are_replicators():
    for cls in (DeltaReplicator, ShippedDeltaReplicator, ReplicaGroup,
                FullCopyReplica):
        assert issubclass(cls, Replicator)


# ----------------------------------------------------------------- factory
def test_make_replicator_modes_and_aliases():
    wq = WorkQueue(num_workers=2)
    for alias in ("delta", "local", "replica"):
        rep = make_replicator(wq, alias)
        assert type(rep) is DeltaReplicator
        rep.close()
    assert type(make_replicator(wq, "full")) is FullCopyReplica
    with pytest.raises(ValueError, match="unknown replicator mode"):
        make_replicator(wq, "carrier-pigeon")
    with pytest.raises(ValueError, match="single-replica"):
        make_replicator(wq, "delta", replicas=3)


def test_factory_defaults_shipped_modes_to_pipelined():
    wq = WorkQueue(num_workers=2)
    rep = make_replicator(wq, "shipped")
    try:
        assert type(rep) is ShippedDeltaReplicator and rep.pipelined
    finally:
        rep.close()
    rep = make_replicator(wq, "shipped", pipelined=False)
    try:
        assert not rep.pipelined
    finally:
        rep.close()
    grp = make_replicator(wq, "fabric", replicas=2)
    try:
        assert type(grp) is ReplicaGroup
        assert all(m.pipelined for m in grp.members)
    finally:
        grp.close()


def test_executor_constructs_replicators_only_via_factory():
    import repro.runtime.executor as executor
    src = inspect.getsource(executor)
    assert "make_replicator" in src
    for cls in ("DeltaReplicator", "ReplicaGroup", "ShippedDeltaReplicator",
                "FullCopyReplica"):
        assert f"{cls}(" not in src, f"executor hand-constructs {cls}"


# ------------------------------------------------------------ codec object
def test_as_codec_aliases_and_errors():
    assert wire.as_codec("raw").name == "raw"
    assert wire.as_codec("varint").name == "varint"
    assert wire.as_codec("adaptive").name == "adaptive"
    c = wire.AdaptiveCodec()
    assert wire.as_codec(c) is c         # objects pass through untouched
    with pytest.raises(ValueError, match="unknown wire codec"):
        wire.as_codec("zstd-from-the-future")


def test_adaptive_codec_per_frame_choice_and_parity():
    """Claim-heavy frames compress (varint); dom-heavy finish frames and
    tiny runs ship raw — and the mixed-frame stream decodes bit-exactly."""
    rng = np.random.default_rng(21)
    wq = WorkQueue(num_workers=8)
    wq.add_tasks(0, 600, domain_in=rng.uniform(0, 1, (600, 3)))
    for r in range(40):
        wq.claim(r % 8, k=1, now=float(r) * 0.25)
    claims = [r for r in wq.log.tail(0) if r.op == "claim"]
    # long claim run: adaptive == varint choice, well under raw
    assert wire.frames_nbytes(claims, "adaptive") \
        == wire.frames_nbytes(claims, "varint")
    assert wire.frames_nbytes(claims, "raw") \
        >= 4 * wire.frames_nbytes(claims, "adaptive")
    # dom-heavy finishes (10 rows/record: the 24 dom bytes/row dwarf the
    # per-record locator overhead): adaptive refuses to varint — raw layout
    run = np.nonzero(wq.store.col("status") == int(Status.RUNNING))[0]
    for ch in np.array_split(run, 4):
        wq.finish(ch, now=99.0,
                  domain_out=rng.normal(0, 1e9, (len(ch), 3)))
    fins = [r for r in wq.log.tail(0) if r.op == "finish"]
    assert wire.frames_nbytes(fins, "adaptive") \
        == wire.frames_nbytes(fins, "raw")
    assert wire.frames_nbytes(fins, "varint") \
        > wire.frames_nbytes(fins, "raw") * 0.7   # varint would barely pay
    # tiny runs (< AdaptiveCodec.min_records) stay raw: varint's field
    # restarts can't amortize
    tiny = claims[:2]
    assert wire.frames_nbytes(tiny, "adaptive") \
        == wire.frames_nbytes(tiny, "raw")
    # the mixed stream (varint claims + raw finishes) round-trips bit-exactly
    recs = wq.log.tail(0)
    buf = wire.delta_to_bytes(recs, codec="adaptive")
    assert wire.frames_nbytes(recs, "adaptive") == len(buf)
    s_ref, s_dec = fresh_store(wq), fresh_store(wq)
    replay_reference(s_ref, recs)
    replay_reference(s_dec, wire.decode_delta(buf))
    assert_stores_equal(s_ref, s_dec, wq.store.cols)


def test_replicator_accepts_codec_object():
    wq = WorkQueue(num_workers=2)
    rep = ShippedDeltaReplicator(wq, codec=wire.VarintCodec())
    try:
        assert rep.codec == "varint"
    finally:
        rep.close()


# ---------------------------------------------------------- encode-once
def test_delta_encoder_encodes_once_per_span():
    rng = np.random.default_rng(31)
    wq = WorkQueue(num_workers=4)
    drive(wq, rng)
    recs = wq.log.tail(0)
    enc = wire.DeltaEncoder()
    a = enc.encode_records(0, len(recs), recs, "adaptive")
    b = enc.encode_records(0, len(recs), recs, "adaptive")
    assert a is b                        # cached, not re-encoded
    assert enc.stats() == {"encodes": 1, "hits": 1, "entries": 1}
    # staged chunks share the (lo, hi, codec) key space with records
    (chunk,) = wire.stage_delta(recs, 0, chunk_records=1 << 30)
    c = enc.encode_staged(chunk, "adaptive")
    assert c is a
    assert enc.stats()["hits"] == 2
    # a different codec is a different span identity
    d = enc.encode_records(0, len(recs), recs, "raw")
    assert d is not a and enc.stats()["encodes"] == 2


def test_group_members_share_one_encoder():
    rng = np.random.default_rng(32)
    wq = WorkQueue(num_workers=3)
    grp = ReplicaGroup(wq, n_replicas=3, pipelined=True)
    try:
        drive(wq, rng)
        grp.sync(upto_version=wq.store.version)
        s = grp.stats()
        # 3 members shipped the same spans: at least 2/3 of encode calls
        # were cache hits (the encode-once win)
        assert s["hits"] >= 2 * s["encodes"]
        assert s["fanout_lag_s"] >= 0.0
        assert s["member_spread_s"] >= 0.0
    finally:
        grp.close()


# ------------------------------------------------- staged views vs compaction
def test_staged_views_survive_log_truncate():
    """Chunks staged BEFORE a compaction must encode the same bytes AFTER
    it: trim_front reallocates, so captured plane views keep aliasing the
    frozen old buffers (the pipelined shipper's correctness anchor)."""
    rng = np.random.default_rng(41)
    wq = WorkQueue(num_workers=4)
    rep = DeltaReplicator(wq)            # consumer to lift the floor
    drive(wq, rng, rounds=3)
    lo = rep.offset
    rep.sync()                           # ack everything: floor = len(log)
    recs = wq.log.slice(lo, len(wq.log))
    chunks = wire.stage_delta(recs, lo, chunk_records=8)
    eager = [wire.encode_staged(c, "adaptive") for c in chunks]
    assert wq.compact_log() > 0          # drops + REBASES the hot planes
    mixed_workload(wq, rng, rounds=2)    # and keeps appending after
    late = [wire.encode_staged(c, "adaptive") for c in chunks]
    assert eager == late
    rep.close()


# ----------------------------------------------------- pipelined failure edges
def test_pipelined_kill_mid_backlog_drains_and_respawns():
    """A member killed with a queued backlog respawns from a fresh snapshot
    and the queue drains without offset loss or parity loss."""
    rng = np.random.default_rng(51)
    wq = WorkQueue(num_workers=3)
    rep = ShippedDeltaReplicator(wq, pipelined=True, chunk_records=4,
                                 queue_depth=64)
    try:
        drive(wq, rng, rounds=3)
        rep.sync()
        rep.flush()
        acked = rep.offset
        rep.process.kill()               # dies holding nothing un-acked
        mixed_workload(wq, rng, rounds=3)
        rep.sync()                       # enqueue a multi-chunk backlog
        rep.sync(upto_version=wq.store.version)   # barrier: drain + pin
        assert rep.spawn_count == 2
        assert rep.offset >= acked       # never rewinds past the ack
        assert rep.offset == len(wq.log)
        view = wq.store.snapshot_view()
        state = rep.fetch_remote_state()
        for name in wq.store.cols:
            assert np.array_equal(view.col(name),
                                  state["snapshot"]["cols"][name],
                                  equal_nan=True), name
    finally:
        rep.close()
    assert not wq.log.has_consumer(rep.consumer)


def test_pipelined_close_with_nonempty_queue_is_idempotent_never_hangs():
    rng = np.random.default_rng(52)
    wq = WorkQueue(num_workers=3)
    rep = ShippedDeltaReplicator(wq, pipelined=True, chunk_records=2,
                                 queue_depth=256)
    drive(wq, rng, rounds=3)
    rep.sync()                           # enqueue a backlog, don't flush
    rep.close()                          # must drain (bounded) and return
    rep.close()                          # second close is a no-op
    assert rep.process is None
    assert not wq.log.has_consumer(rep.consumer)


def test_pipelined_error_surfaces_on_flush_and_respawns():
    """A poison record fails remotely; the background error re-raises at
    the flush barrier and the NEXT sync respawns cleanly."""
    rng = np.random.default_rng(53)
    wq = WorkQueue(num_workers=2)
    rep = ShippedDeltaReplicator(wq, pipelined=True)
    try:
        drive(wq, rng, rounds=2)
        rep.sync()
        rep.flush()
        wq.log.append("mystery_op", {"n": 1}, store_version=wq.store.version)
        rep.sync()
        with pytest.raises(RuntimeError, match="mystery_op"):
            rep.flush()
        # poison is still in the log: the respawn snapshot absorbs it
        # (snapshot state, not replayed), so the pipeline recovers
        wq.claim(0, k=1, now=5.0)
        rep.sync(upto_version=wq.store.version)
        assert rep.offset == len(wq.log)
    finally:
        rep.close()


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 99), workers=st.integers(2, 5))
def test_property_pipelined_bit_identical_vs_sync(seed, workers):
    """Same workload, two consumers of one log — lockstep vs pipelined —
    end in bit-identical replica stores."""
    rng = np.random.default_rng(seed)
    wq = WorkQueue(num_workers=workers)
    a = ShippedDeltaReplicator(wq, pipelined=False)
    b = ShippedDeltaReplicator(wq, pipelined=True, chunk_records=8)
    try:
        wq.add_tasks(0, 20, domain_in=rng.uniform(0, 1, (20, 3)))
        for r in range(3):
            mixed_workload(wq, rng, rounds=2)
            a.sync()
            b.sync()
        v = wq.store.version
        a.sync(upto_version=v)
        b.sync(upto_version=v)
        sa = a.fetch_remote_state()["snapshot"]
        sb = b.fetch_remote_state()["snapshot"]
        assert sa["version"] == sb["version"]
        for name in wq.store.cols:
            assert np.array_equal(sa["cols"][name], sb["cols"][name],
                                  equal_nan=True), name
    finally:
        a.close()
        b.close()


def test_staged_payload_nbytes_exact_vs_per_record_sum():
    """The O(runs) ack-accounting fast path must equal the per-record
    ``payload_nbytes()`` sum bit-exactly for every run shape a real log
    produces — hot runs, cold ops, resize/requeue/fail mixed in."""
    rng = np.random.default_rng(71)
    wq = WorkQueue(num_workers=4)
    wq.add_tasks(0, 60, domain_in=rng.uniform(0, 1, (60, 3)))
    mixed_workload(wq, rng, rounds=4)
    wq.resize(3)
    wq.requeue_worker(1)
    mixed_workload(wq, rng, rounds=2)
    recs = wq.log.tail(0)
    for chunk_records in (5, 64, 4096):
        staged = wire.stage_delta(recs, 0, chunk_records=chunk_records)
        fast = sum(wire.staged_payload_nbytes(run)
                   for c in staged for run in c.runs)
        slow = sum(r.payload_nbytes() for r in recs)
        assert fast == slow, chunk_records


def test_replay_runs_bit_identical_to_record_replay():
    """The child's run-level replay (``decode_delta_runs`` +
    ``replay_runs``) must land the same store as record-level
    ``decode_delta`` + ``replay`` for every codec."""
    from repro.core.replication import replay, replay_runs

    rng = np.random.default_rng(72)
    wq = WorkQueue(num_workers=4)
    wq.add_tasks(0, 50, domain_in=rng.uniform(0, 1, (50, 3)))
    mixed_workload(wq, rng, rounds=3)
    wq.resize(3)                          # cold resize rides the frames
    mixed_workload(wq, rng, rounds=2)
    recs = wq.log.tail(0)
    for codec in wire.CODECS:
        buf = wire.delta_to_bytes(recs, codec=codec)
        sa = fresh_store(wq)
        sb = fresh_store(wq)
        na = replay(sa, wire.decode_delta(buf))
        nb = replay_runs(sb, wire.decode_delta_runs(buf))
        assert na == nb == len(recs)
        assert_stores_equal(sa, sb, wq.store.cols)
