"""Deterministic fallback for ``hypothesis`` when it is not installed.

CI installs the real hypothesis via the ``dev`` extra (pyproject.toml); this
stub only exists so the property-test modules still COLLECT AND RUN in
hermetic environments without it (the paper-repro container bakes jax/numpy
but no dev extras, and nothing may be pip-installed there). It implements
just the surface this repo uses — ``@settings(max_examples=, deadline=)``,
``@given(**kwargs)`` and the ``integers`` / ``booleans`` / ``sampled_from`` /
``floats`` strategies — by looping a seeded RNG over max_examples drawn
inputs. No shrinking, no database, same-seed-same-cases on every run.
"""
from __future__ import annotations

import functools
import inspect
import sys
import types

import numpy as np


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.integers(0, 2)))


def sampled_from(elements) -> _Strategy:
    elems = list(elements)
    return _Strategy(lambda rng: elems[int(rng.integers(0, len(elems)))])


def floats(min_value: float = 0.0, max_value: float = 1.0,
           **_ignored) -> _Strategy:
    return _Strategy(
        lambda rng: float(rng.uniform(min_value, max_value)))


def given(*args, **kwargs):
    if args:
        raise NotImplementedError(
            "hypothesis stub supports keyword strategies only")

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*fargs, **fkwargs):
            conf = getattr(wrapper, "_stub_settings", {})
            n = int(conf.get("max_examples", 20))
            rng = np.random.default_rng(0)
            for _ in range(n):
                drawn = {k: s.draw(rng) for k, s in kwargs.items()}
                fn(*fargs, **fkwargs, **drawn)
        # hide the strategy params from pytest's fixture resolution (the
        # real hypothesis does the same); remaining params stay fixtures
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in kwargs])
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper
    return deco


def settings(**kwargs):
    def deco(fn):
        fn._stub_settings = kwargs
        return fn
    return deco


def install() -> None:
    """Register this stub as the ``hypothesis`` package in sys.modules."""
    strategies = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "booleans", "sampled_from", "floats"):
        setattr(strategies, name, globals()[name])
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.strategies = strategies
    mod.__stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies
