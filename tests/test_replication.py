"""Delta replication: O(delta) replica catch-up by txn-log replay, time-travel
steering, and crash/failover end-to-end (primary data-node loss -> replica
recover -> promoted supervisor resumes with no duplicate or lost tasks)."""
import json

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.risers_workflow import WorkflowConfig
from repro.core import Status, SteeringEngine, WorkQueue
from repro.core.replication import DeltaReplicator, FullCopyReplica, \
    ReplicaSet
from repro.core.supervisor import SecondarySupervisor, Supervisor
from repro.core.transactions import TxnLog


def sweep_key(res):
    return json.dumps(res, sort_keys=True, default=str)


def run_mixed_workload(wq, steer, rng, rounds=12):
    """Claims, finishes, fails, requeue, steering patch/prune, resize —
    every replayable op kind the WorkQueue emits."""
    for r in range(rounds):
        out = wq.claim_all(k=1, now=float(r))
        rows = np.concatenate([v for v in out.values() if len(v)]) \
            if any(len(v) for v in out.values()) else np.empty(0, np.int64)
        if len(rows) == 0:
            break
        if r == 2:
            wq.fail(rows[: max(len(rows) // 4, 1)], now=float(r) + 0.2)
            rows = rows[max(len(rows) // 4, 1):]
        if r == 3:
            victim = wq.num_workers - 1
            wid = wq.store.col("worker_id")[rows]
            wq.requeue_worker(victim)
            rows = rows[wid != victim]
        if len(rows):
            wq.finish(rows, now=float(r) + 0.9,
                      domain_out=rng.normal(0.5, 0.3, (len(rows), 3)))
        if r == 4:
            steer.q8_patch_ready(0, "in0", 5.0, predicate=lambda v: v > 0.6)
        if r == 5:
            steer.prune("in1", 0.0, 0.05)
        if r == 6 and wq.num_workers > 2:
            wq.resize(wq.num_workers - 1)


# --------------------------------------------------------------- catch-up
def test_delta_sync_reproduces_primary_bit_exactly():
    rng = np.random.default_rng(0)
    wq = WorkQueue(num_workers=4)
    rep = DeltaReplicator(wq, sync_every=8)
    steer = SteeringEngine(wq)
    wq.add_tasks(0, 64, domain_in=rng.uniform(0, 1, (64, 3)))
    run_mixed_workload(wq, steer, rng)
    rep.sync()
    view = wq.store.snapshot_view()
    for name in wq.store.cols:
        assert np.array_equal(view.col(name), rep.store.col(name),
                              equal_nan=True), name
    assert rep.store.version == wq.store.version
    assert rep.num_workers == wq.num_workers          # resize rode the log


def test_sweep_on_replica_equals_sweep_on_primary_snapshot():
    """The acceptance criterion: a steering sweep on a caught-up replica at
    version v is identical to a sweep on a primary snapshot_view() at v."""
    rng = np.random.default_rng(1)
    wq = WorkQueue(num_workers=4)
    rep = DeltaReplicator(wq)
    steer = SteeringEngine(wq)
    wq.add_tasks(0, 48, domain_in=rng.uniform(0, 1, (48, 3)))
    run_mixed_workload(wq, steer, rng, rounds=6)
    view = wq.store.snapshot_view()
    run_mixed_workload(wq, steer, rng, rounds=3)   # primary races ahead ...
    rep.sync(upto_version=view.version)            # ... replica pins to v
    assert rep.store.version == view.version
    a = steer.run_all(99.0, view=view)
    b = steer.run_all(99.0, view=rep.snapshot_view())
    assert sweep_key(a) == sweep_key(b)


def test_sync_cost_is_proportional_to_delta_not_store():
    """After catch-up on a large store, k more ops must sync as k records
    (and ship ~k payloads), not re-copy the store."""
    wq = WorkQueue(num_workers=4, capacity=1 << 15)
    rep = DeltaReplicator(wq)
    wq.add_tasks(0, 8000)
    assert rep.sync() == 1                       # the one big insert record
    big_bytes = rep.delta_bytes
    for r in range(3):                           # 3 small claims
        wq.claim(r % 4, k=2, now=float(r))
    assert rep.lag() == 3
    assert rep.sync() == 3
    small_bytes = rep.delta_bytes - big_bytes
    # 3 claim payloads are tiny vs the 8000-row insert — and vastly smaller
    # than what a full-copy sync of the 8000-row store would ship
    assert small_bytes < big_bytes / 50
    assert small_bytes < wq.store.n_rows * wq.store.row_nbytes() / 100


def test_sync_to_older_version_is_a_noop_never_rewinds():
    """sync(upto_version=<older than the replica>) must not rewind the
    consumed-log cursor or the replica version — a rewind would re-apply
    records (insert replay then raises 'replica diverged') on later syncs."""
    wq = WorkQueue(num_workers=2)
    rep = DeltaReplicator(wq)
    wq.add_tasks(0, 8)
    old_view = wq.store.snapshot_view()
    wq.add_tasks(0, 8)
    assert rep.sync() == 2                        # fully caught up
    v, off = rep.store.version, rep.offset
    assert rep.sync(upto_version=old_view.version) == 0
    assert (rep.store.version, rep.offset) == (v, off)
    wq.claim(0, k=1, now=1.0)
    assert rep.sync() == 1                        # and later syncs are clean
    assert rep.store.version == wq.store.version


def test_replicaset_alias_recover_semantics():
    """PR-1 callers: ReplicaSet(wq).sync()/recover() keep working, RUNNING
    tasks return to READY on recovery, fresh ids after restore."""
    wq = WorkQueue(num_workers=2)
    wq.add_tasks(0, 8)
    rep = ReplicaSet(wq, sync_every=1)
    wq.claim(0, k=2)
    rep.sync()
    wq2 = rep.recover()
    assert (wq2.store.col("status") != int(Status.RUNNING)).all()
    assert wq2.counts()["READY"] == 8
    assert wq2.add_tasks(0, 2).min() >= 8


def test_unknown_op_refuses_to_replay():
    wq = WorkQueue(num_workers=2)
    wq.add_tasks(0, 2)
    rep = DeltaReplicator(wq)
    wq.log.append("mystery_op", {"n": 1}, store_version=wq.store.version + 1)
    with pytest.raises(ValueError, match="mystery_op"):
        rep.sync()


# ------------------------------------------------------------- time travel
def test_at_version_matches_historical_snapshots():
    rng = np.random.default_rng(2)
    wq = WorkQueue(num_workers=3)
    steer = SteeringEngine(wq)
    wq.add_tasks(0, 30, domain_in=rng.uniform(0, 1, (30, 3)))
    snaps = []
    for r in range(5):
        out = wq.claim_all(k=1, now=float(r))
        rows = np.concatenate([v for v in out.values() if len(v)])
        wq.finish(rows, now=float(r) + 0.5,
                  domain_out=rng.normal(0.5, 0.3, (len(rows), 3)))
        snaps.append(wq.store.snapshot_view())
    for s in snaps:                              # replay from genesis
        tv = steer.at_version(s.version)
        assert sweep_key(steer.run_all(9.0, view=s)) \
            == sweep_key(steer.run_all(9.0, view=tv))
    tv = steer.at_version(snaps[3].version, base=snaps[0])  # bounded replay
    assert sweep_key(steer.run_all(9.0, view=snaps[3])) \
        == sweep_key(steer.run_all(9.0, view=tv))


def test_at_version_rejects_future_and_inverted_bounds():
    wq = WorkQueue(num_workers=2)
    wq.add_tasks(0, 4)
    steer = SteeringEngine(wq)
    with pytest.raises(ValueError, match="future"):
        steer.at_version(wq.store.version + 1)
    early = wq.store.snapshot_view()
    wq.claim_all(k=1, now=0.0)
    late = wq.store.snapshot_view()
    with pytest.raises(ValueError, match="newer"):
        steer.at_version(early.version, base=late)


# ------------------------------------------- tail_for_version bisect oracle
@settings(max_examples=50, deadline=None)
@given(n=st.integers(1, 60), q=st.integers(-2, 70),
       dup=st.booleans())
def test_tail_for_version_bisect_matches_filter_oracle(n, q, dup):
    log = TxnLog()
    rng = np.random.default_rng(n * 1000 + q)
    v = 0
    for i in range(n):
        # store versions are monotone but non-consecutive (multi-write ops
        # skip versions) and possibly duplicated (dup: same-version batch)
        v += 0 if (dup and i % 3 == 1) else int(rng.integers(1, 4))
        log.append(f"op{i}", {"i": i}, store_version=v)
    got = log.tail_for_version(q)
    want = [r for r in log.records if r.store_version > q]
    assert [r.version for r in got] == [r.version for r in want]
    lo, hi = sorted((int(rng.integers(-1, v + 2)),
                     int(rng.integers(-1, v + 2))))
    got_rng = log.records_between(lo, hi)
    want_rng = [r for r in log.records if lo < r.store_version <= hi]
    assert [r.version for r in got_rng] == [r.version for r in want_rng]


def test_tail_for_version_falls_back_on_non_monotone_log():
    log = TxnLog()
    log.append("a", {}, store_version=5)
    log.append("b", {}, store_version=3)          # out of order: raw append
    log.append("c", {}, store_version=7)
    got = [r.op for r in log.tail_for_version(4)]
    assert got == [r.op for r in log.records if r.store_version > 4]


# -------------------------------------------------- crash/failover e2e
def final_task_set(wq):
    """Id-independent multiset fingerprint of the produced dataflow: per
    activity, the sorted activity-0 ROOT ancestors of its tasks. Child task
    ids interleave differently across crash timelines, but a correct
    failover yields each root exactly once per activity — a duplicate
    expansion doubles a root, a lost one drops it."""
    tid = wq.store.col("task_id")
    par = wq.store.col("parent_task")
    act = wq.store.col("activity_id")
    id2row = {int(t): i for i, t in enumerate(tid)}
    out = {}
    for a in np.unique(act):
        roots = []
        for r in np.nonzero(act == a)[0]:
            rr = int(r)
            while par[rr] >= 0:
                rr = id2row[int(par[rr])]
            roots.append(int(tid[rr]))
        out[int(a)] = sorted(roots)
    return out


def drive(wq, sup, rng, *, crash_at=None, replica=None, secondary=None,
          max_rounds=200):
    """Run the workflow to completion; optionally kill the primary data node
    + supervisor at round ``crash_at`` and continue on the recovered pair."""
    r = 0
    while r < max_rounds:
        if crash_at is not None and r == crash_at:
            # primary data node + supervisor lost: catch the replica up on
            # the surviving log tail, promote the secondary onto it
            sup.crash()
            wq = replica.recover()
            sup = secondary.promote(wq)
            assert sup.state.generation == 1
        out = wq.claim_all(k=1, now=float(r))
        rows = np.concatenate([v for v in out.values() if len(v)]) \
            if any(len(v) for v in out.values()) else np.empty(0, np.int64)
        if len(rows):
            wq.finish(rows, now=float(r) + 0.9,
                      domain_out=rng.normal(0.5, 0.3, (len(rows), 3)))
        n_new = sup.expand(now=float(r))
        if len(rows) == 0 and n_new == 0:
            break
        r += 1
    return wq, sup


def test_crash_failover_no_duplicate_no_lost_tasks():
    """Primary loss mid-workflow: DeltaReplicator.recover + promoted
    SecondarySupervisor must converge to exactly the no-crash task set."""
    wf = WorkflowConfig(activities=("a0", "a1", "a2"))

    def build():
        rng = np.random.default_rng(7)
        wq = WorkQueue(num_workers=3)
        sup = Supervisor(wq, wf)
        sup.seed(18, duration_s=1.0, rng=rng)
        return rng, wq, sup

    rng, wq, sup = build()
    wq_ref, _ = drive(wq, sup, rng)                      # no-crash oracle
    want = final_task_set(wq_ref)
    assert wq_ref.counts()["FINISHED"] == 18 * 3

    rng, wq, sup = build()
    replica = DeltaReplicator(wq, sync_every=4)
    secondary = SecondarySupervisor(sup)
    # replica lags behind on purpose: recovery must drain the log tail
    for _ in range(2):
        replica.maybe_sync()
    secondary.sync()
    wq2, sup2 = drive(wq, sup, rng, crash_at=2, replica=replica,
                      secondary=secondary)
    assert wq2 is not wq                                  # promoted store
    assert sup2.done()
    got = final_task_set(wq2)
    assert got == want                   # no duplicate, no lost expansions
    assert wq2.counts()["FINISHED"] == 18 * 3


def test_expansion_correct_under_out_of_order_finishes():
    """A task finishing AFTER a higher row index was already expanded must
    still get its children (the expanded column, not a row cursor, is the
    dedup watermark)."""
    wf = WorkflowConfig(activities=("a0", "a1"))
    wq = WorkQueue(num_workers=2)
    sup = Supervisor(wq, wf)
    rng = np.random.default_rng(3)
    sup.seed(4, duration_s=1.0, rng=rng)
    wq.claim_all(k=4, now=0.0)
    wq.finish(np.asarray([2, 3]), now=1.0, domain_out=np.ones((2, 3)))
    assert sup.expand(now=1.0) == 2      # high rows expand first
    wq.finish(np.asarray([0, 1]), now=2.0, domain_out=np.ones((2, 3)))
    assert sup.expand(now=2.0) == 2      # low rows still expand
    assert sup.expand(now=3.0) == 0      # and never twice
    kids = wq.store.col("parent_task")[
        wq.store.col("activity_id") == 1]
    assert sorted(kids.tolist()) == [0, 1, 2, 3]


# ------------------------------------------------- replica analyst parity
def test_full_copy_baseline_ships_store_not_delta():
    wq = WorkQueue(num_workers=2, capacity=1 << 14)
    wq.add_tasks(0, 4000)
    full = FullCopyReplica(wq, sync_every=1)
    delta = DeltaReplicator(wq, sync_every=1)
    delta.sync()
    for r in range(4):
        wq.claim(0, k=1, now=float(r))
        full.sync()
        delta.sync()
    # four tiny claims: full-copy re-ships the 4000-row store every time
    assert full.copy_bytes > 4 * 4000 * wq.store.row_nbytes() * 0.9
    assert delta.delta_bytes - 4000 * wq.store.row_nbytes() < \
        full.copy_bytes / 100
