"""Batched (hot-plane) txn-log replay + consumer-offset-aware compaction.

The two contracts of PR 3's tentpole:
- batched replay of ANY op sequence is bit-identical to the record-at-a-time
  oracle and to the primary store (property-tested over random workloads);
- truncation never changes what a consumer observes: a replica syncing
  across truncates stays bit-identical while retained-log memory is bounded,
  and reads that would need dropped records fail loudly (LogCompactedError)
  instead of replaying an incomplete delta.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import Status, SteeringEngine, WorkQueue
from repro.core.replication import DeltaReplicator, replay, replay_reference
from repro.core.schema import LEGAL_TRANSITIONS, TRANSITIONS
from repro.core.store import ColumnStore
from repro.core.transactions import LogCompactedError, TxnLog


def drive_random_ops(wq, steer, rng, rounds):
    """Random mixed workload emitting every replayable op kind, with long
    claim/finish runs AND interleaved stretches (both replay shapes)."""
    for r in range(rounds):
        kind = int(rng.integers(0, 10))
        if kind < 4:                       # per-worker claim bursts
            for _ in range(int(rng.integers(1, 6))):
                w = int(rng.integers(0, wq.num_workers))
                wq.claim(w, k=int(rng.integers(1, 3)), now=float(r),
                         allow_steal=bool(rng.integers(0, 2)))
        elif kind < 6:
            wq.claim_all(k=int(rng.integers(1, 3)), now=float(r))
        elif kind == 6:
            running = np.nonzero(
                wq.store.col("status") == int(Status.RUNNING))[0]
            if len(running):
                take = running[rng.random(len(running)) < 0.7]
                if len(take):
                    dom = rng.normal(0.5, 0.3, (len(take), 3)) \
                        if rng.integers(0, 2) else None
                    wq.finish(take, now=float(r) + 0.5, domain_out=dom)
        elif kind == 7:
            running = np.nonzero(
                wq.store.col("status") == int(Status.RUNNING))[0]
            if len(running):
                wq.fail(running[: max(len(running) // 3, 1)],
                        now=float(r) + 0.2)
        elif kind == 8:
            steer.q8_patch_ready(0, "in0", float(rng.uniform(0, 9)))
            steer.prune("in1", 0.0, float(rng.uniform(0, 0.2)))
        else:
            if rng.integers(0, 2) and wq.num_workers > 2:
                wq.resize(wq.num_workers - 1)
            else:
                wq.requeue_worker(int(rng.integers(0, wq.num_workers)))
        if rng.integers(0, 4) == 0:
            wq.add_tasks(int(rng.integers(0, 3)), int(rng.integers(1, 9)),
                         now=float(r))


def assert_stores_equal(a, b, cols):
    for name in cols:
        assert np.array_equal(a.col(name), b.col(name),
                              equal_nan=True), name


# ------------------------------------------------- batched replay oracle
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), workers=st.integers(1, 6),
       rounds=st.integers(1, 24))
def test_batched_replay_bit_identical_to_reference_and_primary(
        seed, workers, rounds):
    rng = np.random.default_rng(seed)
    wq = WorkQueue(num_workers=workers)
    steer = SteeringEngine(wq)
    wq.add_tasks(0, int(rng.integers(4, 32)),
                 domain_in=None, now=0.0)
    drive_random_ops(wq, steer, rng, rounds)
    records = wq.log.tail(0)
    ref = ColumnStore(wq.store.schema, capacity=1 << 10)
    bat = ColumnStore(wq.store.schema, capacity=1 << 10)
    n_ref = replay_reference(ref, records)
    n_bat = replay(bat, records)
    assert n_ref == n_bat == len(records)
    assert_stores_equal(ref, bat, wq.store.cols)
    assert_stores_equal(wq.store, bat, wq.store.cols)
    assert ref.version == bat.version == wq.store.version


def test_batched_replay_claims_finishes_heavy_runs():
    """The gate workload shape: long single-op runs replayed off the planes."""
    W = 8
    wq = WorkQueue(num_workers=W, capacity=1 << 12)
    wq.add_tasks(0, 256)
    claimed = [wq.claim(r % W, k=1, now=float(r)) for r in range(256)]
    for r, rows in enumerate(claimed):
        wq.finish(rows, now=float(r) + 0.5,
                  domain_out=np.full((len(rows), 3), float(r)))
    records = wq.log.tail(0)
    bat = ColumnStore(wq.store.schema, capacity=1 << 12)
    replay(bat, records)
    assert_stores_equal(wq.store, bat, wq.store.cols)


def test_batched_replay_mixed_dom_and_empty_finish_records():
    """Mixed dom/no-dom and zero-row finish records must not fool the
    all-single-row or all-carry-dom fast paths."""
    wq = WorkQueue(num_workers=2)
    wq.add_tasks(0, 8)
    r1 = wq.claim(0, k=2, now=0.0)
    r2 = wq.claim(1, k=2, now=0.0)
    wq.finish(np.empty(0, np.int64), now=0.5)              # zero rows
    wq.finish(r1, now=1.0, domain_out=np.ones((2, 3)))     # with dom
    wq.finish(r2, now=2.0)                                 # without dom
    bat = ColumnStore(wq.store.schema, capacity=1 << 10)
    replay(bat, wq.log.tail(0))
    assert_stores_equal(wq.store, bat, wq.store.cols)


def test_batched_replay_mixed_dom_widths_in_one_run():
    """Consecutive finishes with DIFFERENT domain_out widths (legal via the
    public API) keep their drifted dom rows out of the plane buffer AND
    must not crash the dict fallback's concatenation — dom applies record
    by record instead."""
    wq = WorkQueue(num_workers=2)
    wq.add_tasks(0, 8)
    r1 = wq.claim(0, k=2, now=0.0)
    r2 = wq.claim(1, k=2, now=0.0)
    wq.finish(r1, now=1.0, domain_out=np.full((2, 2), 0.25))   # width 2
    wq.finish(r2, now=2.0, domain_out=np.full((2, 3), 0.75))   # width 3
    ref = ColumnStore(wq.store.schema, capacity=1 << 10)
    bat = ColumnStore(wq.store.schema, capacity=1 << 10)
    replay_reference(ref, wq.log.tail(0))
    replay(bat, wq.log.tail(0))
    assert_stores_equal(ref, bat, wq.store.cols)
    assert_stores_equal(wq.store, bat, wq.store.cols)


def test_width_drift_only_degrades_its_own_run():
    """A width-drifted finish run must not poison the plane for LATER
    width-consistent runs: both the drifted run (dict path) and the later
    runs (plane path) replay bit-exactly."""
    wq = WorkQueue(num_workers=2)
    wq.add_tasks(0, 12)
    ra = wq.claim(0, k=3, now=0.0)
    rb = wq.claim(1, k=3, now=0.0)
    wq.finish(ra[:1], now=1.0, domain_out=np.full((1, 3), 0.1))  # sets width
    wq.finish(ra[1:2], now=1.5, domain_out=np.full((1, 2), 0.2))  # drift!
    wq.claim(0, k=1, now=2.0)                      # breaks the finish run
    rows_later = np.concatenate([ra[2:], rb])      # width-consistent run
    for i, row in enumerate(rows_later):
        wq.finish(np.asarray([row]), now=3.0 + i,
                  domain_out=np.full((1, 3), float(i)))
    fin_plane = wq.log._planes["finish"]
    ref = ColumnStore(wq.store.schema, capacity=1 << 10)
    bat = ColumnStore(wq.store.schema, capacity=1 << 10)
    replay_reference(ref, wq.log.tail(0))
    replay(bat, wq.log.tail(0))
    assert_stores_equal(ref, bat, wq.store.cols)
    # the later run's dom rows DID make it into the plane buffer
    assert fin_plane.dom.n == 1 + len(rows_later)


# ------------------------------------------------------------- compaction
def test_replica_syncs_across_truncates_bit_identical_and_bounded():
    rng = np.random.default_rng(3)
    wq = WorkQueue(num_workers=4)
    steer = SteeringEngine(wq)
    rep = DeltaReplicator(wq, sync_every=6)
    wq.add_tasks(0, 48, domain_in=rng.uniform(0, 1, (48, 3)))
    max_retained, truncates = 0, 0
    for r in range(30):
        drive_random_ops(wq, steer, rng, 1)
        if rep.maybe_sync():
            truncates += 1 if wq.compact_log() else 0
        max_retained = max(max_retained, wq.log.n_retained)
    rep.sync()
    wq.compact_log()
    assert truncates >= 1                      # synced across >=1 truncate
    assert wq.log.base > 0
    # memory bound: the retained log never held the full history
    assert max_retained < len(wq.log)
    view = wq.store.snapshot_view()
    assert rep.store.version == wq.store.version
    assert_stores_equal(view, rep.store, wq.store.cols)
    # and a full steering sweep agrees (the e_replica_lag hard-fail)
    import json
    a = json.dumps(steer.run_all(99.0, view=view), sort_keys=True,
                   default=str)
    b = json.dumps(steer.run_all(99.0, view=rep.snapshot_view()),
                   sort_keys=True, default=str)
    assert a == b


def test_truncate_respects_slowest_consumer_and_explicit_bound():
    log = TxnLog()
    for i in range(10):
        log.append("op", {"i": i}, store_version=i + 1)
    assert log.truncate() == 0                 # no consumers: no-op
    log.register_consumer("fast", 8)
    log.register_consumer("slow", 3)
    assert log.truncate() == 3                 # floor = slowest consumer
    assert log.base == 3 and len(log) == 10 and log.n_retained == 7
    assert log.truncate(upto=5) == 0           # never past the slowest ack
    log.ack("slow", 6)
    assert log.truncate(upto=5) == 2           # explicit bound caps below
    assert log.base == 5
    log.ack("slow", 99)                        # ack past the end is clamped
    log.ack("fast", 99)
    assert log.truncate() == 5
    assert log.n_retained == 0 and len(log) == 10
    assert log.append("op", {"i": 10}, store_version=11) == 10


def test_compacted_reads_raise_instead_of_incomplete_delta():
    log = TxnLog()
    for i in range(8):
        log.append("op", {"i": i}, store_version=2 * (i + 1))
    log.register_consumer("c", 5)
    assert log.truncate() == 5
    assert log.horizon_version == 10           # max dropped store_version
    with pytest.raises(LogCompactedError):
        log.tail(0)
    with pytest.raises(LogCompactedError):
        log.tail_for_version(9)                # needs dropped record v10
    with pytest.raises(LogCompactedError):
        log.records_between(3, 14)
    # at/after the horizon everything still works, absolutely indexed
    assert [r.payload["i"] for r in log.tail_for_version(10)] == [5, 6, 7]
    assert log.index_after_version(12) == 6
    assert [r.payload["i"] for r in log.records_between(10, 14)] == [5, 6]


def test_at_version_degrades_to_since_last_checkpoint():
    wq = WorkQueue(num_workers=2)
    steer = SteeringEngine(wq)
    wq.add_tasks(0, 8)
    checkpoint = wq.store.snapshot_view()      # "last checkpoint"
    wq.claim_all(k=1, now=0.0)
    mid = wq.store.snapshot_view()
    wq.claim_all(k=1, now=1.0)
    # everything up to the checkpoint is durably elsewhere: compact it
    wq.log.register_consumer("ckpt",
                             wq.log.index_after_version(checkpoint.version))
    # genesis replay still fine pre-truncate
    tv = steer.at_version(mid.version)
    assert tv.version == mid.version
    assert wq.log.truncate() > 0 or wq.log.base == 0
    if wq.log.base:                            # compacted: genesis raises,
        with pytest.raises(LogCompactedError):
            steer.at_version(mid.version)
    tv2 = steer.at_version(mid.version, base=checkpoint)   # base still works
    assert np.array_equal(tv2.col("status"), mid.col("status"))


def test_checkpointer_acks_log_consumer(tmp_path):
    from repro.checkpoint.checkpointer import Checkpointer
    wq = WorkQueue(num_workers=2)
    wq.add_tasks(0, 6)
    wq.claim_all(k=1, now=0.0)
    ck = Checkpointer(str(tmp_path), async_write=False)
    ck.save(1, {"x": np.zeros(2)}, wq)
    assert wq.log.consumer_floor() == len(wq.log)
    n = len(wq.log)
    assert wq.compact_log() == n               # whole prefix checkpointed
    wq.claim_all(k=1, now=1.0)                 # life goes on, absolute idx
    assert len(wq.log) == n + 1 and wq.log.n_retained == 1


def test_async_checkpointer_acks_only_after_durable_publish(tmp_path):
    """The ack that licenses compaction must follow the atomic publish:
    after wait() the consumer offset reflects the snapshot-time log length
    (not the write-completion-time one)."""
    from repro.checkpoint.checkpointer import Checkpointer
    wq = WorkQueue(num_workers=2)
    wq.add_tasks(0, 6)
    ck = Checkpointer(str(tmp_path), async_write=True)
    n_at_save = len(wq.log)
    ck.save(1, {"x": np.zeros(2)}, wq)
    wq.claim_all(k=1, now=0.0)            # races the background write
    ck.wait()
    assert wq.log.consumer_floor() == n_at_save
    assert ck.latest_step() == 1          # durable before the ack


def test_records_held_across_truncate_replay_via_dict_fallback():
    """Txn lists snapshotted BEFORE a truncate lose their plane entries —
    replaying them afterwards must take the dict-payload path, never slice
    the rebased plane buffers (silent wrong-rows corruption)."""
    W = 2
    wq = WorkQueue(num_workers=W)
    wq.add_tasks(0, 8)
    for r in range(8):
        wq.claim(r % W, k=1, now=float(r))
    held = wq.log.tail(0)                      # snapshot before compaction
    ref = ColumnStore(wq.store.schema, capacity=1 << 10)
    replay_reference(ref, held)
    wq.log.register_consumer("c", 5)
    assert wq.log.truncate() == 5              # drops 4 of the held claims
    bat = ColumnStore(wq.store.schema, capacity=1 << 10)
    replay(bat, held)                          # plane is rebased: fallback
    assert_stores_equal(ref, bat, wq.store.cols)


def test_malformed_raw_append_does_not_poison_the_plane():
    """A raw append with a hot op name but a garbage field value must leave
    the plane untouched (exception-safe add) so later legitimate runs still
    replay bit-exactly off it."""
    wq = WorkQueue(num_workers=2)
    wq.add_tasks(0, 8)
    rows = wq.claim(0, k=4, now=0.0)
    wq.finish(rows[:1], now=1.0)
    bad = wq.log.append("finish", {"rows": np.array([9]), "now": "oops"},
                        store_version=wq.store.version)
    assert wq.log.records[-1].plane is None    # fell back to dict payload
    for i in range(1, 4):                      # legitimate multi-record run
        wq.finish(rows[i: i + 1], now=2.0 + i)
    held = [r for r in wq.log.tail(0) if r.version != bad]
    ref = ColumnStore(wq.store.schema, capacity=1 << 10)
    bat = ColumnStore(wq.store.schema, capacity=1 << 10)
    replay_reference(ref, held)
    replay(bat, held)
    assert_stores_equal(ref, bat, wq.store.cols)


def test_zero_width_domain_out_does_not_misalign_the_plane():
    """domain_out with zero columns is legal through the public finish API
    and must neither crash plane accumulation nor shift later entries."""
    wq = WorkQueue(num_workers=2)
    wq.add_tasks(0, 8)
    rows = wq.claim(0, k=4, now=0.0)
    wq.finish(rows[:1], now=1.0, domain_out=np.empty((1, 0)))
    for i in range(1, 4):
        wq.finish(rows[i: i + 1], now=2.0 + i,
                  domain_out=np.full((1, 3), float(i)))
    ref = ColumnStore(wq.store.schema, capacity=1 << 10)
    bat = ColumnStore(wq.store.schema, capacity=1 << 10)
    replay_reference(ref, wq.log.tail(0))
    replay(bat, wq.log.tail(0))
    assert_stores_equal(ref, bat, wq.store.cols)
    assert_stores_equal(wq.store, bat, wq.store.cols)


def test_restore_resumes_absolute_log_offsets_and_horizon(tmp_path):
    """A restored WorkQueue's log continues at the persisted absolute
    offset with the compaction horizon at the checkpoint version, so
    pre-crash time-travel raises instead of replaying an empty delta."""
    from repro.checkpoint.checkpointer import Checkpointer
    wq = WorkQueue(num_workers=2)
    wq.add_tasks(0, 6)
    wq.claim_all(k=1, now=0.0)
    old_version = wq.store.version - 1
    ck = Checkpointer(str(tmp_path), async_write=False)
    ck.save(1, {"x": np.zeros(2)}, wq)
    n_log = len(wq.log)
    _, _, wq2 = ck.restore({"x": np.zeros(2)})
    assert len(wq2.log) == n_log and wq2.log.base == n_log
    assert wq2.log.horizon_version == wq.store.version
    with pytest.raises(LogCompactedError):
        SteeringEngine(wq2).at_version(old_version)
    base = wq2.store.snapshot_view()           # checkpoint-as-base works
    wq2.claim_all(k=1, now=1.0)
    tv = SteeringEngine(wq2).at_version(wq2.store.version, base=base)
    assert np.array_equal(tv.col("status"), wq2.store.col("status"))


def test_ack_does_not_resurrect_closed_consumer():
    """sync() after close() must not re-pin the compaction floor."""
    wq = WorkQueue(num_workers=2)
    wq.add_tasks(0, 4)
    rep = DeltaReplicator(wq)
    rep.sync()
    rep.close()
    wq.claim_all(k=1, now=0.0)
    rep.sync()                                 # acks a released name: no-op
    assert wq.log.consumer_floor() is None
    assert wq.log.ack("never-registered", 3) is False


def test_dropped_replica_unpins_compaction_floor():
    """A DeltaReplicator that is garbage-collected without close() must not
    pin the consumer floor forever (that would disable compaction and
    reintroduce the unbounded-log memory leak)."""
    import gc
    wq = WorkQueue(num_workers=2)
    wq.add_tasks(0, 4)
    rep = DeltaReplicator(wq)
    rep.sync()
    wq.claim_all(k=1, now=0.0)
    assert wq.log.consumer_floor() is not None
    del rep
    gc.collect()
    assert wq.log.consumer_floor() is None     # finalizer unregistered it
    # and deterministic close() does the same without waiting for GC
    rep2 = DeltaReplicator(wq)
    rep2.sync()
    rep2.close()
    assert wq.log.consumer_floor() is None


# ------------------------------------------------ satellites: fast checks
def test_legality_matrix_matches_transitions():
    for frm, tos in TRANSITIONS.items():
        for to in Status:
            assert LEGAL_TRANSITIONS[int(frm), int(to)] == (to in tos), \
                (frm, to)


def test_vectorized_check_transition_still_raises():
    wq = WorkQueue(num_workers=2)
    wq.add_tasks(0, 4)
    rows = wq.claim(0, k=2)
    wq.finish(rows, now=1.0)
    with pytest.raises(ValueError, match="illegal transition"):
        wq.finish(rows, now=2.0)


def test_ready_counts_track_every_transition():
    rng = np.random.default_rng(5)
    wq = WorkQueue(num_workers=3)
    steer = SteeringEngine(wq)
    wq.add_tasks(0, 24, domain_in=rng.uniform(0, 1, (24, 3)))
    drive_random_ops(wq, steer, rng, 12)
    st_, wid = wq.store.col("status"), wq.store.col("worker_id")
    rw = wid[st_ == int(Status.READY)]
    want = np.bincount(rw[(rw >= 0) & (rw < wq.num_workers)],
                       minlength=wq.num_workers)
    assert np.array_equal(wq.ready_counts(), want)


def test_steal_victim_from_counts_after_prune():
    """Pruned rows must leave the counts, or _steal picks a dry victim."""
    wq = WorkQueue(num_workers=3)
    wq.add_tasks(0, 9, domain_in=np.stack(
        [np.arange(9.0), np.arange(9.0), np.arange(9.0)], axis=1))
    steer = SteeringEngine(wq)
    # prune worker 0's partition rows (task_id % 3 == 0 -> in0 in {0,3,6})
    n = steer.prune("in0", -0.5, 0.5)
    assert n == 1
    while len(wq.claim(1, k=1)):
        pass                                   # drain worker 1's partition
    stolen = wq.claim(1, k=1, allow_steal=True)
    assert len(stolen) == 1
    assert wq.store.col("task_id")[stolen[0]] % 3 != 1


def test_claim_all_pool_rescues_negative_worker_id_rows():
    """READY rows with worker_id < 0 (schema default, reachable via the
    documented out-of-band mutation + invalidate_cursors flow) are outside
    every partition, but claim_all's steal pool must still hand them out —
    same as claim_all_reference and the pre-counts suffix-scan pool."""
    wq = WorkQueue(num_workers=2)
    wq.add_tasks(0, 2)
    wq.store.update(np.asarray([0, 1]), worker_id=-1)
    wq.invalidate_cursors(np.asarray([0, 1]))
    ref = WorkQueue(num_workers=2, store=wq.store.from_view(
        wq.store.snapshot_view(), wq.store.schema))
    out = wq.claim_all(k=1, now=0.0)
    want = ref.claim_all_reference(k=1, now=0.0)
    assert {w: v.tolist() for w, v in out.items()} \
        == {w: v.tolist() for w, v in want.items()}
    assert sum(len(v) for v in out.values()) == 2   # both rows rescued


def test_q1_q6_match_per_group_reference_loops():
    rng = np.random.default_rng(7)
    wq = WorkQueue(num_workers=5)
    steer = SteeringEngine(wq)
    for a in range(3):
        wq.add_tasks(a, 20, now=0.0)
    for r in range(4):
        out = wq.claim_all(k=2, now=float(r) * 10)
        rows = np.concatenate([v for v in out.values() if len(v)])
        wq.fail(rows[: len(rows) // 5], now=float(r) * 10 + 1)
        wq.finish(rows[len(rows) // 5:], now=float(r) * 10 + 2,
                  domain_out=rng.normal(0.5, 0.3,
                                        (len(rows) - len(rows) // 5, 3)))
    now, horizon = 40.0, 25.0
    st_, wid, t0 = (wq.store.col(c) for c in
                    ("status", "worker_id", "start_time"))
    fails = wq.store.col("fail_trials")
    recent = (t0 >= now - horizon) & (st_ != int(Status.EMPTY))
    want_q1 = {}
    for w in np.unique(wid[recent]):           # the seed per-worker loop
        m = recent & (wid == w)
        want_q1[int(w)] = {
            "started": int(m.sum()),
            "finished": int((st_[m] == int(Status.FINISHED)).sum()),
            "failures": int(fails[m].sum())}
    assert steer.q1_recent_status_by_node(now, horizon) == want_q1

    act, t1 = wq.store.col("activity_id"), wq.store.col("end_time")
    fin = st_ == int(Status.FINISHED)
    open_acts = np.unique(act[np.isin(
        st_, [int(Status.READY), int(Status.RUNNING)])])
    want_q6 = {}
    for a in open_acts:                        # the seed per-activity loop
        m = fin & (act == a)
        if m.any():
            d = (t1 - t0)[m]
            want_q6[int(a)] = (float(d.mean()), float(d.max()))
    got_q6 = steer.q6_activity_times()
    assert set(got_q6) == set(want_q6)
    for a in want_q6:
        assert got_q6[a][0] == pytest.approx(want_q6[a][0], rel=1e-12)
        assert got_q6[a][1] == want_q6[a][1]
    assert list(got_q6) == sorted(got_q6, key=lambda a: -got_q6[a][0])


def test_q2_plain_argsort_matches_lexsort():
    wq = WorkQueue(num_workers=2)
    wq.add_tasks(0, 12)
    rows = wq.claim(0, k=6)
    wq.finish(rows, now=1.0)
    bi = np.asarray([5, 3, 5, 9, 3, 7])        # ties exercise stability
    wq.store.update(rows, bytes_in=bi)
    steer = SteeringEngine(wq)
    got = steer.q2_bytes_by_task(0, now=2.0, horizon=10.0)
    st_ = wq.store.col("status")
    want = rows[np.lexsort((st_[rows], -bi))]
    assert np.array_equal(got, want)


# -------------------------------------- consumer lag / offset edge cases
def test_consumer_lags_empty_without_consumers_and_truncate_noop():
    """No registered consumer: the lag surface is empty, the floor is None,
    and an unbounded truncate is the conservative no-op (nothing is
    provably durable elsewhere, so nothing may be dropped)."""
    wq = WorkQueue(num_workers=2)
    wq.add_tasks(0, 6)
    wq.claim_all(k=1, now=0.0)
    assert wq.consumer_lags() == {}
    assert wq.log.consumer_offsets() == {}
    assert wq.log.consumer_floor() is None
    n = len(wq.log)
    assert wq.compact_log() == 0
    assert len(wq.log) == n and wq.log.base == 0


def test_consumer_lags_track_acks_and_offsets_are_a_copy():
    wq = WorkQueue(num_workers=2)
    wq.add_tasks(0, 4)
    wq.log.register_consumer("ckpt")
    wq.claim_all(k=1, now=0.0)
    end = len(wq.log)
    assert wq.consumer_lags() == {"ckpt": end}
    wq.log.ack("ckpt", end - 1)
    assert wq.consumer_lags() == {"ckpt": 1}
    # consumption only moves forward: a stale ack cannot regress the lag
    assert wq.log.ack("ckpt", 0) is True
    assert wq.consumer_lags() == {"ckpt": 1}
    # the offsets view is a snapshot copy, not the live map
    offs = wq.log.consumer_offsets()
    offs["ckpt"] = 0
    assert wq.log.consumer_offsets() == {"ckpt": end - 1}


def test_consumer_closed_mid_truncate_releases_its_floor_pin():
    """A consumer unregistered between acks stops pinning the compaction
    floor: the next truncate recomputes min-over-survivors, and a late ack
    from the closed consumer is ignored (returns False) rather than
    resurrecting it."""
    wq = WorkQueue(num_workers=2)
    wq.add_tasks(0, 8)
    wq.log.register_consumer("fast")
    wq.log.register_consumer("slow")
    wq.claim_all(k=1, now=0.0)
    end = len(wq.log)
    wq.log.ack("fast", end)
    wq.log.ack("slow", 1)
    assert wq.log.consumer_floor() == 1        # laggard pins the prefix
    assert wq.compact_log() == 1
    assert wq.log.base == 1
    wq.log.unregister_consumer("slow")         # closed mid-cycle
    assert wq.log.consumer_floor() == end      # floor recomputed
    assert wq.compact_log() == end - 1         # survivor's prefix drops
    assert wq.log.base == end
    assert wq.log.ack("slow", 2) is False      # no resurrection...
    assert wq.log.consumer_floor() == end      # ...and no re-pin
    assert wq.consumer_lags() == {"fast": 0}
    # a consumer registering AFTER compaction starts at the new base
    assert wq.log.register_consumer("late", offset=0) == end
