"""Transport layer: framed byte pipes under the replication fabric.

The fabric only ever assumes the :class:`~repro.core.transport.Transport`
contract — message boundaries preserved, order preserved, ``EOFError`` on
peer loss, ``try_send`` never hangs — so these tests pin exactly that
contract on both implementations (socketpair loopback for TCP; a real
listener/connect pair for the host:port path the multi-host deployment
uses).
"""
import threading

import numpy as np
import pytest

from repro.core import transport as tp


def both_pairs():
    a, b = tp.TCPTransport.pair()
    yield "tcp", a, b
    import multiprocessing
    c1, c2 = multiprocessing.Pipe()
    yield "pipe", tp.PipeTransport(c1), tp.PipeTransport(c2)


@pytest.mark.parametrize("kind", ["tcp", "pipe"])
def test_frames_roundtrip_order_and_boundaries(kind):
    pair = {k: (a, b) for k, a, b in both_pairs()}
    a, b = pair[kind]
    frames = [b"", b"x", b"hello" * 100, np.arange(1000).tobytes()]
    for f in frames:
        a.send_bytes(f)
    got = [b.recv_bytes() for _ in frames]
    assert got == frames                   # boundaries and order survive
    # and the reverse direction works on the same pair
    b.send_bytes(b"reply")
    assert a.recv_bytes() == b"reply"
    a.close()
    b.close()


def test_tcp_large_frame_crosses_in_one_piece():
    a, b = tp.TCPTransport.pair()
    big = np.random.default_rng(0).integers(0, 255, 5 << 20,
                                            dtype=np.uint8).tobytes()
    t = threading.Thread(target=a.send_bytes, args=(big,))
    t.start()                              # > socket buffer: needs a reader
    assert b.recv_bytes() == big
    t.join()
    a.close()
    b.close()


def test_tcp_poll_and_eof_on_peer_close():
    a, b = tp.TCPTransport.pair()
    assert not b.poll(0.0)
    a.send_bytes(b"ping")
    assert b.poll(1.0)
    assert b.recv_bytes() == b"ping"
    a.close()
    with pytest.raises(EOFError):
        b.recv_bytes()
    b.close()


def test_tcp_rejects_corrupt_length_prefix():
    a, b = tp.TCPTransport.pair()
    a.sock.sendall(b"\xff" * 8)            # not a credible frame length
    with pytest.raises(tp.TransportError):
        b.recv_bytes()
    a.close()
    b.close()


def test_try_send_never_raises_on_dead_peer():
    a, b = tp.TCPTransport.pair()
    b.close()
    # first try_send may land in the socket buffer; repeated ones must
    # settle to False without ever raising — the close()/__del__ path
    results = [a.try_send(b"Q", timeout=0.2) for _ in range(3)]
    assert results[-1] is False
    a.close()
    assert a.try_send(b"Q", timeout=0.2) is False   # closed fd: still safe

    import multiprocessing
    c1, c2 = multiprocessing.Pipe()
    p1, p2 = tp.PipeTransport(c1), tp.PipeTransport(c2)
    p2.close()
    results = [p1.try_send(b"Q", timeout=0.2) for _ in range(3)]
    assert results[-1] is False
    p1.close()
    assert p1.try_send(b"Q", timeout=0.2) is False


def test_listener_accept_connect_host_port():
    listener = tp.TCPListener()
    host, port = listener.address
    assert host == "127.0.0.1" and port > 0
    out = {}

    def client():
        c = tp.connect_tcp(host, port)
        c.send_bytes(b"hello from another process, in spirit")
        out["reply"] = c.recv_bytes()
        c.close()

    t = threading.Thread(target=client)
    t.start()
    server = listener.accept(timeout=10)
    listener.close()
    assert server.recv_bytes().startswith(b"hello")
    server.send_bytes(b"ack")
    t.join()
    assert out["reply"] == b"ack"
    server.close()


def test_listener_accept_times_out_without_client():
    listener = tp.TCPListener()
    with pytest.raises(TimeoutError):
        listener.accept(timeout=0.05)
    listener.close()


def test_child_endpoint_spec_dispatch():
    with pytest.raises(ValueError, match="transport spec"):
        tp.child_endpoint(("carrier-pigeon",))
    listener = tp.TCPListener()
    host, port = listener.address
    done = {}

    def child():
        c = tp.child_endpoint(("tcp", host, port))
        c.send_bytes(b"up")
        done["sent"] = True
        c.close()

    t = threading.Thread(target=child)
    t.start()
    server = listener.accept(timeout=10)
    assert server.recv_bytes() == b"up"
    t.join()
    assert done["sent"]
    server.close()
    listener.close()


@pytest.mark.parametrize("kind", ["tcp", "pipe"])
def test_send_chunks_is_one_frame(kind):
    """A multi-chunk (scatter-gather) send arrives as ONE frame identical
    to the joined bytes — the pipelined shipper's coalesced D messages."""
    pair = {k: (a, b) for k, a, b in both_pairs()}
    a, b = pair[kind]
    chunks = [b"D" + b"\x00" * 24, b"hot" * 500, b"", np.arange(64).tobytes()]
    a.send_chunks(chunks)
    a.send_bytes(b"after")                 # framing stays aligned
    assert b.recv_bytes() == b"".join(chunks)
    assert b.recv_bytes() == b"after"
    # a large multi-chunk frame (past any single sendmsg) still coheres
    big = [np.random.default_rng(i).bytes(1 << 20) for i in range(4)]
    t = threading.Thread(target=a.send_chunks, args=(big,))
    t.start()
    got = b.recv_bytes()
    t.join()
    assert got == b"".join(big)
    a.close()
    b.close()


def test_recv_timeout_raises_instead_of_hanging():
    """A live-but-silent peer (socket open, nothing arriving) surfaces as a
    TransportError once recv_timeout elapses, naming the partial frame."""
    a, b = tp.TCPTransport.pair()
    a.recv_timeout = 0.05
    with pytest.raises(tp.TransportError, match="timed out"):
        a.recv_bytes()
    # a mid-frame stall is caught too: prefix arrives, payload never does
    b.sock.sendall(tp._LEN.pack(64))
    with pytest.raises(tp.TransportError, match="0/64 bytes"):
        a.recv_bytes()
    # and a timeout is NOT sticky: traffic after the stall still flows
    b.send_bytes(b"late")
    assert a.recv_bytes() == b"late"
    a.close()
    b.close()


def test_connect_tcp_retries_with_bounded_backoff():
    """No listener yet: connect_tcp must retry (doubling delay) and give up
    by max_retries — bounded attempts, not a 20 Hz hammer for the full
    deadline window."""
    import socket as socketlib
    import time as timelib
    # grab a port with no listener on it
    probe = socketlib.socket()
    probe.bind(("127.0.0.1", 0))
    port = probe.getsockname()[1]
    probe.close()
    t0 = timelib.monotonic()
    with pytest.raises(OSError):
        tp.connect_tcp("127.0.0.1", port, timeout=30.0,
                       retry_every=0.01, max_retry_every=0.02,
                       max_retries=3)
    took = timelib.monotonic() - t0
    # 3 retries at 0.01 + 0.02 + 0.02 ~= 0.05s — nowhere near the 30s
    # deadline, proving max_retries bounded the attempt budget
    assert took < 5.0


def test_connect_tcp_succeeds_after_listener_appears():
    """The spawn race the backoff exists for: the client starts connecting
    BEFORE the listener binds, and wins once it appears."""
    import time as timelib
    lst_box = {}
    # bind first to learn the port, close, reopen late on the same port
    lst = tp.TCPListener()
    host, port = lst.address
    lst.close()

    def reopen():
        timelib.sleep(0.15)
        lst_box["l"] = tp.TCPListener(host, port)
        lst_box["conn"] = lst_box["l"].accept(timeout=5.0)

    th = threading.Thread(target=reopen)
    th.start()
    try:
        client = tp.connect_tcp(host, port, timeout=5.0, retry_every=0.01)
        th.join()
        client.send_bytes(b"made it")
        assert lst_box["conn"].recv_bytes() == b"made it"
        client.close()
        lst_box["conn"].close()
    finally:
        th.join()
        lst_box["l"].close()
