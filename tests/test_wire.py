"""Wire codec + cross-process shipped replication.

Round-trip property: encode -> decode -> replay of any logged workload is
bit-identical to record-at-a-time replay of the original records (mixed
dom widths, no-dom finishes, zero-width doms, records straddling a
``TxnLog.truncate``). Process tests: a ``ShippedDeltaReplicator`` in a
spawned OS process stays bit-identical to the primary across truncations,
survives being killed mid-ship (re-sync from the last acked offset), and
performs recover/promote remotely. Plus the delta-bytes accounting
regression: sync bookkeeping is transactional with the applied offset.
"""
import json
import os

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import Status, SteeringEngine, WorkQueue
from repro.core import wire
from repro.core.replication import DeltaReplicator, ShippedDeltaReplicator, \
    replay, replay_reference
from repro.core.store import ColumnStore


def sweep_key(res):
    return json.dumps(res, sort_keys=True, default=str)


def fresh_store(wq):
    return ColumnStore(wq.store.schema, capacity=max(256, 2 * wq.store.n_rows))


def assert_stores_equal(a, b, names):
    for name in names:
        assert np.array_equal(a.col(name), b.col(name),
                              equal_nan=True), name
    assert a.version == b.version


def mixed_workload(wq, rng, rounds=10, widths=(3, 2, 0)):
    """Claims, finishes with MIXED domain widths (incl. zero-width and
    no-dom), fails, requeue, steering patch/prune, resize — every op kind,
    with finish runs that are plane-servable, width-drifted, and mixed."""
    steer = SteeringEngine(wq)
    for r in range(rounds):
        out = wq.claim_all(k=int(rng.integers(1, 3)), now=float(r))
        rows = np.concatenate([v for v in out.values() if len(v)]) \
            if any(len(v) for v in out.values()) else np.empty(0, np.int64)
        if len(rows) == 0:
            break
        if r % 4 == 2 and len(rows) > 1:
            wq.fail(rows[:1], now=float(r) + 0.1)
            rows = rows[1:]
        if r == 3:
            victim = wq.num_workers - 1
            wid = wq.store.col("worker_id")[rows]
            wq.requeue_worker(victim)
            rows = rows[wid != victim]
        for ch in np.array_split(rows, min(3, max(len(rows), 1))):
            if not len(ch):
                continue
            if rng.integers(0, 4) == 0:
                wq.finish(ch, now=float(r) + 0.5)          # no dom payload
            else:
                w = int(widths[int(rng.integers(0, len(widths)))])
                wq.finish(ch, now=float(r) + 0.5,
                          domain_out=rng.normal(0.5, 0.3, (len(ch), w)))
        if r == 4:
            steer.q8_patch_ready(0, "in0", 7.0, predicate=lambda v: v > 0.5)
        if r == 5:
            wq.add_tasks(0, 3, domain_in=np.full((3, 3), 0.05),
                         now=float(r))         # guaranteed prune matches
            steer.prune("in1", 0.0, 0.1)
        if r == 6 and wq.num_workers > 2:
            wq.resize(wq.num_workers - 1)


# ------------------------------------------------------------ codec core
def test_wire_roundtrip_every_op_type_bit_exactly():
    rng = np.random.default_rng(0)
    wq = WorkQueue(num_workers=4)
    wq.add_tasks(0, 48, domain_in=rng.uniform(0, 1, (48, 3)))
    mixed_workload(wq, rng)
    recs = wq.log.tail(0)
    ops = {r.op for r in recs}
    assert {"insert", "claim_all", "finish", "fail", "requeue_worker",
            "steer_patch", "steer_prune", "resize"} <= ops
    buf = wire.delta_to_bytes(recs)
    assert wire.frames_nbytes(recs) == len(buf)
    dec = wire.decode_delta(buf)
    assert len(dec) == len(recs)
    s_ref, s_dec = fresh_store(wq), fresh_store(wq)
    replay_reference(s_ref, recs)
    replay(s_dec, dec)
    assert_stores_equal(s_ref, s_dec, wq.store.cols)
    assert_stores_equal(wq.store, s_dec, wq.store.cols)


def test_wire_single_record_hot_frames_and_claim_op():
    """Per-worker claim records (worker column on the wire) and 1-record
    hot runs (replayed through the lazy payload path) round-trip."""
    wq = WorkQueue(num_workers=3)
    wq.add_tasks(0, 9)
    for w in range(3):
        wq.claim(w, k=1, now=float(w) + 0.25)
        wq.finish(wq.store.where(worker_id=w,
                                 status=int(Status.RUNNING)),
                  now=float(w) + 0.5,
                  domain_out=np.full((1, 3), w, float))
    recs = wq.log.tail(0)
    dec = wire.decode_delta(wire.delta_to_bytes(recs))
    # claim/finish alternate: every hot run is a single record, so replay
    # must reconstruct payloads lazily from the received plane
    s_dec = fresh_store(wq)
    replay(s_dec, dec)
    assert_stores_equal(wq.store, s_dec, wq.store.cols)
    claims = [d for d in dec if d.op == "claim"]
    assert [d.payload["worker"] for d in claims] == [0, 1, 2]


def test_wire_records_straddling_truncate_fall_back_to_cold_frames():
    """Records held across a TxnLog.truncate lose their plane entries; the
    codec must ship them from their frozen payloads (cold frames), not
    mis-slice retained plane rows."""
    wq = WorkQueue(num_workers=2)
    wq.add_tasks(0, 12)
    for r in range(4):
        wq.claim(r % 2, k=1, now=float(r))
    held = wq.log.tail(0)                   # hold refs across the truncate
    wq.log.register_consumer("c", len(wq.log))
    wq.log.truncate()
    assert wq.log.base > 0
    for r in range(4, 6):
        wq.claim(r % 2, k=1, now=float(r))  # appended AFTER the truncate
    recs = held + wq.log.tail(wq.log.base)
    buf = wire.delta_to_bytes(recs)
    assert wire.frames_nbytes(recs) == len(buf)
    dec = wire.decode_delta(buf)
    # the pre-truncate hot-op records must have shipped cold (no rx plane)
    assert any(d.plane is None and d.op == "claim"
               for d in dec[:len(held)])
    s_ref, s_dec = fresh_store(wq), fresh_store(wq)
    replay_reference(s_ref, recs)
    replay(s_dec, dec)
    assert_stores_equal(s_ref, s_dec, wq.store.cols)


def test_wire_rejects_garbage():
    with pytest.raises(wire.WireError):
        wire.decode_delta(b"\x00" * 32)
    wq = WorkQueue(num_workers=2)
    wq.add_tasks(0, 2)
    buf = wire.delta_to_bytes(wq.log.tail(0))
    with pytest.raises(wire.WireError):
        wire.decode_delta(buf[: len(buf) - 3])


# --------------------------------------------------- varint codec parity
def test_varint_primitives_roundtrip_extremes():
    i64 = np.iinfo(np.int64)
    vals = np.array([0, 1, -1, 127, 128, -128, i64.max, i64.min,
                     i64.max - 1, i64.min + 1], np.int64)
    enc = wire._enc_delta_i64(vals)
    dec, cur = wire._dec_delta_i64(enc, len(vals), 0)
    assert cur == len(enc)
    assert np.array_equal(dec, vals)
    f = np.array([0.0, -0.0, np.nan, np.inf, -np.inf, 1e-308, 1e308,
                  3.14, 3.15], np.float64)
    encf = wire._enc_f64_dd(f)
    decf, cur = wire._dec_f64_dd(encf, len(f), 0)
    assert cur == len(encf)
    # bit-pattern equality: -0.0 and NaN payloads must survive exactly
    assert np.array_equal(decf.view(np.uint64), f.view(np.uint64))


@settings(max_examples=15, deadline=None)
@given(n=st.integers(0, 200), seed=st.integers(0, 99))
def test_property_varint_streams_roundtrip(n, seed):
    rng = np.random.default_rng(seed)
    ints = rng.integers(-(1 << 62), 1 << 62, n, dtype=np.int64)
    dec, cur = wire._dec_delta_i64(wire._enc_delta_i64(ints), n, 0)
    assert np.array_equal(dec, ints)
    floats = rng.normal(scale=10.0 ** rng.integers(-5, 5), size=n)
    decf, _ = wire._dec_f64_dd(wire._enc_f64_dd(floats), n, 0)
    assert np.array_equal(decf.view(np.uint64),
                          np.ascontiguousarray(floats).view(np.uint64))


def test_compressed_codec_bit_exact_vs_raw_oracle():
    """The varint codec must replay bit-identically to the raw codec (and
    the record-at-a-time oracle) on the full mixed op inventory."""
    rng = np.random.default_rng(7)
    wq = WorkQueue(num_workers=4)
    wq.add_tasks(0, 48, domain_in=rng.uniform(0, 1, (48, 3)))
    mixed_workload(wq, rng)
    recs = wq.log.tail(0)
    buf_raw = wire.delta_to_bytes(recs, codec="raw")
    buf_c = wire.delta_to_bytes(recs, codec="varint")
    assert wire.frames_nbytes(recs, "raw") == len(buf_raw)
    assert wire.frames_nbytes(recs, "varint") == len(buf_c)
    s_ref, s_c = fresh_store(wq), fresh_store(wq)
    replay_reference(s_ref, recs)
    replay(s_c, wire.decode_delta(buf_c))
    assert_stores_equal(s_ref, s_c, wq.store.cols)
    assert_stores_equal(wq.store, s_c, wq.store.cols)
    # cold frames are byte-identical across codecs; hot frames shrink
    d_raw = wire.frames_nbytes_detail(recs, "raw")
    d_c = wire.frames_nbytes_detail(recs, "varint")
    assert d_raw["cold"] == d_c["cold"]
    assert d_c["hot"] < d_raw["hot"]


@settings(max_examples=10, deadline=None)
@given(workers=st.integers(1, 6), tasks=st.integers(0, 60),
       seed=st.integers(0, 99))
def test_property_compressed_roundtrip_random_workloads(workers, tasks,
                                                       seed):
    rng = np.random.default_rng(seed)
    wq = WorkQueue(num_workers=workers)
    if tasks:
        wq.add_tasks(0, tasks, domain_in=rng.uniform(0, 1, (tasks, 3)))
    mixed_workload(wq, rng, rounds=8)
    recs = wq.log.tail(0)
    buf = wire.delta_to_bytes(recs, codec="varint")
    assert wire.frames_nbytes(recs, "varint") == len(buf)
    s_ref, s_dec = fresh_store(wq), fresh_store(wq)
    replay_reference(s_ref, recs)
    replay(s_dec, wire.decode_delta(buf))
    assert_stores_equal(s_ref, s_dec, wq.store.cols)


def test_compressed_claim_frames_hit_ratio_target():
    """Per-worker claim records — the op the ROADMAP targeted — must
    compress well past the gated 2x on their hot frames (row indices and
    versions are near-unit deltas; timestamps double-delta to ~1 byte)."""
    wq = WorkQueue(num_workers=8)
    wq.add_tasks(0, 1000)
    for r in range(1000):
        wq.claim(r % 8, k=1, now=float(r) * 0.25)
    recs = [r for r in wq.log.tail(0) if r.op == "claim"]
    d_raw = wire.frames_nbytes_detail(recs, "raw")
    d_c = wire.frames_nbytes_detail(recs, "varint")
    assert d_raw["hot"] / d_c["hot"] >= 4.0     # measured ~6-7x
    assert d_raw["cold"] == d_c["cold"] == 0


def test_negotiate_prefers_varint_falls_back_raw():
    assert wire.negotiate(["varint", "raw"]) == "varint"
    assert wire.negotiate(["raw", "varint"]) == "raw"
    assert wire.negotiate(["zstd-from-the-future"]) == "raw"
    assert wire.negotiate([]) == "raw"
    # the default offer leads with the per-frame adaptive codec
    assert wire.negotiate(wire.CODECS) == "adaptive"


@settings(max_examples=15, deadline=None)
@given(workers=st.integers(1, 6), tasks=st.integers(0, 60),
       seed=st.integers(0, 99))
def test_property_wire_roundtrip_random_workloads(workers, tasks, seed):
    rng = np.random.default_rng(seed)
    wq = WorkQueue(num_workers=workers)
    if tasks:
        wq.add_tasks(0, tasks, domain_in=rng.uniform(0, 1, (tasks, 3)))
    mixed_workload(wq, rng, rounds=8)
    recs = wq.log.tail(0)
    buf = wire.delta_to_bytes(recs)
    assert wire.frames_nbytes(recs) == len(buf)
    dec = wire.decode_delta(buf)
    s_ref, s_dec = fresh_store(wq), fresh_store(wq)
    replay_reference(s_ref, recs)
    replay(s_dec, dec)
    assert_stores_equal(s_ref, s_dec, wq.store.cols)
    assert_stores_equal(wq.store, s_dec, wq.store.cols)


# ------------------------------------------- delta-bytes accounting fix
def test_sync_accounting_transactional_on_midtail_failure():
    """A sync that raises mid-tail must have counted (and consumed) exactly
    the applied prefix — retrying neither re-applies nor re-counts it."""
    wq = WorkQueue(num_workers=2)
    rep = DeltaReplicator(wq)
    wq.add_tasks(0, 8)
    wq.claim(0, k=1, now=0.0)
    prefix = wq.log.tail(0)
    wq.log.append("mystery_op", {"n": 1}, store_version=wq.store.version)
    wq.claim(1, k=1, now=1.0)
    want_bytes = sum(r.payload_nbytes() for r in prefix)
    want_encoded = wire.frames_nbytes(prefix)
    with pytest.raises(ValueError, match="mystery_op"):
        rep.sync()
    assert rep.delta_bytes == want_bytes
    assert rep.encoded_bytes == want_encoded
    assert rep.offset == len(prefix)          # consumed exactly the prefix
    assert rep.records_applied == len(prefix)
    with pytest.raises(ValueError, match="mystery_op"):
        rep.sync()                             # retry: nothing re-counted
    assert rep.delta_bytes == want_bytes
    assert rep.records_applied == len(prefix)


def test_sync_transient_failure_then_retry_counts_each_record_once(
        monkeypatch):
    """Transient apply failure: the retry applies (and counts) only the
    un-consumed suffix, and the replica still reaches bit-parity."""
    from repro.core import replication as R
    wq = WorkQueue(num_workers=2)
    rep = DeltaReplicator(wq)
    steer = SteeringEngine(wq)
    wq.add_tasks(0, 8)
    wq.claim(0, k=2, now=0.0)
    steer.q8_patch_ready(0, "in0", 3.0)        # single-record _APPLY run
    wq.claim(1, k=2, now=1.0)
    orig = R._APPLY["steer_patch"]
    boom = {"armed": True}

    def flaky(store, p):
        if boom.pop("armed", False):
            raise RuntimeError("transient apply failure")
        orig(store, p)

    monkeypatch.setitem(R._APPLY, "steer_patch", flaky)
    with pytest.raises(RuntimeError, match="transient"):
        rep.sync()
    applied_at_failure = rep.records_applied
    assert 0 < applied_at_failure < len(wq.log)
    rep.sync()                                 # retry resumes, not restarts
    assert rep.records_applied == len(wq.log)
    assert rep.delta_bytes == sum(r.payload_nbytes()
                                  for r in wq.log.tail(0))
    assert rep.encoded_bytes > 0
    view = wq.store.snapshot_view()
    for name in wq.store.cols:
        assert np.array_equal(view.col(name), rep.store.col(name),
                              equal_nan=True), name


# --------------------------------------------------- cross-process ship
def test_shipped_replicator_parity_across_truncate_and_promote():
    rng = np.random.default_rng(3)
    wq = WorkQueue(num_workers=4)
    steer = SteeringEngine(wq)
    rep = ShippedDeltaReplicator(wq, sync_every=8)
    assert rep.remote_pid is not None and rep.remote_pid != os.getpid()
    wq.add_tasks(0, 48, domain_in=rng.uniform(0, 1, (48, 3)))
    mixed_workload(wq, rng, rounds=6)
    rep.sync()
    assert wq.compact_log() > 0                # replica acked -> truncate
    mixed_workload(wq, rng, rounds=3)          # ship ACROSS the truncate
    view = wq.store.snapshot_view()
    rep.sync(upto_version=view.version)
    assert sweep_key(rep.remote_sweep(42.0)) \
        == sweep_key(steer.run_all(42.0, view=view))
    state = rep.fetch_remote_state()
    assert state["pid"] != os.getpid()
    for name in wq.store.cols:
        assert np.array_equal(view.col(name), state["snapshot"]["cols"][name],
                              equal_nan=True), name
    assert rep.encoded_bytes > 0
    wq2 = rep.promote()                        # remote failover
    assert (wq2.store.col("status") != int(Status.RUNNING)).all()
    assert wq2.num_workers == rep.num_workers
    assert wq2.add_tasks(0, 2).min() >= wq.store.n_rows  # fresh ids


def test_shipped_replica_death_mid_ship_resyncs_without_parity_loss():
    rng = np.random.default_rng(4)
    wq = WorkQueue(num_workers=3)
    steer = SteeringEngine(wq)
    rep = ShippedDeltaReplicator(wq, sync_every=4)
    wq.add_tasks(0, 30, domain_in=rng.uniform(0, 1, (30, 3)))
    mixed_workload(wq, rng, rounds=4)
    rep.sync()
    acked = rep.offset
    rep.process.kill()                         # dies with un-shipped state
    mixed_workload(wq, rng, rounds=3)
    rep.sync()                                 # respawn + catch-up
    assert rep.spawn_count == 2
    assert rep.offset >= acked                 # never rewinds past the ack
    view = wq.store.snapshot_view()
    rep.sync(upto_version=view.version)
    assert sweep_key(rep.remote_sweep(77.0)) \
        == sweep_key(steer.run_all(77.0, view=view))
    state = rep.fetch_remote_state()
    for name in wq.store.cols:
        assert np.array_equal(view.col(name), state["snapshot"]["cols"][name],
                              equal_nan=True), name
    rep.close()
    assert not wq.log.has_consumer(rep.consumer)


def test_shipped_replicator_tcp_transport_parity():
    """The identical protocol over a real TCP socket (loopback): separate
    pid, negotiated adaptive codec, parity across a truncate."""
    rng = np.random.default_rng(5)
    wq = WorkQueue(num_workers=3)
    steer = SteeringEngine(wq)
    rep = ShippedDeltaReplicator(wq, sync_every=8, transport="tcp")
    assert rep.transport == "tcp"
    assert rep.codec == "adaptive"         # hello negotiation landed
    assert rep.remote_pid is not None and rep.remote_pid != os.getpid()
    wq.add_tasks(0, 30, domain_in=rng.uniform(0, 1, (30, 3)))
    mixed_workload(wq, rng, rounds=4)
    rep.sync()
    assert wq.compact_log() > 0            # replica acked -> truncate
    mixed_workload(wq, rng, rounds=2)      # ship ACROSS the truncate
    view = wq.store.snapshot_view()
    rep.sync(upto_version=view.version)
    assert sweep_key(rep.remote_sweep(9.0)) \
        == sweep_key(steer.run_all(9.0, view=view))
    state = rep.fetch_remote_state()
    assert state["pid"] != os.getpid()
    for name in wq.store.cols:
        assert np.array_equal(view.col(name), state["snapshot"]["cols"][name],
                              equal_nan=True), name
    rep.close()
    assert not wq.log.has_consumer(rep.consumer)


def test_forced_raw_codec_still_ships_parity():
    """codec="raw" pins the oracle encoding end-to-end — the back-compat
    arm the compressed path is measured against."""
    rng = np.random.default_rng(6)
    wq = WorkQueue(num_workers=2)
    rep = ShippedDeltaReplicator(wq, codec="raw")
    assert rep.codec == "raw"
    wq.add_tasks(0, 12, domain_in=rng.uniform(0, 1, (12, 3)))
    mixed_workload(wq, rng, rounds=3)
    view = wq.store.snapshot_view()
    rep.sync(upto_version=view.version)
    state = rep.fetch_remote_state()
    for name in wq.store.cols:
        assert np.array_equal(view.col(name), state["snapshot"]["cols"][name],
                              equal_nan=True), name
    # raw accounting matches the analytic sizer exactly
    assert rep.encoded_bytes == wire.frames_nbytes(wq.log.tail(0), "raw")
    rep.close()


def test_close_is_idempotent_and_safe_after_child_crash():
    """Satellite regression: close() must not hang or raise on a dead
    child/pipe, a second close must be a no-op, and __del__ must be safe
    after both — the executor's teardown path when a replica died first."""
    wq = WorkQueue(num_workers=2)
    rep = ShippedDeltaReplicator(wq)
    wq.add_tasks(0, 4)
    rep.sync()
    rep.process.kill()                     # child crashes with the pipe up
    rep.process.join()
    rep.close()                            # dead pipe: bounded, no raise
    assert rep.process is None and rep.tr is None
    rep.close()                            # idempotent
    assert not wq.log.has_consumer(rep.consumer)
    rep.__del__()                          # last-resort path: still a no-op

    rep2 = ShippedDeltaReplicator(wq, transport="tcp")
    rep2.process.kill()
    rep2.process.join()
    rep2.close()
    rep2.close()
    rep2.__del__()
    assert not wq.log.has_consumer(rep2.consumer)


def test_shipped_remote_error_surfaces_and_respawns():
    """A poison record makes the REMOTE replay fail: the error must carry
    the remote traceback, and the next sync must recover via respawn."""
    wq = WorkQueue(num_workers=2)
    rep = ShippedDeltaReplicator(wq)
    wq.add_tasks(0, 4)
    wq.log.append("mystery_op", {"n": 1}, store_version=wq.store.version)
    with pytest.raises(RuntimeError, match="mystery_op"):
        rep.sync()
    wq.claim(0, k=1, now=1.0)
    rep.sync()                        # fresh snapshot skips the poison rec
    state = rep.fetch_remote_state()
    view = wq.store.snapshot_view()
    for name in wq.store.cols:
        assert np.array_equal(view.col(name), state["snapshot"]["cols"][name],
                              equal_nan=True), name
    rep.close()


# ------------------------------------------------- sweep-partial codec
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 999), n=st.integers(0, 64))
def test_sweep_partial_codec_round_trip(seed, n):
    """encode/decode of steering sweep partials is bit-exact: scalars by
    value, arrays (any dtype, empty included) by bytes."""
    rng = np.random.default_rng(seed)
    part = {
        "n_workers": int(rng.integers(1, 9)),
        "version": int(rng.integers(0, 1 << 40)),
        "started": rng.integers(0, 99, 4).astype(np.int64),
        "q4": int(rng.integers(0, 99)),
        "q7_sum": float(rng.uniform(-1e6, 1e6)),
        "q7_any": bool(rng.integers(0, 2)),
        "hit_dur": rng.uniform(0, 9, n),
        "anc_ids": rng.integers(0, 1 << 30, n).astype(np.int64),
        "anc_pruned": rng.integers(0, 2, n).astype(bool),
        "q5_counts": np.empty(0, np.int64),
        "q6_max": np.full(3, -np.inf),
    }
    buf = wire.encode_sweep_partial(part)
    back = wire.decode_sweep_partial(buf)
    assert set(back) == set(part)
    for k, v in part.items():
        if isinstance(v, np.ndarray):
            assert back[k].dtype == v.dtype and back[k].shape == v.shape
            assert np.array_equal(back[k], v, equal_nan=True), k
        else:
            assert back[k] == v and type(back[k]) is type(v), k
    # decoded arrays alias the wire buffer: no copy on the analyst path
    if n:
        assert back["anc_ids"].base is not None


def test_sweep_partial_codec_rejects_trailing_garbage():
    buf = wire.encode_sweep_partial({"version": 1,
                                     "xs": np.arange(3, dtype=np.int64)})
    with pytest.raises(wire.WireError, match="body mismatch"):
        wire.decode_sweep_partial(buf + b"\x00")


def test_sweep_partial_of_real_view_round_trips():
    """Partials of an actual store view survive the wire bit-exactly and
    merge to the same result as the un-shipped partials."""
    from repro.core.sharding_router import merge_partials
    from repro.core.steering import sweep_partials
    rng = np.random.default_rng(21)
    wq = WorkQueue(num_workers=4)
    wq.add_tasks(0, 24, domain_in=rng.uniform(0, 1, (24, 3)))
    mixed_workload(wq, rng, rounds=4)
    part = sweep_partials(wq.store.snapshot_view(), 4, 50.0)
    back = wire.decode_sweep_partial(wire.encode_sweep_partial(part))
    assert sweep_key(merge_partials([back])) \
        == sweep_key(merge_partials([part]))
