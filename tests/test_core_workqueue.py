"""WorkQueue unit + property tests (the paper's scheduling invariants)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Status, WorkQueue
from repro.core.partition import assign_workers, imbalance, partition_sizes


def make_wq(workers=4, tasks=20, ready=True):
    wq = WorkQueue(num_workers=workers)
    wq.add_tasks(0, tasks, status=Status.READY if ready else Status.BLOCKED)
    return wq


def test_insert_assigns_round_robin():
    wq = make_wq(workers=4, tasks=16)
    sizes = partition_sizes(wq.store.col("worker_id"), 4)
    assert (sizes == 4).all()


def test_claim_is_partition_private():
    wq = make_wq(workers=4, tasks=16)
    rows = wq.claim(2, k=3)
    assert len(rows) == 3
    assert (wq.store.col("worker_id")[rows] == 2).all()
    assert (wq.store.col("status")[rows] == int(Status.RUNNING)).all()


def test_no_double_claim():
    wq = make_wq(workers=2, tasks=8)
    r1 = wq.claim(0, k=4)
    r2 = wq.claim(0, k=4)
    assert len(np.intersect1d(r1, r2)) == 0


def test_claim_all_claims_every_worker():
    wq = make_wq(workers=4, tasks=16)
    out = wq.claim_all(k=1)
    rows = np.concatenate(list(out.values()))
    assert len(rows) == 4
    assert len(np.unique(rows)) == 4


def test_steal_from_loaded_partition():
    wq = WorkQueue(num_workers=2)
    ids = wq.add_tasks(0, 6)
    # drain worker 0's partition
    while len(wq.claim(0, k=1)):
        pass
    stolen = wq.claim(0, k=1, allow_steal=True)
    assert len(stolen) == 1


def test_finish_and_fail_transitions():
    wq = make_wq(workers=2, tasks=4)
    rows = wq.claim(0, k=2)
    wq.finish(rows[:1], now=1.0, domain_out=np.ones((1, 3)))
    wq.fail(rows[1:], max_trials=2)
    st_ = wq.store.col("status")
    assert st_[rows[0]] == int(Status.FINISHED)
    assert st_[rows[1]] == int(Status.READY)       # first failure -> retry
    rows2 = wq.claim(0, k=1)
    wq.fail(rows2, max_trials=2)
    assert wq.store.col("status")[rows2[0]] == int(Status.FAILED)


def test_illegal_transition_raises():
    wq = make_wq(workers=2, tasks=2)
    rows = wq.claim(0, k=1)
    wq.finish(rows, now=1.0)
    with pytest.raises(ValueError):
        wq.finish(rows, now=2.0)


def test_requeue_worker_reassigns():
    wq = make_wq(workers=3, tasks=9)
    rows = wq.claim(1, k=3)
    n = wq.requeue_worker(1)
    assert n == 3
    st_ = wq.store.col("status")[rows]
    assert (st_ == int(Status.READY)).all()
    assert (wq.store.col("worker_id")[rows] != 1).all()


def test_resize_rehashes_minimally():
    wq = make_wq(workers=4, tasks=32)
    moved = wq.resize(8)
    assert wq.num_workers == 8
    sizes = partition_sizes(wq.store.col("worker_id"), 8)
    assert sizes.sum() == 32
    assert imbalance(wq.store.col("worker_id"), 8) < 0.5


@settings(max_examples=25, deadline=None)
@given(workers=st.integers(1, 8), tasks=st.integers(0, 64),
       k=st.integers(1, 4), steal=st.booleans())
def test_property_claim_conservation(workers, tasks, k, steal):
    """No task lost or duplicated through claim/finish cycles."""
    wq = WorkQueue(num_workers=workers)
    if tasks:
        wq.add_tasks(0, tasks)
    total_claimed = 0
    for _ in range(tasks // max(workers, 1) + 2):
        out = wq.claim_all(k=k, steal=steal)
        rows = np.concatenate([v for v in out.values() if len(v)]) \
            if any(len(v) for v in out.values()) else np.empty(0, int)
        assert len(np.unique(rows)) == len(rows)     # no double claims
        per_w = {w: len(v) for w, v in out.items()}
        if not steal:
            assert all(n <= k for n in per_w.values())
        total_claimed += len(rows)
        if len(rows):
            wq.finish(rows, now=1.0)
        wq.check_invariants()
    c = wq.counts()
    assert c["FINISHED"] == total_claimed == tasks


@settings(max_examples=25, deadline=None)
@given(workers=st.integers(1, 8), tasks=st.integers(0, 80),
       k=st.integers(1, 4), steal=st.booleans(), seed=st.integers(0, 7))
def test_property_vectorized_claim_matches_seed_loop(workers, tasks, k,
                                                     steal, seed):
    """The vectorized claim fast-path is observationally equivalent to the
    seed O(n·W) loop (claim_all_reference): same per-worker rows through
    interleaved claim/finish/fail cycles, same final store state."""
    rng = np.random.default_rng(seed)
    wq_vec = WorkQueue(num_workers=workers)
    wq_ref = WorkQueue(num_workers=workers)
    if tasks:
        wq_vec.add_tasks(0, tasks)
        wq_ref.add_tasks(0, tasks)
    for rnd in range(tasks // max(workers, 1) + 2):
        o1 = wq_vec.claim_all(k=k, steal=steal, now=float(rnd))
        o2 = wq_ref.claim_all_reference(k=k, steal=steal, now=float(rnd))
        assert set(o1) == set(o2)
        for w in o1:
            assert np.array_equal(o1[w], o2[w]), (w, o1[w], o2[w])
        rows = np.concatenate([v for v in o1.values() if len(v)]) \
            if any(len(v) for v in o1.values()) else np.empty(0, np.int64)
        if len(rows):
            # same random mix of finishes and retries on both queues
            fail_mask = rng.random(len(rows)) < 0.3
            if fail_mask.any():
                wq_vec.fail(rows[fail_mask])
                wq_ref.fail(rows[fail_mask])
            if (~fail_mask).any():
                wq_vec.finish(rows[~fail_mask], now=float(rnd) + 0.5)
                wq_ref.finish(rows[~fail_mask], now=float(rnd) + 0.5)
        wq_vec.check_invariants()
    assert np.array_equal(wq_vec.store.col("status"),
                          wq_ref.store.col("status"))
    assert np.array_equal(wq_vec.store.col("worker_id"),
                          wq_ref.store.col("worker_id"))


@settings(max_examples=20, deadline=None)
@given(tasks=st.integers(1, 200), w1=st.integers(1, 16),
       w2=st.integers(1, 16))
def test_property_rehash_balance(tasks, w1, w2):
    ids = np.arange(tasks, dtype=np.int64)
    a1 = assign_workers(ids, w1)
    a2 = assign_workers(ids, w2)
    s2 = partition_sizes(a2, w2)
    assert s2.sum() == tasks
    assert s2.max() - s2.min() <= 1                 # round-robin balance
