"""Checkpoint roundtrip/corruption + deterministic data pipeline."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs import smoke_config
from repro.core import WorkQueue
from repro.data.pipeline import DataConfig, batch_for
from repro.launch.steps import init_train_state


def test_checkpoint_roundtrip(tmp_path):
    cfg = smoke_config("qwen2-0.5b")
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    wq = WorkQueue(num_workers=2)
    wq.add_tasks(0, 6)
    ck = Checkpointer(str(tmp_path), async_write=False)
    ck.save(10, state, wq)
    step, restored, wq2 = ck.restore(jax.device_get(state))
    assert step == 10
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert wq2.store.n_rows == 6
    assert wq2.num_workers == 2


def test_checkpoint_gc_and_latest(tmp_path):
    cfg = smoke_config("qwen2-0.5b")
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    ck = Checkpointer(str(tmp_path), keep=2, async_write=False)
    for s in (1, 2, 3, 4):
        ck.save(s, state)
    assert ck.latest_step() == 4
    dirs = sorted(p.name for p in tmp_path.iterdir())
    assert dirs == ["step_00000003", "step_00000004"]


def test_checkpoint_detects_corruption(tmp_path):
    cfg = smoke_config("qwen2-0.5b")
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    ck = Checkpointer(str(tmp_path), async_write=False)
    ck.save(1, state)
    d = tmp_path / "step_00000001"
    with np.load(d / "arrays.npz") as z:
        flat = {k: z[k].copy() for k in z.files}
    key = next(iter(flat))
    flat[key] = flat[key] + 1.0
    np.savez(d / "arrays.npz", **flat)
    with pytest.raises(IOError):
        ck.restore(jax.device_get(state))


def test_async_checkpoint_completes(tmp_path):
    cfg = smoke_config("qwen2-0.5b")
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    ck = Checkpointer(str(tmp_path), async_write=True)
    ck.save(7, state)
    ck.wait()
    assert ck.latest_step() == 7


def test_data_pipeline_deterministic_per_shard():
    cfg = smoke_config("qwen2-0.5b")
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, batch_size=4)
    b1 = batch_for(cfg, dc, 7)
    b2 = batch_for(cfg, dc, 7)
    b3 = batch_for(cfg, dc, 8)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert (b1["tokens"] != b3["tokens"]).any()
    # labels are next-token shifted
    assert b1["labels"].shape == b1["tokens"].shape


def test_data_pipeline_families():
    for arch in ("seamless-m4t-large-v2", "qwen2-vl-2b", "mamba2-1.3b"):
        cfg = smoke_config(arch)
        dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, batch_size=2)
        b = batch_for(cfg, dc, 0)
        if cfg.family == "encdec":
            assert b["frames"].shape[-1] == cfg.d_model
        elif cfg.embed_stub:
            assert b["embeds"].shape == (2, 16, cfg.d_model)
        else:
            assert b["tokens"].shape == (2, 16)
