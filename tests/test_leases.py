"""Lease-based claims + the vectorized reaper (PR 8, Work Claim Pattern).

Contracts under test:
- every claim path stamps claimed_at / heartbeat_at / expires_at in the
  SAME transaction as the RUNNING flip;
- reap_expired requeues expired RUNNING rows in one masked, legality-
  checked transition (retry bump; exhausted rows -> FAILED) and logs an
  ordinary record;
- lease columns replay to replica BIT-PARITY through every replay path
  (per-record, batched, hot-plane) including across a log truncate —
  without any new wire fields, because expires_at is derived from the
  lease duration carried on the store snapshot;
- the sharded router reaps per shard and rebalance treats the reaped
  backlog as stealable.
"""
import numpy as np
import pytest

from repro.core import Status, WorkQueue
from repro.core.replication import DeltaReplicator, replay, replay_reference
from repro.core.sharding_router import ShardRouter
from repro.core.store import ColumnStore, DEFAULT_LEASE_S


def assert_stores_equal(a, b, cols):
    for name in cols:
        assert np.array_equal(a.col(name), b.col(name),
                              equal_nan=True), name


# ------------------------------------------------------------ claim stamps
def test_claim_paths_stamp_lease_columns():
    wq = WorkQueue(num_workers=2, lease_s=30.0)
    wq.add_tasks(0, 8, now=0.0)
    out = wq.claim_all(k=1, now=5.0)
    rows = np.concatenate([v for v in out.values()])
    assert np.array_equal(wq.store.col("claimed_at")[rows], np.full(2, 5.0))
    assert np.array_equal(wq.store.col("heartbeat_at")[rows],
                          np.full(2, 5.0))
    assert np.array_equal(wq.store.col("expires_at")[rows], np.full(2, 35.0))
    # per-worker claim() stamps too
    more = wq.claim(0, k=1, now=6.0)
    assert wq.store.col("expires_at")[more[0]] == 36.0
    # unclaimed rows carry no lease
    ready = wq.store.col("status") == int(Status.READY)
    assert np.isnan(wq.store.col("expires_at")[ready]).all()


def test_finish_renews_heartbeat():
    wq = WorkQueue(num_workers=1, lease_s=30.0)
    wq.add_tasks(0, 2, now=0.0)
    rows = wq.claim(0, k=2, now=1.0)
    wq.finish(rows, now=9.0)
    assert (wq.store.col("heartbeat_at")[rows] == 9.0).all()


# ----------------------------------------------------------------- reaper
def test_reap_requeues_expired_and_bumps_trials():
    wq = WorkQueue(num_workers=2, lease_s=10.0)
    wq.add_tasks(0, 6, now=0.0)
    out = wq.claim_all(k=1, now=0.0)            # leases expire at t=10
    rows = np.concatenate([v for v in out.values()])
    assert wq.reap_expired(now=5.0) == 0        # live leases: no-op, no log
    assert [t.op for t in wq.log.records if t.op == "reap"] == []
    n = wq.reap_expired(now=11.0)
    assert n == len(rows)
    st = wq.store.col("status")[rows]
    assert (st == int(Status.READY)).all()
    assert (wq.store.col("fail_trials")[rows] == 1).all()
    # lease columns cleared: the row is visibly unleased again
    assert np.isnan(wq.store.col("expires_at")[rows]).all()
    assert np.isnan(wq.store.col("claimed_at")[rows]).all()
    wq.check_invariants()
    # reaped rows are immediately claimable again
    again = wq.claim_all(k=1, now=12.0)
    assert sum(len(v) for v in again.values()) == 2


def test_reap_exhausts_to_failed():
    wq = WorkQueue(num_workers=1, lease_s=1.0)
    wq.add_tasks(0, 2, now=0.0)
    for round_ in range(3):                     # claim -> expire -> reap x3
        out = wq.claim_all(k=2, now=float(round_ * 10))
        assert sum(len(v) for v in out.values()) == 2
        wq.reap_expired(now=float(round_ * 10) + 5.0)
    st = wq.store.col("status")
    assert (st[:2] == int(Status.FAILED)).all()
    assert (wq.store.col("fail_trials")[:2] == 3).all()
    assert (wq.store.col("end_time")[:2] == 25.0).all()
    wq.check_invariants()


def test_reap_ignores_unleased_running_rows():
    """NaN expires_at (a RUNNING row that never took a lease, e.g. written
    by out-of-band test mutation) never matches the expiry mask."""
    wq = WorkQueue(num_workers=1, lease_s=5.0)
    wq.add_tasks(0, 2, now=0.0)
    rows = wq.claim(0, k=2, now=0.0)
    wq.store.update(rows[:1], expires_at=np.nan)   # simulate legacy claim
    assert wq.reap_expired(now=100.0) == 1          # only the leased row


def test_renew_leases_extends_expiry_and_skips_non_running():
    wq = WorkQueue(num_workers=1, lease_s=10.0)
    wq.add_tasks(0, 3, now=0.0)
    rows = wq.claim(0, k=3, now=0.0)
    wq.finish(rows[:1], now=2.0)
    assert wq.renew_leases(rows, now=8.0) == 2      # FINISHED row skipped
    assert (wq.store.col("expires_at")[rows[1:]] == 18.0).all()
    assert wq.reap_expired(now=12.0) == 0           # renewal kept them alive
    assert wq.reap_expired(now=19.0) == 2
    assert wq.renew_leases(rows, now=20.0) == 0     # late heartbeat: no-op
    assert [t.op for t in wq.log.records].count("lease_renew") == 1


# ------------------------------------------------------- autoscale signals
def test_autoscale_signals_from_the_relation():
    wq = WorkQueue(num_workers=2, lease_s=60.0)
    wq.add_tasks(0, 10, now=3.0)
    sig = wq.autoscale_signals(now=13.0)
    assert sig["pending"] == 10.0
    assert sig["backlog_age_s"] == 10.0
    assert sig["claim_p95_s"] == 0.0            # nothing claimed yet
    wq.claim_all(k=2, now=7.0)                  # 4 claims, 4s after submit
    sig = wq.autoscale_signals(now=13.0)
    assert sig["pending"] == 6.0
    assert sig["running"] == 4.0
    assert sig["claim_p95_s"] == pytest.approx(4.0)
    wq.claim_all(k=3, now=13.0)
    wq.finish(np.nonzero(wq.store.col("status")
                         == int(Status.RUNNING))[0], now=14.0)
    sig = wq.autoscale_signals(now=14.0)
    assert sig["pending"] == 0.0 and sig["backlog_age_s"] == 0.0


# ---------------------------------------------------------- replay parity
def _lease_workload(wq, rounds=12):
    """Mixed workload exercising claim/renew/reap/finish on a short lease."""
    rng = np.random.default_rng(7)
    wq.add_tasks(0, 24, now=0.0)
    for r in range(rounds):
        t = float(r * 4)
        wq.claim_all(k=int(rng.integers(1, 3)), now=t)
        running = np.nonzero(
            wq.store.col("status") == int(Status.RUNNING))[0]
        if len(running) and rng.integers(0, 2):
            wq.renew_leases(running[:: 2], now=t + 1.0)
        if len(running):
            done = running[rng.random(len(running)) < 0.4]
            if len(done):
                wq.finish(done, now=t + 2.0,
                          domain_out=np.full((len(done), 3), t))
        wq.reap_expired(now=t + 3.0 + float(rng.integers(0, 8)))
        if rng.integers(0, 3) == 0:
            wq.add_tasks(1, int(rng.integers(1, 5)), now=t)


def test_lease_ops_replay_bit_identical_all_paths():
    """reap/lease_renew records replay identically via the per-record
    oracle AND the batched path, and lease columns land bit-identical."""
    wq = WorkQueue(num_workers=3, lease_s=6.0)
    _lease_workload(wq)
    assert any(t.op == "reap" for t in wq.log.records)
    assert any(t.op == "lease_renew" for t in wq.log.records)
    records = wq.log.tail(0)
    ref = ColumnStore(wq.store.schema, capacity=1 << 10)
    bat = ColumnStore(wq.store.schema, capacity=1 << 10)
    ref.lease_s = bat.lease_s = 6.0     # what a snapshot restore carries
    n_ref = replay_reference(ref, records)
    n_bat = replay(bat, records)
    assert n_ref == n_bat == len(records)
    assert_stores_equal(ref, bat, wq.store.cols)
    assert_stores_equal(wq.store, bat, wq.store.cols)


def test_lease_parity_on_replica_across_truncate():
    """A DeltaReplicator syncing across a compaction keeps every lease
    column bit-identical — the custom lease duration reaches the replica
    through the restore snapshot, not through any wire field."""
    wq = WorkQueue(num_workers=3, lease_s=6.0)
    rep = DeltaReplicator(wq, sync_every=1)
    truncated = 0
    rng = np.random.default_rng(11)
    wq.add_tasks(0, 16, now=0.0)
    for r in range(10):
        t = float(r * 5)
        wq.claim_all(k=1, now=t)
        wq.reap_expired(now=t + 7.0)
        if rng.integers(0, 2):
            running = np.nonzero(
                wq.store.col("status") == int(Status.RUNNING))[0]
            if len(running):
                wq.finish(running[:2], now=t + 1.0)
        rep.sync()
        truncated += wq.compact_log()
    assert truncated > 0                      # synced ACROSS a truncate
    assert rep.store.lease_s == 6.0           # duration rode the snapshot
    rep.sync(upto_version=wq.store.version)
    assert_stores_equal(wq.store, rep.store, wq.store.cols)


def test_store_snapshot_carries_lease_duration():
    st = ColumnStore(capacity=64)
    st.lease_s = 12.5
    snap = st.snapshot()
    assert ColumnStore.restore(snap).lease_s == 12.5
    assert ColumnStore.from_view(st.snapshot_view()).lease_s == 12.5
    # legacy snapshots (no lease_s key) restore to the default
    snap.pop("lease_s")
    assert ColumnStore.restore(snap).lease_s == DEFAULT_LEASE_S


# ---------------------------------------------------------------- sharded
def test_sharded_reap_feeds_cross_shard_stealing():
    """Kill one shard's workers (stop claiming/heartbeating): the router
    reaper requeues their expired claims per shard, the live task-id set
    is conserved, and rebalance steals the reaped backlog to a drained
    sibling."""
    router = ShardRouter(2, 2, lease_s=5.0)
    router.add_tasks(0, 24, now=0.0)
    live_before = router.live_task_ids()
    router.claim_all(k=3, now=0.0)
    # shard 0 finishes its claims (alive); shard 1's workers go silent
    sh0, sh1 = router.shards
    run0 = np.nonzero(sh0.wq.store.col("status")
                      == int(Status.RUNNING))[0]
    sh0.wq.finish(run0, now=1.0)
    n_run1 = int((sh1.wq.store.col("status")
                  == int(Status.RUNNING)).sum())
    assert n_run1 > 0
    reaped = router.reap_expired(now=6.0)     # shard 1's leases expired
    assert reaped == n_run1
    for sh in router.shards:
        sh.wq.check_invariants()
    # drain shard 0 so rebalance sees it starved, then steal shard 1's
    # reaped backlog across
    while int(sh0.wq.ready_counts().sum()):
        got = sh0.wq.claim_all(k=4, now=7.0)
        rows = np.concatenate([v for v in got.values()])
        sh0.wq.finish(rows, now=8.0)
    assert int(sh1.wq.ready_counts().sum()) > 0
    moved = router.rebalance(now=9.0)
    assert moved > 0                          # reaped rows were stealable
    assert np.array_equal(router.live_task_ids(), live_before)
