"""Parallel steering plane (PR 10): sweep partials + remote scatter.

The invariants under test: ``run_all``'s two pure pieces compose exactly —
``merge_partials(map(sweep_partials, views))`` is bit-identical to a
single-primary oracle on random workloads (Q8 patches and prunes
interleaved, version-vector pinned), and computing the partials
concurrently changes nothing; the shipped-replica ``G`` op runs
``sweep_partials`` INSIDE the replica process and the merged remote sweep
is bit-identical to the local path at the same pinned version vector
(across a log truncate); dead shards surface as :class:`DeadShardError`,
not AttributeError; a wedged steal sibling rolls back via the transport
recv timeout; and ``close()`` is idempotent across failover."""
import concurrent.futures
import json

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.schema import Status
from repro.core.sharding_router import (DeadShardError, ShardRouter,
                                        merge_partials)
from repro.core.steering import SteeringEngine, sweep_partials
from repro.core.transport import TCPTransport
from repro.core.workqueue import WorkQueue

S, L = 4, 4
W = S * L


def _fp(x):
    return json.dumps(x, sort_keys=True, default=str)


def _dom(ids):
    h = (ids * 2654435761) % (1 << 10)
    return np.stack([(h % 977) / 976.0, ((h * 3) % 911) / 910.0,
                     ((h * 7) % 1013) / 1012.0], 1)


def _dom_out(ids):
    # dyadic denominators: exact in float64, so merged sums are bit-stable
    return np.stack([(ids % 7) / 8.0, (ids % 5) / 4.0, (ids % 3) / 2.0], 1)


def _paired(n_per_act=40, activities=3, **router_kw):
    r = ShardRouter(S, L, **router_kw)
    o = WorkQueue(num_workers=W)
    prev = None
    for a in range(activities):
        ids = np.arange(a * n_per_act, (a + 1) * n_per_act, dtype=np.int64)
        kw = dict(domain_in=_dom(ids), duration_est=1.0, now=0.0)
        if prev is not None:
            kw["parent_task"] = prev
        r.add_tasks(a, n_per_act, **kw)
        o.add_tasks(a, n_per_act, **kw)
        prev = ids
    return r, o


def _shard_rows(r, ids):
    out = []
    owner = r.shard_of(ids)
    for s in range(S):
        m = owner == s
        if not m.any():
            continue
        tid = r.shards[s].wq.store.col("task_id")
        pos = np.searchsorted(tid, ids[m])
        assert np.array_equal(tid[pos], ids[m])
        out.append((s, pos))
    return out


def _drive(r, o, rng, rounds):
    """Random mirrored claims/fails/finishes with Q8 patches and prunes
    interleaved at random rounds; dyadic times keep merged sums exact."""
    clock = 1.0
    patch_rnd = int(rng.integers(0, max(rounds, 1)))
    prune_rnd = int(rng.integers(0, max(rounds, 1)))
    for rnd in range(rounds):
        k = int(rng.integers(1, 4))
        oc = o.claim_all(k=k, now=clock, steal=False)
        r.claim_all(k=k, now=clock, steal=False)
        o_ids = {g: np.sort(o.store.col("task_id")[rows])
                 for g, rows in oc.items() if len(rows)}
        if o_ids:
            all_ids = np.sort(np.concatenate(list(o_ids.values())))
            stride = int(rng.integers(3, 9))
            fail_ids = all_ids[::stride] if rng.random() < 0.4 \
                else all_ids[:0]
            fin = np.setdiff1d(all_ids, fail_ids)
            fa, fb = fin[fin % 2 == 0], fin[fin % 2 == 1]
            if len(fail_ids):
                o.fail(fail_ids, now=clock + 0.25)
                for s, pos in _shard_rows(r, fail_ids):
                    r.shards[s].wq.fail(pos, now=clock + 0.25)
            for ids_, dt in ((fa, 1.0), (fb, 1.5)):
                if not len(ids_):
                    continue
                o.finish(ids_, now=clock + dt, domain_out=_dom_out(ids_))
                for s, pos in _shard_rows(r, ids_):
                    tid = r.shards[s].wq.store.col("task_id")[pos]
                    r.shards[s].wq.finish(pos, now=clock + dt,
                                          domain_out=_dom_out(tid))
        if rnd == patch_rnd:
            SteeringEngine(o).q8_patch_ready(0, "in0", 9.5,
                                             predicate=lambda v: v > 0.8)
            for sh in r.shards:
                SteeringEngine(sh.wq).q8_patch_ready(
                    0, "in0", 9.5, predicate=lambda v: v > 0.8)
        if rnd == prune_rnd:
            SteeringEngine(o).prune("in1", 0.0, 0.05)
            for sh in r.shards:
                SteeringEngine(sh.wq).prune("in1", 0.0, 0.05)
        clock += 2.0
    return clock


# ------------------------------------------------ partials decomposition
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 999), rounds=st.integers(1, 8),
       n_per_act=st.integers(8, 48))
def test_merged_partials_bit_identical_to_oracle(seed, rounds, n_per_act):
    """merge_partials(map(sweep_partials, views)) at a pinned version
    vector == the single-primary oracle, on random workloads with Q8
    patches + prunes interleaved — the refactor's bit-parity property."""
    rng = np.random.default_rng(seed)
    r, o = _paired(n_per_act=n_per_act)
    clock = _drive(r, o, rng, rounds)
    views = r.snapshot_vector()
    merged = merge_partials(
        [sweep_partials(v, L, clock) for v in views])
    assert merged["version"] == [v.version for v in views]
    via_run_all = r.run_all(clock, views=views)
    assert _fp(merged) == _fp(via_run_all)
    oview = o.store.snapshot_view()
    onorm = ShardRouter.oracle_normalize(
        SteeringEngine(o).run_all(clock, view=oview), oview)
    assert _fp(ShardRouter.comparable(merged)) == _fp(onorm)
    # concurrent partials (one thread per shard) merge identically: the
    # partials are pure functions of pinned COW views
    with concurrent.futures.ThreadPoolExecutor(max_workers=S) as pool:
        conc = list(pool.map(lambda v: sweep_partials(v, L, clock), views))
    assert _fp(merge_partials(conc)) == _fp(merged)
    r.close()


def test_merge_partials_rejects_empty():
    with pytest.raises(ValueError):
        merge_partials([])


# ------------------------------------------------- remote partial sweeps
def test_remote_sweep_merges_full_q1_q7_across_truncate():
    """The shipped G op: per-shard sweep_partials INSIDE the replica
    processes, merged result bit-identical to the local run_all and the
    single-primary oracle at the same pinned vector — across a per-shard
    log truncate — and the concurrent scatter equals the serial one."""
    rng = np.random.default_rng(7)
    r, o = _paired(replicate="shipped", sync_every=8)
    clock = _drive(r, o, rng, rounds=4)
    r.sync_replicas()
    assert r.compact() > 0                     # acked -> per-shard truncate
    clock = max(clock, _drive(r, o, rng, rounds=3))  # ship ACROSS it
    vec = r.sync_replicas()
    views = r.snapshot_vector()
    assert tuple(vec) == tuple(v.version for v in views)
    res = r.remote_sweep(clock, versions=vec, sync=False)
    assert set(res) == {"q1", "q3", "q4", "q5", "q6", "q7", "version"}
    assert res["version"] == [int(v) for v in vec]
    assert _fp(res) == _fp(r.run_all(clock, views=views))
    oview = o.store.snapshot_view()
    onorm = ShardRouter.oracle_normalize(
        SteeringEngine(o).run_all(clock, view=oview), oview)
    assert _fp(ShardRouter.comparable(res)) == _fp(onorm)
    serial = r.remote_sweep(clock, versions=vec, sync=False,
                            concurrent_scatter=False)
    assert _fp(serial) == _fp(res)
    assert len(r.last_scatter_wall_s) == S
    assert all(w > 0 for w in r.last_scatter_wall_s)
    assert r.scatter_spread_s() >= 0.0
    # a stale pinned vector is a hard error, not a silent mismatch
    with pytest.raises(RuntimeError, match="expected pinned"):
        r.remote_sweep(clock, versions=[v + 1 for v in vec], sync=False)
    r.close()


def test_remote_sweep_default_sync_pins_current_vector():
    rng = np.random.default_rng(11)
    r, o = _paired(replicate="shipped", sync_every=4)
    clock = _drive(r, o, rng, rounds=3)
    res = r.remote_sweep(clock)                # sync=True: pins + catches up
    assert res["version"] == [int(v) for v in r.version_vector()]
    assert _fp(res) == _fp(r.run_all(clock))
    r.close()


def test_remote_sweep_requires_process_replicas_and_live_shards():
    r = ShardRouter(2, 2, replicate="delta")
    r.add_tasks(0, 8, now=0.0)
    with pytest.raises(ValueError, match="replicate='remote'"):
        r.remote_sweep(1.0)
    r.close()
    r2 = ShardRouter(2, 2)
    r2.add_tasks(0, 8, now=0.0)
    with pytest.raises(ValueError, match="replicate="):
        r2.remote_sweep(1.0)
    r2.close()


def test_remote_sweep_dead_shard_raises_dead_shard_error():
    r, _ = _paired(replicate="shipped")
    r.fail_shard(1)
    with pytest.raises(DeadShardError, match="shard 1 is down"):
        r.remote_sweep(1.0)
    r.promote_shard(1)                        # failover re-arms the shard
    res = r.remote_sweep(1.0)                 # ...and sweeps work again
    assert _fp(res) == _fp(r.run_all(1.0))
    r.close()


def test_concurrent_sync_and_replica_vector_match_serial():
    rng = np.random.default_rng(13)
    r, o = _paired(replicate="delta", sync_every=4)
    _drive(r, o, rng, rounds=3)
    vec = r.sync_replicas()
    assert tuple(vec) == r.version_vector()
    serial_vec = r.sync_replicas(concurrent_scatter=False)
    assert tuple(serial_vec) == tuple(vec)
    views_c = r.replica_vector()
    views_s = r.replica_vector(concurrent_scatter=False)
    assert [v.version for v in views_c] == [v.version for v in views_s]
    assert _fp(r.run_all(9.0, views=views_c)) \
        == _fp(r.run_all(9.0, views=views_s))
    r.close()


# ------------------------------------------------------- steal timeout
def test_wedged_steal_sibling_times_out_and_rolls_back():
    """A sibling that never acks turns the steal into a TransportError
    (the PR 8 recv_timeout knob, now armed on the steal pair) and the
    two-phase rollback re-inserts the pruned chunk — no hung recv, no
    lost task."""
    r = ShardRouter(2, 2, steal_recv_timeout=0.2)
    r.add_tasks(0, 16, now=0.0)
    # drain shard 0 so rebalance will steal from shard 1
    sh0 = r.shards[0]
    got = sh0.wq.claim_all(k=16, now=1.0)
    rows = np.concatenate([v for v in got.values() if len(v)])
    sh0.wq.finish(rows, now=2.0)
    # wedge the wire: tx now feeds a foreign endpoint, so the thief-side
    # ack never reaches _steal_rx and the recv must hit its deadline
    wedged_a, wedged_b = TCPTransport.pair()
    real_tx = r._steal_tx
    r._steal_tx = wedged_a
    live = r.live_task_ids()
    assert r.rebalance(now=3.0) == 0
    assert r.steal_stats.rollbacks == 1
    assert r.steal_stats.rolled_back_tasks > 0
    assert np.array_equal(live, r.live_task_ids())   # rollback conserved
    ready = r.shards[1].wq.store.col("status") == int(Status.READY)
    assert ready.sum() > 0                           # chunk claimable again
    r._steal_tx = real_tx
    wedged_a.close()
    wedged_b.close()
    r.check_invariants()
    r.close()


# --------------------------------------------------------- close safety
def test_close_is_idempotent():
    r = ShardRouter(2, 2, replicate="delta")
    r.add_tasks(0, 4, now=0.0)
    r.close()
    r.close()                                  # second close: no-op


def test_close_safe_after_fail_and_promote():
    r, _ = _paired(n_per_act=8, replicate="shipped")
    r.fail_shard(0)                            # frozen replica still armed
    r.close()
    r.close()
    r2, _ = _paired(n_per_act=8, replicate="shipped")
    r2.fail_shard(1)
    r2.promote_shard(1)                        # re-arms a fresh replicator
    r2.close()
    r2.close()


def test_close_noop_single_shard_without_scatter_pool():
    r = ShardRouter(1, 2)
    assert r._scatter is None                  # no pool to shut down
    r.add_tasks(0, 2, now=0.0)
    r.close()
    r.close()


# ------------------------------------------------------------ executor
def test_train_executor_sharded_remote_analyst_merged_sweep():
    """analyst='remote' + shards: the producer thread pins the vector via
    sync_replicas, the analyst pool scatters the partial sweeps into the
    replica processes, and last_steering carries the FULL merged Q1-Q7
    result (not the old Q1/Q4 union)."""
    from repro.configs import smoke_config
    from repro.data.pipeline import DataConfig
    from repro.runtime.executor import TrainExecutor
    cfg = smoke_config("qwen2-0.5b")
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, batch_size=4)
    ex = TrainExecutor(cfg, num_workers=4, shards=2, data_cfg=data,
                       steer_every=4, analyst="remote")
    ex.submit_steps(12)
    hist = ex.run()
    ex.close()
    assert len(hist) == 12
    assert ex.router.tasks_left() == 0
    assert ex.last_steering is not None
    assert set(ex.last_steering) \
        == {"q1", "q3", "q4", "q5", "q6", "q7", "version"}
    assert ex.last_steering["q4"] == 0
    assert isinstance(ex.last_steering["version"], list)
    assert len(ex.last_steering["version"]) == 2
