"""Snapshot isolation (HTAP): steering sweeps on immutable store versions
while claims mutate the live arrays — plus the COW mechanics behind it."""
import threading
import time

import numpy as np
import pytest

from repro.core import Status, SteeringEngine, WorkQueue
from repro.core.store import ColumnStore


def make_wq(workers=4, tasks=32):
    wq = WorkQueue(num_workers=workers)
    wq.add_tasks(0, tasks)
    return wq


def status_counts(view):
    st = view.col("status")
    return {s: int((st == int(s)).sum()) for s in Status}


def test_snapshot_pins_version_across_claims():
    wq = make_wq(tasks=32)
    snap = wq.store.snapshot_view()
    v0 = snap.version
    wq.claim_all(k=2, now=1.0)
    rows = np.nonzero(wq.store.col("status") == int(Status.RUNNING))[0]
    wq.finish(rows[:4], now=2.0, domain_out=np.ones((4, 3)))
    # live store moved on ...
    assert wq.store.version > v0
    live = status_counts(wq.store)
    assert live[Status.RUNNING] == len(rows) - 4
    assert live[Status.FINISHED] == 4
    # ... but the snapshot still shows the pre-claim state, untouched
    old = status_counts(snap)
    assert old[Status.READY] == 32
    assert old[Status.RUNNING] == 0 and old[Status.FINISHED] == 0
    assert snap.version == v0


def test_snapshot_survives_store_growth():
    wq = WorkQueue(num_workers=2, capacity=16)
    wq.add_tasks(0, 12)
    snap = wq.store.snapshot_view()
    wq.add_tasks(0, 100)                     # forces _grow + reallocation
    assert wq.store.n_rows == 112
    assert snap.n_rows == 12
    assert (snap.col("status") == int(Status.READY)).all()


def test_run_all_on_mid_claim_snapshot_is_internally_consistent():
    """The sweep sees ONE version: no READY+RUNNING double-count even though
    claims commit between the sweep's individual queries."""
    wq = make_wq(workers=4, tasks=40)
    steer = SteeringEngine(wq)
    wq.claim_all(k=1, now=1.0)                    # 4 RUNNING
    snap = wq.store.snapshot_view()               # <- mid-workload snapshot
    # concurrent-looking mutation: more claims + finishes AFTER the snapshot
    out = wq.claim_all(k=2, now=2.0)
    rows = np.concatenate([v for v in out.values() if len(v)])
    wq.finish(rows, now=3.0, domain_out=np.ones((len(rows), 3)))
    res = steer.run_all(4.0, view=snap)
    # on the snapshot: 4 running + 36 ready, nothing finished yet
    assert res["q4"] == 40
    assert res["version"] == snap.version
    c = status_counts(snap)
    assert c[Status.READY] + c[Status.RUNNING] == 40
    assert c[Status.RUNNING] == 4 and c[Status.FINISHED] == 0
    # live sweep sees the later version
    live = steer.run_all(4.0)
    assert live["q4"] == 40 - len(rows)
    assert live["version"] > snap.version


def test_concurrent_steering_never_tears(n_tasks=1500, workers=8):
    """Analyst thread sweeps on snapshots while the main thread claims and
    finishes; every sweep must conserve the task count across its separate
    queries (the READY->FINISHED double-count a live read would produce)."""
    wq = WorkQueue(num_workers=workers, capacity=4 * n_tasks)
    wq.add_tasks(0, n_tasks)
    steer = SteeringEngine(wq)
    errors = []
    stop = threading.Event()

    def analyst():
        while not stop.is_set():
            with steer.snapshot_scope() as v:
                left = steer.q4_tasks_left()          # query 1
                time.sleep(0.0005)                    # let claims commit
                c = status_counts(v)                  # query 2, same view
                total = (left + c[Status.FINISHED] + c[Status.FAILED]
                         + c[Status.PRUNED] + c[Status.EMPTY])
                if total != v.n_rows:
                    errors.append((v.version, left, c))
                run = np.nonzero(v.col("status") == int(Status.RUNNING))[0]
                if np.isnan(v.col("start_time")[run]).any():
                    errors.append(("torn start_time", v.version))

    t = threading.Thread(target=analyst)
    t.start()
    try:
        done = 0
        while done < n_tasks:
            out = wq.claim_all(k=2, now=float(done))
            rows = np.concatenate([v for v in out.values() if len(v)]) \
                if any(len(v) for v in out.values()) else np.empty(0, int)
            if len(rows) == 0:
                break
            wq.finish(rows, now=float(done) + 0.5,
                      domain_out=np.ones((len(rows), 3)))
            done += len(rows)
    finally:
        stop.set()
        t.join()
    assert not errors, errors[:3]
    assert wq.counts()["FINISHED"] == n_tasks


def test_q8_and_prune_write_live_store_inside_sweep():
    """Adaptations are transactions: even inside a snapshot scope they read
    and write the LIVE store, never the pinned view."""
    wq = WorkQueue(num_workers=2)
    wq.add_tasks(0, 10, domain_in=np.linspace(0, 9, 10)[:, None]
                 * np.ones((10, 3)))
    steer = SteeringEngine(wq)
    with steer.snapshot_scope():
        n = steer.q8_patch_ready(0, "in0", 42.0, predicate=lambda v: v > 5.0)
        assert n == 4
    assert (wq.store.col("in0") == 42.0).sum() == 4


def test_device_claim_flag_matches_reference():
    from repro.flags import device_claims, wq_device_claim
    assert not wq_device_claim()
    with device_claims():
        wq_dev = WorkQueue(num_workers=3)        # picks the flag up
        assert wq_dev.device_claim
    wq_ref = WorkQueue(num_workers=3)
    assert not wq_ref.device_claim
    wq_dev.add_tasks(0, 20)
    wq_ref.add_tasks(0, 20)
    for r in range(3):
        o1 = wq_dev.claim_all(k=2, now=float(r))
        o2 = wq_ref.claim_all_reference(k=2, now=float(r))
        for w in range(3):
            assert np.array_equal(o1[w], o2[w])


def test_device_claim_routes_orphaned_partitions_to_steal_pool():
    """Shrink-resize can leave retried tasks with worker_id >= W; the kernel
    'claims' those at rank 0, so the device path must divert them to the
    steal pool exactly like the host path does."""
    results = {}
    for device in (False, True):
        wq = WorkQueue(num_workers=4, device_claim=device)
        wq.add_tasks(0, 12)
        out = wq.claim_all(k=1, now=0.0)          # 4 RUNNING, one per worker
        running = np.concatenate(list(out.values()))
        wq.resize(2)                              # RUNNING rows keep wid 2,3
        wq.fail(running, max_trials=5)            # ... and retry to READY
        assert (wq.store.col("worker_id")[running] >= 2).sum() > 0
        # quota-exact round: in-range workers fill without touching the
        # orphans, so their cursors advance past the orphan rows — the
        # orphan watermark must keep those rows visible to later steals
        mid = wq.claim_all(k=4, now=0.5)
        res = wq.claim_all(k=20, now=1.0)         # budget >> tasks: steal all
        rows = np.concatenate([v for v in list(mid.values())
                               + list(res.values()) if len(v)])
        assert len(np.unique(rows)) == len(rows)
        assert wq.counts()["READY"] == 0          # orphans claimed via steal
        results[device] = (mid, res)
    for phase in (0, 1):                          # device path == host path
        for w in results[False][phase]:
            assert np.array_equal(results[False][phase][w],
                                  results[True][phase][w])


def test_snapshot_id_index_and_q7_vectorized_walk():
    """Q7's iterative parent-gather on a snapshot equals the per-hit walk."""
    wq = WorkQueue(num_workers=2)
    rng = np.random.default_rng(0)
    parents = wq.add_tasks(0, 6)
    wq.finish(np.concatenate(list(wq.claim_all(k=3, now=0.0).values())),
              now=1.0, domain_out=rng.normal(0.6, 0.2, (6, 3)))
    kids = wq.add_tasks(1, 6, parent_task=parents,
                        domain_in=rng.normal(0.5, 0.2, (6, 3)))
    wq.finish(np.concatenate(list(wq.claim_all(k=3, now=1.0).values())),
              now=2.0, domain_out=rng.normal(0.6, 0.2, (6, 3)))
    grand = wq.add_tasks(2, 6, parent_task=kids,
                         domain_in=rng.normal(0.5, 0.2, (6, 3)))
    rows = np.concatenate(list(wq.claim_all(k=3, now=2.0).values()))
    # two finish batches with different durations so "slower than the
    # activity average" selects a real subset
    wq.finish(rows[:3], now=3.0, domain_out=rng.normal(0.6, 0.2, (3, 3)))
    wq.finish(rows[3:], now=6.0, domain_out=rng.normal(0.6, 0.2, (3, 3)))
    steer = SteeringEngine(wq)
    with steer.snapshot_scope() as v:
        got = steer.q7_provenance_join(act_a=0, act_b=2, thr=0.3)
    # oracle: the seed per-hit Python walk
    st = wq.store.col("status")
    act = wq.store.col("activity_id")
    t0, t1 = wq.store.col("start_time"), wq.store.col("end_time")
    f1 = wq.store.col("out0")
    parent = wq.store.col("parent_task")
    tid = wq.store.col("task_id")
    fin_b = (st == int(Status.FINISHED)) & (act == 2)
    dur = t1 - t0
    slow = dur > np.nanmean(dur[fin_b])
    hits = np.nonzero(fin_b & (f1 > 0.3) & slow)[0]
    id_to_row = {int(t): i for i, t in enumerate(tid)}
    want = []
    for row in hits:
        r = int(row)
        while act[r] > 0 and parent[r] >= 0:
            r = id_to_row.get(int(parent[r]), -1)
            if r < 0:
                break
        if r >= 0 and act[r] == 0:
            want.append(r)
    assert np.array_equal(got, np.asarray(want, np.int64))
    assert len(got) > 0                       # the join actually fired
