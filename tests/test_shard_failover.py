"""Shard-primary failover (PR 9).

The invariants under test are the availability half of the paper's
partitioned-ownership design: killing a shard primary strands its in-flight
claims but loses no committed transaction (the replica + frozen log tail
recover everything on ``promote_shard``); surviving shards keep claiming
id-for-id with a single-primary oracle throughout the outage; a two-phase
cross-shard steal rolls back to the victim when the transport dies
mid-move; sharded checkpoints cut one atomic version-vector manifest that
restores bit-identically (torn manifests are skipped, never half-loaded);
lease reaping rehashes onto the post-resize worker map; and supervision
survives a promote with a bumped generation.
"""
import json

import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.risers_workflow import WorkflowConfig
from repro.core.replication import (AllReplicasDeadError, DeltaReplicator,
                                    make_replicator)
from repro.core.schema import Status
from repro.core.sharding_router import ShardRouter, UnrecoverableShardError
from repro.core.steering import SteeringEngine
from repro.core.workqueue import WorkQueue
from repro.runtime.fault import HeartbeatMonitor

S, L = 3, 2
W = S * L


def _fp(x):
    return json.dumps(x, sort_keys=True, default=str)


def _dom(ids):
    h = (ids * 2654435761) % (1 << 10)
    return np.stack([(h % 977) / 976.0, ((h * 3) % 911) / 910.0,
                     ((h * 7) % 1013) / 1012.0], 1)


def _dom_out(ids):
    # dyadic denominators: exact in float64, so merged sums are bit-stable
    return np.stack([(ids % 7) / 8.0, (ids % 5) / 4.0, (ids % 3) / 2.0], 1)


def _paired(n_per_act=48, activities=3, **router_kw):
    """Router + oracle loaded with the identical chained workflow."""
    r = ShardRouter(S, L, **router_kw)
    o = WorkQueue(num_workers=W)
    prev = None
    for a in range(activities):
        ids = np.arange(a * n_per_act, (a + 1) * n_per_act, dtype=np.int64)
        kw = dict(domain_in=_dom(ids), duration_est=1.0, now=0.0)
        if prev is not None:
            kw["parent_task"] = prev
        assert np.array_equal(r.add_tasks(a, n_per_act, **kw), ids)
        assert np.array_equal(o.add_tasks(a, n_per_act, **kw), ids)
        prev = ids
    return r, o


def _shard_rows(r, ids):
    """(shard, rows) for global ids — pre-steal, task_id cols ascending."""
    out = []
    owner = r.shard_of(ids)
    for s in range(S):
        m = owner == s
        if not m.any():
            continue
        tid = r.shards[s].wq.store.col("task_id")
        pos = np.searchsorted(tid, ids[m])
        assert np.array_equal(tid[pos], ids[m])
        out.append((s, pos))
    return out


def _router_ids(r, rc):
    return {g: np.sort(r.shards[s].wq.store.col("task_id")[rows])
            for g, (s, rows) in rc.items() if len(rows)}


def _finish_router(r, ids, now):
    for s, pos in _shard_rows(r, ids):
        tid = r.shards[s].wq.store.col("task_id")[pos]
        r.shards[s].wq.finish(pos, now=now, domain_out=_dom_out(tid))


# --------------------------------------------------------- primary failover
def test_fail_and_promote_shard_keeps_oracle_parity():
    """Kill shard 0 with claims in flight: survivors never stall, claims
    stay id-identical with a single-primary oracle through the outage,
    promote drains the frozen WAL tail and requeues the stranded claims,
    and the recovered run drains to a bit-identical final sweep."""
    # huge sync_every: promote MUST recover from the unsynced log tail
    r, o = _paired(48, replicate="delta", sync_every=1 << 20)
    osteer = SteeringEngine(o)
    total = 3 * 48
    clock = 1.0

    for _ in range(3):                       # warm rounds, full parity
        rc = r.claim_all(k=2, now=clock, steal=False)
        oc = o.claim_all(k=2, now=clock, steal=False)
        r_ids, o_ids = _router_ids(r, rc), {
            g: np.sort(o.store.col("task_id")[v])
            for g, v in oc.items() if len(v)}
        assert set(r_ids) == set(o_ids)
        for g in r_ids:
            assert np.array_equal(r_ids[g], o_ids[g])
        done = np.sort(np.concatenate(list(o_ids.values())))
        o.finish(done, now=clock + 1.0, domain_out=_dom_out(done))
        _finish_router(r, done, clock + 1.0)
        clock += 2.0

    # claims in flight on every shard, then shard 0's primary dies —
    # its workers die with it, holding their leases
    rc = r.claim_all(k=2, now=clock, steal=False)
    oc = o.claim_all(k=2, now=clock, steal=False)
    r_ids = _router_ids(r, rc)
    all_ids = np.sort(np.concatenate(
        [o.store.col("task_id")[v] for v in oc.values() if len(v)]))
    strand = all_ids[(all_ids % W) // L == 0]       # owned by shard 0
    assert len(strand)                              # the kill is mid-claim
    work = np.setdiff1d(all_ids, strand)
    o.finish(work, now=clock + 1.0, domain_out=_dom_out(work))
    _finish_router(r, work, clock + 1.0)
    r.fail_shard(0)
    assert not r.shards[0].alive
    assert r.shards[0].replicator.lag() > 0         # WAL tail to drain
    with pytest.raises(RuntimeError):               # inserts bounce loudly
        r.add_tasks(0, W, now=clock)
    clock += 2.0

    # dead window: survivors claim id-for-id with an oracle restricted to
    # the surviving global workers (shard 0's stranded rows stay RUNNING)
    for _ in range(2):
        rc = r.claim_all(k=2, now=clock, steal=False)
        r_ids = _router_ids(r, rc)
        assert all(g // L != 0 for g in rc)         # dead shard skipped
        o_ids = {}
        for g in range(W):
            if g // L == 0:
                continue
            rows = o.claim(g, k=2, now=clock, allow_steal=False)
            if len(rows):
                o_ids[g] = np.sort(o.store.col("task_id")[rows])
        assert sum(len(v) for v in r_ids.values()) > 0   # never stalls
        assert set(r_ids) == set(o_ids)
        for g in r_ids:
            assert np.array_equal(r_ids[g], o_ids[g])
        done = np.sort(np.concatenate(list(o_ids.values())))
        o.finish(done, now=clock + 1.0, domain_out=_dom_out(done))
        _finish_router(r, done, clock + 1.0)
        clock += 2.0

    # promote: replica + full log-tail drain; the stranded claims requeue
    wq0 = r.promote_shard(0)
    assert r.shards[0].alive and r.shards[0].wq is wq0
    assert r.shards[0].replicator is not None       # policy re-armed
    assert not (wq0.store.col("status") == int(Status.RUNNING)).any()
    # mirror on the oracle: recover() only flips status — the stranded
    # rows of the dead shard go back to READY, cursors invalidated
    tid, st = o.store.col("task_id"), o.store.col("status")
    rows = np.nonzero((st == int(Status.RUNNING))
                      & ((tid % W) // L == 0))[0]
    assert len(rows) == len(strand)
    o.store.update(rows, status=int(Status.READY))
    o.invalidate_cursors(rows)

    # not one committed transaction lost across the kill+promote
    assert np.array_equal(r.live_task_ids(),
                          np.arange(total, dtype=np.int64))

    # lockstep drain: the promoted shard claims exactly like the oracle
    while True:
        rc = r.claim_all(k=2, now=clock, steal=False)
        oc = o.claim_all(k=2, now=clock, steal=False)
        r_ids = _router_ids(r, rc)
        o_ids = {g: np.sort(o.store.col("task_id")[v])
                 for g, v in oc.items() if len(v)}
        assert set(r_ids) == set(o_ids)
        for g in r_ids:
            assert np.array_equal(r_ids[g], o_ids[g])
        if not o_ids:
            break
        done = np.sort(np.concatenate(list(o_ids.values())))
        o.finish(done, now=clock + 1.0, domain_out=_dom_out(done))
        _finish_router(r, done, clock + 1.0)
        clock += 2.0
    assert r.tasks_left() == 0

    # final merged sweep bit-identical to the single-primary oracle
    ov = o.store.snapshot_view()
    merged = ShardRouter.comparable(
        r.run_all(clock, views=r.snapshot_vector()))
    oracle = ShardRouter.oracle_normalize(
        osteer.run_all(clock, view=ov), ov)
    assert _fp(merged) == _fp(oracle)

    # the re-armed replicator replays the post-promote traffic to parity
    sh = r.shards[0]
    sh.replicator.sync()
    for n in sh.wq.store.cols:
        a, b = sh.wq.store.col(n), sh.replicator.store.col(n)
        assert np.array_equal(a, b, equal_nan=a.dtype.kind == "f"), n
    r.check_invariants()
    o.check_invariants()
    r.close()


def test_supervision_survives_promote_with_generation_bump():
    r = ShardRouter(S, L, replicate="delta", sync_every=4)
    r.attach_supervision(WorkflowConfig(name="drill", activities=("a0",)))
    r.add_tasks(0, 4 * W, duration_est=1.0, now=0.0)
    r.claim_all(k=1, now=1.0, steal=False)
    r.sync_secondaries()
    gen0 = r.shards[2].supervisor.state.generation
    r.fail_shard(2)
    assert r.shards[2].supervisor.alive is False    # died with the primary
    wq2 = r.promote_shard(2)
    sup = r.shards[2].supervisor
    assert sup.alive and sup.wq is wq2
    assert sup.state.generation == gen0 + 1
    assert r.shards[2].secondary is not None        # shadow re-armed too
    r.close()


def test_expand_all_rejects_multi_activity_workflows():
    r = ShardRouter(2, 1)
    r.attach_supervision(WorkflowConfig(name="m", activities=("a0", "a1")))
    with pytest.raises(ValueError):
        r.expand_all()
    r.close()


def test_promote_without_replicator_is_unrecoverable():
    r = ShardRouter(2, 1)                           # replicate=None
    r.add_tasks(0, 4, now=0.0)
    r.fail_shard(1)
    with pytest.raises(UnrecoverableShardError):
        r.promote_shard(1)
    r.close()


def test_replica_group_all_dead_raises():
    """Every member process killed: election must fail loudly — promoting
    a dead member would serve an empty store as if it were the truth."""
    wq = WorkQueue(num_workers=2)
    wq.add_tasks(0, 8, now=0.0)
    rep = make_replicator(wq, "group", replicas=2, sync_every=4)
    try:
        rep.sync()
        for m in rep.members:
            m.process.kill()
            m.process.join(timeout=10)
        with pytest.raises(AllReplicasDeadError):
            rep.promote()
    finally:
        rep.close()


# ------------------------------------------------------- two-phase stealing
def test_steal_rolls_back_when_transport_dies():
    """Phase-1 prune is provisional: with the steal wire dead, the chunk
    is re-inserted on the victim — conserved, claimable where it was, and
    ordinary logged traffic the victim's replica replays to parity."""
    r = ShardRouter(2, 2, replicate="delta", sync_every=4)
    r.add_tasks(0, 64, duration_est=1.0, now=0.0)
    sh0 = r.shards[0]
    got = sh0.wq.claim_all(k=32, now=1.0)           # drain shard 0 dry
    done = np.concatenate([v for v in got.values() if len(v)])
    sh0.wq.finish(done, now=2.0)
    assert int((sh0.wq.store.col("status") == int(Status.READY)).sum()) == 0
    live_before = r.live_task_ids()
    r._steal_tx.close()                             # the wire dies
    assert r.rebalance(now=3.0) == 0                # nothing moved
    assert r.steal_stats.rollbacks >= 1
    assert r.steal_stats.rolled_back_tasks > 0
    assert np.array_equal(live_before, r.live_task_ids())
    # the rolled-back chunk is claimable on the victim again
    got = r.shards[1].wq.claim_all(k=4, now=4.0)
    assert sum(len(v) for v in got.values()) > 0
    # rollback is normal logged traffic: the victim replica replays it
    rep = r.shards[1].replicator
    rep.sync()
    for n in r.shards[1].wq.store.cols:
        a, b = r.shards[1].wq.store.col(n), rep.store.col(n)
        assert np.array_equal(a, b, equal_nan=a.dtype.kind == "f"), n
    r.check_invariants()
    r.close()                                       # double-close is safe


# --------------------------------------------------------------- checkpoints
def test_sharded_checkpoint_restores_exact_version_vector(tmp_path):
    r, _ = _paired(32, replicate="delta", sync_every=8)
    clock = 1.0
    for _ in range(4):
        rc = r.claim_all(k=2, now=clock, steal=False)
        ids = np.sort(np.concatenate(
            [r.shards[s].wq.store.col("task_id")[rows]
             for s, rows in rc.values() if len(rows)]))
        _finish_router(r, ids, clock + 1.0)
        clock += 2.0

    ck = Checkpointer(str(tmp_path), async_write=False)
    with pytest.raises(ValueError):                 # wq= and router= are
        ck.save(1, {"w": np.zeros(2)}, r.shards[0].wq, router=r)  # exclusive
    vec = [int(v) for v in r.version_vector()]
    fp = _fp(ShardRouter.comparable(
        r.run_all(clock, views=r.snapshot_vector())))
    ck.save(1, {"w": np.arange(8.0)}, router=r)

    # ONE manifest carries the vector and every shard's store file
    man = json.loads(
        (tmp_path / "step_00000001" / "manifest.json").read_text())
    assert man["version_vector"] == vec
    assert man["store_files"] == [f"store_{i}.npz" for i in range(S)]

    step, state, r2 = ck.restore({"w": np.zeros(8)})
    assert step == 1 and isinstance(r2, ShardRouter)
    assert np.array_equal(state["w"], np.arange(8.0))
    assert [int(v) for v in r2.version_vector()] == vec
    fp2 = _fp(ShardRouter.comparable(
        r2.run_all(clock, views=r2.snapshot_vector())))
    assert fp2 == fp                                # bit-identical resume
    assert np.array_equal(r.live_task_ids(), r2.live_task_ids())
    # the restored router serves claims and keeps allocating unique ids
    got = r2.claim_all(k=1, now=clock)
    assert sum(len(rows) for _, rows in got.values()) > 0
    fresh = r2.add_tasks(0, W, now=clock)
    assert int(fresh.min()) > int(r.live_task_ids().max())
    # checkpoint consumer re-registered: compaction can't outrun the save
    assert all(sh.wq.log.has_consumer("checkpointer") for sh in r2.shards)
    r2.close()
    r.close()


def test_restore_skips_torn_checkpoints(tmp_path):
    ck = Checkpointer(str(tmp_path), async_write=False)
    wq = WorkQueue(num_workers=2)
    wq.add_tasks(0, 8, now=0.0)
    state = {"w": np.arange(4.0)}
    ck.save(1, state, wq)
    wq.claim_all(k=2, now=1.0)
    ck.save(2, state, wq)
    # tear step 2: a torn manifest must make the whole dir non-restorable
    m = tmp_path / "step_00000002" / "manifest.json"
    m.write_text(m.read_text()[:37])
    assert ck.latest_step() == 1                    # torn dir skipped
    step, _, wq2 = ck.restore({"w": np.zeros(4)})
    assert step == 1 and wq2 is not None
    with pytest.raises(IOError):                    # explicit ask is loud
        ck.restore({"w": np.zeros(4)}, step=2)
    # a manifest that parses but lost its store file is torn too
    ck.save(3, state, wq)
    (tmp_path / "step_00000003" / "store.npz").unlink()
    assert ck.latest_step() == 1


# ----------------------------------------------- resize x reaper x heartbeat
def test_reap_rehashes_onto_post_resize_partitions():
    """Workers die holding leases, THEN the pool shrinks: reaped retries
    must land on the post-resize worker map (not the dead partitions),
    ride the log to replica parity, and be claimable by the smaller pool."""
    wq = WorkQueue(num_workers=8, lease_s=2.0)
    rep = DeltaReplicator(wq, sync_every=1 << 20)
    wq.add_tasks(0, 64, duration_est=1.0, now=0.0)
    for w in range(8):
        wq.claim(w, k=2, now=0.0)                   # then everyone dies
    wq.resize(4)                                    # shrink mid-outage
    assert wq.reap_expired(now=10.0) == 16
    st = wq.store.col("status")
    ready = np.nonzero(st == int(Status.READY))[0]
    tid = wq.store.col("task_id")[ready]
    wid = wq.store.col("worker_id")[ready]
    assert (wid == tid % 4).all()                   # post-resize map
    rep.sync()                                      # rehash rides the log
    for n in wq.store.cols:
        a, b = wq.store.col(n), rep.store.col(n)
        assert np.array_equal(a, b, equal_nan=a.dtype.kind == "f"), n
    got = wq.claim_all(k=16, now=11.0)
    assert sum(len(v) for v in got.values()) == 64  # all claimable
    rep.close()


def test_heartbeat_monitor_resyncs_across_resizes():
    wq = WorkQueue(num_workers=6, lease_s=2.0)
    wq.add_tasks(0, 12, duration_est=1.0, now=0.0)
    mon = HeartbeatMonitor(wq, timeout_s=2.0, now=0.0)
    for w in range(6):
        wq.claim(w, k=1, now=0.0)
    wq.resize(3)                                    # decommission 3..5
    dead = mon.sweep(now=10.0)                      # resync THEN detect
    assert set(mon.beats) == {0, 1, 2}              # no ghost beats
    assert set(dead) == {0, 1, 2} and mon.dead == {0, 1, 2}
    assert mon.sweep(now=10.5) == []                # no re-declare
    wq.resize(5)                                    # grow back
    assert mon.sweep(now=11.0) == []                # new workers seeded
    assert set(mon.beats) == {0, 1, 2, 3, 4}        # at now, not dead
    assert mon.dead <= {0, 1, 2}


# ------------------------------------------------------------------ executor
def test_sharded_executor_checkpoints_and_fails_over(tmp_path):
    """The PR 9 lift: shards>1 + checkpointer now compose, and the
    executor surfaces fail_shard/promote_shard end-to-end."""
    from repro.configs import smoke_config
    from repro.data.pipeline import DataConfig
    from repro.runtime.executor import TrainExecutor
    cfg = smoke_config("qwen2-0.5b")
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, batch_size=4)
    ck = Checkpointer(str(tmp_path), async_write=False)
    ex = TrainExecutor(cfg, num_workers=4, shards=2, analyst="replica",
                       data_cfg=data, checkpointer=ck, checkpoint_every=4)
    ex.submit_steps(8)
    for _ in range(6):                              # past a checkpoint save
        ex.tick()
    assert ck.latest_step() is not None
    ex.fail_shard(1)
    assert not ex.router.shards[1].alive
    ex.promote_shard(1)
    assert ex.router.shards[1].alive
    hist = ex.run()
    assert ex.router.tasks_left() == 0
    assert sum(int(sh.wq.counts()["FINISHED"])
               for sh in ex.router.shards) == 8
    assert len(hist) >= 8
    # supervision survived the promote with a generation bump
    assert ex.router.shards[1].supervisor.state.generation >= 1
    # the saved checkpoint restores a full router at its version vector
    import jax
    step, _, r2 = ck.restore(jax.device_get(ex.state))
    assert isinstance(r2, ShardRouter) and step >= 4
    r2.close()
    ex.close()
