"""End-to-end behaviour tests: WQ-driven training + serving executors with
steering, failure injection, and checkpoint/resume — the paper's full loop
with real ML tasks."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import Checkpointer
from repro.configs import smoke_config
from repro.data.pipeline import DataConfig
from repro.runtime.executor import ServeExecutor, TrainExecutor


def small_data(cfg):
    return DataConfig(vocab_size=cfg.vocab_size, seq_len=32, batch_size=4)


def test_train_executor_reduces_loss_and_records_provenance():
    cfg = smoke_config("qwen2-0.5b")
    ex = TrainExecutor(cfg, num_workers=2, data_cfg=small_data(cfg),
                       base_lr=3e-3)
    ex.submit_steps(24)
    hist = ex.run()
    assert len(hist) == 24
    first = np.mean([h["loss"] for h in hist[:6]])
    last = np.mean([h["loss"] for h in hist[-6:]])
    assert last < first, (first, last)     # synthetic language is learnable
    # provenance: every task carries its loss in the domain columns
    out0 = ex.wq.store.col("out0")
    assert np.isfinite(out0[:24]).all()
    assert ex.wq.counts()["FINISHED"] == 24


def test_train_executor_replica_analyst_mode():
    """Sweeps run against a delta-caught-up replica store: the analyst
    thread never reads the live arrays, and the replica it reads is
    bit-identical to the primary once synced."""
    cfg = smoke_config("qwen2-0.5b")
    ex = TrainExecutor(cfg, num_workers=2, data_cfg=small_data(cfg),
                       steer_every=2, analyst="replica")
    ex.submit_steps(6)
    ex.run()
    ex.close()
    assert ex.last_steering is not None            # sweeps actually ran
    assert ex.replica.records_applied > 0          # ... fed by log replay
    ex.replica.sync()                              # drain the final tail
    view = ex.wq.store.snapshot_view()
    for name in ex.wq.store.cols:
        assert np.array_equal(view.col(name), ex.replica.store.col(name),
                              equal_nan=True), name
    assert ex.wq.counts()["FINISHED"] == 6


def test_train_executor_survives_worker_failure_and_failover():
    cfg = smoke_config("qwen2-0.5b")
    ex = TrainExecutor(cfg, num_workers=3, data_cfg=small_data(cfg))
    ex.submit_steps(9)
    ex.tick()
    requeued = ex.fail_worker(1)           # node loss mid-flight
    ex.promote_secondary()                 # supervisor loss
    hist = ex.run()
    assert ex.wq.counts()["FINISHED"] == 9
    assert ex.steering.q4_tasks_left() == 0


def test_train_executor_steering_prune_reduces_work():
    cfg = smoke_config("qwen2-0.5b")
    ex = TrainExecutor(cfg, num_workers=2, data_cfg=small_data(cfg))
    ex.submit_steps(6, lr_scale=1.0, sweep_id=0)
    ex.submit_steps(6, lr_scale=8.0, sweep_id=1)   # diverging member
    ex.tick()
    # user steers: prune the high-lr sweep member (paper Q8/data reduction)
    pruned = ex.steering.prune("in0", 7.0, 9.0)
    assert pruned > 0
    ex.run()
    c = ex.wq.counts()
    assert c["PRUNED"] == pruned
    assert c["FINISHED"] + c["PRUNED"] == 12


def test_checkpoint_resume_mid_workflow(tmp_path):
    cfg = smoke_config("qwen2-0.5b")
    ck = Checkpointer(str(tmp_path), async_write=False)
    ex = TrainExecutor(cfg, num_workers=2, data_cfg=small_data(cfg),
                       checkpointer=ck, checkpoint_every=4)
    ex.submit_steps(8)
    for _ in range(4):
        ex.tick()
    ck.save(ex.step, ex.state, ex.wq)      # explicit cut, then "crash"
    step, state, wq = ck.restore(jax.device_get(ex.state))
    left = (wq.counts()["READY"] + wq.counts()["RUNNING"]
            + wq.counts()["BLOCKED"])
    assert wq.counts()["FINISHED"] == step
    assert left == 8 - step


def test_serve_executor_continuous_batching():
    cfg = smoke_config("qwen2-0.5b")
    ex = ServeExecutor(cfg, slots=2, max_len=48)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (5, 8)).astype(np.int32)
    ids = ex.submit(prompts, max_new=5)
    n = ex.drain()
    assert n == 5
    for t in ids:
        out = ex.wq.store.blobs[int(t)]["output"]
        assert len(out) == 5
    assert ex.wq.counts()["FINISHED"] == 5
