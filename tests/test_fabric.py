"""Replication fabric: N-replica fan-out groups over the wire transports.

A :class:`ReplicaGroup` broadcasts the same deltas to N independent
``ShippedDeltaReplicator`` members (each its own txn-log consumer). These
tests pin the fabric semantics: member-for-member bit-parity after a
broadcast sync, the min-over-group compaction floor (a lagging member pins
exactly its unconsumed prefix), round-robin sweep dispatch, and failover
election — promote() must crown the highest-acked SURVIVOR after the
leader dies.
"""
import json

import numpy as np
import pytest

from repro.core import Status, SteeringEngine, WorkQueue
from repro.core.replication import ReplicaGroup, ReplicationFabric, \
    ShippedDeltaReplicator


def sweep_key(res):
    return json.dumps(res, sort_keys=True, default=str)


def churn(wq, rng, rounds=3):
    for r in range(rounds):
        out = wq.claim_all(k=1, now=float(r))
        rows = np.concatenate([v for v in out.values() if len(v)]) \
            if any(len(v) for v in out.values()) else np.empty(0, np.int64)
        if not len(rows):
            break
        half = rows[: len(rows) // 2]
        if len(half):
            wq.finish(half, now=float(r) + 0.5,
                      domain_out=rng.normal(0.5, 0.3, (len(half), 3)))


def test_group_fanout_parity_and_min_over_group_floor():
    rng = np.random.default_rng(0)
    wq = WorkQueue(num_workers=3)
    steer = SteeringEngine(wq)
    grp = ReplicaGroup(wq, n_replicas=3, sync_every=8)
    assert ReplicationFabric is ReplicaGroup
    assert len({m.consumer for m in grp.members}) == 3   # independent acks
    wq.add_tasks(0, 24, domain_in=rng.uniform(0, 1, (24, 3)))
    churn(wq, rng)

    # only two members sync: the laggard's ack (its spawn offset) is the
    # compaction floor, so nothing it still needs may be dropped
    grp.members[0].sync()
    grp.members[1].sync()
    laggard_off = grp.members[2].offset
    wq.compact_log()
    assert wq.log.base <= laggard_off
    lags = wq.consumer_lags()
    assert lags[grp.members[2].consumer] > 0
    assert lags[grp.members[0].consumer] == 0

    # laggard catches up -> the floor advances and truncation happens
    grp.members[2].sync()
    assert wq.compact_log() > 0
    churn(wq, rng, rounds=2)               # broadcast ACROSS the truncate

    view = wq.store.snapshot_view()
    grp.sync(upto_version=view.version)
    assert grp.lag() == 0 and grp.lags() == [0, 0, 0]
    ref = sweep_key(steer.run_all(7.0, view=view))
    for m in grp.members:
        assert sweep_key(m.remote_sweep(7.0)) == ref
        state = m.fetch_remote_state()
        for name in wq.store.cols:
            assert np.array_equal(view.col(name),
                                  state["snapshot"]["cols"][name],
                                  equal_nan=True), (m.consumer, name)
    assert grp.fanout_lag_s() >= 0.0
    grp.close()
    for m in grp.members:
        assert not wq.log.has_consumer(m.consumer)


def test_group_round_robin_sweep_dispatch():
    wq = WorkQueue(num_workers=2)
    grp = ReplicaGroup(wq, n_replicas=3)
    calls = []
    for i, m in enumerate(grp.members):
        m.remote_sweep = (lambda j: lambda now: calls.append(j) or {})(i)
    for _ in range(7):
        grp.remote_sweep(0.0)
    assert calls == [0, 1, 2, 0, 1, 2, 0]
    grp.close()


def test_group_promote_elects_highest_acked_survivor():
    rng = np.random.default_rng(1)
    wq = WorkQueue(num_workers=2)
    grp = ReplicaGroup(wq, n_replicas=3, sync_every=4)
    wq.add_tasks(0, 16, domain_in=rng.uniform(0, 1, (16, 3)))
    churn(wq, rng, rounds=2)
    # stagger the acks: member0 (leader) > member1 > member2
    grp.members[0].sync()
    grp.members[1].sync()
    wq.add_tasks(0, 4, now=5.0)
    grp.members[0].sync()
    assert grp.members[0].offset > grp.members[1].offset \
        > grp.members[2].offset
    assert grp.elect() is grp.members[0]

    grp.members[0].process.kill()          # the leader dies
    grp.members[0].process.join()
    elected = grp.elect()
    assert elected is grp.members[1]       # highest-acked SURVIVOR

    wq2 = grp.promote()                    # member1's store becomes primary
    assert (wq2.store.col("status") != int(Status.RUNNING)).all()
    assert wq2.store.n_rows == wq.store.n_rows
    for name in ("task_id", "activity_id", "in0", "out0"):
        assert np.array_equal(wq2.store.col(name), wq.store.col(name),
                              equal_nan=True), name
    for m in grp.members:                  # promote released everyone
        assert not wq.log.has_consumer(m.consumer)


def test_group_n1_is_the_shipped_replicator_special_case():
    rng = np.random.default_rng(2)
    wq = WorkQueue(num_workers=2)
    grp = ReplicaGroup(wq, n_replicas=1)
    assert len(grp.members) == 1
    assert isinstance(grp.members[0], ShippedDeltaReplicator)
    wq.add_tasks(0, 8, domain_in=rng.uniform(0, 1, (8, 3)))
    churn(wq, rng, rounds=1)
    view = wq.store.snapshot_view()
    grp.sync(upto_version=view.version)
    steer = SteeringEngine(wq)
    assert sweep_key(grp.remote_sweep(3.0)) \
        == sweep_key(steer.run_all(3.0, view=view))
    grp.close()


def test_group_rejects_empty_and_cleans_up_on_spawn_failure(monkeypatch):
    wq = WorkQueue(num_workers=2)
    with pytest.raises(ValueError, match="at least one"):
        ReplicaGroup(wq, n_replicas=0)
    # member #2 failing to spawn must not leak member #1's process/consumer
    import repro.core.replication as R
    real_init = R.ShippedDeltaReplicator.__init__
    built = []

    def flaky_init(self, *a, **kw):
        if len(built) >= 1:
            raise RuntimeError("no more replicas for you")
        real_init(self, *a, **kw)
        built.append(self)

    monkeypatch.setattr(R.ShippedDeltaReplicator, "__init__", flaky_init)
    with pytest.raises(RuntimeError, match="no more replicas"):
        ReplicaGroup(wq, n_replicas=2)
    assert built and built[0].process is None    # closed, not leaked
    assert not wq.log.has_consumer(built[0].consumer)
