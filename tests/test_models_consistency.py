"""Cross-implementation consistency: decode==full forward, chunked==ref
attention, MoE dispatch paths agree, microbatching is loss-neutral."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import smoke_config
from repro.models import build_model
from repro.models import layers as L
from repro.models import moe as M
from repro.models import transformer as T
from repro.models.attention import sdpa_ref
from repro.models.chunked_attn import chunked_sdpa

CONSISTENCY_ARCHS = ["qwen2-0.5b", "mamba2-1.3b", "recurrentgemma-9b",
                     "granite-moe-3b-a800m", "qwen2-vl-2b"]


@pytest.mark.parametrize("arch", CONSISTENCY_ARCHS)
def test_decode_matches_full_forward(arch):
    cfg = smoke_config(arch)
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch="dense"))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                                cfg.vocab_size)
    if cfg.embed_stub:
        emb = jax.random.normal(jax.random.PRNGKey(2),
                                (B, S + 1, cfg.d_model)) * 0.1
        full, pre = {"embeds": emb}, {"embeds": emb[:, :S]}
        if cfg.mrope:
            mp = jnp.broadcast_to(jnp.arange(S + 1)[None, None],
                                  (3, B, S + 1)).astype(jnp.int32)
            full["mrope_positions"], pre["mrope_positions"] = mp, mp[:, :, :S]
        last = emb[:, S:S + 1]
    else:
        full, pre = {"tokens": tokens}, {"tokens": tokens[:, :S]}
        last = tokens[:, S:S + 1]
    x = T._embed_inputs(cfg, params, full)
    pos = jnp.arange(S + 1)[None, :]
    x, _, _ = T._run_stack(cfg, params, x, positions=pos,
                           mrope=full.get("mrope_positions"))
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    ref = x[:, -1] @ T._head_table(cfg, params).T
    _, cache = m.prefill(params, pre, S + 4)
    got, _ = m.decode_step(params, last, cache)
    assert float(jnp.max(jnp.abs(got[:, 0] - ref))) < 2e-3


@settings(max_examples=8, deadline=None)
@given(s=st.sampled_from([64, 128, 256]),
       hq=st.sampled_from([2, 4]), g=st.sampled_from([1, 2]),
       causal=st.booleans(), packed=st.booleans(),
       qc=st.sampled_from([16, 32, 64]))
def test_property_chunked_attention_matches_ref(s, hq, g, causal, packed, qc):
    hkv = max(1, hq // g)
    ks = jax.random.split(jax.random.PRNGKey(s + hq + qc), 3)
    q = jax.random.normal(ks[0], (1, s, hq, 16))
    k = jax.random.normal(ks[1], (1, s, hkv, 16))
    v = jax.random.normal(ks[2], (1, s, hkv, 16))
    ref = sdpa_ref(q, k, v, causal=causal, window=0)
    got = chunked_sdpa(q, k, v, causal=causal, q_chunk=qc, packed=packed)
    assert float(jnp.max(jnp.abs(got - ref))) < 1e-5


def test_moe_sort_matches_dense_oracle():
    cfg = smoke_config("granite-moe-3b-a800m")
    p = M.moe_init(jax.random.PRNGKey(3), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 32, cfg.d_model)) * 0.5
    yd, auxd = M.moe_ffn_dense(p, x, cfg)
    ys, auxs = M.moe_ffn_sort(p, x, cfg, capacity_factor=8.0)
    assert float(jnp.max(jnp.abs(ys - yd))) < 1e-4
    assert abs(float(auxd) - float(auxs)) < 1e-6


def test_moe_capacity_drops_tokens_but_stays_finite():
    cfg = smoke_config("granite-moe-3b-a800m")
    p = M.moe_init(jax.random.PRNGKey(3), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 32, cfg.d_model))
    y, _ = M.moe_ffn_sort(p, x, cfg, capacity_factor=0.25)   # heavy drops
    assert bool(jnp.isfinite(y).all())


def test_microbatching_is_gradient_neutral():
    """mb=1 vs mb=4 must produce the same loss and (averaged) grads."""
    from repro.launch.steps import init_train_state, make_train_step
    cfg1 = smoke_config("qwen2-0.5b")
    cfg4 = dataclasses.replace(cfg1, microbatches=4)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(0), (8, 32),
                                          0, cfg1.vocab_size),
             "labels": jax.random.randint(jax.random.PRNGKey(1), (8, 32),
                                          0, cfg1.vocab_size)}
    knobs = {"lr": jnp.float32(1e-3)}
    s1 = init_train_state(cfg1, jax.random.PRNGKey(2))
    s4 = init_train_state(cfg4, jax.random.PRNGKey(2))
    o1, m1 = jax.jit(make_train_step(cfg1))(s1, batch, knobs)
    o4, m4 = jax.jit(make_train_step(cfg4))(s4, batch, knobs)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-4
    assert abs(float(m1["grad_norm"]) - float(m4["grad_norm"])) < 1e-3


def test_grad_compression_roundtrip_small_error():
    from repro.optim.compression import compress_grads, init_error
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 64))}
    e = init_error(g)
    total = jnp.zeros((64, 64))
    exact = jnp.zeros((64, 64))
    for i in range(10):
        gc, e = compress_grads(g, e)
        total = total + gc["w"]
        exact = exact + g["w"]
    # error feedback: accumulated compressed grads track the exact sum
    rel = float(jnp.linalg.norm(total - exact) / jnp.linalg.norm(exact))
    assert rel < 0.01
