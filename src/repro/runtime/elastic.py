"""Elastic scaling: resize the worker set W -> W' at runtime.

The WQ re-hash is core (workqueue.resize, stable task ids, minimal moves);
this module adds the orchestration policy: when to grow/shrink based on the
queue depth vs worker throughput, mirroring an autoscaler at 1000+ nodes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.schema import Status
from repro.core.workqueue import WorkQueue


@dataclasses.dataclass
class ElasticPolicy:
    min_workers: int = 1
    max_workers: int = 4096
    target_tasks_per_worker: float = 8.0
    hysteresis: float = 0.5     # only act when off-target by >50%


class ElasticController:
    def __init__(self, wq: WorkQueue, policy: Optional[ElasticPolicy] = None):
        self.wq = wq
        self.policy = policy or ElasticPolicy()
        self.resizes = 0

    def desired_workers(self) -> int:
        st = self.wq.store.col("status")
        backlog = int(np.isin(st, [int(Status.READY),
                                   int(Status.BLOCKED)]).sum())
        p = self.policy
        want = int(np.clip(round(backlog / p.target_tasks_per_worker),
                           p.min_workers, p.max_workers))
        return max(want, p.min_workers)

    def maybe_resize(self) -> Optional[int]:
        want = self.desired_workers()
        cur = self.wq.num_workers
        if want == cur:
            return None
        if abs(want - cur) / max(cur, 1) < self.policy.hysteresis:
            return None
        moved = self.wq.resize(want)
        self.resizes += 1
        return want
