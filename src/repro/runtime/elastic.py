"""Elastic scaling: resize the worker set W -> W' at runtime.

The WQ re-hash is core (workqueue.resize, stable task ids, minimal moves);
this module adds the orchestration policy: when to grow/shrink based on the
queue depth vs worker throughput, mirroring an autoscaler at 1000+ nodes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.workqueue import WorkQueue


@dataclasses.dataclass
class ElasticPolicy:
    min_workers: int = 1
    max_workers: int = 4096
    target_tasks_per_worker: float = 8.0
    hysteresis: float = 0.5     # only act when off-target by >50%
    # staleness escalation (the HPA side of the Work Claim Pattern): when
    # the oldest pending task or the p95 submit-to-claim latency exceeds
    # these, the pool is starved regardless of the count-based target —
    # grow by `escalation_factor` and BYPASS the hysteresis band. inf
    # disables (pure count-based scaling, the pre-lease behavior).
    max_backlog_age_s: float = float("inf")
    max_claim_p95_s: float = float("inf")
    escalation_factor: float = 2.0


class ElasticController:
    def __init__(self, wq: WorkQueue, policy: Optional[ElasticPolicy] = None):
        self.wq = wq
        self.policy = policy or ElasticPolicy()
        self.resizes = 0
        self.last_signals: Optional[dict] = None
        self._escalated = False

    def desired_workers(self, now: Optional[float] = None) -> int:
        """Pool size from the relation's own autoscaling signals
        (``WorkQueue.autoscale_signals``): pending backlog / target ratio,
        escalated past the count target when the backlog is STALE (age or
        p95 claim latency over threshold — only meaningful when ``now`` is
        supplied on the workload clock)."""
        p = self.policy
        sig = self.wq.autoscale_signals(
            now=now if now is not None else 0.0)
        self.last_signals = sig
        want = int(np.clip(round(sig["pending"] / p.target_tasks_per_worker),
                           p.min_workers, p.max_workers))
        self._escalated = bool(
            now is not None and sig["pending"] > 0
            and (sig["backlog_age_s"] > p.max_backlog_age_s
                 or sig["claim_p95_s"] > p.max_claim_p95_s))
        if self._escalated:
            want = int(np.clip(
                round(max(want, self.wq.num_workers) * p.escalation_factor),
                p.min_workers, p.max_workers))
        return max(want, p.min_workers)

    def maybe_resize(self, now: Optional[float] = None) -> Optional[int]:
        want = self.desired_workers(now)
        cur = self.wq.num_workers
        if want == cur:
            return None
        if not self._escalated \
                and abs(want - cur) / max(cur, 1) < self.policy.hysteresis:
            return None
        moved = self.wq.resize(want)
        self.resizes += 1
        return want
