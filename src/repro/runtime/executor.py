"""WQ-driven executors: the paper's architecture running real ML work.

TrainExecutor — the supervisor expands a (sweep x step-stream) workflow into
tasks; each scheduler tick claims the next task per worker slice from the
partitioned WQ (one vectorized claim — the wq_claim semantics), executes the
jitted train step with the task's knobs (lr scale, data shard, sweep member),
and commits provenance (loss, grad norm, timing) back to the SAME store the
steering engine queries — the paper's single-database HTAP design, with
training steps in place of Risers simulations.

ServeExecutor — continuous batching: requests are WQ rows; decode slots claim
requests from their partition; per-token progress/results are store updates.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.risers_workflow import WorkflowConfig
from repro.core.replication import make_replicator
from repro.core.schema import Status
from repro.core.sharding_router import ShardRouter
from repro.core.steering import SteeringEngine
from repro.core.supervisor import SecondarySupervisor, Supervisor
from repro.core.workqueue import WorkQueue
from repro.data.pipeline import DataConfig, batch_for
from repro.launch.steps import init_train_state, make_serve_step, \
    make_train_step
from repro.models.registry import build_model


@dataclasses.dataclass
class TrainTaskSpec:
    """Domain columns of a training task: in0 = lr scale, in1 = data shard,
    in2 = sweep member id. Outputs: out0 = loss, out1 = grad norm,
    out2 = tokens/s (sim)."""
    lr_scale: float
    shard: int
    sweep_id: int


class TrainExecutor:
    def __init__(self, cfg: ModelConfig, *, num_workers: int = 1,
                 base_lr: float = 3e-4, data_cfg: Optional[DataConfig] = None,
                 checkpointer=None, checkpoint_every: int = 50,
                 steer_every: int = 0, seed: int = 0,
                 analyst: str = "snapshot", replicas: int = 1,
                 shards: int = 1, lease_s: Optional[float] = None):
        self.cfg = cfg
        self.num_workers = num_workers
        self.base_lr = base_lr
        self.data_cfg = data_cfg or DataConfig(
            vocab_size=cfg.vocab_size, seq_len=128, batch_size=8)
        # shards > 1: the sharded topology — num_workers partitions split
        # across `shards` full primaries behind a ShardRouter; claims,
        # replication, and compaction run per shard, steering is the
        # router's scatter-gather sweep, and drained shards pull work from
        # rich siblings (cross-shard stealing) each tick.
        if shards < 1:
            raise ValueError("shards must be >= 1")
        if shards > 1 and num_workers % shards:
            raise ValueError(f"num_workers={num_workers} must divide "
                             f"evenly across shards={shards}")
        self.workflow = WorkflowConfig(name="train-sweep",
                                       activities=("train_step",))
        self.router: Optional[ShardRouter] = None
        if shards > 1:
            # checkpointing a sharded run is supported since PR 9: the
            # Checkpointer cuts one store-lock-consistent snapshot per
            # shard plus the version vector into a single atomic manifest
            self.router = ShardRouter(
                shards, num_workers // shards,
                replicate=None if analyst == "snapshot" else analyst,
                replicas=replicas, lease_s=lease_s)
            # per-shard supervision: each Shard gets a Supervisor +
            # SecondarySupervisor so expansion state survives a
            # promote_shard (the single-activity training workflow keeps
            # shard-local id allocation safe)
            self.router.attach_supervision(self.workflow)
            self.wq = self.router.shards[0].wq   # compat: a primary handle
            self.supervisor = self.secondary = None
            self.steering = None
        else:
            self.wq = WorkQueue(num_workers=num_workers, lease_s=lease_s)
        if self.router is None:
            self.supervisor = Supervisor(self.wq, self.workflow)
            self.secondary = SecondarySupervisor(self.supervisor)
            self.steering = SteeringEngine(self.wq)
        # analyst="snapshot": sweeps read COW snapshot views of the LIVE
        # store (share its arrays until the next write). analyst="replica":
        # sweeps read a delta-caught-up REPLICA store fed only by the txn
        # log — the paper's "steering never touches the transactional hot
        # path", made structural: the analyst thread never holds a single
        # live array. analyst="remote": the replica lives in a SEPARATE OS
        # process fed wire-encoded deltas over a transport (pipe, or TCP
        # for another host); sweeps execute in that process and only the
        # result ships back — the paper's distributed topology (analytical
        # node != data node) for real. ``replicas`` > 1 fans the remote
        # mode out to an N-member ReplicaGroup: deltas broadcast to every
        # member, sweeps round-robin across them.
        if analyst not in ("snapshot", "replica", "remote"):
            raise ValueError(f"unknown analyst mode {analyst!r}")
        self.analyst = analyst
        self.replica = None
        if analyst != "snapshot" and self.router is None:
            # all replication policy lives behind the factory: "replica"
            # maps to the in-process delta arm (nothing ships, so the
            # wire-size accounting is skipped), "remote" to a pipelined
            # replica group fed over the wire
            self.replica = make_replicator(
                self.wq, analyst, replicas=replicas,
                account_encoded=False)
        self.checkpointer = checkpointer
        self.checkpoint_every = checkpoint_every
        self.steer_every = steer_every
        # steering sweeps run on an analyst thread against a store snapshot,
        # concurrent with the claim/train/commit loop (HTAP, paper Exp. 7)
        self._steer_pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="steering")
        self._steer_future: Optional[concurrent.futures.Future] = None
        self.last_steering: Optional[Dict[str, object]] = None
        self.step_fn = jax.jit(make_train_step(cfg))
        self.state = init_train_state(cfg, jax.random.PRNGKey(seed))
        self.step = 0
        self.reaped_total = 0
        self.history: List[Dict[str, float]] = []

    # ------------------------------------------------------------- seeding
    def submit_steps(self, n: int, *, lr_scale: float = 1.0,
                     sweep_id: int = 0) -> np.ndarray:
        dom = np.stack([
            np.full(n, lr_scale),
            np.arange(self.step, self.step + n) % (1 << 20),
            np.full(n, sweep_id),
        ], axis=1)
        if self.router is not None:
            return self.router.add_tasks(0, n, domain_in=dom,
                                         now=time.time())
        return self.wq.add_tasks(0, n, domain_in=dom, now=time.time())

    # ---------------------------------------------------------------- tick
    def tick(self) -> Dict[str, float]:
        """One scheduler tick: claim -> execute -> commit provenance."""
        now = time.time()
        if self.router is not None:
            # any drained shard refills from the richest sibling BEFORE
            # claiming — the cross-shard stealing path
            if (self.router.ready_counts()
                    .reshape(self.router.num_shards, -1).sum(1) == 0).any():
                self.router.rebalance(now=now)
            claims = [(self.router.shards[s].wq, rows)
                      for s, rows in self.router.claim_all(
                          k=1, now=now).values()]
        else:
            claims = [(self.wq, rows)
                      for rows in self.wq.claim_all(k=1, now=now).values()]
        metrics_out: Dict[str, float] = {}
        for wq, rows in claims:
            for row in rows:
                lr_scale = wq.store.col("in0")[row]
                shard = int(wq.store.col("in1")[row])
                batch = batch_for(self.cfg, self.data_cfg, shard)
                knobs = {"lr": jnp.asarray(self.base_lr * lr_scale,
                                           jnp.float32)}
                t0 = time.time()
                self.state, metrics = self.step_fn(self.state, batch, knobs)
                loss = float(metrics["loss"])
                gnorm = float(metrics["grad_norm"])
                dt_s = time.time() - t0
                wq.finish(np.asarray([row]), now=time.time(),
                          domain_out=np.asarray(
                              [[loss, gnorm, dt_s]]))
                self.step += 1
                rec = {"step": self.step, "loss": loss, "grad_norm": gnorm,
                       "s_per_step": dt_s}
                self.history.append(rec)
                metrics_out = rec
        if self.checkpointer and self.checkpoint_every \
                and self.step and self.step % self.checkpoint_every == 0:
            if self.router is not None:
                self.router.sync_secondaries()
                self.checkpointer.save(self.step, self.state,
                                       router=self.router)
            else:
                self.checkpointer.save(self.step, self.state, self.wq)
            self._maybe_compact_log()
        if self._steer_future is not None and self._steer_future.done():
            self.last_steering = self._steer_future.result()
            metrics_out["steering"] = self.last_steering
            self._steer_future = None
        if self.steer_every and self.step % self.steer_every == 0 \
                and self._steer_future is None:
            # the steering tick doubles as the lease sweep: requeue every
            # expired RUNNING claim (data-plane dead-worker recovery) before
            # analyzing, so the sweep sees the recovered backlog — sharded
            # runs reap per shard and the reclaimed rows feed rebalance
            self.reaped_total += self.reap(now=time.time())
            if self.router is not None:
                # scatter-gather sweep: pin a consistent version vector on
                # THIS thread (at this tick's commits), merge on the
                # analyst thread; "remote" scatters the sweep into the
                # per-shard replica processes instead
                if self.analyst == "remote":
                    # pin + ship on THIS (producer) thread — sync_replicas
                    # settles every shard's replica exactly at this tick's
                    # version vector — then scatter the partial sweeps into
                    # the per-shard replica processes from the analyst
                    # thread (sync=False: only log-free sweep requests ride
                    # the pipes, so the producer keeps claiming meanwhile)
                    vec = self.router.sync_replicas()
                    self._steer_future = self._steer_pool.submit(
                        self.router.remote_sweep, time.time(),
                        versions=vec, sync=False)
                else:
                    views = (self.router.replica_vector()
                             if self.analyst == "replica"
                             else self.router.snapshot_vector())
                    self._steer_future = self._steer_pool.submit(
                        self.router.run_all, time.time(), views)
                return metrics_out
            if self.replica is not None:
                # catch the replica up to this tick's commits (O(delta)
                # wire ship for "remote", in-process log replay for
                # "replica"); the sync acked the replica's consumer
                # offset, so compaction piggybacks once a durable
                # checkpoint anchors history
                self.replica.sync()
                self._maybe_compact_log()
            if self.analyst == "remote":
                # run the sweep IN the replica process: the analyst thread
                # only waits on the result pipe — no store array, live or
                # copied, crosses back
                self._steer_future = self._steer_pool.submit(
                    self.replica.remote_sweep, time.time())
            else:
                # replica: sweep the caught-up shadow store — the live
                # arrays are never handed to the analyst thread at all.
                # snapshot: COW view of the live store at this tick's
                # commits, analyzed while the next ticks keep claiming
                view = self.replica.snapshot_view() \
                    if self.replica is not None \
                    else self.wq.store.snapshot_view()
                self._steer_future = self._steer_pool.submit(
                    self.steering.run_all, time.time(), view)
        return metrics_out

    def _maybe_compact_log(self) -> None:
        """Compact the txn log only once a DURABLE checkpoint has acked an
        offset: truncation is then 'since last checkpoint' by construction,
        so `SteeringEngine.at_version` keeps its documented degradation path
        (base snapshot = the checkpoint). Without a checkpoint consumer the
        log is left whole — genesis time-travel stays available and memory
        is bounded by the caller's own `wq.compact_log()` policy instead."""
        if self.router is not None:
            for sh in self.router.shards:
                if sh.alive and sh.wq.log.has_consumer("checkpointer"):
                    sh.wq.compact_log()
            return
        if self.wq.log.has_consumer("checkpointer"):
            self.wq.compact_log()

    def run(self, max_ticks: int = 10_000) -> List[Dict[str, float]]:
        for _ in range(max_ticks):
            left = (self.router.tasks_left() if self.router is not None
                    else self.steering.q4_tasks_left())
            if left == 0:
                break
            self.tick()
        self._drain_steering()
        return self.history

    def _drain_steering(self) -> None:
        """Harvest an in-flight sweep; record it on the latest history entry
        so short runs still surface their final (paid-for) sweep."""
        if self._steer_future is not None:
            self.last_steering = self._steer_future.result()
            self._steer_future = None
            if self.history:
                self.history[-1].setdefault("steering", self.last_steering)

    def close(self) -> None:
        """Release the steering analyst thread (ticks after close raise)."""
        self._drain_steering()
        self._steer_pool.shutdown(wait=True)
        if self.replica is not None:
            self.replica.close()     # stop pinning the log compaction floor
        if self.router is not None:
            self.router.close()      # per-shard replicators + steal pipe

    def __del__(self):
        try:
            self._steer_pool.shutdown(wait=False)
        except Exception:
            pass

    # -------------------------------------------------------------- fault
    def reap(self, *, now: Optional[float] = None,
             max_trials: int = 3) -> int:
        """Requeue expired-lease RUNNING rows (``WorkQueue.reap_expired``),
        across every shard when sharded. Runs automatically on the steering
        tick; callable directly for tighter recovery cadences."""
        now = time.time() if now is None else now
        if self.router is not None:
            return self.router.reap_expired(now=now, max_trials=max_trials)
        return self.wq.reap_expired(now=now, max_trials=max_trials)

    def fail_worker(self, worker_id: int) -> int:
        """Simulate a node failure: requeue its RUNNING tasks elsewhere
        (sharded: within the shard owning that global worker)."""
        if self.router is not None:
            L = self.router.workers_per_shard
            sh = self.router.shards[worker_id // L]
            return sh.wq.requeue_worker(worker_id % L)
        return self.wq.requeue_worker(worker_id)

    def promote_secondary(self, shard: Optional[int] = None) -> None:
        """Fail the supervisor over to its shadow. Sharded runs promote
        per shard (``shard=None`` promotes every shard's secondary) — each
        promoted supervisor gets a bumped generation and resumes expansion
        exactly via the store's ``expanded`` column."""
        if self.router is not None:
            shards = (range(self.router.num_shards) if shard is None
                      else [shard])
            for s in shards:
                sh = self.router.shards[s]
                if sh.secondary is None:
                    raise ValueError(f"shard {s} has no supervision "
                                     "attached")
                sh.supervisor.crash()
                sh.supervisor = sh.secondary.promote()
                sh.secondary = SecondarySupervisor(sh.supervisor)
            return
        self.supervisor.crash()
        self.supervisor = self.secondary.promote()
        self.secondary = SecondarySupervisor(self.supervisor)

    def fail_shard(self, shard: int) -> None:
        """Kill a shard primary mid-run (sharded executors only)."""
        if self.router is None:
            raise ValueError("fail_shard requires a sharded executor")
        self.router.fail_shard(shard)

    def promote_shard(self, shard: int):
        """Fail a dead shard over onto its most-caught-up replica; the
        compat ``self.wq`` handle tracks shard 0's promoted queue."""
        if self.router is None:
            raise ValueError("promote_shard requires a sharded executor")
        wq = self.router.promote_shard(shard)
        if shard == 0:
            self.wq = wq
        return wq


class ServeExecutor:
    """Continuous batching driven by the store: requests are WQ rows."""

    def __init__(self, cfg: ModelConfig, *, slots: int = 4,
                 max_len: int = 128, seed: int = 0):
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.wq = WorkQueue(num_workers=slots)
        self.model = build_model(cfg)
        self.params = self.model.init(jax.random.PRNGKey(seed))
        self.serve_fn = jax.jit(make_serve_step(cfg))
        self.prefill_fn = jax.jit(
            lambda p, b: self.model.prefill(p, b, self.max_len))
        self.cache = None
        self.slot_row: Dict[int, int] = {}
        self.slot_tokens: Dict[int, List[int]] = {}
        self.slot_budget: Dict[int, int] = {}
        self.rng = jax.random.PRNGKey(seed)

    def submit(self, prompts: np.ndarray, max_new: int = 16) -> np.ndarray:
        n = prompts.shape[0]
        dom = np.stack([np.full(n, max_new), np.zeros(n), np.zeros(n)],
                       axis=1)
        ids = self.wq.add_tasks(0, n, domain_in=dom, now=time.time())
        for tid, p in zip(ids, prompts):
            self.wq.store.blobs[int(tid)] = {"prompt": p}
        return ids

    def _admit(self) -> None:
        """Claim queued requests into free slots (continuous batching)."""
        free = [s for s in range(self.slots) if s not in self.slot_row]
        if not free:
            return
        for s in free:
            rows = self.wq.claim(s, k=1, now=time.time(), allow_steal=True)
            if len(rows) == 0:
                continue
            row = int(rows[0])
            tid = int(self.wq.store.col("task_id")[row])
            prompt = self.wq.store.blobs[tid]["prompt"]
            batch = {"tokens": prompt[None, :].astype(np.int32)}
            logits, cache = self.prefill_fn(self.params, batch)
            nxt = int(jnp.argmax(logits[0, -1]))
            if self.cache is None or s not in self.slot_tokens:
                pass
            self.slot_row[s] = row
            self.slot_tokens[s] = [nxt]
            self.slot_budget[s] = int(self.wq.store.col("in0")[row])
            self._caches = getattr(self, "_caches", {})
            self._caches[s] = cache

    def step_decode(self) -> int:
        """One decode step across active slots; returns #finished."""
        self._admit()
        finished = 0
        for s in list(self.slot_row):
            cache = self._caches[s]
            tok = jnp.asarray([[self.slot_tokens[s][-1]]], jnp.int32)
            self.rng, sub = jax.random.split(self.rng)
            nxt, cache, _ = self.serve_fn(self.params, tok, cache, sub)
            self._caches[s] = cache
            self.slot_tokens[s].append(int(nxt[0, 0]))
            if len(self.slot_tokens[s]) >= self.slot_budget[s] \
                    or int(cache["idx"]) >= self.max_len - 1:
                row = self.slot_row.pop(s)
                toks = self.slot_tokens.pop(s)
                tid = int(self.wq.store.col("task_id")[row])
                self.wq.store.blobs[tid]["output"] = np.asarray(toks)
                self.wq.finish(np.asarray([row]), now=time.time(),
                               domain_out=np.asarray(
                                   [[float(len(toks)), 0.0, 0.0]]))
                finished += 1
        return finished

    def drain(self, max_steps: int = 10_000) -> int:
        total = 0
        for _ in range(max_steps):
            left = SteeringEngine(self.wq).q4_tasks_left()
            if left == 0 and not self.slot_row:
                break
            total += self.step_decode()
        return total
