"""Fault handling: heartbeats, failure detection/injection, recovery drill.

The paper's availability story (Section 3.1): every component is replaceable
— data-node loss is covered by replication (core/replication.py), worker
loss by requeue + rehash (workqueue.requeue_worker), supervisor loss by the
secondary. This module adds the detection loop and a deterministic failure
injector used by tests and the fault-tolerance example.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.workqueue import WorkQueue


@dataclasses.dataclass
class Heartbeat:
    worker_id: int
    last_seen: float


class HeartbeatMonitor:
    def __init__(self, wq: WorkQueue, timeout_s: float = 30.0,
                 now: Optional[float] = None):
        self.wq = wq
        self.timeout_s = timeout_s
        t0 = now if now is not None else time.time()
        self.beats: Dict[int, float] = {
            w: t0 for w in range(wq.num_workers)}
        self.dead: set = set()

    def beat(self, worker_id: int, now: Optional[float] = None) -> None:
        self.beats[worker_id] = now if now is not None else time.time()
        self.dead.discard(worker_id)

    def resync(self, now: Optional[float] = None) -> None:
        """Rebuild the beat map after ``WorkQueue.resize``: drop entries for
        removed workers (a stale entry would otherwise re-trigger a
        requeue_worker on every sweep forever) and seed newly added workers
        at ``now`` so they get a full timeout before being declared dead."""
        now = now if now is not None else time.time()
        live = range(self.wq.num_workers)
        self.beats = {w: self.beats.get(w, now) for w in live}
        self.dead &= set(live)

    def sweep(self, now: Optional[float] = None) -> List[int]:
        """Detect dead workers and requeue their RUNNING tasks."""
        now = now if now is not None else time.time()
        if len(self.beats) != self.wq.num_workers \
                or self.wq.num_workers - 1 not in self.beats:
            self.resync(now)       # pool was resized since the last sweep
        newly_dead = []
        for w, seen in self.beats.items():
            if w in self.dead:
                continue
            if now - seen > self.timeout_s:
                self.dead.add(w)
                n = self.wq.requeue_worker(w)
                newly_dead.append(w)
        return newly_dead


class FailureInjector:
    """Deterministic failure schedule for tests/examples: kill worker w at
    tick t, crash the supervisor at tick t', drop a fraction of tasks."""

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)
        self.schedule: List[tuple] = []

    def kill_worker_at(self, tick: int, worker_id: int):
        self.schedule.append((tick, "worker", worker_id))
        return self

    def crash_supervisor_at(self, tick: int):
        self.schedule.append((tick, "supervisor", None))
        return self

    def fail_task_fraction(self, frac: float):
        self.schedule.append((-1, "task_noise", frac))
        return self

    def events_at(self, tick: int) -> List[tuple]:
        return [e for e in self.schedule if e[0] == tick]

    def should_fail_task(self) -> bool:
        for t, kind, frac in self.schedule:
            if kind == "task_noise" and self.rng.random() < frac:
                return True
        return False
