"""Straggler mitigation: work stealing + speculative re-execution.

Stealing is built into WorkQueue.claim(..., allow_steal=True) / claim_all
(paper's load-balancing flexibility). This module adds speculative
re-execution: RUNNING tasks whose elapsed time exceeds a percentile of the
completed-task distribution get a duplicate READY copy (first-writer-wins at
commit; duplicates are reconciled by task id).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.schema import Status
from repro.core.workqueue import WorkQueue


class SpeculativeReexec:
    def __init__(self, wq: WorkQueue, percentile: float = 95.0,
                 min_samples: int = 20, factor: float = 2.0):
        self.wq = wq
        self.percentile = percentile
        self.min_samples = min_samples
        self.factor = factor
        self.speculated: Dict[int, int] = {}   # original task -> clone task

    def threshold(self) -> float:
        st = self.wq.store.col("status")
        fin = st == int(Status.FINISHED)
        if fin.sum() < self.min_samples:
            return np.inf
        dur = (self.wq.store.col("end_time")[fin]
               - self.wq.store.col("start_time")[fin])
        return float(np.percentile(dur, self.percentile) * self.factor)

    def sweep(self, now: float) -> List[int]:
        """Clone slow RUNNING tasks as READY duplicates."""
        thr = self.threshold()
        if not np.isfinite(thr):
            return []
        st = self.wq.store.col("status")
        running = np.nonzero(st == int(Status.RUNNING))[0]
        t0 = self.wq.store.col("start_time")[running]
        # expired-lease rows belong to the REAPER (they requeue, and the
        # original re-runs); cloning them here would double-execute. NaN
        # expires_at (no lease) compares False, so unleased rows still
        # speculate as before.
        exp = self.wq.store.col("expires_at")[running]
        slow = running[((now - t0) > thr) & ~(exp < now)]
        cloned = []
        for row in slow:
            tid = int(self.wq.store.col("task_id")[row])
            if tid in self.speculated:
                continue
            act = int(self.wq.store.col("activity_id")[row])
            dom = np.asarray([[self.wq.store.col(f"in{i}")[row]
                               for i in range(3)]])
            new = self.wq.add_tasks(act, 1, domain_in=dom, now=now)
            self.speculated[tid] = int(new[0])
            cloned.append(int(new[0]))
        return cloned

    def reconcile(self) -> int:
        """First-writer-wins: when either copy FINISHES, prune the other."""
        st = self.wq.store.col("status")
        tid_col = self.wq.store.col("task_id")
        id_to_row = {int(t): i for i, t in enumerate(tid_col)}
        pruned = 0
        for orig, clone in list(self.speculated.items()):
            ro, rc = id_to_row.get(orig), id_to_row.get(clone)
            if ro is None or rc is None:
                continue
            fo = st[ro] == int(Status.FINISHED)
            fc = st[rc] == int(Status.FINISHED)
            if fo or fc:
                loser = rc if fo else ro
                if st[loser] in (int(Status.READY), int(Status.RUNNING)):
                    self.wq.store.update(np.asarray([loser]),
                                         status=int(Status.PRUNED))
                    pruned += 1
                del self.speculated[orig]
        return pruned
