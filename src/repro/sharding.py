"""Logical-axis sharding annotations, decoupled from any concrete mesh.

Models annotate activations/params with *logical* axis names ("batch", "seq",
"model_ff", ...). The launch layer installs a rule set mapping logical axes to
physical mesh axes for the current (arch x shape x mesh); outside such a
context every annotation is a no-op, so smoke tests on one CPU device run the
exact same model code.

This is the pjit/GSPMD idiom: `with_sharding_constraint` steers the sharding
propagation; in/out shardings at the `jax.jit` boundary come from the same
rules (see repro.launch.shardrules).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Logical = Union[str, None, Tuple[str, ...]]

_state = threading.local()


class Rules:
    """Mapping logical axis name -> physical mesh axis (or tuple, or None)."""

    def __init__(self, mesh: Mesh, table: Dict[str, Logical]):
        self.mesh = mesh
        self.table = dict(table)
        self._axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def physical(self, logical: Logical) -> Logical:
        if logical is None:
            return None
        if isinstance(logical, tuple):
            parts: Tuple[str, ...] = ()
            for l in logical:
                p = self.physical(l)
                if p is None:
                    continue
                parts += p if isinstance(p, tuple) else (p,)
            return parts or None
        phys = self.table.get(logical)
        if phys is None:
            return None
        if isinstance(phys, tuple):
            phys = tuple(a for a in phys if a in self._axis_sizes)
            return phys or None
        return phys if phys in self._axis_sizes else None

    def spec(self, *logical: Logical) -> P:
        return P(*[self.physical(l) for l in logical])

    def sharding(self, *logical: Logical) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*logical))


def current_rules() -> Optional[Rules]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[Rules]):
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield rules
    finally:
        _state.rules = prev


def shard(x: jax.Array, *logical: Logical) -> jax.Array:
    """Annotate ``x`` with a sharding constraint derived from logical axes.

    No-op when no rule set is installed (single-device smoke paths).
    Trailing unannotated dims are left unconstrained.
    """
    rules = current_rules()
    if rules is None:
        return x
    names = list(logical) + [None] * (x.ndim - len(logical))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, rules.spec(*names)))
