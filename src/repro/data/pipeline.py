"""Deterministic synthetic data pipeline.

Each task row in the work queue references a data shard id; the pipeline
deterministically regenerates that shard from (seed, shard_id) — which makes
task retry after worker failure bit-identical (the fault-tolerance story
depends on this) and avoids any filesystem dependency in tests.

The token stream is a structured synthetic language (Zipf unigrams + local
bigram structure) so models actually reduce loss during the example runs —
a flat-random stream has no learnable signal.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    zipf_a: float = 1.3


def shard_batch(cfg: DataConfig, shard_id: int) -> Dict[str, np.ndarray]:
    """Deterministic batch for a shard id: tokens + next-token labels."""
    rng = np.random.default_rng((cfg.seed << 32) ^ shard_id)
    b, s, v = cfg.batch_size, cfg.seq_len, cfg.vocab_size
    base = rng.zipf(cfg.zipf_a, size=(b, s + 1)) % v
    # bigram structure: with p=0.5, token t+1 = f(token t)
    follow = (base * 31 + 7) % v
    mask = rng.random((b, s + 1)) < 0.5
    stream = np.where(mask, np.roll(follow, 1, axis=1), base).astype(np.int32)
    return {"tokens": stream[:, :s], "labels": stream[:, 1:]}


def embed_stub_batch(cfg: DataConfig, model_cfg: ModelConfig,
                     shard_id: int) -> Dict[str, np.ndarray]:
    """Precomputed frame/patch embeddings for the [audio]/[vlm] stub archs."""
    rng = np.random.default_rng((cfg.seed << 32) ^ shard_id ^ 0xA5A5)
    b, s = cfg.batch_size, cfg.seq_len
    d = model_cfg.d_model
    tok = shard_batch(cfg, shard_id)
    out: Dict[str, np.ndarray] = {
        "embeds": rng.standard_normal((b, s, d)).astype(np.float32) * 0.1,
        "labels": tok["labels"],
    }
    if model_cfg.mrope:
        pos = np.broadcast_to(np.arange(s, dtype=np.int32)[None, None],
                              (3, b, s)).copy()
        out["mrope_positions"] = pos
    return out


def batch_for(model_cfg: ModelConfig, data_cfg: DataConfig,
              shard_id: int) -> Dict[str, np.ndarray]:
    if model_cfg.family == "encdec":
        rng = np.random.default_rng((data_cfg.seed << 32) ^ shard_id ^ 0xE5)
        b, s = data_cfg.batch_size, data_cfg.seq_len
        tok = shard_batch(dataclasses.replace(data_cfg,
                                              seq_len=max(8, s // 8)),
                          shard_id)
        return {"frames": rng.standard_normal(
                    (b, s, model_cfg.d_model)).astype(np.float32) * 0.1,
                "tokens": tok["tokens"], "labels": tok["labels"]}
    if model_cfg.embed_stub:
        return embed_stub_batch(data_cfg, model_cfg, shard_id)
    return shard_batch(data_cfg, shard_id)


class Prefetcher:
    """Double-buffered host-side prefetch (overlaps data gen with compute)."""

    def __init__(self, model_cfg: ModelConfig, data_cfg: DataConfig):
        import threading
        self.model_cfg, self.data_cfg = model_cfg, data_cfg
        self._next: Optional[Dict[str, np.ndarray]] = None
        self._tid: Optional[int] = None
        self._thread: Optional[threading.Thread] = None

    def prefetch(self, shard_id: int) -> None:
        import threading

        def work():
            self._next = batch_for(self.model_cfg, self.data_cfg, shard_id)
            self._tid = shard_id
        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def get(self, shard_id: int) -> Dict[str, np.ndarray]:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._tid == shard_id and self._next is not None:
            out, self._next, self._tid = self._next, None, None
            return out
        return batch_for(self.model_cfg, self.data_cfg, shard_id)
