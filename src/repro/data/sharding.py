"""Host->device batch placement with the step's input shardings."""
from __future__ import annotations

from typing import Any, Dict

import jax
import numpy as np


def place_batch(batch: Dict[str, np.ndarray], shardings: Dict[str, Any]
                ) -> Dict[str, jax.Array]:
    """device_put each field with its NamedSharding (multi-host would use
    jax.make_array_from_process_local_data — same call signature here)."""
    out = {}
    for k, v in batch.items():
        sh = shardings.get(k)
        out[k] = jax.device_put(v, sh) if sh is not None else jax.device_put(v)
    return out
