"""qwen2-0.5b — dense decoder, GQA, QKV bias, tied embeddings.

[arXiv:2407.10671; hf] 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936.
"""
from repro.configs.base import FAMILY_DENSE, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family=FAMILY_DENSE,
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151936,
    rope_theta=1e6,
    qkv_bias=True,
    tie_embeddings=True,
    source="arXiv:2407.10671; hf",
)
