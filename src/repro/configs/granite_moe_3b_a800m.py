"""granite-moe-3b-a800m — MoE, 40 experts top-8, GQA.

[hf:ibm-granite/granite-3.0-1b-a400m-base; hf] 32L d_model=1536 24H (GQA kv=8)
d_ff=512 (per-expert) vocab=49155, MoE 40e top-8.
"""
from repro.configs.base import FAMILY_MOE, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family=FAMILY_MOE,
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    moe=MoEConfig(num_experts=40, top_k=8, expert_ff=512, dispatch="sort"),
    tie_embeddings=True,
    fsdp=True,
    microbatches=4,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)
