"""Base configuration dataclasses for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``; input shapes as
``ShapeConfig``; the production mesh as ``MeshConfig``. Configs are plain frozen
dataclasses so they hash (usable as static args) and serialize to JSON.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple

# ---------------------------------------------------------------------------
# Model families
# ---------------------------------------------------------------------------
FAMILY_DENSE = "dense"          # decoder-only full attention
FAMILY_MOE = "moe"              # decoder-only, MoE FFN
FAMILY_SSM = "ssm"              # attention-free (Mamba2 SSD)
FAMILY_HYBRID = "hybrid"        # RG-LRU + local attention (RecurrentGemma)
FAMILY_ENCDEC = "encdec"        # encoder-decoder (SeamlessM4T)
FAMILY_VLM = "vlm"              # decoder-only w/ M-RoPE + patch-embedding stub

SUBQUADRATIC_FAMILIES = (FAMILY_SSM, FAMILY_HYBRID)


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 0
    expert_ff: int = 0              # per-expert hidden dim
    aux_loss_weight: float = 0.01
    # dispatch mode: "dense" (one-hot matmul, MXU-friendly, small E) or
    # "sort" (ragged sort-based, the >64-expert scale path)
    dispatch: str = "dense"


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128            # N in Mamba2
    head_dim: int = 64              # P
    expand: int = 2                 # d_inner = expand * d_model
    chunk: int = 256                # SSD chunk length
    conv_width: int = 4


@dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0              # 0 -> d_model
    window: int = 2048              # local attention window
    pattern: Tuple[str, ...] = ("rec", "rec", "attn")   # 2 recurrent : 1 attn
    conv_width: int = 4


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads
    # --- attention details ---
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    mrope: bool = False             # Qwen2-VL multimodal RoPE (3D position ids)
    tie_embeddings: bool = False
    norm: str = "rmsnorm"           # or "layernorm"
    act: str = "silu"               # glu act; "gelu" for enc-dec MLP
    glu: bool = True                # gated MLP (SwiGLU) vs plain MLP
    # --- family extensions ---
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # enc-dec only
    num_encoder_layers: int = 0
    cross_kv_len: int = 4096        # precomputed encoder frames seen by decoder
    # modality stub: tokens are replaced by precomputed embeddings (audio/vlm)
    embed_stub: bool = False
    # --- attention implementation (smoke: "ref"; dry-run/train: "chunked";
    # TPU: "pallas") ---
    attn_impl: str = "ref"
    q_chunk: int = 256
    packed_causal: bool = False     # triangle-packed causal schedule (§Perf)
    loss_chunk: int = 256           # sequence-chunked xent (big-vocab memory)
    microbatches: int = 1           # gradient-accumulation steps per train step
    # --- numerics / parallelism hints ---
    dtype: str = "bfloat16"         # compute dtype
    param_dtype: str = "float32"    # master params ("bfloat16" for >=100B)
    optimizer: str = "adamw"        # "adafactor" for the >100B archs
    remat: bool = True
    fsdp: bool = False              # additionally shard params over the data axis
    pipeline_stages: int = 1
    # source annotation
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), for roofline 6ND."""
        d, v = self.d_model, self.vocab_size
        n = v * d  # embeddings
        if not self.tie_embeddings:
            n += v * d  # lm head
        hd = self.resolved_head_dim
        attn = d * (self.num_heads * hd) + d * (self.num_kv_heads * hd) * 2 \
            + (self.num_heads * hd) * d
        if self.family == FAMILY_SSM:
            s = self.ssm
            d_in = s.expand * d
            nh = d_in // s.head_dim
            per_layer = d * (2 * d_in + 2 * nh * s.state_dim // (s.state_dim // s.state_dim) if False else 0)
            # explicit: in_proj (z,x,B,C,dt), out_proj, conv, A, D, dt_bias, norm
            proj_in = d * (2 * d_in + 2 * s.state_dim + nh)
            per_layer = proj_in + d_in * d + s.conv_width * (d_in + 2 * s.state_dim) + 3 * nh + 2 * d
            return n + self.num_layers * per_layer
        if self.family == FAMILY_HYBRID:
            r = self.rglru
            lw = r.lru_width or d
            ff = 3 * d * self.d_ff if self.glu else 2 * d * self.d_ff
            rec = d * lw * 2 + lw * d + 2 * lw + r.conv_width * lw  # in/out proj + gates + conv
            n_attn = self.num_layers // len(r.pattern) * sum(1 for p in r.pattern if p == "attn")
            n_rec = self.num_layers - n_attn
            return n + n_attn * (attn + ff + 2 * d) + n_rec * (rec + ff + 2 * d)
        ff_params = (3 if self.glu else 2) * d * self.d_ff
        if self.moe is not None:
            m = self.moe
            ff_params = d * m.num_experts + m.num_experts * (3 if self.glu else 2) * d * m.expert_ff
        per_layer = attn + ff_params + 2 * d  # + norms
        total = n + self.num_layers * per_layer
        if self.family == FAMILY_ENCDEC:
            # encoder blocks + decoder cross-attention
            enc_layer = attn + (2 * d * self.d_ff) + 2 * d
            total += self.num_encoder_layers * enc_layer + self.num_layers * attn
        return total

    @property
    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.moe is None:
            return self.param_count
        m = self.moe
        full_ff = m.num_experts * (3 if self.glu else 2) * self.d_model * m.expert_ff
        act_ff = m.top_k * (3 if self.glu else 2) * self.d_model * m.expert_ff
        return self.param_count - self.num_layers * (full_ff - act_ff)


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Mesh
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


SINGLE_POD = MeshConfig((16, 16), ("data", "model"))
MULTI_POD = MeshConfig((2, 16, 16), ("pod", "data", "model"))


# ---------------------------------------------------------------------------
# Hardware (TPU v5e target)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class HardwareConfig:
    peak_flops_bf16: float = 197e12     # per chip
    hbm_bandwidth: float = 819e9        # bytes/s per chip
    ici_bandwidth: float = 50e9         # bytes/s per link
    hbm_bytes: int = 16 * 2**30


V5E = HardwareConfig()


def to_json(cfg: Any) -> str:
    return json.dumps(dataclasses.asdict(cfg), indent=2)
