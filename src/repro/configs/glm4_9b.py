"""glm4-9b — dense decoder, RoPE, GQA.

[hf:THUDM/glm-4-9b; hf] 40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552.
"""
from repro.configs.base import FAMILY_DENSE, ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family=FAMILY_DENSE,
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=151552,
    rope_theta=10000.0,
    qkv_bias=True,              # GLM-4 uses qkv bias
    fsdp=True,
    microbatches=4,
    source="hf:THUDM/glm-4-9b; hf",
)
