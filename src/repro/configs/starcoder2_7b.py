"""starcoder2-7b — dense decoder, GQA, RoPE.

[arXiv:2402.19173; hf] 32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152.
"""
from repro.configs.base import FAMILY_DENSE, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family=FAMILY_DENSE,
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab_size=49152,
    rope_theta=1e5,
    qkv_bias=True,
    norm="layernorm",
    glu=False,                  # starcoder2 uses plain GELU MLP
    act="gelu",
    microbatches=4,
    source="arXiv:2402.19173; hf",
)
