"""command-r-plus-104b — dense decoder, GQA, no bias.

[hf:CohereForAI/c4ai-command-r-v01; unverified] 64L d_model=12288 96H (GQA kv=8)
d_ff=33792 vocab=256000.
"""
from repro.configs.base import FAMILY_DENSE, ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family=FAMILY_DENSE,
    num_layers=64,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab_size=256000,
    rope_theta=75e6,
    qkv_bias=False,
    tie_embeddings=True,        # command-r ties input/output embeddings
    optimizer="adafactor",
    param_dtype="bfloat16",      # HBM budget at 512 chips (see DESIGN.md §4)
    fsdp=True,
    microbatches=16,
    source="hf:CohereForAI/c4ai-command-r-v01; unverified",
)
