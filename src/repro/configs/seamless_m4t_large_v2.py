"""seamless-m4t-large-v2 — enc-dec multimodal (audio) backbone.

[arXiv:2308.11596; hf] 24L d_model=1024 16H (GQA kv=16) d_ff=8192 vocab=256206.
Modality frontend is a STUB: ``input_specs()`` provides precomputed frame
embeddings for the encoder; the text decoder is real.
"""
from repro.configs.base import FAMILY_ENCDEC, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family=FAMILY_ENCDEC,
    num_layers=24,              # decoder layers
    num_encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,            # MHA
    d_ff=8192,
    vocab_size=256206,
    rope_theta=10000.0,
    norm="layernorm",
    act="gelu",
    glu=False,                  # seamless uses plain (non-gated) FFN
    embed_stub=True,            # audio frames arrive as precomputed embeddings
    cross_kv_len=4096,
    source="arXiv:2308.11596; hf",
)
