"""qwen2-vl-2b — VLM backbone, M-RoPE, dynamic resolution (patch stub).

[arXiv:2409.12191; hf] 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.
The vision frontend is a STUB: ``input_specs()`` provides precomputed patch
embeddings and 3D (t,h,w) M-RoPE position ids.
"""
from repro.configs.base import FAMILY_VLM, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family=FAMILY_VLM,
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    rope_theta=1e6,
    qkv_bias=True,
    mrope=True,
    tie_embeddings=True,
    embed_stub=True,            # patch embeddings precomputed
    source="arXiv:2409.12191; hf",
)
