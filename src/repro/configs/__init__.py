"""Config registry: ``get_config(arch_id)`` and reduced smoke configs."""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.configs.base import (
    FAMILY_DENSE, FAMILY_ENCDEC, FAMILY_HYBRID, FAMILY_MOE, FAMILY_SSM,
    FAMILY_VLM, SUBQUADRATIC_FAMILIES, MULTI_POD, SHAPES, SINGLE_POD, V5E,
    HardwareConfig, MeshConfig, ModelConfig, MoEConfig, RGLRUConfig,
    ShapeConfig, SSMConfig,
)

from repro.configs.seamless_m4t_large_v2 import CONFIG as _seamless
from repro.configs.mamba2_1_3b import CONFIG as _mamba2
from repro.configs.recurrentgemma_9b import CONFIG as _rgemma
from repro.configs.starcoder2_7b import CONFIG as _starcoder2
from repro.configs.qwen2_0_5b import CONFIG as _qwen2
from repro.configs.glm4_9b import CONFIG as _glm4
from repro.configs.command_r_plus_104b import CONFIG as _commandr
from repro.configs.granite_moe_3b_a800m import CONFIG as _granite
from repro.configs.kimi_k2_1t_a32b import CONFIG as _kimi
from repro.configs.qwen2_vl_2b import CONFIG as _qwen2vl

ARCHS: Dict[str, ModelConfig] = {
    c.name: c
    for c in [
        _seamless, _mamba2, _rgemma, _starcoder2, _qwen2, _glm4, _commandr,
        _granite, _kimi, _qwen2vl,
    ]
}

ARCH_IDS: List[str] = list(ARCHS)


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; available: {ARCH_IDS}")
    return ARCHS[arch]


def cell_status(cfg: ModelConfig, shape: ShapeConfig) -> str:
    """Whether an (arch x shape) cell runs or is a documented skip."""
    if shape.name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return "skip:full-attention"
    return "run"


def smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests (small layers/width/
    experts/vocab) — structure preserved, scale shrunk."""
    cfg = get_config(arch)
    upd = dict(
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        dtype="float32",
        param_dtype="float32",
        remat=False,
        fsdp=False,
        microbatches=1,
        optimizer=cfg.optimizer,
    )
    if cfg.moe is not None:
        upd["moe"] = MoEConfig(num_experts=4, top_k=2, expert_ff=32,
                               dispatch=cfg.moe.dispatch)
        upd["d_ff"] = 32
    if cfg.ssm is not None:
        upd["ssm"] = SSMConfig(state_dim=16, head_dim=8, expand=2, chunk=16,
                               conv_width=4)
        upd["num_heads"] = 16   # d_inner(128)/head_dim(8)
        upd["num_kv_heads"] = 16
        upd["d_ff"] = 0
    if cfg.rglru is not None:
        upd["rglru"] = RGLRUConfig(lru_width=64, window=8,
                                   pattern=cfg.rglru.pattern, conv_width=4)
        upd["num_layers"] = 3   # one full rec/rec/attn pattern
        upd["num_kv_heads"] = 1
    if cfg.family == FAMILY_ENCDEC:
        upd["num_encoder_layers"] = 2
        upd["cross_kv_len"] = 16
    return dataclasses.replace(cfg, **upd)


__all__ = [
    "ARCHS", "ARCH_IDS", "SHAPES", "SINGLE_POD", "MULTI_POD", "V5E",
    "get_config", "smoke_config", "cell_status",
    "ModelConfig", "ShapeConfig", "MeshConfig", "HardwareConfig",
    "MoEConfig", "SSMConfig", "RGLRUConfig",
    "FAMILY_DENSE", "FAMILY_MOE", "FAMILY_SSM", "FAMILY_HYBRID",
    "FAMILY_ENCDEC", "FAMILY_VLM",
]
