"""mamba2-1.3b — attention-free SSD (state-space duality).

[arXiv:2405.21060; unverified] 48L d_model=2048 d_ff=0 vocab=50280 ssm_state=128.
"""
from repro.configs.base import FAMILY_SSM, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family=FAMILY_SSM,
    num_layers=48,
    d_model=2048,
    num_heads=64,               # d_inner(4096) / head_dim(64)
    num_kv_heads=64,
    d_ff=0,                     # attention-free, no FFN block (SSD mixer only)
    vocab_size=50280,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk=256, conv_width=4),
    tie_embeddings=True,
    source="arXiv:2405.21060; unverified",
)
