"""kimi-k2-1t-a32b — trillion-param MoE, 384 experts top-8.

[arXiv:2501.kimi2; unverified] 61L d_model=7168 64H (GQA kv=8) d_ff=2048
(per-expert) vocab=163840, MoE 384e top-8.
"""
from repro.configs.base import FAMILY_MOE, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family=FAMILY_MOE,
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=112,               # 7168/64; kernels pad lanes 112->128
    d_ff=2048,
    vocab_size=163840,
    moe=MoEConfig(num_experts=384, top_k=8, expert_ff=2048, dispatch="sort"),
    optimizer="adafactor",
    param_dtype="bfloat16",      # 1T params: Adam states cannot fit 512 x 16GB
    fsdp=True,
    microbatches=8,
    source="arXiv:2501.kimi2; unverified (paper-table)",
)
