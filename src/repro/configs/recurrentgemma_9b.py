"""recurrentgemma-9b — RG-LRU + local attention hybrid, 1:2 attn:recurrent.

[arXiv:2402.19427; unverified] 38L d_model=4096 16H (GQA kv=1, i.e. MQA)
d_ff=12288 vocab=256000, window=2048.
"""
from repro.configs.base import FAMILY_HYBRID, ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family=FAMILY_HYBRID,
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,             # MQA for the local-attention blocks
    head_dim=256,               # Griffin uses wide heads (4096/16)
    d_ff=12288,
    vocab_size=256000,
    rglru=RGLRUConfig(lru_width=4096, window=2048,
                      pattern=("rec", "rec", "attn"), conv_width=4),
    glu=True,
    act="gelu",                 # GeGLU
    tie_embeddings=True,
    microbatches=4,
    source="arXiv:2402.19427; unverified",
)
