"""The paper's own workflow: Risers Fatigue Analysis (Fig. 8).

Seven chained activities; each activity-k task spawns an activity-(k+1) task on
completion (1:1 pipeline, as in the paper's synthetic workloads derived from the
Risers specification). Domain columns mirror the paper's examples: input params
(a, b, c ~ environmental conditions), outputs (x, y ~ stress results), and the
Q7 f1 wear-and-tear output.

Used by benchmarks/exp*.py and examples/parameter_sweep_steering.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class WorkflowConfig:
    name: str = "risers-fatigue-analysis"
    activities: Tuple[str, ...] = (
        "preprocessing",         # paper: Pre-Processing (produces cx, cy, cz)
        "analyze_risers",        # Q8 retargets inputs of this activity
        "calculate_wear_tear",   # produces f1 (Q7 filters f1 > 0.5)
        "dynamic_analysis",
        "static_analysis",
        "fatigue_assessment",
        "postprocessing",
    )
    # synthetic-workload knobs (paper Section 5.1): #tasks and mean duration
    num_tasks: int = 13_000
    mean_task_duration_s: float = 60.0
    # domain parameter ranges (wind speed / wave frequency analogues)
    param_low: float = 0.0
    param_high: float = 40.0

    @property
    def num_activities(self) -> int:
        return len(self.activities)


DEFAULT = WorkflowConfig()

# Paper experiment workloads (Section 5)
EXP1_WORKLOAD = WorkflowConfig(num_tasks=13_000, mean_task_duration_s=60.0)
EXP2_WORKLOADS = tuple(
    WorkflowConfig(num_tasks=n, mean_task_duration_s=60.0)
    for n in (6_000, 12_000, 23_400)
)
EXP3_TASK_COUNTS = (4_600, 12_000, 23_400)
EXP3_DURATIONS = (5.0, 60.0)
EXP4_DURATIONS = (5.0, 10.0, 30.0, 60.0, 120.0)
EXP4_TASK_COUNTS = (4_600, 23_400)
EXP5_DURATIONS = (1.0, 2.0, 3.0, 4.0, 5.0, 10.0, 30.0, 60.0)
EXP5_TASKS = 23_400
EXP8_WORKLOADS = (
    ("medium-short", 5_000, 1.0),
    ("medium-long", 5_000, 16.0),
    ("large-short", 20_000, 1.0),
    ("large-long", 20_000, 16.0),
)
