"""Atomic, async checkpointing of train state + the SchalaDB store.

Layout (one directory per step):
  <root>/step_<n>.tmp/ -> fsync'd -> rename to <root>/step_<n>/
    manifest.json      step, leaf index, content hashes, wall time
    arrays.npz         flattened train-state leaves (path-keyed)
    store.npz          column store snapshot + txn-log offset

The tmp+rename protocol makes partially written checkpoints invisible;
restore picks the newest complete manifest and replays the txn-log tail.
Async mode snapshots to host (device_get) synchronously — a consistent
cut — then writes on a daemon thread (double-buffered), the standard
TPU-friendly pattern: the accelerator never waits on disk.
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.core.store import ColumnStore
from repro.core.workqueue import WorkQueue


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_into(tree, flat: Dict[str, np.ndarray]):
    def one(path, leaf):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        v = flat[key]
        return np.asarray(v, dtype=leaf.dtype).reshape(leaf.shape) \
            if hasattr(leaf, "dtype") else v
    return jax.tree_util.tree_map_with_path(one, tree)


class Checkpointer:
    def __init__(self, root: str, keep: int = 3, async_write: bool = True):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None

    # ---------------------------------------------------------------- save
    def save(self, step: int, state: Any, wq: Optional[WorkQueue] = None
             ) -> None:
        flat = _flatten(jax.device_get(state))       # consistent host cut
        store_snap, log_ack = None, None
        if wq is not None:
            with wq.store.txn():     # snapshot + log length: ONE atomic cut
                snap = wq.store.snapshot()           # (log appends happen
                log_len = len(wq.log)                # inside this lock)
            store_snap = {"n_rows": snap["n_rows"], "version": snap["version"],
                          "log_len": log_len, "num_workers": wq.num_workers,
                          **{f"col__{k}": v for k, v in snap["cols"].items()}}
            # the checkpoint persists the store through log offset log_len;
            # the consumer registration/ack happens only AFTER the atomic
            # publish in _write — compaction must never be justified by a
            # checkpoint that did not become durable
            log_ack = (wq.log, log_len)
        if self._thread is not None:
            self._thread.join()                      # one write in flight
        if self.async_write:
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, store_snap, log_ack),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, flat, store_snap, log_ack)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, flat, store_snap, log_ack=None):
        tmp = self.root / f"step_{step:08d}.tmp"
        final = self.root / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "arrays.npz", **flat)
        if store_snap is not None:
            np.savez(tmp / "store.npz",
                     **{k: v for k, v in store_snap.items()
                        if isinstance(v, np.ndarray)},
                     __meta__=np.asarray(json.dumps(
                         {k: int(v) for k, v in store_snap.items()
                          if not isinstance(v, np.ndarray)})))
        manifest = {
            "step": step,
            "time": time.time(),
            "leaves": {k: [list(v.shape), str(v.dtype),
                           hashlib.sha1(v.tobytes()).hexdigest()[:16]]
                       for k, v in flat.items()},
            "has_store": store_snap is not None,
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():                           # re-save of same step
            shutil.rmtree(final)
        os.replace(tmp, final)                       # atomic publish
        if log_ack is not None:                      # durable: safe to let
            log, offset = log_ack                    # compaction pass us
            if not log.ack("checkpointer", offset):  # first save registers
                log.register_consumer("checkpointer", offset)
        self._gc()

    def _gc(self):
        done = sorted(p for p in self.root.iterdir()
                      if p.is_dir() and not p.name.endswith(".tmp"))
        for p in done[: -self.keep]:
            shutil.rmtree(p)

    # ------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        steps = [int(p.name.split("_")[1]) for p in self.root.iterdir()
                 if p.is_dir() and not p.name.endswith(".tmp")
                 and (p / "manifest.json").exists()]
        return max(steps) if steps else None

    def restore(self, state_template: Any, step: Optional[int] = None
                ) -> Tuple[int, Any, Optional[WorkQueue]]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = self.root / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        with np.load(d / "arrays.npz") as z:
            flat = {k: z[k] for k in z.files}
        for k, (shape, dtype, sha) in manifest["leaves"].items():
            got = hashlib.sha1(flat[k].tobytes()).hexdigest()[:16]
            if got != sha:
                raise IOError(f"checkpoint corruption at leaf {k}")
        state = _unflatten_into(state_template, flat)
        wq = None
        if manifest.get("has_store") and (d / "store.npz").exists():
            with np.load(d / "store.npz") as z:
                meta = json.loads(str(z["__meta__"]))
                cols = {k[len("col__"):]: z[k] for k in z.files
                        if k.startswith("col__")}
            snap = {"n_rows": meta["n_rows"], "version": meta["version"],
                    "cols": cols, "blobs": {}}
            store = ColumnStore.restore(snap)
            wq = WorkQueue(meta["num_workers"], store=store)
            wq._next_task_id = int(store.col("task_id").max() + 1) \
                if store.n_rows else 0
            # the pre-crash log records are gone: resume absolute offsets at
            # the persisted log length and put the compaction horizon at the
            # checkpoint version, so consumer offsets stay meaningful and
            # time-travel below the checkpoint raises LogCompactedError
            # instead of silently replaying an empty delta
            if meta.get("log_len"):
                wq.log.base = int(meta["log_len"])
                wq.log.horizon_version = int(meta["version"])
        return step, state, wq
