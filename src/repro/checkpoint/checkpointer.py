"""Atomic, async checkpointing of train state + the SchalaDB store.

Layout (one directory per step):
  <root>/step_<n>.tmp/ -> fsync'd -> rename to <root>/step_<n>/
    manifest.json      step, leaf index, content hashes, wall time
    arrays.npz         flattened train-state leaves (path-keyed)
    store.npz          column store snapshot + txn-log offset
    store_<s>.npz      (sharded runs) one store cut per shard; the
                       manifest carries the full version VECTOR

The tmp+rename protocol makes partially written checkpoints invisible;
restore picks the newest COMPLETE manifest (torn directories — truncated
manifest, missing array or store file — are skipped, falling back to the
previous complete step) and replays the txn-log tail. Sharded runs cut one
store-lock-consistent snapshot per shard and publish them with the version
vector in a single manifest, so a restore resumes every shard at
``[v0..vN-1]`` or none at all — there is no torn vector. Async mode
snapshots to host (device_get) synchronously — a consistent cut — then
writes on a daemon thread (double-buffered), the standard TPU-friendly
pattern: the accelerator never waits on disk.
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.store import ColumnStore
from repro.core.workqueue import WorkQueue


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_into(tree, flat: Dict[str, np.ndarray]):
    def one(path, leaf):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        v = flat[key]
        return np.asarray(v, dtype=leaf.dtype).reshape(leaf.shape) \
            if hasattr(leaf, "dtype") else v
    return jax.tree_util.tree_map_with_path(one, tree)


class Checkpointer:
    def __init__(self, root: str, keep: int = 3, async_write: bool = True):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None

    # ---------------------------------------------------------------- save
    def save(self, step: int, state: Any, wq: Optional[WorkQueue] = None,
             *, router=None) -> None:
        """Checkpoint ``state`` plus the store(s): pass ``wq`` for a
        single-primary run (on-disk format unchanged from earlier PRs) or
        ``router`` (a ``ShardRouter``) for a sharded run — one snapshot
        per shard, each cut under that shard's store lock, published with
        the version vector in the single atomic manifest."""
        if wq is not None and router is not None:
            raise ValueError("pass wq or router, not both")
        flat = _flatten(jax.device_get(state))       # consistent host cut
        store_snaps: Optional[List[dict]] = None
        log_acks: List[tuple] = []
        queues = [wq] if wq is not None else \
            [sh.wq for sh in router.shards] if router is not None else []
        if queues:
            store_snaps = []
            for q in queues:
                with q.store.txn():  # snapshot + log length: ONE atomic cut
                    snap = q.store.snapshot()        # (log appends happen
                    log_len = len(q.log)             # inside this lock)
                store_snaps.append(
                    {"n_rows": snap["n_rows"], "version": snap["version"],
                     "log_len": log_len, "num_workers": q.num_workers,
                     **{f"col__{k}": v for k, v in snap["cols"].items()}})
                # the checkpoint persists the store through log offset
                # log_len; the consumer registration/ack happens only AFTER
                # the atomic publish in _write — compaction must never be
                # justified by a checkpoint that did not become durable
                log_acks.append((q.log, log_len))
        if self._thread is not None:
            self._thread.join()                      # one write in flight
        sharded = router is not None
        if self.async_write:
            self._thread = threading.Thread(
                target=self._write,
                args=(step, flat, store_snaps, log_acks, sharded),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, flat, store_snaps, log_acks, sharded)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, flat, store_snaps, log_acks=(),
               sharded: bool = False):
        tmp = self.root / f"step_{step:08d}.tmp"
        final = self.root / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "arrays.npz", **flat)
        store_files: List[str] = []
        for i, snap in enumerate(store_snaps or []):
            name = f"store_{i}.npz" if sharded else "store.npz"
            store_files.append(name)
            np.savez(tmp / name,
                     **{k: v for k, v in snap.items()
                        if isinstance(v, np.ndarray)},
                     __meta__=np.asarray(json.dumps(
                         {k: int(v) for k, v in snap.items()
                          if not isinstance(v, np.ndarray)})))
        manifest = {
            "step": step,
            "time": time.time(),
            "leaves": {k: [list(v.shape), str(v.dtype),
                           hashlib.sha1(v.tobytes()).hexdigest()[:16]]
                       for k, v in flat.items()},
            "has_store": bool(store_snaps),
        }
        if sharded:
            # the version VECTOR and the per-shard files ride ONE manifest:
            # either every shard's cut becomes restorable together, or (on
            # a torn write) none does
            manifest["store_files"] = store_files
            manifest["version_vector"] = [int(s["version"])
                                          for s in store_snaps or []]
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():                           # re-save of same step
            shutil.rmtree(final)
        os.replace(tmp, final)                       # atomic publish
        for log, offset in log_acks:                 # durable: safe to let
            if not log.ack("checkpointer", offset):  # compaction pass us;
                log.register_consumer("checkpointer", offset)  # 1st save
        self._gc()

    def _gc(self):
        done = sorted(p for p in self.root.iterdir()
                      if p.is_dir() and not p.name.endswith(".tmp"))
        for p in done[: -self.keep]:
            shutil.rmtree(p)

    # ------------------------------------------------------------- restore
    @staticmethod
    def _complete(d: pathlib.Path) -> bool:
        """True iff the checkpoint directory is restorable: manifest
        parses, the array file exists, and every store file the manifest
        names is present. A torn directory (truncated manifest, missing
        npz) is skipped by latest_step/restore rather than raised on."""
        try:
            manifest = json.loads((d / "manifest.json").read_text())
        except (OSError, json.JSONDecodeError):
            return False
        if not (d / "arrays.npz").exists():
            return False
        if manifest.get("has_store"):
            files = manifest.get("store_files") or ["store.npz"]
            if not all((d / f).exists() for f in files):
                return False
        return True

    def latest_step(self) -> Optional[int]:
        steps = [int(p.name.split("_")[1]) for p in self.root.iterdir()
                 if p.is_dir() and not p.name.endswith(".tmp")
                 and self._complete(p)]
        return max(steps) if steps else None

    def restore(self, state_template: Any, step: Optional[int] = None,
                *, router_kw: Optional[dict] = None
                ) -> Tuple[int, Any, object]:
        """Restore the newest COMPLETE checkpoint (or ``step``). Returns
        ``(step, state, wq_or_router)`` — a ``WorkQueue`` for a
        single-primary checkpoint, a ``ShardRouter`` for a sharded one
        (rebuilt shard by shard: stores, log offsets/compaction horizons
        pinned at the persisted version vector, the ``checkpointer``
        consumer re-registered per shard, replicators re-armed from
        ``router_kw``, e.g. ``{"replicate": "delta"}``)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = self.root / f"step_{step:08d}"
        if not self._complete(d):
            raise IOError(f"checkpoint {d.name} is torn/incomplete "
                          f"(explicitly requested step {step})")
        manifest = json.loads((d / "manifest.json").read_text())
        with np.load(d / "arrays.npz") as z:
            flat = {k: z[k] for k in z.files}
        for k, (shape, dtype, sha) in manifest["leaves"].items():
            got = hashlib.sha1(flat[k].tobytes()).hexdigest()[:16]
            if got != sha:
                raise IOError(f"checkpoint corruption at leaf {k}")
        state = _unflatten_into(state_template, flat)
        if not manifest.get("has_store"):
            return step, state, None
        if manifest.get("store_files"):              # sharded checkpoint
            from repro.core.sharding_router import ShardRouter
            shard_states = [self._load_store(d / f)
                            for f in manifest["store_files"]]
            router = ShardRouter.from_checkpoint(shard_states,
                                                 **(router_kw or {}))
            for sh, (_, meta) in zip(router.shards, shard_states):
                # the checkpoint IS this log's consumer floor: re-register
                # it at the resumed base so compaction never outruns the
                # next durable save
                sh.wq.log.register_consumer("checkpointer",
                                            int(meta["log_len"]))
            return step, state, router
        store, meta = self._load_store(d / "store.npz")
        wq = WorkQueue(meta["num_workers"], store=store)
        wq._next_task_id = int(store.col("task_id").max() + 1) \
            if store.n_rows else 0
        # the pre-crash log records are gone: resume absolute offsets at
        # the persisted log length and put the compaction horizon at the
        # checkpoint version, so consumer offsets stay meaningful and
        # time-travel below the checkpoint raises LogCompactedError
        # instead of silently replaying an empty delta
        if meta.get("log_len"):
            wq.log.base = int(meta["log_len"])
            wq.log.horizon_version = int(meta["version"])
        return step, state, wq

    @staticmethod
    def _load_store(path: pathlib.Path) -> Tuple[ColumnStore, dict]:
        with np.load(path) as z:
            meta = json.loads(str(z["__meta__"]))
            cols = {k[len("col__"):]: z[k] for k in z.files
                    if k.startswith("col__")}
        snap = {"n_rows": meta["n_rows"], "version": meta["version"],
                "cols": cols, "blobs": {}}
        return ColumnStore.restore(snap), meta
