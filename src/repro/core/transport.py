"""Transport layer of the replication fabric: framed byte pipes.

The wire protocol (``core/wire.py`` + the control tags in
``core/replication.py``) is transport-agnostic by construction:
length-prefixed frames, exactly one reply per request, and the only bulk
payload is a delta buffer. This module gives that invariant a name — a
minimal :class:`Transport` — and two interchangeable implementations:

* :class:`PipeTransport` — a ``multiprocessing`` duplex pipe, the PR 4
  plumbing extracted. Frames ride the pipe's own length-prefixed message
  protocol (``send_bytes``/``recv_bytes``); parent and child must share a
  machine.
* :class:`TCPTransport` — a TCP stream with an explicit ``u64``
  length prefix per frame, so a replica can run on ANOTHER HOST unchanged:
  the parent listens (:class:`TCPListener`, ``host:port``), the child
  connects (:func:`connect_tcp`). Tests and CI run the same code over
  127.0.0.1 loopback (or a :func:`TCPTransport.pair` socketpair), which is
  exactly the multi-host path minus the NIC.

Contract shared by all implementations (what the fabric layer relies on):

* ``send_bytes(buf)`` ships one complete frame; ``recv_bytes()`` returns
  one complete frame or raises ``EOFError`` when the peer is gone.
* ``poll(timeout)`` waits for a readable frame without consuming it.
* ``try_send(buf, timeout)`` is the shutdown-path best-effort send: it
  must NEVER block indefinitely (a wedged or dead peer cannot hang
  ``close()``/``__del__``) and returns False instead of raising.
* Framing preserves message boundaries and order; there is no interleaving
  because each direction has a single writer (the request/reply discipline
  serializes on the fabric's lock).
"""
from __future__ import annotations

import multiprocessing.connection
import select
import socket
import struct
import time
from typing import Optional, Tuple


class TransportError(ConnectionError):
    """The peer is gone or the stream is corrupt mid-frame."""


class Transport:
    """Minimal framed-bytes interface the replication fabric speaks.

    Both implementations are FULL-DUPLEX: a send and a recv may be in
    flight at once (pipe and TCP both buffer each direction
    independently), which is what lets the pipelined shipper keep a
    window of unacked delta frames on the wire and harvest acks while the
    next frame encodes — the request/reply discipline still holds per
    frame (every D gets exactly one A, in order), only the LOCKSTEP is
    relaxed.
    """

    def send_bytes(self, buf) -> None:
        raise NotImplementedError

    def send_chunks(self, chunks) -> None:
        """Ship ONE frame given as multiple bytes-like chunks (header +
        encoded buffers), avoiding the caller-side join where the
        transport can scatter-gather. Base implementation joins."""
        self.send_bytes(b"".join(chunks))

    def recv_bytes(self) -> bytes:
        raise NotImplementedError

    def poll(self, timeout: Optional[float] = 0.0) -> bool:
        raise NotImplementedError

    def fileno(self) -> int:
        raise NotImplementedError

    def close(self) -> None:
        raise NotImplementedError

    def try_send(self, buf, timeout: float = 1.0) -> bool:
        """Best-effort send that never blocks past ``timeout`` and never
        raises — the graceful-shutdown path (a tiny control frame to a peer
        that may be dead, wedged, or mid-read). Returns True only when the
        frame was handed to the OS."""
        try:
            _, writable, _ = select.select([], [self.fileno()], [], timeout)
            if not writable:
                return False
            self.send_bytes(buf)
            return True
        except (OSError, ValueError, EOFError, BrokenPipeError):
            return False


class PipeTransport(Transport):
    """A ``multiprocessing`` duplex pipe endpoint as a Transport.

    The Connection already speaks length-prefixed messages, so frames map
    1:1 onto ``send_bytes``/``recv_bytes``; this class only normalizes the
    error surface (peer loss -> ``EOFError``) and adds ``try_send``.
    """

    def __init__(self, conn: multiprocessing.connection.Connection):
        self.conn = conn

    def send_bytes(self, buf) -> None:
        self.conn.send_bytes(buf)

    def recv_bytes(self) -> bytes:
        return self.conn.recv_bytes()          # raises EOFError on peer loss

    def poll(self, timeout: Optional[float] = 0.0) -> bool:
        return self.conn.poll(timeout)

    def fileno(self) -> int:
        return self.conn.fileno()

    def close(self) -> None:
        try:
            self.conn.close()
        except OSError:
            pass


_LEN = struct.Struct("<Q")
# Frames above this are a corrupt length prefix, not a real payload: the
# largest legitimate delta is bounded by log memory, far below 1 TiB.
_MAX_FRAME = 1 << 40


class TCPTransport(Transport):
    """A connected TCP stream as a Transport: ``u64 length | payload``.

    ``TCP_NODELAY`` is set — the request/reply protocol ships many small
    control frames, and Nagle would serialize them against the peer's ACK
    clock. Construction sites: :func:`TCPTransport.pair` (in-process
    loopback for tests), :class:`TCPListener` + :func:`connect_tcp`
    (parent/child across processes — or across hosts: nothing below cares
    where the other end of the socket lives).
    """

    def __init__(self, sock: socket.socket,
                 recv_timeout: Optional[float] = None):
        try:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:
            pass          # AF_UNIX socketpair (the test rig) has no Nagle
        sock.setblocking(True)
        self.sock = sock
        # per-read deadline: a hung peer (live socket, nothing arriving)
        # surfaces as a TransportError instead of blocking recv forever.
        # None = wait indefinitely (the pre-knob behavior).
        self.recv_timeout = recv_timeout

    @classmethod
    def pair(cls, recv_timeout: Optional[float] = None
             ) -> Tuple["TCPTransport", "TCPTransport"]:
        """Connected loopback endpoints (socketpair) — the unit-test rig.
        ``recv_timeout`` applies to both ends: a wedged peer surfaces as a
        ``TransportError`` on recv instead of a hung thread."""
        a, b = socket.socketpair()
        return cls(a, recv_timeout=recv_timeout), cls(b, recv_timeout=recv_timeout)

    def send_bytes(self, buf) -> None:
        n = len(buf)
        try:
            if n < 4096:
                # control frames: one syscall for prefix+payload
                self.sock.sendall(_LEN.pack(n) + bytes(buf))
            else:
                # bulk deltas: no copy, sendall handles partial writes
                self.sock.sendall(_LEN.pack(n))
                self.sock.sendall(buf)
        except OSError as e:
            raise TransportError(f"tcp send failed: {e}") from e

    def send_chunks(self, chunks) -> None:
        """Vectored frame send: length prefix + chunks in one ``sendmsg``
        (scatter-gather — no join copy of a multi-buffer delta frame).
        Falls back to the join path when the kernel's iovec limit or a
        partial write gets in the way."""
        bufs = [memoryview(c) for c in chunks]
        total = sum(b.nbytes for b in bufs)
        iov = [memoryview(_LEN.pack(total))] + [b for b in bufs if b.nbytes]
        try:
            sent = self.sock.sendmsg(iov)
        except OSError as e:
            raise TransportError(f"tcp send failed: {e}") from e
        want = _LEN.size + total
        if sent == want:
            return
        # partial vectored write (large frame vs socket buffer): finish
        # with the joined remainder — correctness over zero-copy
        rest = b"".join(iov)[sent:]
        try:
            self.sock.sendall(rest)
        except OSError as e:
            raise TransportError(f"tcp send failed: {e}") from e

    def _recv_exact(self, n: int) -> bytes:
        out = bytearray(n)
        view = memoryview(out)
        got = 0
        while got < n:
            if self.recv_timeout is not None:
                readable, _, _ = select.select(
                    [self.sock], [], [], self.recv_timeout)
                if not readable:
                    raise TransportError(
                        f"tcp recv timed out after {self.recv_timeout}s "
                        f"({got}/{n} bytes of the frame received)")
            try:
                k = self.sock.recv_into(view[got:], n - got)
            except OSError as e:
                raise EOFError(f"tcp recv failed: {e}") from e
            if k == 0:
                raise EOFError("tcp peer closed mid-frame")
            got += k
        return bytes(out)

    def recv_bytes(self) -> bytes:
        (n,) = _LEN.unpack(self._recv_exact(_LEN.size))
        if n > _MAX_FRAME:
            raise TransportError(f"tcp frame length {n} is not credible — "
                                 "stream is corrupt or misaligned")
        return self._recv_exact(int(n))

    def poll(self, timeout: Optional[float] = 0.0) -> bool:
        readable, _, _ = select.select([self.sock], [], [], timeout)
        return bool(readable)

    def fileno(self) -> int:
        return self.sock.fileno()

    def close(self) -> None:
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self.sock.close()
        except OSError:
            pass


class TCPListener:
    """Parent-side accept socket: bind an ephemeral (or given) port, spawn
    the replica with the address, ``accept`` its connection."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0):
        self.sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self.sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self.sock.bind((host, port))
        self.sock.listen(1)

    @property
    def address(self) -> Tuple[str, int]:
        host, port = self.sock.getsockname()[:2]
        return host, int(port)

    def accept(self, timeout: float = 60.0) -> TCPTransport:
        readable, _, _ = select.select([self.sock], [], [], timeout)
        if not readable:
            raise TimeoutError(
                f"no replica connected within {timeout}s")
        conn, _addr = self.sock.accept()
        return TCPTransport(conn)

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass


def connect_tcp(host: str, port: int, timeout: float = 60.0,
                retry_every: float = 0.05, max_retry_every: float = 1.0,
                max_retries: Optional[int] = None) -> TCPTransport:
    """Child-side connect with bounded exponential backoff — the listener
    may not be accepting yet when a freshly spawned interpreter gets here
    first (replica spawn races the listener under load). The retry interval
    doubles from ``retry_every`` up to ``max_retry_every`` so a slow
    listener isn't hammered at 20 Hz for the whole window; the attempt
    budget is bounded by ``timeout`` (deadline) and optionally
    ``max_retries``. The last OSError propagates when the budget runs out.
    """
    deadline = time.monotonic() + timeout
    delay = retry_every
    attempts = 0
    while True:
        try:
            sock = socket.create_connection((host, port), timeout=timeout)
            return TCPTransport(sock)
        except OSError:
            attempts += 1
            if time.monotonic() >= deadline or \
                    (max_retries is not None and attempts > max_retries):
                raise
            time.sleep(min(delay, max(deadline - time.monotonic(), 0.0)))
            delay = min(delay * 2, max_retry_every)


def child_endpoint(spec) -> Transport:
    """Materialize the replica-process end of a transport from the picklable
    spec the parent passed to ``Process(args=...)``:
    ``("pipe", conn)`` or ``("tcp", host, port)``."""
    kind = spec[0]
    if kind == "pipe":
        return PipeTransport(spec[1])
    if kind == "tcp":
        return connect_tcp(spec[1], spec[2])
    raise ValueError(f"unknown transport spec {spec!r}")
