"""SchalaDB core: distributed in-memory data management for workflow
executions (the paper's primary contribution, adapted to JAX/TPU — see
DESIGN.md §2)."""
from repro.core.schema import Status, wq_schema  # noqa: F401
from repro.core.store import ColumnStore  # noqa: F401
from repro.core.workqueue import WorkQueue  # noqa: F401
from repro.core.supervisor import SecondarySupervisor, Supervisor  # noqa: F401
from repro.core.steering import SteeringEngine  # noqa: F401
from repro.core.replication import (DeltaReplicator, ReplicaGroup,  # noqa: F401
                                    ReplicaSet, ReplicationFabric,
                                    ShippedDeltaReplicator)
from repro.core.sharding_router import Shard, ShardRouter  # noqa: F401
