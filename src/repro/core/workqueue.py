"""Distributed Work Queue on the column store (paper Sections 3.2-3.3).

Passive multi-master semantics: workers *claim* from their own partition
(``WHERE worker_id = i AND status = READY ORDER BY task_id LIMIT k``); the
partition-private access removes write conflicts, exactly the paper's
argument. ``claim_all`` is the batched SPMD form: one vectorized operation
claims the next task for every worker at once — this is what the executor
uses per training step and what the ``wq_claim`` Pallas kernel implements
on-device.

Work stealing (straggler mitigation) claims from the most-loaded sibling
partition when the own partition is dry (paper: "more partitions than data
nodes gives flexibility ... load balancing").
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.partition import assign_workers, partition_sizes, rehash
from repro.core.schema import Status, TRANSITIONS
from repro.core.store import ColumnStore
from repro.core.transactions import TxnLog


class WorkQueue:
    def __init__(self, num_workers: int, store: Optional[ColumnStore] = None,
                 txn_log: Optional[TxnLog] = None, capacity: int = 1 << 16):
        self.store = store or ColumnStore(capacity=capacity)
        self.num_workers = num_workers
        self.log = txn_log or TxnLog()
        self._next_task_id = int(self.store.n_rows)

    # -------------------------------------------------------------- inserts
    def add_tasks(self, activity_id: int, n: int, *,
                  status: Status = Status.READY,
                  duration_est: float = 0.0,
                  domain_in: Optional[np.ndarray] = None,
                  parent_task: Optional[np.ndarray] = None,
                  now: float = 0.0) -> np.ndarray:
        ids = np.arange(self._next_task_id, self._next_task_id + n,
                        dtype=np.int64)
        self._next_task_id += n
        rows = {
            "task_id": ids,
            "activity_id": np.full(n, activity_id, np.int32),
            "worker_id": assign_workers(ids, self.num_workers),
            "status": np.full(n, int(status), np.int32),
            "submit_time": np.full(n, now, np.float64),
            "duration_est": (np.full(n, 0.0) if duration_est == 0.0
                             else np.full(n, duration_est)),
        }
        if domain_in is not None:
            for i in range(domain_in.shape[1]):
                rows[f"in{i}"] = domain_in[:, i]
        if parent_task is not None:
            rows["parent_task"] = parent_task
        idx = self.store.insert(rows)
        self.log.append("insert", {"activity_id": activity_id, "n": n,
                                   "ids": ids})
        return ids

    # ---------------------------------------------------------------- claim
    def claim(self, worker_id: int, k: int = 1, *,
              now: float = 0.0, allow_steal: bool = False) -> np.ndarray:
        """getREADYtasks + updateToRUNNING for one worker (partition-private).

        Returns claimed row indices (== task ids here).
        """
        status = self.store.col("status")
        wid = self.store.col("worker_id")
        mask = (status == int(Status.READY)) & (wid == worker_id)
        idx = np.nonzero(mask)[0][:k]
        if len(idx) == 0 and allow_steal:
            idx = self._steal(worker_id, k)
        if len(idx):
            self.store.update(idx, status=int(Status.RUNNING),
                              start_time=now, worker_id=worker_id,
                              core_id=worker_id)
            self.log.append("claim", {"worker": worker_id,
                                      "ids": self.store.col("task_id")[idx]})
        return idx

    def _steal(self, thief: int, k: int) -> np.ndarray:
        """Claim from the most-loaded sibling partition."""
        status = self.store.col("status")
        wid = self.store.col("worker_id")
        ready = status == int(Status.READY)
        if not ready.any():
            return np.empty(0, np.int64)
        sizes = np.bincount(wid[ready], minlength=self.num_workers)
        victim = int(np.argmax(sizes))
        if sizes[victim] == 0 or victim == thief:
            return np.empty(0, np.int64)
        idx = np.nonzero(ready & (wid == victim))[0][:k]
        return idx

    def claim_all(self, k: int = 1, *, now: float = 0.0,
                  steal: bool = True) -> Dict[int, np.ndarray]:
        """Batched claim: next k READY tasks for EVERY worker in one pass.

        This is the SPMD form the executor uses (and the semantics of the
        wq_claim kernel): one vectorized scan over the store instead of W
        separate queries.
        """
        status = self.store.col("status")
        wid = self.store.col("worker_id")
        ready = status == int(Status.READY)
        out: Dict[int, np.ndarray] = {}
        claimed_rows: List[np.ndarray] = []
        for w in range(self.num_workers):
            idx = np.nonzero(ready & (wid == w))[0][:k]
            out[w] = idx
            claimed_rows.append(idx)
        if steal:
            leftovers = np.nonzero(ready)[0]
            taken = set(np.concatenate(claimed_rows).tolist())
            pool = [i for i in leftovers if i not in taken]
            for w in range(self.num_workers):
                need = k - len(out[w])
                if need > 0 and pool:
                    extra = np.asarray(pool[:need], dtype=np.int64)
                    pool = pool[need:]
                    out[w] = np.concatenate([out[w], extra])
                    claimed_rows.append(extra)
        all_idx = np.concatenate([v for v in out.values() if len(v)]) \
            if any(len(v) for v in out.values()) else np.empty(0, np.int64)
        if len(all_idx):
            self.store.update(all_idx, status=int(Status.RUNNING),
                              start_time=now)
            self.log.append("claim_all", {"n": len(all_idx)})
        return out

    # ------------------------------------------------------------- complete
    def finish(self, idx: np.ndarray, *, now: float = 0.0,
               domain_out: Optional[np.ndarray] = None) -> None:
        self._check_transition(idx, Status.FINISHED)
        upd = {"status": int(Status.FINISHED), "end_time": now}
        self.store.update(np.asarray(idx), **upd)
        if domain_out is not None:
            cols = {f"out{i}": domain_out[:, i]
                    for i in range(domain_out.shape[1])}
            self.store.update(np.asarray(idx), **cols)
        self.log.append("finish", {"ids": np.asarray(idx)})

    def fail(self, idx: np.ndarray, *, now: float = 0.0,
             max_trials: int = 3) -> None:
        """Failure handling: retry (back to READY) until fail_trials exhausts."""
        idx = np.asarray(idx)
        trials = self.store.col("fail_trials")[idx] + 1
        retry = idx[trials < max_trials]
        dead = idx[trials >= max_trials]
        self.store.update(idx, fail_trials=trials)
        if len(retry):
            self.store.update(retry, status=int(Status.READY))
        if len(dead):
            self.store.update(dead, status=int(Status.FAILED), end_time=now)
        self.log.append("fail", {"retry": retry, "dead": dead})

    def requeue_worker(self, worker_id: int, *, reassign: bool = True) -> int:
        """Node failure: return the dead worker's RUNNING tasks to READY and
        (optionally) rehash them to live partitions."""
        idx = self.store.where(worker_id=worker_id,
                               status=int(Status.RUNNING))
        if len(idx) == 0:
            return 0
        self.store.update(idx, status=int(Status.READY))
        trials = self.store.col("fail_trials")[idx] + 1
        self.store.update(idx, fail_trials=trials)
        if reassign and self.num_workers > 1:
            live = [w for w in range(self.num_workers) if w != worker_id]
            new_w = np.asarray(live, np.int32)[
                self.store.col("task_id")[idx] % len(live)]
            self.store.update(idx, worker_id=new_w)
        self.log.append("requeue_worker", {"worker": worker_id,
                                           "n": len(idx)})
        return len(idx)

    # --------------------------------------------------------------- elastic
    def resize(self, new_workers: int) -> int:
        """Elastic scaling: re-hash non-terminal tasks to W' partitions."""
        status = self.store.col("status")
        movable = np.isin(status, [int(Status.READY), int(Status.BLOCKED)])
        idx = np.nonzero(movable)[0]
        tids = self.store.col("task_id")[idx]
        new_assign = assign_workers(tids, new_workers)
        moved = int(np.sum(new_assign !=
                           self.store.col("worker_id")[idx]))
        self.store.update(idx, worker_id=new_assign)
        self.num_workers = new_workers
        self.log.append("resize", {"workers": new_workers, "moved": moved})
        return moved

    # ------------------------------------------------------------ invariants
    def _check_transition(self, idx: np.ndarray, to: Status) -> None:
        cur = self.store.col("status")[np.asarray(idx)]
        for c in np.unique(cur):
            if to not in TRANSITIONS[Status(int(c))]:
                raise ValueError(
                    f"illegal transition {Status(int(c)).name} -> {to.name}")

    def check_invariants(self) -> None:
        """Property-test hooks: every task in exactly one status; RUNNING
        tasks have start_time; FINISHED have end >= start; partition ids in
        range."""
        st = self.store.col("status")
        assert ((st >= int(Status.EMPTY)) & (st <= int(Status.PRUNED))).all()
        wid = self.store.col("worker_id")
        used = st != int(Status.EMPTY)
        assert (wid[used] >= 0).all() and (wid[used] < self.num_workers).all()
        running = st == int(Status.RUNNING)
        assert not np.isnan(self.store.col("start_time")[running]).any()
        fin = st == int(Status.FINISHED)
        ok = (self.store.col("end_time")[fin]
              >= self.store.col("start_time")[fin])
        assert ok.all()

    # ------------------------------------------------------------- counters
    def counts(self) -> Dict[str, int]:
        st = self.store.col("status")
        return {s.name: int(np.sum(st == int(s))) for s in Status}
