"""Distributed Work Queue on the column store (paper Sections 3.2-3.3).

Passive multi-master semantics: workers *claim* from their own partition
(``WHERE worker_id = i AND status = READY ORDER BY task_id LIMIT k``); the
partition-private access removes write conflicts, exactly the paper's
argument. ``claim_all`` is the batched SPMD form: one vectorized operation
claims the next task for every worker at once — this is what the executor
uses per training step and what the ``wq_claim`` Pallas kernel implements
on-device.

Claim fast-path
---------------
The paper's Experiment 6 shows getREADYtasks + the RUNNING flip dominate DBMS
time, so the hot path here is fully vectorized: ONE scan over the ready
suffix of the store (per-partition ready cursors skip the claimed prefix),
per-worker ranks via a stable worker-sort + ``np.bincount`` segment offsets,
and work stealing as one vectorized redistribution of the leftover pool onto
deficit workers — no per-worker Python loop anywhere. ``claim_all_reference``
keeps the original O(n·W) loop as the oracle for equivalence tests and the
speedup benchmark. With ``device_claim`` enabled the primary phase runs the
``wq_claim`` Pallas op on the accelerator instead.

Work stealing (straggler mitigation) claims from the most-loaded sibling
partition when the own partition is dry (paper: "more partitions than data
nodes gives flexibility ... load balancing").
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.partition import assign_workers, partition_sizes, rehash
from repro.core.schema import LEGAL_TRANSITIONS, Status
from repro.core.store import ColumnStore
from repro.core.transactions import TxnLog


class WorkQueue:
    def __init__(self, num_workers: int, store: Optional[ColumnStore] = None,
                 txn_log: Optional[TxnLog] = None, capacity: int = 1 << 16,
                 device_claim: Optional[bool] = None,
                 lease_s: Optional[float] = None):
        self.store = store or ColumnStore(capacity=capacity)
        if lease_s is not None:
            # lease duration rides ON THE STORE (and inside its snapshot)
            # so replicas restored from it derive identical expires_at
            # values when replaying claim records — see store.DEFAULT_LEASE_S
            self.store.lease_s = float(lease_s)
        self.num_workers = num_workers
        self.log = txn_log or TxnLog()
        self._next_task_id = int(self.store.n_rows)
        if device_claim is None:
            from repro.flags import wq_device_claim
            device_claim = wq_device_claim()
        self.device_claim = bool(device_claim)
        # ready cursor per partition: no READY row of partition w exists at a
        # row index < _cursor[w]. Claims advance it; any transition that can
        # re-create READY rows at lower indices lowers it again.
        self._cursor = np.zeros(num_workers, np.int64)
        # orphan watermark: min row index at which a READY row whose
        # worker_id fell outside [0, W) may exist (shrink-resize + retry).
        # No per-partition cursor covers those rows, so scans start at
        # min(cursor.min(), _orphan_lo) to keep them reachable by stealing.
        self._orphan_lo = self._NO_ORPHANS
        # exact READY count per partition (index may exceed W for partitions
        # orphaned by a shrink-resize; negative ids in a scalar bucket),
        # maintained incrementally on every status transition: _steal picks
        # its victim and claim_all bounds its block scan from these instead
        # of rescanning the ready suffix.
        self._ready = np.zeros(num_workers, np.int64)
        self._ready_neg = 0
        self._recount_ready()

    _NO_ORPHANS = np.iinfo(np.int64).max

    def _scan_start(self) -> int:
        return int(min(self._cursor.min(), self._orphan_lo))

    # --------------------------------------------------------- ready counts
    def _ready_delta(self, wids: np.ndarray, sign: int) -> None:
        """Shift per-partition READY counts for rows entering (+1) or
        leaving (-1) READY, keyed by their worker_id at that moment.
        Negative partition ids go to a scalar bucket: no partition-private
        claim or steal victim pick can reach them, but claim_all's steal
        POOL can (matching claim_all_reference), so they must still count
        toward total availability."""
        wids = np.asarray(wids)
        neg = int((wids < 0).sum())
        if neg:
            self._ready_neg += sign * neg
        w = wids[wids >= 0].astype(np.int64, copy=False)
        if not w.size:
            return
        hi = int(w.max()) + 1
        if hi > self._ready.size:
            self._ready = np.concatenate(
                [self._ready, np.zeros(hi - self._ready.size, np.int64)])
        self._ready[:hi] += sign * np.bincount(w, minlength=hi)

    def _recount_ready(self) -> None:
        """Rebuild the counts from the store (init / out-of-band mutations)."""
        st = self.store.col("status")
        rw = self.store.col("worker_id")[st == int(Status.READY)]
        self._ready_neg = int((rw < 0).sum())
        rw = rw[rw >= 0].astype(np.int64, copy=False)
        size = max(self.num_workers, int(rw.max()) + 1 if rw.size else 0)
        self._ready = np.bincount(rw, minlength=size) \
            if rw.size else np.zeros(size, np.int64)

    def ready_counts(self) -> np.ndarray:
        """READY tasks per partition (copy; length num_workers)."""
        out = np.zeros(self.num_workers, np.int64)
        n = min(self.num_workers, self._ready.size)
        out[:n] = self._ready[:n]
        return out

    # ----------------------------------------------------------- txn helper
    def _append_log(self, op: str, payload: Dict) -> None:
        self.log.append(op, payload, store_version=self.store.version)

    def compact_log(self) -> int:
        """Drop the txn-log prefix every registered consumer (checkpointer,
        replicas — each member of a replica GROUP registers independently,
        so the floor is min-over-group) has acked past — bounds long-run
        log memory. A no-op when no consumer is registered (nothing is
        provably durable elsewhere)."""
        return self.log.truncate()

    def consumer_lags(self) -> Dict[str, int]:
        """Log records each registered consumer still has to consume —
        the per-replica lag surface the replication fabric (and its
        ``fanout_lag`` benchmark metric) reports from."""
        end = len(self.log)
        return {name: end - off
                for name, off in self.log.consumer_offsets().items()}

    # -------------------------------------------------------------- cursors
    def invalidate_cursors(self, rows: Optional[np.ndarray] = None) -> None:
        """Lower the ready cursors after an out-of-band status change.

        Call with the affected rows when external code mutates ``status`` (or
        ``worker_id``) directly on the store instead of going through the
        WorkQueue API; with ``rows=None`` all cursors reset to 0.
        """
        if rows is None or len(rows) == 0:
            self._cursor[:] = 0
            self._orphan_lo = 0
        else:
            self._cursor[:] = np.minimum(self._cursor, int(np.min(rows)))
            self._orphan_lo = min(self._orphan_lo, int(np.min(rows)))
        self._recount_ready()          # counts cannot be patched blind

    def _lower_cursors(self, rows: np.ndarray, wid: np.ndarray) -> None:
        """Per-partition lower bound for rows that just became READY."""
        ok = (wid >= 0) & (wid < self.num_workers)
        if ok.any():
            np.minimum.at(self._cursor, wid[ok], rows[ok])
        if (~ok).any():                    # orphaned partition rows: tracked
            self._orphan_lo = min(self._orphan_lo,   # by the watermark, not
                                  int(np.min(rows[~ok])))    # any cursor

    # -------------------------------------------------------------- inserts
    def add_tasks(self, activity_id: int, n: int, *,
                  status: Status = Status.READY,
                  duration_est=0.0,
                  domain_in: Optional[np.ndarray] = None,
                  parent_task: Optional[np.ndarray] = None,
                  now: float = 0.0,
                  mark_expanded: Optional[np.ndarray] = None,
                  task_ids: Optional[np.ndarray] = None,
                  worker_ids: Optional[np.ndarray] = None) -> np.ndarray:
        """Insert ``n`` tasks; ``duration_est`` may be a scalar or per-task
        array. ``mark_expanded`` flips the ``expanded`` flag of the given
        parent rows in the SAME transaction / log record, so dependency
        expansion (children inserted + parents marked) is atomic: a replica
        can never observe the children without the dedup mark.

        ``task_ids`` overrides the queue-local id counter so an external
        router (e.g. ``ShardRouter``) can keep ids globally unique across
        shards — cross-shard work stealing re-inserts tasks under their
        original ids. ``worker_ids`` overrides the default round-robin
        partition assignment (values must lie in ``[0, num_workers)``)."""
        if task_ids is not None:
            ids = np.asarray(task_ids, np.int64)
            if len(ids) != n:
                raise ValueError(f"task_ids has {len(ids)} entries, n={n}")
            if n:
                self._next_task_id = max(self._next_task_id,
                                         int(ids.max()) + 1)
        else:
            ids = np.arange(self._next_task_id, self._next_task_id + n,
                            dtype=np.int64)
            self._next_task_id += n
        dur = np.asarray(duration_est, np.float64)
        rows = {
            "task_id": ids,
            "activity_id": np.full(n, activity_id, np.int32),
            "worker_id": (np.asarray(worker_ids, np.int32)
                          if worker_ids is not None
                          else assign_workers(ids, self.num_workers)),
            "status": np.full(n, int(status), np.int32),
            "submit_time": np.full(n, now, np.float64),
            "duration_est": (np.full(n, float(dur)) if dur.ndim == 0
                             else dur.astype(np.float64, copy=False)),
        }
        if domain_in is not None:
            for i in range(domain_in.shape[1]):
                rows[f"in{i}"] = domain_in[:, i]
        if parent_task is not None:
            rows["parent_task"] = parent_task
        with self.store.txn():
            idx = self.store.insert(rows)
            if mark_expanded is not None and len(mark_expanded):
                self.store.update(np.asarray(mark_expanded), expanded=1)
            payload = {"activity_id": activity_id, "n": n, "ids": ids,
                       "rows": rows, "row_idx": idx}
            if mark_expanded is not None and len(mark_expanded):
                payload["expanded_rows"] = np.asarray(mark_expanded)
            self._append_log("insert", payload)
            if status == Status.READY:
                self._ready_delta(rows["worker_id"], +1)
        return ids

    # ---------------------------------------------------------------- claim
    def claim(self, worker_id: int, k: int = 1, *,
              now: float = 0.0, allow_steal: bool = False) -> np.ndarray:
        """getREADYtasks + updateToRUNNING for one worker (partition-private).

        Returns claimed row indices (== task ids here). Scans the partition's
        ready suffix (``_cursor``) in geometrically growing blocks, stopping
        as soon as k matches are found — O(k·W)-ish for round-robin
        partitions instead of O(store).
        """
        with self.store.txn():
            n = self.store.n_rows
            start = int(self._cursor[worker_id])
            status = self.store.col("status")
            wid = self.store.col("worker_id")
            found: List[np.ndarray] = []
            n_found = 0
            pos = start
            block = max(1024, 16 * k * self.num_workers)
            while pos < n and n_found <= k:      # one extra match tells us
                end = min(n, pos + block)        # the partition isn't drained
                m = (status[pos:end] == int(Status.READY)) \
                    & (wid[pos:end] == worker_id)
                rel = np.nonzero(m)[0]
                if len(rel):
                    found.append(rel + pos)
                    n_found += len(rel)
                pos = end
                block *= 2
            rel_all = np.concatenate(found) if found \
                else np.empty(0, np.int64)
            idx = rel_all[:k]
            if n_found <= k and pos >= n:        # partition drained
                self._cursor[worker_id] = n
            elif len(idx):
                self._cursor[worker_id] = int(idx[-1]) + 1
            if len(idx) == 0 and allow_steal:
                idx = self._steal(worker_id, k)
            if len(idx):
                # decrement against the partitions the rows LEAVE (stolen
                # rows leave the victim's count) before wid is overwritten
                self._ready_delta(wid[idx], -1)
                self.store.update(idx, status=int(Status.RUNNING),
                                  start_time=now, worker_id=worker_id,
                                  core_id=worker_id, claimed_at=now,
                                  heartbeat_at=now,
                                  expires_at=now + self.store.lease_s)
                self._append_log("claim", {
                    "worker": worker_id, "rows": idx, "now": now,
                    "ids": self.store.col("task_id")[idx]})
        return idx

    def _steal(self, thief: int, k: int) -> np.ndarray:
        """Claim from the most-loaded sibling partition.

        Victim pick is O(W) off the incrementally maintained ready counts —
        no suffix scan, no bincount over READY rows. Only the VICTIM's
        cursor suffix is then scanned to materialize its first k rows.
        No [0, W) cap on the victim id: a partition orphaned by a
        shrink-resize is a valid victim (counts extend past num_workers),
        same as the seed loop — otherwise claim()-driven schedulers could
        never rescue those rows.
        """
        if not self._ready.size:
            return np.empty(0, np.int64)
        victim = int(np.argmax(self._ready))
        if self._ready[victim] == 0 or victim == thief:
            return np.empty(0, np.int64)
        n = self.store.n_rows
        start = int(self._cursor[victim]) if victim < self.num_workers \
            else min(int(self._orphan_lo), n)
        status = self.store.col("status")
        wid = self.store.col("worker_id")
        idx = np.nonzero((status[start:] == int(Status.READY))
                         & (wid[start:] == victim))[0][:k] + start
        return idx

    def claim_all(self, k: int = 1, *, now: float = 0.0,
                  steal: bool = True) -> Dict[int, np.ndarray]:
        """Batched claim: next k READY tasks for EVERY worker in one pass.

        This is the SPMD form the executor uses (and the semantics of the
        wq_claim kernel). Vectorized end to end: stable worker-sort of the
        ready rows gives per-worker segments, bincount offsets give in-segment
        ranks (rank < k == claimed), and stealing redistributes the unclaimed
        pool onto deficit workers with one repeat/argsort/split round.
        Observationally equivalent to :meth:`claim_all_reference`.
        """
        W = self.num_workers
        if k < 1:
            return {w: np.empty(0, np.int64) for w in range(W)}
        with self.store.txn():
            n = self.store.n_rows
            start = self._scan_start()
            if self.device_claim:
                claimed, n_claimed, pool = self._primary_device(start, k)
            else:
                claimed, n_claimed, pool = self._primary_host(start, k)

            # advance cursors: a worker that claimed < k drained its
            # partition; one that claimed exactly k stops right after its
            # k-th claimed row (earlier READY rows are all claimed)
            offs_c = np.cumsum(n_claimed) - n_claimed
            new_cur = np.full(W, n, np.int64)
            full = n_claimed >= k
            if full.any():
                new_cur[full] = claimed[offs_c[full] + k - 1] + 1
            self._cursor = np.maximum(self._cursor, new_cur)

            # stealing as ONE vectorized redistribution: deficit workers
            # (ascending id, reference semantics) receive the leftover pool
            # (ascending row order) in contiguous chunks
            extras = np.empty(0, np.int64)
            recipients = np.empty(0, np.int64)
            if steal and pool.size:
                need = k - n_claimed
                if need.sum() > 0:
                    recipients = np.repeat(np.arange(W), need)[: pool.size]
                    extras = pool[: recipients.size]

            rows_all = np.concatenate([claimed, extras])
            w_all = np.concatenate(
                [np.repeat(np.arange(W), n_claimed), recipients])
            redo = np.argsort(w_all, kind="stable")   # per worker: primary
            rows_all = rows_all[redo]                 # rows, then stolen rows
            tot = n_claimed + np.bincount(recipients, minlength=W)
            out = dict(enumerate(np.split(rows_all, np.cumsum(tot)[:-1])))

            if len(rows_all):
                # claim_all never reassigns worker_id: decrement the counts
                # of the partitions the rows leave (stolen rows included)
                self._ready_delta(self.store.col("worker_id")[rows_all], -1)
                # lease stamps ride the SAME transaction / log record as the
                # RUNNING flip: the hot wire frame still carries only
                # rows/now — both sides derive expires_at = now + lease_s
                self.store.update(rows_all, status=int(Status.RUNNING),
                                  start_time=now, claimed_at=now,
                                  heartbeat_at=now,
                                  expires_at=now + self.store.lease_s)
                self._append_log("claim_all", {"n": len(rows_all),
                                               "rows": rows_all, "now": now})
        return out

    def _primary_host(self, start: int, k: int
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Vectorized primary claim phase over the ready suffix.

        Scans in geometrically growing blocks and stops as soon as every
        worker's budget is met — for dense round-robin partitions that is
        one small block, independent of store size. Per block, k == 1 uses
        a stable worker-sort + bincount segment offsets for in-partition
        ranks (rank below the remaining quota == claimed); k > 1 uses a
        SEGMENTED ARGPARTITION over the exact per-partition ready counts
        (:meth:`_block_take_argpartition`) — selection instead of a full
        sort of the block's ready rows. The leftover pool for stealing is
        only materialized when quotas stay unmet after a full scan (and the
        suffix is cheap to rescan exactly then).

        Returns (claimed rows in worker-major order, per-worker claim counts,
        leftover READY rows in ascending row order).
        """
        W = self.num_workers
        n = self.store.n_rows
        status = self.store.col("status")
        wid = self.store.col("worker_id")
        # quota capped by the maintained per-partition READY counts: a
        # partition can never yield more than it has, so capping changes
        # nothing about what gets claimed — but the scan loop now stops as
        # soon as every AVAILABLE row is found instead of walking the whole
        # suffix hunting for rows that do not exist (heavy-tail k>1 claims
        # on dried-up partitions used to pay a full O(store) rescan here)
        total_ready = int(self._ready.sum()) + self._ready_neg
        need = np.minimum(np.full(W, k, np.int64), self.ready_counts())
        take_block = self._block_take_sort if k == 1 \
            else self._block_take_argpartition
        parts: List[np.ndarray] = []
        pos = start
        # k > 1 right-sizes the first block to the QUOTA the ready counts
        # prove is claimable (~2 rows scanned per claim on a round-robin
        # suffix) instead of 16x it — selection cost tracks what gets
        # claimed, and geometric growth still covers skewed layouts
        block = max(4096, 16 * k * W) if k == 1 else max(1024, 2 * k * W)
        while pos < n and need.any():
            end = min(n, pos + block)
            rr = np.nonzero(status[pos:end] == int(Status.READY))[0] + pos
            if rr.size:
                got, counts = take_block(rr, wid[rr], need)
                parts.append(got)
                need -= np.minimum(counts, need)
            pos = end
            block *= 2
        rows = np.concatenate(parts) if parts else np.empty(0, np.int64)
        if k == 1:
            # blocks are ascending and the sort path keeps row order within
            # each partition: stable sort by worker suffices
            order = np.argsort(wid[rows], kind="stable")
        else:
            # argpartition leaves rows unordered within a partition: lexsort
            # the <= k*W claimed rows back to (worker-major, row-ascending),
            # the reference order the cursor advance and callers rely on
            order = np.lexsort((rows, wid[rows]))
        claimed = rows[order]                          # sorted within worker
        n_claimed = np.bincount(wid[rows], minlength=W)
        if (n_claimed < k).any() and total_ready > len(rows):
            # deficits remain AND unclaimed READY rows exist (beyond-quota
            # rows of loaded partitions, or orphaned partitions): only then
            # is the steal pool materialized, via one suffix scan — when the
            # counts show nothing is left the scan is skipped entirely
            left = np.zeros(n - start, bool)
            left[np.nonzero(status[start:] == int(Status.READY))[0]] = True
            left[rows - start] = False
            pool = np.nonzero(left)[0] + start
            self._advance_orphan_watermark(pool, wid)
        else:
            pool = np.empty(0, np.int64)
        return claimed, n_claimed, pool

    def _block_take_sort(self, rr: np.ndarray, rw: np.ndarray,
                         need: np.ndarray
                         ) -> Tuple[np.ndarray, np.ndarray]:
        """k == 1 block selection: stable worker-sort + bincount ranks.

        Returns (claimed rows of this block — row-ascending within each
        partition, in-range partition counts). One stable sort groups the
        partitions while keeping row order, so in-segment position IS the
        rank; partition ids outside [0, W) are dropped by the searchsorted
        bounds (they belong to the steal pool).
        """
        W = self.num_workers
        order = np.argsort(rw, kind="stable")      # groups workers,
        srows = rr[order]                          # keeps row order
        sw = rw[order]                             # within each
        lo = int(np.searchsorted(sw, 0))           # partition ids
        hi = int(np.searchsorted(sw, W))           # outside [0, W)
        seg_rows, seg_w = srows[lo:hi], sw[lo:hi]
        counts = np.bincount(seg_w, minlength=W)
        offs = np.cumsum(counts) - counts
        rank = np.arange(len(seg_rows)) - np.repeat(offs, counts)
        return seg_rows[rank < need[seg_w]], counts

    def _block_take_argpartition(self, rr: np.ndarray, rw: np.ndarray,
                                 need: np.ndarray
                                 ) -> Tuple[np.ndarray, np.ndarray]:
        """k > 1 block selection: segmented argpartition, no full sort.

        Composite key (partition-major, row-minor) makes the global sorted
        order partition-contiguous; one multi-kth ``np.argpartition`` with a
        pin at every partition's END (so segments cannot bleed into each
        other) plus a pin at every partition's QUOTA CUT (exact ready
        counts bound the cut) places each partition's ``need[w]``
        lowest-index ready rows — the exact rows the reference loop claims —
        in its quota window, in O(R) selection passes instead of the
        O(R log R) stable sort the k == 1 path pays. The claimed rows come
        back UNORDERED within each partition; the caller re-orders the
        (small) claimed set, never the block.
        """
        W = self.num_workers
        ok = (rw >= 0) & (rw < W)              # out-of-range ids: steal pool
        rr_in = rr[ok]
        rw_in = rw[ok].astype(np.int64, copy=False)
        counts = np.bincount(rw_in, minlength=W)
        take = np.minimum(counts, need)
        tot = int(take.sum())
        if not tot:
            return np.empty(0, np.int64), counts
        key = rw_in * np.int64(self.store.n_rows + 1) + rr_in
        ends = np.cumsum(counts)
        offs = ends - counts
        kth = np.unique(np.concatenate(
            [ends[counts > 0] - 1, (offs + take - 1)[take > 0]]))
        part = np.argpartition(key, kth)
        seg = np.repeat(np.arange(W), take)    # quota-window positions:
        within = np.arange(tot) \
            - np.repeat(np.cumsum(take) - take, take)
        return rr_in[part[offs[seg] + within]], counts

    def _advance_orphan_watermark(self, pool: np.ndarray,
                                  wid: np.ndarray) -> None:
        """Given the COMPLETE set of unclaimed READY rows, re-derive the
        orphan watermark exactly (lazy advance — it only ever lowers on
        fail-retry, so this is where it recovers)."""
        pw = wid[pool]
        orph = pool[(pw < 0) | (pw >= self.num_workers)]
        self._orphan_lo = int(orph.min()) if orph.size else self._NO_ORPHANS

    def _primary_device(self, start: int, k: int
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Primary claim phase on the accelerator via the wq_claim Pallas op.

        The kernel computes the per-worker rank<k claim mask in one
        data-parallel pass; the host applies the resulting mask to the
        authoritative store (stealing stays host-side).
        """
        from repro.kernels.wq_claim.ops import wq_claim_columns
        status = self.store.col("status")[start:]
        wid_full = self.store.col("worker_id")
        claim_mask, new_status = wq_claim_columns(
            status, wid_full[start:], num_workers=self.num_workers, k=k)
        rows = np.nonzero(claim_mask)[0] + start
        # the kernel's rank trick degenerates to rank 0 for rows whose
        # partition id is outside [0, W) (all-zero one-hot), so it "claims"
        # them regardless of budget — route those to the steal pool instead,
        # matching the host path's searchsorted lo/hi split
        w_rows = wid_full[rows]
        ok = (w_rows >= 0) & (w_rows < self.num_workers)
        orphans = rows[~ok]
        rows, w_rows = rows[ok], w_rows[ok]
        order = np.argsort(w_rows, kind="stable")
        claimed = rows[order]
        n_claimed = np.bincount(w_rows, minlength=self.num_workers)
        pool = np.sort(np.concatenate(
            [np.nonzero(new_status == int(Status.READY))[0] + start,
             orphans]))
        self._advance_orphan_watermark(pool, wid_full)
        return claimed, n_claimed, pool

    def claim_all_reference(self, k: int = 1, *, now: float = 0.0,
                            steal: bool = True) -> Dict[int, np.ndarray]:
        """The seed O(n·W) loop implementation, kept verbatim as the oracle
        for equivalence tests and the claim-path speedup benchmark."""
        status = self.store.col("status")
        wid = self.store.col("worker_id")
        ready = status == int(Status.READY)
        out: Dict[int, np.ndarray] = {}
        claimed_rows: List[np.ndarray] = []
        for w in range(self.num_workers):
            idx = np.nonzero(ready & (wid == w))[0][:k]
            out[w] = idx
            claimed_rows.append(idx)
        if steal:
            leftovers = np.nonzero(ready)[0]
            taken = set(np.concatenate(claimed_rows).tolist())
            pool = [i for i in leftovers if i not in taken]
            for w in range(self.num_workers):
                need = k - len(out[w])
                if need > 0 and pool:
                    extra = np.asarray(pool[:need], dtype=np.int64)
                    pool = pool[need:]
                    out[w] = np.concatenate([out[w], extra])
                    claimed_rows.append(extra)
        all_idx = np.concatenate([v for v in out.values() if len(v)]) \
            if any(len(v) for v in out.values()) else np.empty(0, np.int64)
        if len(all_idx):
            self.store.update(all_idx, status=int(Status.RUNNING),
                              start_time=now, claimed_at=now,
                              heartbeat_at=now,
                              expires_at=now + self.store.lease_s)
            self._append_log("claim_all", {"n": len(all_idx),
                                           "rows": all_idx, "now": now})
        self.invalidate_cursors()      # bypasses the cursor bookkeeping
        return out

    # ------------------------------------------------------------- complete
    def finish(self, idx: np.ndarray, *, now: float = 0.0,
               domain_out: Optional[np.ndarray] = None) -> None:
        self._check_transition(idx, Status.FINISHED)
        with self.store.txn():
            # finishing IS the lease renewal for the terminal hop: a worker
            # that reports a result proves liveness at `now`
            upd = {"status": int(Status.FINISHED), "end_time": now,
                   "heartbeat_at": now}
            self.store.update(np.asarray(idx), **upd)
            payload = {"ids": np.asarray(idx), "rows": np.asarray(idx),
                       "now": now}
            if domain_out is not None:
                cols = {f"out{i}": domain_out[:, i]
                        for i in range(domain_out.shape[1])}
                self.store.update(np.asarray(idx), **cols)
                payload["domain_out"] = np.asarray(domain_out)
            self._append_log("finish", payload)

    def fail(self, idx: np.ndarray, *, now: float = 0.0,
             max_trials: int = 3) -> None:
        """Failure handling: retry (back to READY) until fail_trials exhausts."""
        idx = np.asarray(idx)
        with self.store.txn():
            trials = self.store.col("fail_trials")[idx] + 1
            retry = idx[trials < max_trials]
            dead = idx[trials >= max_trials]
            self.store.update(idx, fail_trials=trials)
            if len(retry):
                self.store.update(retry, status=int(Status.READY))
                self._lower_cursors(retry, self.store.col("worker_id")[retry])
                self._ready_delta(self.store.col("worker_id")[retry], +1)
            if len(dead):
                self.store.update(dead, status=int(Status.FAILED),
                                  end_time=now)
            self._append_log("fail", {"retry": retry, "dead": dead,
                                      "rows": idx, "trials": trials,
                                      "now": now})

    def requeue_worker(self, worker_id: int, *, reassign: bool = True) -> int:
        """Node failure: return the dead worker's RUNNING tasks to READY and
        (optionally) rehash them to live partitions."""
        with self.store.txn():
            idx = self.store.where(worker_id=worker_id,
                                   status=int(Status.RUNNING))
            if len(idx) == 0:
                return 0
            self.store.update(idx, status=int(Status.READY))
            trials = self.store.col("fail_trials")[idx] + 1
            self.store.update(idx, fail_trials=trials)
            if reassign and self.num_workers > 1:
                live = [w for w in range(self.num_workers) if w != worker_id]
                new_w = np.asarray(live, np.int32)[
                    self.store.col("task_id")[idx] % len(live)]
                self.store.update(idx, worker_id=new_w)
            self._lower_cursors(idx, self.store.col("worker_id")[idx])
            self._ready_delta(self.store.col("worker_id")[idx], +1)
            self._append_log("requeue_worker", {
                "worker": worker_id, "n": len(idx), "rows": idx,
                "trials": trials,
                "new_worker": self.store.col("worker_id")[idx]})
            return len(idx)

    # --------------------------------------------------------------- leases
    def reap_expired(self, *, now: float = 0.0, max_trials: int = 3) -> int:
        """Vectorized stale-claim reaper (Work Claim Pattern).

        Requeues every RUNNING row whose lease deadline has passed in ONE
        masked transition: fail_trials bumps, rows below ``max_trials`` go
        back to READY (lease columns cleared so the row is visibly
        unleased), exhausted rows go to FAILED — both legs checked against
        the legality matrix. Worker death thus becomes a data-plane event:
        no supervisor round-trip, and the record replays on replicas and
        per-shard stores through the ordinary cold log path. NaN
        ``expires_at`` (no lease taken) never matches the mask, so rows
        claimed by legacy paths are left alone.

        Requeued rows are rehashed onto the CURRENT partition map
        (``assign_workers`` at today's ``num_workers``): the dead worker's
        partition may no longer exist after a :meth:`resize`, and a stale
        ``worker_id`` would strand the row outside every live scan range.
        The assignment rides the log record (``new_worker``) so replicas
        land the rows identically. Returns rows reaped.
        """
        with self.store.txn():
            st = self.store.col("status")
            exp = self.store.col("expires_at")
            mask = (st == int(Status.RUNNING)) & (exp < now)
            idx = np.nonzero(mask)[0]
            if not len(idx):
                return 0
            trials = self.store.col("fail_trials")[idx] + 1
            retry = idx[trials < max_trials]
            dead = idx[trials >= max_trials]
            self._check_transition(retry, Status.READY)
            self._check_transition(dead, Status.FAILED)
            self.store.update(idx, fail_trials=trials)
            new_worker = None
            if len(retry):
                new_worker = assign_workers(
                    self.store.col("task_id")[retry], self.num_workers)
                self.store.update(retry, status=int(Status.READY),
                                  claimed_at=np.nan, heartbeat_at=np.nan,
                                  expires_at=np.nan, worker_id=new_worker)
                self._lower_cursors(retry, new_worker)
                self._ready_delta(new_worker, +1)
            if len(dead):
                self.store.update(dead, status=int(Status.FAILED),
                                  end_time=now)
            self._append_log("reap", {"rows": idx, "retry": retry,
                                      "dead": dead, "trials": trials,
                                      "new_worker": new_worker,
                                      "now": now})
            return len(idx)

    def renew_leases(self, idx: np.ndarray, *, now: float = 0.0) -> int:
        """Heartbeat: push the lease deadline of still-RUNNING rows to
        ``now + lease_s``. Rows that already left RUNNING (finished, reaped)
        are skipped — a late heartbeat cannot resurrect a reaped claim.
        Returns the number of leases renewed."""
        idx = np.asarray(idx, np.int64)
        with self.store.txn():
            if len(idx):
                st = self.store.col("status")[idx]
                idx = idx[st == int(Status.RUNNING)]
            if not len(idx):
                return 0
            self.store.update(idx, heartbeat_at=now,
                              expires_at=now + self.store.lease_s)
            self._append_log("lease_renew", {"rows": idx, "now": now})
            return len(idx)

    def autoscale_signals(self, *, now: float = 0.0) -> Dict[str, float]:
        """HPA-style signals derived from the relation itself: pending
        (READY+BLOCKED) count, oldest-pending backlog age, p95
        submit-to-claim latency over claimed rows, and the RUNNING count.
        This is what ``ElasticController`` scales the pool from."""
        st = self.store.col("status")
        pending = (st == int(Status.READY)) | (st == int(Status.BLOCKED))
        n_pending = int(pending.sum())
        backlog_age = 0.0
        if n_pending:
            oldest = np.nanmin(self.store.col("submit_time")[pending])
            if not np.isnan(oldest):
                backlog_age = max(0.0, float(now) - float(oldest))
        lat = (self.store.col("claimed_at")
               - self.store.col("submit_time"))
        lat = lat[~np.isnan(lat)]
        p95 = max(0.0, float(np.percentile(lat, 95))) if lat.size else 0.0
        return {"pending": float(n_pending),
                "backlog_age_s": backlog_age,
                "claim_p95_s": p95,
                "running": float((st == int(Status.RUNNING)).sum())}

    # ------------------------------------------------------------- steering
    def prune(self, rows: np.ndarray) -> int:
        """Steering's data reduction: mark the given READY/BLOCKED rows
        PRUNED, with txn logging and ready-count maintenance. Lives here —
        not in the steering engine — so every status write that touches the
        incremental ready counts stays inside the WorkQueue."""
        rows = np.asarray(rows)
        if not len(rows):
            return 0
        with self.store.txn():
            st = self.store.col("status")[rows]
            was_ready = rows[st == int(Status.READY)]
            if len(was_ready):
                self._ready_delta(self.store.col("worker_id")[was_ready], -1)
            self.store.update(rows, status=int(Status.PRUNED))
            self._append_log("steer_prune", {"n": len(rows), "rows": rows})
        return len(rows)

    # --------------------------------------------------------------- elastic
    def resize(self, new_workers: int) -> int:
        """Elastic scaling: re-hash non-terminal tasks to W' partitions."""
        with self.store.txn():
            status = self.store.col("status")
            movable = np.isin(status, [int(Status.READY),
                                       int(Status.BLOCKED)])
            idx = np.nonzero(movable)[0]
            tids = self.store.col("task_id")[idx]
            new_assign = assign_workers(tids, new_workers)
            moved = int(np.sum(new_assign !=
                               self.store.col("worker_id")[idx]))
            self.store.update(idx, worker_id=new_assign)
            self.num_workers = new_workers
            self._cursor = np.zeros(new_workers, np.int64)
            # re-hash reassigned every READY/BLOCKED row into [0, W'), so no
            # READY orphan can exist right after a resize
            self._orphan_lo = self._NO_ORPHANS
            self._recount_ready()        # same READY set, new partition keys
            self._append_log("resize", {"workers": new_workers,
                                        "moved": moved, "rows": idx,
                                        "assign": new_assign})
            return moved

    # ------------------------------------------------------------ invariants
    def _check_transition(self, idx: np.ndarray, to: Status) -> None:
        """Vectorized legality check: one gather into the precomputed
        boolean matrix (schema.LEGAL_TRANSITIONS) indexed by
        (current_status, to) — no per-distinct-status Python loop."""
        cur = self.store.col("status")[np.asarray(idx)]
        bad = ~LEGAL_TRANSITIONS[cur, int(to)]
        if bad.any():
            c = int(cur[np.argmax(bad)])
            raise ValueError(
                f"illegal transition {Status(c).name} -> {to.name}")

    def check_invariants(self) -> None:
        """Property-test hooks: every task in exactly one status; RUNNING
        tasks have start_time; FINISHED have end >= start; partition ids in
        range; no READY row hides below its partition's ready cursor."""
        st = self.store.col("status")
        assert ((st >= int(Status.EMPTY)) & (st <= int(Status.PRUNED))).all()
        wid = self.store.col("worker_id")
        used = st != int(Status.EMPTY)
        assert (wid[used] >= 0).all() and (wid[used] < self.num_workers).all()
        running = st == int(Status.RUNNING)
        assert not np.isnan(self.store.col("start_time")[running]).any()
        fin = st == int(Status.FINISHED)
        ok = (self.store.col("end_time")[fin]
              >= self.store.col("start_time")[fin])
        assert ok.all()
        ready_rows = np.nonzero(st == int(Status.READY))[0]
        rw = wid[ready_rows]
        in_range = (rw >= 0) & (rw < self.num_workers)
        assert not (ready_rows[in_range]
                    < self._cursor[rw[in_range]]).any()
        # incremental ready counts must equal a fresh recount, exactly
        want = np.bincount(rw[rw >= 0].astype(np.int64),
                           minlength=self._ready.size) if rw.size \
            else np.zeros(self._ready.size, np.int64)
        if want.size < self._ready.size:
            want = np.concatenate(
                [want, np.zeros(self._ready.size - want.size, np.int64)])
        assert np.array_equal(self._ready, want), (self._ready, want)
        assert self._ready_neg == int((rw < 0).sum())

    # ------------------------------------------------------------- counters
    def counts(self) -> Dict[str, int]:
        stats = self.store.stats()           # one bincount (_status_stats)
        return {s.name: stats[int(s)] for s in Status}
