"""In-memory columnar store (struct-of-arrays) with partition views.

The TPU-native adaptation of the paper's MySQL-Cluster data nodes: execution /
domain / provenance columns live in ONE preallocated SoA region, hash-
partitioned by ``worker_id``. The authoritative copy is host-resident (the
control plane mutates it transactionally); hot columns mirror to the device
for analytical steering reductions and for the vectorized / Pallas claim ops.

Updates go through ``apply`` with a transaction record so the txn log
(transactions.py) can replay them on replicas and after restarts.

HTAP snapshot isolation
-----------------------
``snapshot_view()`` returns an immutable :class:`SnapshotView` of the store at
the current committed version in O(columns) time: the live arrays are frozen
(``writeable = False``) and handed to the view; the NEXT transactional write to
a frozen column copies it first (column-granular copy-on-write). Analytical
steering sweeps therefore read a consistent version while claims keep mutating
the live store — the paper's "same store, OLTP claims + OLAP scans" argument
without torn reads. Snapshot creation and transaction commits serialize on one
lock so a view can never observe half a committed batch.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass
from typing import Any, Dict, Iterable, List, Optional, Sequence

import numpy as np

from repro.core.schema import Column, Status, wq_schema

# Default claim-lease duration (seconds). Lives on the store (not the
# WorkQueue) so replicas restored from a snapshot derive the SAME
# ``expires_at = now + lease_s`` when replaying claim records — lease columns
# stay bit-identical across the wire with zero new frame fields.
DEFAULT_LEASE_S = 60.0


def _build_id_index(tid: np.ndarray) -> np.ndarray:
    """``id_to_row`` gather table: arr[task_id] == row, -1 for unknown ids."""
    hi = int(tid.max(initial=-1)) + 1
    idx = np.full(max(hi, 1), -1, np.int64)
    valid = tid >= 0
    idx[tid[valid]] = np.nonzero(valid)[0]
    return idx


class SnapshotView:
    """Immutable, internally consistent view of a store version.

    Holds references to the store's frozen column arrays (zero-copy at
    creation); exposes the read-side query API of :class:`ColumnStore` so the
    steering engine can run against either interchangeably.
    """

    def __init__(self, cols: Dict[str, np.ndarray], n_rows: int,
                 version: int, lease_s: float = DEFAULT_LEASE_S):
        self._cols = cols
        self.n_rows = n_rows
        self.version = version
        self.lease_s = float(lease_s)
        self._id_index: Optional[np.ndarray] = None

    def col(self, name: str) -> np.ndarray:
        return self._cols[name][: self.n_rows]

    def where(self, **eq) -> np.ndarray:
        mask = np.ones(self.n_rows, bool)
        for name, val in eq.items():
            mask &= self.col(name) == val
        return np.nonzero(mask)[0]

    def partition(self, worker_id: int) -> np.ndarray:
        return self.where(worker_id=worker_id)

    def device_view(self, names: Sequence[str]):
        import jax.numpy as jnp
        return {n: jnp.asarray(self.col(n)) for n in names}

    def id_index(self) -> np.ndarray:
        """``id_to_row`` gather table at this version (computed lazily once —
        the view is immutable, so no invalidation is ever needed)."""
        if self._id_index is None:
            self._id_index = _build_id_index(self.col("task_id"))
        return self._id_index

    def stats(self) -> Dict[int, int]:
        return _status_stats(self.col("status"))


def _status_stats(status: np.ndarray) -> Dict[int, int]:
    """One bincount instead of one full-column scan per Status member."""
    c = np.bincount(status, minlength=int(max(Status)) + 1)
    return {int(s): int(c[int(s)]) for s in Status}


class ColumnStore:
    def __init__(self, schema: Optional[List[Column]] = None,
                 capacity: int = 1 << 16):
        self.schema = schema or wq_schema()
        self.capacity = capacity
        self.cols: Dict[str, np.ndarray] = {
            c.name: np.full(capacity, c.default, dtype=c.dtype)
            for c in self.schema}
        self.n_rows = 0
        self.version = 0          # bumped per committed transaction
        self.lease_s = DEFAULT_LEASE_S   # claim-lease duration (schema.py)
        self.blobs: Dict[int, Dict[str, Any]] = {}   # task_id -> raw pointers
        # serializes commits against snapshot creation (snapshot isolation);
        # reentrant so insert -> _grow nests safely
        self._mu = threading.RLock()
        self._id_index: Optional[np.ndarray] = None   # task_id -> row cache
        self._id_index_rows = -1

    # --------------------------------------------------------------- writes
    def _writable(self, name: str) -> np.ndarray:
        """Column array safe to mutate: copy-on-write if a snapshot holds it."""
        arr = self.cols[name]
        if not arr.flags.writeable:
            arr = arr.copy()
            self.cols[name] = arr
        return arr

    # ------------------------------------------------------------------ rows
    def insert(self, rows: Dict[str, np.ndarray]) -> np.ndarray:
        with self._mu:
            n = len(next(iter(rows.values())))
            if self.n_rows + n > self.capacity:
                self._grow(max(self.capacity * 2, self.n_rows + n))
            idx = np.arange(self.n_rows, self.n_rows + n)
            for name, vals in rows.items():
                self._writable(name)[idx] = vals
            self.n_rows += n
            self.version += 1
            self._id_index_rows = -1
            return idx

    def _grow(self, new_cap: int):
        with self._mu:
            for c in self.schema:
                new = np.full(new_cap, c.default, dtype=c.dtype)
                new[: self.n_rows] = self.cols[c.name][: self.n_rows]
                self.cols[c.name] = new
            self.capacity = new_cap

    def update(self, idx: np.ndarray, **values) -> None:
        with self._mu:
            for name, vals in values.items():
                self._writable(name)[idx] = vals
            self.version += 1

    # --------------------------------------------------------------- queries
    def col(self, name: str) -> np.ndarray:
        return self.cols[name][: self.n_rows]

    def where(self, **eq) -> np.ndarray:
        """Row indices matching all column==value predicates."""
        mask = np.ones(self.n_rows, bool)
        for name, val in eq.items():
            mask &= self.col(name) == val
        return np.nonzero(mask)[0]

    def partition(self, worker_id: int) -> np.ndarray:
        """The paper's 'WHERE worker_id = i' partition view."""
        return self.where(worker_id=worker_id)

    def id_index(self) -> np.ndarray:
        """``id_to_row`` lookup: arr[task_id] == row, -1 for unknown ids.

        Cached per insert-generation (task_id is immutable after insert), so
        provenance walks (Q7, derivation paths) gather instead of dict-probing.
        """
        if self._id_index_rows != self.n_rows:
            self._id_index = _build_id_index(self.col("task_id"))
            self._id_index_rows = self.n_rows
        return self._id_index

    # ---------------------------------------------------------- transactions
    @contextlib.contextmanager
    def txn(self):
        """Commit boundary: writes inside the block form one atomic batch.

        Holds the commit lock across the block so ``snapshot_view`` (and other
        committers) serialize at batch granularity — a snapshot can never see
        e.g. a status flip without its matching start_time write. Nests freely
        (RLock); individual insert/update calls are single-op batches.
        """
        with self._mu:
            yield self

    # ------------------------------------------------------------ device I/O
    def device_view(self, names: Sequence[str]):
        """jnp mirror of selected columns (for steering / claim kernels)."""
        import jax.numpy as jnp
        return {n: jnp.asarray(self.col(n)) for n in names}

    # ------------------------------------------------------------- snapshots
    def snapshot_view(self) -> SnapshotView:
        """O(columns) immutable view at the current committed version.

        Freezes the live arrays; the next committed write to a frozen column
        copies it (COW), so the view keeps observing this version forever.
        """
        with self._mu:
            for name, arr in self.cols.items():
                arr.flags.writeable = False
            return SnapshotView(dict(self.cols), self.n_rows, self.version,
                                lease_s=self.lease_s)

    def snapshot(self) -> Dict[str, Any]:
        with self._mu:
            return {
                "n_rows": self.n_rows,
                "version": self.version,
                "cols": {n: self.cols[n][: self.n_rows].copy()
                         for n in self.cols},
                "blobs": dict(self.blobs),
                "lease_s": self.lease_s,
            }

    @classmethod
    def from_view(cls, view: SnapshotView,
                  schema: Optional[List[Column]] = None) -> "ColumnStore":
        """Materialize a MUTABLE store from an immutable snapshot view.

        This is the replica-side restore step of delta catch-up: copy the
        view's columns into a fresh store at the view's version, then replay
        the txn-log tail (``replication.replay``) on top. O(rows x cols)
        once at restore time; all subsequent syncs are O(delta).
        """
        st = cls(schema, capacity=max(1 << 10, int(view.n_rows * 2)))
        n = view.n_rows
        for name in st.cols:
            st.cols[name][:n] = view.col(name)
        st.n_rows = n
        st.version = view.version
        st.lease_s = getattr(view, "lease_s", DEFAULT_LEASE_S)
        return st

    def set_version(self, version: int) -> None:
        """Pin the committed version after replaying a log record.

        Replaying one record may issue several internal writes (each bumping
        ``version`` by one); aligning to the record's ``store_version``
        afterwards keeps replica versions bit-identical to the primary's, so
        version-keyed equality checks (time travel, sweep parity) hold.
        """
        with self._mu:
            self.version = int(version)

    def row_nbytes(self) -> int:
        """Bytes per row across all schema columns (full-copy cost unit)."""
        return int(sum(c.dtype.itemsize for c in self.schema))

    @classmethod
    def restore(cls, snap: Dict[str, Any],
                schema: Optional[List[Column]] = None) -> "ColumnStore":
        st = cls(schema, capacity=max(1 << 10, int(snap["n_rows"] * 2)))
        n = snap["n_rows"]
        for name, vals in snap["cols"].items():
            st.cols[name][:n] = vals
        st.n_rows = n
        st.version = snap["version"]
        st.blobs = dict(snap["blobs"])
        st.lease_s = float(snap.get("lease_s", DEFAULT_LEASE_S))
        return st

    # ------------------------------------------------------------- integrity
    def stats(self) -> Dict[int, int]:
        return _status_stats(self.col("status"))
