"""In-memory columnar store (struct-of-arrays) with partition views.

The TPU-native adaptation of the paper's MySQL-Cluster data nodes: execution /
domain / provenance columns live in ONE preallocated SoA region, hash-
partitioned by ``worker_id``. The authoritative copy is host-resident (the
control plane mutates it transactionally); hot columns mirror to the device
for analytical steering reductions and for the vectorized / Pallas claim ops.

Updates go through ``apply`` with a transaction record so the txn log
(transactions.py) can replay them on replicas and after restarts.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Iterable, List, Optional, Sequence

import numpy as np

from repro.core.schema import Column, Status, wq_schema


class ColumnStore:
    def __init__(self, schema: Optional[List[Column]] = None,
                 capacity: int = 1 << 16):
        self.schema = schema or wq_schema()
        self.capacity = capacity
        self.cols: Dict[str, np.ndarray] = {
            c.name: np.full(capacity, c.default, dtype=c.dtype)
            for c in self.schema}
        self.n_rows = 0
        self.version = 0          # bumped per committed transaction
        self.blobs: Dict[int, Dict[str, Any]] = {}   # task_id -> raw pointers

    # ------------------------------------------------------------------ rows
    def insert(self, rows: Dict[str, np.ndarray]) -> np.ndarray:
        n = len(next(iter(rows.values())))
        if self.n_rows + n > self.capacity:
            self._grow(max(self.capacity * 2, self.n_rows + n))
        idx = np.arange(self.n_rows, self.n_rows + n)
        for name, vals in rows.items():
            self.cols[name][idx] = vals
        self.n_rows += n
        self.version += 1
        return idx

    def _grow(self, new_cap: int):
        for c in self.schema:
            new = np.full(new_cap, c.default, dtype=c.dtype)
            new[: self.n_rows] = self.cols[c.name][: self.n_rows]
            self.cols[c.name] = new
        self.capacity = new_cap

    def update(self, idx: np.ndarray, **values) -> None:
        for name, vals in values.items():
            self.cols[name][idx] = vals
        self.version += 1

    # --------------------------------------------------------------- queries
    def col(self, name: str) -> np.ndarray:
        return self.cols[name][: self.n_rows]

    def where(self, **eq) -> np.ndarray:
        """Row indices matching all column==value predicates."""
        mask = np.ones(self.n_rows, bool)
        for name, val in eq.items():
            mask &= self.col(name) == val
        return np.nonzero(mask)[0]

    def partition(self, worker_id: int) -> np.ndarray:
        """The paper's 'WHERE worker_id = i' partition view."""
        return self.where(worker_id=worker_id)

    # ------------------------------------------------------------ device I/O
    def device_view(self, names: Sequence[str]):
        """jnp mirror of selected columns (for steering / claim kernels)."""
        import jax.numpy as jnp
        return {n: jnp.asarray(self.col(n)) for n in names}

    # ------------------------------------------------------------- snapshots
    def snapshot(self) -> Dict[str, Any]:
        return {
            "n_rows": self.n_rows,
            "version": self.version,
            "cols": {n: self.cols[n][: self.n_rows].copy()
                     for n in self.cols},
            "blobs": dict(self.blobs),
        }

    @classmethod
    def restore(cls, snap: Dict[str, Any],
                schema: Optional[List[Column]] = None) -> "ColumnStore":
        st = cls(schema, capacity=max(1 << 10, int(snap["n_rows"] * 2)))
        n = snap["n_rows"]
        for name, vals in snap["cols"].items():
            st.cols[name][:n] = vals
        st.n_rows = n
        st.version = snap["version"]
        st.blobs = dict(snap["blobs"])
        return st

    # ------------------------------------------------------------- integrity
    def stats(self) -> Dict[str, int]:
        status = self.col("status")
        return {int(s): int(np.sum(status == int(s))) for s in Status}
