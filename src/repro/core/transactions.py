"""Append-only transaction log: replication feed + crash recovery delta.

Every WorkQueue mutation appends a record; replicas (replication.py) consume
the tail; checkpoints persist (snapshot, log-offset) so restart = restore
snapshot + replay tail — the paper's in-memory-DBMS durability story
("in-memory data nodes with occasional on-disk checkpoints").
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Tuple

import numpy as np


@dataclass
class Txn:
    version: int
    op: str
    payload: Dict[str, Any]
    wall_time: float


class TxnLog:
    def __init__(self):
        self.records: List[Txn] = []

    def append(self, op: str, payload: Dict[str, Any]) -> int:
        v = len(self.records)
        self.records.append(Txn(v, op, _freeze(payload), time.time()))
        return v

    def tail(self, since: int) -> List[Txn]:
        return self.records[since:]

    def __len__(self) -> int:
        return len(self.records)


def _freeze(payload: Dict[str, Any]) -> Dict[str, Any]:
    out = {}
    for k, v in payload.items():
        out[k] = np.array(v, copy=True) if isinstance(v, np.ndarray) else v
    return out
