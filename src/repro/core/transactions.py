"""Append-only transaction log: replication feed + crash recovery delta.

Every WorkQueue mutation appends a record; replicas (replication.py) consume
the tail; checkpoints persist (snapshot, log-offset) so restart = restore
snapshot + replay tail — the paper's in-memory-DBMS durability story
("in-memory data nodes with occasional on-disk checkpoints").

Records carry the store version they committed at (``store_version``) so a
consumer can align the log with a :class:`~repro.core.store.SnapshotView`:
``tail_for_version(v)`` is exactly the delta to replay ON TOP of a snapshot
taken at version ``v`` — the foundation for txn-log replay onto snapshots and
multi-host replica catch-up.

Payloads are REPLAYABLE: each record carries the row indices and column
values its op wrote (the store is append-only, so primary row indices are
valid verbatim on any replica that replayed the same prefix). ``store_version``
is monotone non-decreasing across records — commits serialize on the store
lock and append inside it — so the version-aligned lookups bisect instead of
scanning the whole log.

Compaction (consumer-offset-aware truncation)
---------------------------------------------
Replayable payloads deep-copy written row data, so an unbounded log pays
~2x task-metadata memory on long runs. Consumers (checkpointer, replicas)
``register_consumer`` + ``ack`` the absolute offset they have durably
consumed; ``truncate`` drops the prefix every registered consumer is past.
Record indices are ABSOLUTE: ``base`` is the index of the first retained
record, so offsets held by consumers stay valid across truncations and
``len(log)`` keeps returning the absolute end offset. Lookups that would
need dropped records (``tail_for_version`` / ``records_between`` below the
compaction horizon) raise :class:`LogCompactedError` instead of silently
returning an incomplete delta — time-travel from genesis degrades to
"replay since the last checkpoint" (pass a base snapshot at or after the
horizon).
"""
from __future__ import annotations

import bisect
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np


class LogCompactedError(RuntimeError):
    """The requested records were dropped by ``TxnLog.truncate``.

    Raised instead of returning an INCOMPLETE delta. Recover by replaying
    from a snapshot at or after ``TxnLog.horizon_version`` (e.g. the last
    checkpoint) rather than from genesis.
    """


@dataclass
class Txn:
    version: int                     # log sequence number
    op: str
    payload: Dict[str, Any]
    wall_time: float
    store_version: int = -1          # ColumnStore.version at commit time
    # hot-plane locator: the columnar plane this record's fields were
    # accumulated into at append time, and its index there (replay slices
    # the plane instead of re-extracting payload dicts record by record)
    plane: Optional["_HotPlane"] = field(default=None, repr=False,
                                         compare=False)
    pidx: int = -1
    _nbytes: int = field(default=-1, repr=False, compare=False)

    def payload_nbytes(self) -> int:
        """Wire size of this record's payload (what delta-shipping costs):
        array bytes plus a small fixed charge per scalar field. Cached on
        first call — replicas account it once per sync."""
        if self._nbytes < 0:
            total = 0
            for v in self.payload.values():
                if isinstance(v, np.ndarray):
                    total += v.nbytes
                elif isinstance(v, dict):
                    total += sum(a.nbytes if isinstance(a, np.ndarray) else 8
                                 for a in v.values())
                else:
                    total += 8
            self._nbytes = total
        return self._nbytes


_VERSION_FLOOR = -(1 << 62)


class _GrowBuf:
    """Amortized-doubling typed append buffer (1D, or 2D row blocks).

    ``width`` distinguishes by identity, not truthiness: ``width=0`` is a
    legal 2-D buffer of zero-wide rows (a ``domain_out`` with no columns),
    and collapsing it to 1-D would crash ``shape[1]`` probes mid-append.
    """

    __slots__ = ("data", "n")

    def __init__(self, dtype, width: Optional[int] = None, cap: int = 256):
        self.data = np.empty(cap if width is None else (cap, width), dtype)
        self.n = 0

    def _grow(self, need: int) -> None:
        shape = list(self.data.shape)
        shape[0] = max(self.data.shape[0] * 2, need)
        new = np.empty(tuple(shape), self.data.dtype)
        new[: self.n] = self.data[: self.n]
        self.data = new

    def append(self, v) -> None:
        if self.n == self.data.shape[0]:
            self._grow(self.n + 1)
        self.data[self.n] = v
        self.n += 1

    def extend(self, arr) -> None:
        k = len(arr)
        need = self.n + k
        if need > self.data.shape[0]:
            self._grow(need)
        self.data[self.n: need] = arr
        self.n = need

    def view(self, lo: int, hi: int) -> np.ndarray:
        return self.data[lo: hi]

    def trim_front(self, k: int, shift=None) -> None:
        """Drop the first k valid entries (compaction), optionally
        subtracting ``shift`` from the survivors (offset re-basing).

        Allocates a FRESH buffer instead of moving data in place: views
        handed out before the trim (``slice_fields`` captures staged for
        the pipelined shipper on another thread) keep aliasing the OLD
        buffer, whose contents stay frozen — compaction must never mutate
        bytes a concurrent encoder may still be reading.
        """
        n = self.n - k
        shape = list(self.data.shape)
        new = np.empty(tuple(shape), self.data.dtype)
        if shift is None:
            new[:n] = self.data[k: self.n]
        else:
            new[:n] = self.data[k: self.n] - shift
        self.data = new
        self.n = n


class _HotPlane:
    """Columnar accumulation of one hot op's replayable fields.

    The log's dominant ops (claims, finishes) are appended thousands of
    times with tiny per-record payloads; replaying them record-at-a-time —
    or even batch-extracting the payload dicts at replay time — pays a
    per-record Python toll. The plane pays a small fixed cost at APPEND
    time instead (one typed-buffer append per field), so a consecutive run
    of records becomes O(1) array slices at replay: row indices are one
    contiguous view, per-record scalars repeat out by the segment lengths.
    ``off`` has n+1 entries (cumulative row counts); ``base`` advances on
    truncation so record ``pidx`` locators stay valid.

    Memory: the plane DUPLICATES the hot fields the frozen payload dict
    already copied (the buffers must stay contiguous across payload
    lifetimes, so they cannot alias the payload arrays; ``trim_front``
    compacts into a fresh allocation so already-captured views survive
    compaction unchanged). The overhead is ~rows*8B + ~24B/record for
    the dominant ops and is bounded by the same consumer-floor truncation
    as the record list itself.
    """

    __slots__ = ("base", "n", "off", "rows", "now", "worker",
                 "dom_off", "dom", "dom_flag")

    def __init__(self, has_worker: bool = False, has_dom: bool = False):
        self.base = 0
        self.n = 0
        self.off = _GrowBuf(np.int64)
        self.off.append(0)
        self.rows = _GrowBuf(np.int64)
        self.now = _GrowBuf(np.float64)
        self.worker = _GrowBuf(np.int32) if has_worker else None
        self.dom_off = _GrowBuf(np.int64) if has_dom else None
        if has_dom:
            self.dom_off.append(0)
        self.dom: Optional[_GrowBuf] = None       # allocated on first dom
        # 1 per entry that CARRIES domain outputs, even when a width drift
        # kept them out of the dom buffer: a run whose dom row-range is
        # empty but whose flags are not must replay via the dict path —
        # and only THAT run pays the fallback, not the whole plane
        self.dom_flag = _GrowBuf(np.int8) if has_dom else None

    def add(self, payload: Dict[str, Any]) -> int:
        """Accumulate one record's fields; returns its plane index."""
        # validate AND convert every field before the first buffer mutation:
        # a malformed payload must raise here, leaving the plane untouched —
        # a partial append would silently misalign every later run slice
        rows = np.asarray(payload["rows"], np.int64)
        if rows.ndim != 1:
            raise ValueError("plane rows must be 1-D")
        now = float(payload["now"])
        w = int(payload["worker"]) if self.worker is not None else None
        dom = payload.get("domain_out") if self.dom_off is not None else None
        if dom is not None:
            dom = np.asarray(dom, np.float64)
            if dom.ndim != 2:
                raise ValueError("plane domain_out must be 2-D")
        dwidth = dom.shape[1] if dom is not None else 0
        self.rows.extend(rows)
        self.off.append(self.rows.n)
        self.now.append(now)
        if self.worker is not None:
            self.worker.append(w)
        if self.dom_off is not None:
            if dom is not None:
                if self.dom is None:
                    self.dom = _GrowBuf(np.float64, width=dwidth)
                if dwidth == self.dom.data.shape[1]:
                    self.dom.extend(dom)
                # else: width drift — the entry's flag stays set while its
                # dom rows stay out of the buffer, so its run (and only its
                # run) replays via the dict path
            self.dom_flag.append(0 if dom is None else 1)
            self.dom_off.append(self.dom.n if self.dom is not None else 0)
        self.n += 1
        return self.base + self.n - 1

    def slice_fields(self, lo: int, hi: int) -> Dict[str, Any]:
        """Raw field views of plane entries [lo, hi) — the wire codec's
        zero-copy export (and the replay fast-path's source arrays).

        ``off``/``dom_off`` carry hi-lo+1 entries and are NOT re-based:
        consumers subtract ``off[0]`` themselves (the codec re-bases into
        the frame, replay indexes the shared buffer directly). ``dom`` is
        the 2-D output-row block for the slice's dom range, or None when
        the plane never saw a domain payload.
        """
        off = self.off.view(lo, hi + 1)
        out: Dict[str, Any] = {
            "off": off,
            "rows": self.rows.view(int(off[0]), int(off[-1])),
            "now": self.now.view(lo, hi),
        }
        if self.worker is not None:
            out["worker"] = self.worker.view(lo, hi)
        if self.dom_off is not None:
            doff = out["dom_off"] = self.dom_off.view(lo, hi + 1)
            out["dom_flag"] = self.dom_flag.view(lo, hi)
            out["dom"] = None if self.dom is None else \
                self.dom.view(int(doff[0]), int(doff[-1]))
        return out

    def truncate(self, upto_pidx: int) -> None:
        """Drop plane entries with index < upto_pidx (log compaction).

        Every buffer re-bases via ``trim_front``'s fresh-allocation path:
        views captured before the truncate stay valid against the old
        buffers (see :meth:`_GrowBuf.trim_front`).
        """
        d = min(max(upto_pidx - self.base, 0), self.n)
        if d == 0:
            return
        shift = int(self.off.data[d])
        self.rows.trim_front(shift)
        self.off.trim_front(d, shift=shift)
        self.now.trim_front(d)
        if self.worker is not None:
            self.worker.trim_front(d)
        if self.dom_off is not None:
            dshift = int(self.dom_off.data[d])
            if self.dom is not None:
                self.dom.trim_front(dshift)
            self.dom_off.trim_front(d, shift=dshift)
            self.dom_flag.trim_front(d)
        self.base += d
        self.n -= d


# hot ops get a columnar plane: (has_worker, has_dom) per op. Claims and
# finishes dominate real logs (paper Fig. 12), so these three cover the
# replay hot path; rare ops (fail, resize, steering) stay dict-payload-only.
_HOT_OPS = {
    "claim": (True, False),
    "claim_all": (False, False),
    "finish": (False, True),
}


def plane_run(recs: Sequence["Txn"]):
    """(plane, lo, hi) when a same-op run lives contiguously in one plane.

    Shared by batched replay (plane-slice fast path) and the wire codec
    (hot-frame eligibility): both must route a run to the dict-payload path
    whenever its plane entries are gone or split. Records held by a caller
    across a ``TxnLog.truncate`` may predate the plane's base — their plane
    entries were trimmed, so they must replay/encode from their (intact)
    frozen payloads; a negative offset here would silently slice the wrong
    retained entries.
    """
    first, last = recs[0], recs[-1]
    plane = first.plane
    if plane is None or last.plane is not plane \
            or last.pidx - first.pidx + 1 != len(recs) \
            or first.pidx < plane.base:
        return None
    return plane, first.pidx - plane.base, last.pidx + 1 - plane.base


class TxnLog:
    """Threading contract: record/plane MUTATION (append, truncate) and
    record READS (tail/slice/tail_for_version/replay over plane views)
    belong to the producer thread — the WorkQueue appends inside the store
    commit lock and the executor truncates between ticks on that same
    thread. Only the CONSUMER-OFFSET map is cross-thread safe
    (``_consumers_mu``): the async checkpoint writer acks from its own
    thread after the durable publish.
    """

    def __init__(self):
        self.records: List[Txn] = []
        # absolute index of records[0]: truncate drops the consumed prefix
        # and advances base, so consumer offsets / record.version stay valid
        self.base = 0
        # max store_version among DROPPED records: deltas anchored strictly
        # below this horizon are incomplete and raise LogCompactedError
        self.horizon_version = _VERSION_FLOOR
        self._consumers: Dict[str, int] = {}
        # acks arrive from other threads (the checkpointer's async writer
        # acks after its atomic publish) while truncate/consumer_floor read
        # the map on the producer thread — serialize map access
        self._consumers_mu = threading.Lock()
        self._planes: Dict[str, _HotPlane] = {}
        # bisect in tail_for_version needs records sorted by store_version;
        # WorkQueue appends inside the commit lock so this always holds, but
        # a raw append() with an out-of-order version flips the flag and the
        # lookups fall back to the filter scan instead of mis-bisecting
        self._monotone = True
        self._max_store_version = _VERSION_FLOOR

    def append(self, op: str, payload: Dict[str, Any],
               store_version: int = -1) -> int:
        v = self.base + len(self.records)
        rec = Txn(v, op, _freeze(payload), time.time(), store_version)
        hot = _HOT_OPS.get(op)
        if hot is not None:
            plane = self._planes.get(op)
            if plane is None:
                plane = self._planes[op] = _HotPlane(*hot)
            try:
                rec.pidx = plane.add(rec.payload)
                rec.plane = plane
            except (KeyError, AttributeError, IndexError, TypeError,
                    ValueError):
                pass        # raw append with a nonstandard payload: the
                            # record replays through the dict path instead
        self.records.append(rec)
        if store_version < self._max_store_version:
            self._monotone = False
        else:
            self._max_store_version = store_version
        return v

    # ------------------------------------------------------------ consumers
    def register_consumer(self, name: str, offset: Optional[int] = None
                          ) -> int:
        """Declare a consumer that still needs records from ``offset`` on
        (default: the current compaction base). ``truncate`` never drops a
        record any registered consumer has not acked past."""
        off = self.base if offset is None else max(int(offset), self.base)
        with self._consumers_mu:
            self._consumers[name] = off
        return off

    def ack(self, name: str, offset: int) -> bool:
        """Record that ``name`` has durably consumed everything before
        ``offset`` (absolute). Consumption only moves forward. Safe to call
        from any thread (the async checkpoint writer does). Unknown names —
        never registered, or released by ``unregister_consumer`` — are
        IGNORED (returns False): an ack must never resurrect a consumer and
        re-pin the compaction floor."""
        with self._consumers_mu:
            if name not in self._consumers:
                return False
            self._consumers[name] = max(self._consumers[name], int(offset))
            return True

    def unregister_consumer(self, name: str) -> None:
        with self._consumers_mu:
            self._consumers.pop(name, None)

    def has_consumer(self, name: str) -> bool:
        with self._consumers_mu:
            return name in self._consumers

    def consumer_floor(self) -> Optional[int]:
        """Smallest acked offset across registered consumers (None if no
        consumer is registered — then truncate without an explicit bound
        is a no-op, the conservative default). With an N-replica group
        each member is its own consumer, so this IS the min-over-group
        truncate floor: a lagging replica pins exactly its unconsumed
        prefix."""
        with self._consumers_mu:
            return min(self._consumers.values()) if self._consumers else None

    def consumer_offsets(self) -> Dict[str, int]:
        """Snapshot of every registered consumer's acked offset (copy) —
        the fabric's per-replica lag bookkeeping reads this, it never
        reaches into the map."""
        with self._consumers_mu:
            return dict(self._consumers)

    def truncate(self, upto: Optional[int] = None) -> int:
        """Drop the consumed prefix: records with absolute index below
        min(every registered consumer's acked offset[, ``upto``]).

        Advances ``base`` and ``horizon_version`` so later version-aligned
        lookups below the horizon fail loudly (LogCompactedError) instead of
        replaying an incomplete delta. With no registered consumers and no
        explicit ``upto`` this is a no-op. Returns #records dropped.
        """
        floor = self.consumer_floor()
        if upto is not None:
            floor = upto if floor is None else min(floor, int(upto))
        if floor is None or floor <= self.base:
            return 0
        drop = min(int(floor), self.base + len(self.records)) - self.base
        if drop <= 0:
            return 0
        dropped = self.records[:drop]
        self.horizon_version = max(self.horizon_version,
                                   max(r.store_version for r in dropped))
        # trim each hot plane past its last dropped entry so plane memory
        # is bounded by the same consumer floor as the record list
        plane_cut: Dict[str, int] = {}
        for r in dropped:
            if r.plane is not None:
                plane_cut[r.op] = r.pidx + 1
        for op, cut in plane_cut.items():
            self._planes[op].truncate(cut)
        del self.records[:drop]
        self.base += drop
        return drop

    # --------------------------------------------------------------- reads
    def _check_not_compacted(self, abs_index: int) -> None:
        if abs_index < self.base:
            raise LogCompactedError(
                f"log records [{abs_index}, {self.base}) were truncated; "
                f"replay from a snapshot at version >= {self.horizon_version}"
                " (the last checkpoint) instead")

    def tail(self, since: int) -> List[Txn]:
        self._check_not_compacted(since)
        return self.records[since - self.base:]

    def slice(self, lo: int, hi: int) -> List[Txn]:
        """Records with absolute index in [lo, hi)."""
        self._check_not_compacted(lo)
        return self.records[lo - self.base: max(hi, lo) - self.base]

    def _check_horizon(self, store_version: int) -> None:
        """A delta anchored strictly below the compaction horizon would be
        missing truncated records — fail loudly, never return it."""
        if store_version < self.horizon_version:
            raise LogCompactedError(
                f"delta since store version {store_version} is incomplete: "
                f"records up to version {self.horizon_version} were "
                "truncated; anchor at the last checkpoint instead")

    def index_after_version(self, store_version: int) -> int:
        """ABSOLUTE index of the first record with ``store_version`` strictly
        greater than the argument — O(log n) bisect over the monotone version
        column. Raises LogCompactedError when records at that boundary were
        truncated (the delta anchored there is no longer complete)."""
        self._check_horizon(store_version)
        if not self._monotone:
            for i, r in enumerate(self.records):
                if r.store_version > store_version:
                    return self.base + i
            return self.base + len(self.records)
        return self.base + bisect.bisect_right(
            self.records, store_version, key=lambda r: r.store_version)

    def tail_for_version(self, store_version: int) -> List[Txn]:
        """Records committed strictly after a store version (snapshot delta).

        O(log n) bisect to the start index — records are monotone in
        ``store_version`` for any log fed through the WorkQueue (appends
        happen inside the commit lock); a log made non-monotone by raw
        appends falls back to the O(n) filter scan this replaces.
        """
        if not self._monotone:
            self._check_horizon(store_version)
            return [r for r in self.records
                    if r.store_version > store_version]
        return self.records[self.index_after_version(store_version)
                            - self.base:]

    def records_between(self, after_version: int, upto_version: int
                        ) -> List[Txn]:
        """Records with ``after_version < store_version <= upto_version`` —
        the bounded delta between two snapshot versions (time travel)."""
        if not self._monotone:
            self._check_horizon(after_version)
            return [r for r in self.records
                    if after_version < r.store_version <= upto_version]
        lo = self.index_after_version(after_version)
        hi = self.index_after_version(upto_version)
        return self.records[lo - self.base: hi - self.base]

    def __len__(self) -> int:
        """Absolute end offset (total records ever appended) — unchanged by
        truncation, so lag/offset arithmetic survives compaction."""
        return self.base + len(self.records)

    @property
    def n_retained(self) -> int:
        """Records currently held in memory (what compaction bounds)."""
        return len(self.records)


def _freeze(payload: Dict[str, Any]) -> Dict[str, Any]:
    out = {}
    for k, v in payload.items():
        if isinstance(v, np.ndarray):
            out[k] = np.array(v, copy=True)
        elif isinstance(v, dict):
            out[k] = {kk: (np.array(vv, copy=True)
                           if isinstance(vv, np.ndarray) else vv)
                      for kk, vv in v.items()}
        else:
            out[k] = v
    return out
