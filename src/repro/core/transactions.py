"""Append-only transaction log: replication feed + crash recovery delta.

Every WorkQueue mutation appends a record; replicas (replication.py) consume
the tail; checkpoints persist (snapshot, log-offset) so restart = restore
snapshot + replay tail — the paper's in-memory-DBMS durability story
("in-memory data nodes with occasional on-disk checkpoints").

Records carry the store version they committed at (``store_version``) so a
consumer can align the log with a :class:`~repro.core.store.SnapshotView`:
``tail_for_version(v)`` is exactly the delta to replay ON TOP of a snapshot
taken at version ``v`` — the foundation for txn-log replay onto snapshots and
multi-host replica catch-up.

Payloads are REPLAYABLE: each record carries the row indices and column
values its op wrote (the store is append-only, so primary row indices are
valid verbatim on any replica that replayed the same prefix). ``store_version``
is monotone non-decreasing across records — commits serialize on the store
lock and append inside it — so the version-aligned lookups bisect instead of
scanning the whole log.
"""
from __future__ import annotations

import bisect
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np


@dataclass
class Txn:
    version: int                     # log sequence number
    op: str
    payload: Dict[str, Any]
    wall_time: float
    store_version: int = -1          # ColumnStore.version at commit time

    def payload_nbytes(self) -> int:
        """Wire size of this record's payload (what delta-shipping costs):
        array bytes plus a small fixed charge per scalar field."""
        total = 0
        for v in self.payload.values():
            if isinstance(v, np.ndarray):
                total += v.nbytes
            elif isinstance(v, dict):
                total += sum(a.nbytes if isinstance(a, np.ndarray) else 8
                             for a in v.values())
            else:
                total += 8
        return total


class TxnLog:
    def __init__(self):
        self.records: List[Txn] = []
        # bisect in tail_for_version needs records sorted by store_version;
        # WorkQueue appends inside the commit lock so this always holds, but
        # a raw append() with an out-of-order version flips the flag and the
        # lookups fall back to the filter scan instead of mis-bisecting
        self._monotone = True
        self._max_store_version = -(1 << 62)

    def append(self, op: str, payload: Dict[str, Any],
               store_version: int = -1) -> int:
        v = len(self.records)
        self.records.append(Txn(v, op, _freeze(payload), time.time(),
                                store_version))
        if store_version < self._max_store_version:
            self._monotone = False
        else:
            self._max_store_version = store_version
        return v

    def tail(self, since: int) -> List[Txn]:
        return self.records[since:]

    def index_after_version(self, store_version: int) -> int:
        """First record index with ``store_version`` strictly greater than
        the argument — O(log n) bisect over the monotone version column."""
        if not self._monotone:
            for i, r in enumerate(self.records):
                if r.store_version > store_version:
                    return i
            return len(self.records)
        return bisect.bisect_right(self.records, store_version,
                                   key=lambda r: r.store_version)

    def tail_for_version(self, store_version: int) -> List[Txn]:
        """Records committed strictly after a store version (snapshot delta).

        O(log n) bisect to the start index — records are monotone in
        ``store_version`` for any log fed through the WorkQueue (appends
        happen inside the commit lock); a log made non-monotone by raw
        appends falls back to the O(n) filter scan this replaces.
        """
        if not self._monotone:
            return [r for r in self.records
                    if r.store_version > store_version]
        return self.records[self.index_after_version(store_version):]

    def records_between(self, after_version: int, upto_version: int
                        ) -> List[Txn]:
        """Records with ``after_version < store_version <= upto_version`` —
        the bounded delta between two snapshot versions (time travel)."""
        if not self._monotone:
            return [r for r in self.records
                    if after_version < r.store_version <= upto_version]
        lo = self.index_after_version(after_version)
        hi = self.index_after_version(upto_version)
        return self.records[lo:hi]

    def __len__(self) -> int:
        return len(self.records)


def _freeze(payload: Dict[str, Any]) -> Dict[str, Any]:
    out = {}
    for k, v in payload.items():
        if isinstance(v, np.ndarray):
            out[k] = np.array(v, copy=True)
        elif isinstance(v, dict):
            out[k] = {kk: (np.array(vv, copy=True)
                           if isinstance(vv, np.ndarray) else vv)
                      for kk, vv in v.items()}
        else:
            out[k] = v
    return out
