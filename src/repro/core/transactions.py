"""Append-only transaction log: replication feed + crash recovery delta.

Every WorkQueue mutation appends a record; replicas (replication.py) consume
the tail; checkpoints persist (snapshot, log-offset) so restart = restore
snapshot + replay tail — the paper's in-memory-DBMS durability story
("in-memory data nodes with occasional on-disk checkpoints").

Records carry the store version they committed at (``store_version``) so a
consumer can align the log with a :class:`~repro.core.store.SnapshotView`:
``tail_for_version(v)`` is exactly the delta to replay ON TOP of a snapshot
taken at version ``v`` — the foundation for txn-log replay onto snapshots and
multi-host replica catch-up.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np


@dataclass
class Txn:
    version: int                     # log sequence number
    op: str
    payload: Dict[str, Any]
    wall_time: float
    store_version: int = -1          # ColumnStore.version at commit time


class TxnLog:
    def __init__(self):
        self.records: List[Txn] = []

    def append(self, op: str, payload: Dict[str, Any],
               store_version: int = -1) -> int:
        v = len(self.records)
        self.records.append(Txn(v, op, _freeze(payload), time.time(),
                                store_version))
        return v

    def tail(self, since: int) -> List[Txn]:
        return self.records[since:]

    def tail_for_version(self, store_version: int) -> List[Txn]:
        """Records committed strictly after a store version (snapshot delta)."""
        return [r for r in self.records if r.store_version > store_version]

    def __len__(self) -> int:
        return len(self.records)


def _freeze(payload: Dict[str, Any]) -> Dict[str, Any]:
    out = {}
    for k, v in payload.items():
        out[k] = np.array(v, copy=True) if isinstance(v, np.ndarray) else v
    return out
