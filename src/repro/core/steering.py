"""Steering engine: the paper's runtime analytical queries (Table 2) + the
dynamic adaptations they enable (Q8 / data reduction).

Q1-Q6 analyze execution metadata, Q7 joins execution + provenance + domain
data, Q8 *adapts* the workflow (patches inputs of READY tasks).

HTAP isolation: analytical queries execute against an immutable
:class:`~repro.core.store.SnapshotView` (``run_all`` pins one snapshot for
the whole sweep), so a sweep observes ONE committed store version while
claims keep mutating the live arrays concurrently — no READY/RUNNING
double-counts across queries, the consistency half of the paper's
single-database argument. Q8 and prune are transactions: they always write
the LIVE store (reading their predicates live too), never a snapshot.

``device_qN`` variants run the same reduction with jnp on the device mirror
(used by the benchmark that measures steering overhead on-accelerator).
"""
from __future__ import annotations

import contextlib
import threading
import time
from typing import Callable, Dict, Optional, Tuple, Union

import numpy as np

from repro.core.schema import Status
from repro.core.store import SnapshotView
from repro.core.workqueue import WorkQueue


# Q7 join parameters — one definition shared by the single-primary query
# (q7_provenance_join defaults), the distributed partial sweep below, and
# ShardRouter's merge, so every sweep path answers the same question.
Q7_ACT_A, Q7_ACT_B, Q7_THR = 0, 2, 0.5


def sweep_partials(view: SnapshotView, num_workers: int, now: float,
                   horizon: float = 60.0) -> Dict[str, object]:
    """Per-shard half of the distributed Q1-Q7 sweep: PURE and picklable.

    Reduces one pinned snapshot to the partial aggregates
    ``ShardRouter.merge_partials`` combines into the single-primary result
    — Q1/Q3 per-worker bincount slabs, the Q4 open count, Q5/Q6 segment
    partials, Q7's duration sum/count, and the COMPACTED ancestry inputs
    (ids/activity/parent/pruned of every materialized row, plus the
    pre-mean Q7 candidate hits) the cross-shard provenance walk needs.
    Rows are compact indices into the ``anc_*`` arrays, not store rows, so
    a partial computed inside a replica process merges bit-identically
    with one computed from a local view: nothing here depends on where
    the snapshot lives. Every numpy reduction matches the single-primary
    queries op-for-op — that is what keeps the merged result bit-identical
    (dyadic times assumed, as everywhere in the parity drills).
    """
    st = view.col("status")
    wid = view.col("worker_id")
    t0 = view.col("start_time")
    t1 = view.col("end_time")
    act = view.col("activity_id")
    L = int(num_workers)
    empty_i = np.zeros(0, np.int64)
    p: Dict[str, object] = {
        "n_workers": L, "version": int(view.version),
        "started": np.zeros(L, np.int64),
        "finished": np.zeros(L, np.int64),
        "failures": np.zeros(L, np.int64),
        "fail_counts": np.zeros(L, np.int64),
        "q5_counts": empty_i,
        "q6_cnt": empty_i, "q6_sum": np.zeros(0, np.float64),
        "q6_max": np.zeros(0, np.float64),
        "q7_sum": 0.0, "q7_cnt": 0, "q7_any": False,
    }
    # Q1 slab: recent rows bucketed by local worker id
    recent = (t0 >= now - horizon) & (st != int(Status.EMPTY))
    rw = wid[recent]
    if rw.size:
        p["started"] = np.bincount(rw, minlength=L)
        p["finished"] = np.bincount(
            rw, weights=(st[recent] == int(Status.FINISHED)),
            minlength=L).astype(np.int64)
        p["failures"] = np.bincount(
            rw, weights=view.col("fail_trials")[recent],
            minlength=L).astype(np.int64)
    # Q3 slab: FAILED-recently counts per local worker
    m3 = (st == int(Status.FAILED)) & (t1 >= now - horizon)
    if m3.any():
        p["fail_counts"] = np.bincount(wid[m3], minlength=L)
    # Q4 / Q5: open rows
    mo = np.isin(st, [int(Status.READY), int(Status.RUNNING),
                      int(Status.BLOCKED)])
    p["q4"] = int(mo.sum())
    if mo.any():
        p["q5_counts"] = np.bincount(act[mo])
    # Q6 partials per activity: finished count / duration sum / max
    fin = st == int(Status.FINISHED)
    p["q6_open"] = np.unique(act[np.isin(
        st, [int(Status.READY), int(Status.RUNNING)])])
    af = act[fin]
    if af.size:
        d = t1[fin] - t0[fin]
        n_act = int(af.max()) + 1
        p["q6_cnt"] = np.bincount(af, minlength=n_act)
        p["q6_sum"] = np.bincount(af, weights=d, minlength=n_act)
        q6_max = np.full(n_act, -np.inf)
        np.maximum.at(q6_max, af, d)
        p["q6_max"] = q6_max
    # Q7 scalar partials: duration sum/count over finished act_b rows
    # (the global mean only exists at merge time)
    fb = fin & (act == Q7_ACT_B)
    if fb.any():
        db = (t1 - t0)[fb]
        p["q7_any"] = True
        p["q7_sum"] = float(np.nansum(db))
        p["q7_cnt"] = int((~np.isnan(db)).sum())
    # ancestry inputs: every materialized row, order-preserving compaction
    # (PRUNED tombstones included — live rows shadow them at merge)
    sel = st != int(Status.EMPTY)
    p["anc_ids"] = view.col("task_id")[sel]
    p["anc_act"] = act[sel]
    p["anc_parent"] = view.col("parent_task")[sel]
    p["anc_pruned"] = st[sel] == int(Status.PRUNED)
    # Q7 candidate hits as COMPACT indices, durations kept for the
    # merge-time global-mean filter
    c_st = st[sel]
    c_act = act[sel]
    cand = (c_st == int(Status.FINISHED)) & (c_act == Q7_ACT_B) \
        & (view.col("out0")[sel] > Q7_THR)
    p["hit_idx"] = np.nonzero(cand)[0].astype(np.int64)
    p["hit_dur"] = (t1 - t0)[sel][cand]
    return p


class SteeringEngine:
    def __init__(self, wq: WorkQueue, *, use_snapshots: bool = True):
        self.wq = wq
        self.use_snapshots = use_snapshots
        # the pinned view is THREAD-LOCAL: an analyst thread's sweep must not
        # leak its snapshot into live queries issued from other threads
        self._tls = threading.local()

    # --------------------------------------------------------------- helpers
    def _store(self):
        """Read-side source: the snapshot pinned by this thread's sweep, else
        the live store (single queries are trivially consistent)."""
        view = getattr(self._tls, "view", None)
        return view if view is not None else self.wq.store

    def _cols(self, *names):
        v = self._store()
        return tuple(v.col(n) for n in names)

    @contextlib.contextmanager
    def snapshot_scope(self, view: Optional[SnapshotView] = None):
        """Pin all queries in the block (on this thread) to one version."""
        prev = getattr(self._tls, "view", None)
        self._tls.view = view if view is not None \
            else self.wq.store.snapshot_view()
        try:
            yield self._tls.view
        finally:
            self._tls.view = prev

    # ---------------------------------------------------------- time travel
    def at_version(self, version: int,
                   base: Optional[SnapshotView] = None) -> SnapshotView:
        """Pin a sweep to ANY historical committed version.

        Rebuilds an immutable view of the store as of ``version`` by
        snapshot-restore + bounded txn-log replay: start from ``base`` (any
        snapshot at a version <= the target; an empty store when omitted) and
        replay exactly the log records in ``(base.version, version]`` — the
        two boundaries are bisected, the replay is O(delta). Pass the result
        as ``run_all(now, view=...)`` (or to ``snapshot_scope``) to run the
        whole Q1-Q7 sweep against history.

        Requires every mutation since ``base`` to have gone through the
        logged WorkQueue/steering API (true for the executor and simkit
        paths); raw ``store.update`` calls are invisible to the log and
        cannot be time-traveled. Once ``TxnLog.truncate`` has compacted the
        consumed prefix, genesis replay degrades to "since the last
        checkpoint": pass a ``base`` snapshot at or after the log's
        compaction horizon (e.g. the checkpointed store) or this raises
        :class:`~repro.core.transactions.LogCompactedError`.
        """
        from repro.core.replication import replay
        from repro.core.transactions import LogCompactedError
        live = self.wq.store
        if version > live.version:
            raise ValueError(f"version {version} is in the future "
                             f"(live store is at {live.version})")
        if base is not None and base.version > version:
            raise ValueError(f"base snapshot v{base.version} is newer than "
                             f"target v{version}")
        if base is None:
            store = type(live)(live.schema, capacity=1 << 10)
            after = store.version            # 0: replay the log from genesis
        else:
            store = type(live).from_view(base, live.schema)
            after = base.version
        try:
            delta = self.wq.log.records_between(after, version)
        except LogCompactedError as e:
            raise LogCompactedError(
                f"cannot time-travel to v{version} from "
                f"{'genesis' if base is None else f'base v{base.version}'}: "
                f"{e}") from None
        replay(store, delta)
        store.set_version(version)
        return store.snapshot_view()

    # Q1: per-node task status counts within the last minute
    def q1_recent_status_by_node(self, now: float, horizon: float = 60.0
                                 ) -> Dict[int, Dict[str, int]]:
        """Loop-free sweep: one segment reduction (bincount over the worker
        ids of the recent rows) per metric, instead of re-masking the whole
        store once per distinct worker."""
        st, wid, t0 = self._cols("status", "worker_id", "start_time")
        recent = (t0 >= now - horizon) & (st != int(Status.EMPTY))
        fails = self._store().col("fail_trials")
        rw = wid[recent]
        if not rw.size:
            return {}
        workers, inv = np.unique(rw, return_inverse=True)
        started = np.bincount(inv)
        finished = np.bincount(
            inv, weights=(st[recent] == int(Status.FINISHED)))
        failures = np.bincount(inv, weights=fails[recent])
        return {int(w): {"started": int(s), "finished": int(f),
                         "failures": int(x)}
                for w, s, f, x in zip(workers, started, finished, failures)}

    # Q2: per-task bytes consumed on a node, finished in last minute
    def q2_bytes_by_task(self, worker: int, now: float, horizon: float = 60.0
                         ) -> np.ndarray:
        st, wid, te, bi = self._cols("status", "worker_id", "end_time",
                                     "bytes_in")
        m = (wid == worker) & (st == int(Status.FINISHED)) \
            & (te >= now - horizon)
        idx = np.nonzero(m)[0]
        # every selected row is FINISHED, so the old lexsort's status
        # tie-break key was dead weight: plain stable argsort on -bytes_in
        # yields the identical permutation with one key pass
        order = np.argsort(-bi[idx], kind="stable")
        return idx[order]

    # Q3: node(s) with most aborted/failed in last minute
    def q3_worst_nodes(self, now: float, horizon: float = 60.0) -> np.ndarray:
        st, wid, te = self._cols("status", "worker_id", "end_time")
        m = (st == int(Status.FAILED)) & (te >= now - horizon)
        if not m.any():
            return np.empty(0, np.int64)
        counts = np.bincount(wid[m], minlength=self.wq.num_workers)
        return np.nonzero(counts == counts.max())[0]

    # Q4: tasks left
    def q4_tasks_left(self) -> int:
        st = self._store().col("status")
        return int(np.isin(st, [int(Status.READY), int(Status.RUNNING),
                                int(Status.BLOCKED)]).sum())

    # Q5: activity with most unfinished tasks
    def q5_worst_activity(self) -> Tuple[int, int]:
        st, act = self._cols("status", "activity_id")
        m = np.isin(st, [int(Status.READY), int(Status.RUNNING),
                         int(Status.BLOCKED)])
        if not m.any():
            return -1, 0
        counts = np.bincount(act[m])
        return int(np.argmax(counts)), int(counts.max())

    # Q6: avg/max exec time per unfinished activity
    def q6_activity_times(self) -> Dict[int, Tuple[float, float]]:
        """Loop-free sweep: per-activity mean via bincount segment sums and
        per-activity max via sorted-segment ``maximum.reduceat`` — one sort
        of the finished rows replaces a full-store re-mask per open
        activity."""
        st, act, t0, t1 = self._cols("status", "activity_id", "start_time",
                                     "end_time")
        fin = st == int(Status.FINISHED)
        open_acts = np.unique(act[np.isin(
            st, [int(Status.READY), int(Status.RUNNING)])])
        af = act[fin]
        if not (af.size and open_acts.size):
            return {}
        d = t1[fin] - t0[fin]
        order = np.argsort(af, kind="stable")
        sa, sd = af[order], d[order]
        starts = np.nonzero(np.r_[True, sa[1:] != sa[:-1]])[0]
        seg_act = sa[starts]
        seg_cnt = np.diff(np.r_[starts, sa.size])
        seg_sum = np.add.reduceat(sd, starts)
        seg_max = np.maximum.reduceat(sd, starts)
        keep = np.isin(seg_act, open_acts)
        out = {int(a): (float(s / c), float(m))
               for a, s, c, m in zip(seg_act[keep], seg_sum[keep],
                                     seg_cnt[keep], seg_max[keep])}
        return dict(sorted(out.items(), key=lambda kv: -kv[1][0]))

    # Q7: provenance join — outputs of activity A where activity B's f1 > thr
    # and B's task took longer than B's average
    def q7_provenance_join(self, act_a: int = 0, act_b: int = 2,
                           thr: float = 0.5) -> np.ndarray:
        """Vectorized provenance walk: all hits step one parent edge per
        pass via the precomputed id->row index (O(depth) gathers instead of
        a Python while-loop per hit)."""
        v = self._store()
        st, act, t0, t1 = self._cols("status", "activity_id", "start_time",
                                     "end_time")
        f1 = v.col("out0")
        parent = v.col("parent_task")
        fin_b = (st == int(Status.FINISHED)) & (act == act_b)
        if not fin_b.any():
            return np.empty(0, np.int64)
        dur = t1 - t0
        slow = dur > np.nanmean(dur[fin_b])
        hits = np.nonzero(fin_b & (f1 > thr) & slow)[0]
        if not len(hits):
            return np.empty(0, np.int64)
        id_to_row = v.id_index()
        cur = hits.astype(np.int64)
        while True:
            safe = np.maximum(cur, 0)
            walk = (cur >= 0) & (act[safe] > act_a) & (parent[safe] >= 0)
            if not walk.any():
                break
            pid = parent[cur[walk]]
            inb = pid < id_to_row.shape[0]
            cur[walk] = np.where(
                inb, id_to_row[np.minimum(pid, id_to_row.shape[0] - 1)], -1)
        ok = (cur >= 0) & (act[np.maximum(cur, 0)] == act_a)
        return cur[ok]

    # Q8: ADAPT — patch inputs of READY tasks of an activity (user steering)
    def q8_patch_ready(self, activity: int, col: str, value: float,
                       predicate: Optional[Callable[[np.ndarray], np.ndarray]]
                       = None) -> int:
        store = self.wq.store                 # transactional: live store only
        with store.txn():
            st = store.col("status")
            act = store.col("activity_id")
            m = (st == int(Status.READY)) & (act == activity)
            if predicate is not None:
                m &= predicate(store.col(col))
            idx = np.nonzero(m)[0]
            if len(idx):
                store.update(idx, **{col: value})
                self.wq.log.append("steer_patch",
                                   {"activity": activity, "col": col,
                                    "n": len(idx), "rows": idx,
                                    "value": float(value)},
                                   store_version=store.version)
        return len(idx)

    # data reduction (paper [49]): prune READY/BLOCKED tasks by predicate
    def prune(self, predicate_col: str, lo: float, hi: float) -> int:
        store = self.wq.store                 # transactional: live store only
        with store.txn():
            st = store.col("status")
            vals = store.col(predicate_col)
            m = np.isin(st, [int(Status.READY), int(Status.BLOCKED)]) \
                & (vals >= lo) & (vals <= hi)
            idx = np.nonzero(m)[0]
            # the status write (and its ready-count + txn-log bookkeeping)
            # belongs to the WorkQueue; steering only owns the predicate
            return self.wq.prune(idx)

    # ------------------------------------------------------------ on-device
    def device_monitor(self) -> Dict[str, float]:
        """Same aggregations with jnp over the device mirror (HTAP on-chip).

        The mirror is cut from the pinned snapshot when inside a sweep, so
        on-device analytics see the same version as the host queries.
        """
        import jax.numpy as jnp
        dv = self._store().device_view(["status", "worker_id", "start_time",
                                        "end_time"])
        st = dv["status"]
        fin = (st == int(Status.FINISHED))
        run = (st == int(Status.RUNNING))
        dur = jnp.where(fin, dv["end_time"] - dv["start_time"], 0.0)
        return {
            "finished": int(fin.sum()),
            "running": int(run.sum()),
            "mean_task_s": float(dur.sum() / jnp.maximum(fin.sum(), 1)),
        }

    def run_all(self, now: float,
                view: Optional[SnapshotView] = None) -> Dict[str, object]:
        """One steering sweep (the paper runs the full set every 15 s).

        The whole sweep executes against ONE snapshot version (pass ``view``
        to analyze a snapshot taken earlier, e.g. mid-claim); claims proceed
        on the live store concurrently.
        """
        if view is not None or self.use_snapshots:
            ctx = self.snapshot_scope(view)
        else:
            ctx = contextlib.nullcontext(self.wq.store)
        with ctx as v:
            return {
                "q1": self.q1_recent_status_by_node(now),
                "q3": self.q3_worst_nodes(now).tolist(),
                "q4": self.q4_tasks_left(),
                "q5": self.q5_worst_activity(),
                "q6": self.q6_activity_times(),
                "q7": self.q7_provenance_join().tolist(),
                "version": getattr(v, "version", self.wq.store.version),
            }
