"""Steering engine: the paper's runtime analytical queries (Table 2) + the
dynamic adaptations they enable (Q8 / data reduction).

Q1-Q6 analyze execution metadata, Q7 joins execution + provenance + domain
data, Q8 *adapts* the workflow (patches inputs of READY tasks). All queries
are vectorized reductions over the live column store — the HTAP design the
paper argues for: same store, transactional claims + analytical scans.

``device_qN`` variants run the same reduction with jnp on the device mirror
(used by the benchmark that measures steering overhead on-accelerator).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.core.schema import Status
from repro.core.workqueue import WorkQueue


class SteeringEngine:
    def __init__(self, wq: WorkQueue):
        self.wq = wq

    # --------------------------------------------------------------- helpers
    def _cols(self, *names):
        return tuple(self.wq.store.col(n) for n in names)

    # Q1: per-node task status counts within the last minute
    def q1_recent_status_by_node(self, now: float, horizon: float = 60.0
                                 ) -> Dict[int, Dict[str, int]]:
        st, wid, t0 = self._cols("status", "worker_id", "start_time")
        recent = (t0 >= now - horizon) & (st != int(Status.EMPTY))
        out: Dict[int, Dict[str, int]] = {}
        for w in np.unique(wid[recent]):
            m = recent & (wid == w)
            out[int(w)] = {
                "started": int(m.sum()),
                "finished": int((st[m] == int(Status.FINISHED)).sum()),
                "failures": int(self.wq.store.col("fail_trials")[m].sum()),
            }
        return out

    # Q2: per-task bytes consumed on a node, finished in last minute
    def q2_bytes_by_task(self, worker: int, now: float, horizon: float = 60.0
                         ) -> np.ndarray:
        st, wid, te, bi = self._cols("status", "worker_id", "end_time",
                                     "bytes_in")
        m = (wid == worker) & (st == int(Status.FINISHED)) \
            & (te >= now - horizon)
        idx = np.nonzero(m)[0]
        order = np.lexsort((st[idx], -bi[idx]))
        return idx[order]

    # Q3: node(s) with most aborted/failed in last minute
    def q3_worst_nodes(self, now: float, horizon: float = 60.0) -> np.ndarray:
        st, wid, te = self._cols("status", "worker_id", "end_time")
        m = (st == int(Status.FAILED)) & (te >= now - horizon)
        if not m.any():
            return np.empty(0, np.int64)
        counts = np.bincount(wid[m], minlength=self.wq.num_workers)
        return np.nonzero(counts == counts.max())[0]

    # Q4: tasks left
    def q4_tasks_left(self) -> int:
        st = self.wq.store.col("status")
        return int(np.isin(st, [int(Status.READY), int(Status.RUNNING),
                                int(Status.BLOCKED)]).sum())

    # Q5: activity with most unfinished tasks
    def q5_worst_activity(self) -> Tuple[int, int]:
        st, act = self._cols("status", "activity_id")
        m = np.isin(st, [int(Status.READY), int(Status.RUNNING),
                         int(Status.BLOCKED)])
        if not m.any():
            return -1, 0
        counts = np.bincount(act[m])
        return int(np.argmax(counts)), int(counts.max())

    # Q6: avg/max exec time per unfinished activity
    def q6_activity_times(self) -> Dict[int, Tuple[float, float]]:
        st, act, t0, t1 = self._cols("status", "activity_id", "start_time",
                                     "end_time")
        fin = st == int(Status.FINISHED)
        open_acts = np.unique(act[np.isin(
            st, [int(Status.READY), int(Status.RUNNING)])])
        out = {}
        for a in open_acts:
            m = fin & (act == a)
            if m.any():
                d = t1[m] - t0[m]
                out[int(a)] = (float(d.mean()), float(d.max()))
        return dict(sorted(out.items(), key=lambda kv: -kv[1][0]))

    # Q7: provenance join — outputs of activity A where activity B's f1 > thr
    # and B's task took longer than B's average
    def q7_provenance_join(self, act_a: int = 0, act_b: int = 2,
                           thr: float = 0.5) -> np.ndarray:
        st, act, t0, t1 = self._cols("status", "activity_id", "start_time",
                                     "end_time")
        f1 = self.wq.store.col("out0")
        parent = self.wq.store.col("parent_task")
        tid = self.wq.store.col("task_id")
        fin_b = (st == int(Status.FINISHED)) & (act == act_b)
        if not fin_b.any():
            return np.empty(0, np.int64)
        dur = t1 - t0
        slow = dur > np.nanmean(dur[fin_b])
        hits = np.nonzero(fin_b & (f1 > thr) & slow)[0]
        # walk provenance edges back to activity A
        out = []
        id_to_row = {int(t): i for i, t in enumerate(tid[: len(st)])}
        for row in hits:
            r = int(row)
            while act[r] > act_a and parent[r] >= 0:
                r = id_to_row.get(int(parent[r]), -1)
                if r < 0:
                    break
            if r >= 0 and act[r] == act_a:
                out.append(r)
        return np.asarray(out, np.int64)

    # Q8: ADAPT — patch inputs of READY tasks of an activity (user steering)
    def q8_patch_ready(self, activity: int, col: str, value: float,
                       predicate: Optional[Callable[[np.ndarray], np.ndarray]]
                       = None) -> int:
        st, act = self._cols("status", "activity_id")
        m = (st == int(Status.READY)) & (act == activity)
        if predicate is not None:
            m &= predicate(self.wq.store.col(col))
        idx = np.nonzero(m)[0]
        if len(idx):
            self.wq.store.update(idx, **{col: value})
            self.wq.log.append("steer_patch", {"activity": activity,
                                               "col": col, "n": len(idx)})
        return len(idx)

    # data reduction (paper [49]): prune READY/BLOCKED tasks by predicate
    def prune(self, predicate_col: str, lo: float, hi: float) -> int:
        st = self.wq.store.col("status")
        vals = self.wq.store.col(predicate_col)
        m = np.isin(st, [int(Status.READY), int(Status.BLOCKED)]) \
            & (vals >= lo) & (vals <= hi)
        idx = np.nonzero(m)[0]
        if len(idx):
            self.wq.store.update(idx, status=int(Status.PRUNED))
            self.wq.log.append("steer_prune", {"n": len(idx)})
        return len(idx)

    # ------------------------------------------------------------ on-device
    def device_monitor(self) -> Dict[str, float]:
        """Same aggregations with jnp over the device mirror (HTAP on-chip)."""
        import jax.numpy as jnp
        dv = self.wq.store.device_view(["status", "worker_id", "start_time",
                                        "end_time"])
        st = dv["status"]
        fin = (st == int(Status.FINISHED))
        run = (st == int(Status.RUNNING))
        dur = jnp.where(fin, dv["end_time"] - dv["start_time"], 0.0)
        return {
            "finished": int(fin.sum()),
            "running": int(run.sum()),
            "mean_task_s": float(dur.sum() / jnp.maximum(fin.sum(), 1)),
        }

    def run_all(self, now: float) -> Dict[str, object]:
        """One steering sweep (the paper runs the full set every 15 s)."""
        return {
            "q1": self.q1_recent_status_by_node(now),
            "q3": self.q3_worst_nodes(now).tolist(),
            "q4": self.q4_tasks_left(),
            "q5": self.q5_worst_activity(),
            "q6": self.q6_activity_times(),
        }
