"""ShardRouter: N full primaries behind one routing facade (paper §4-5).

SchalaDB's scalability argument rests on PARTITIONED OWNERSHIP: the Task
table is hash-distributed across data nodes, every node is a primary for
its partitions, and the execution engine + steering queries operate on the
union. Our single ``WorkQueue`` reproduces the node-local engine; this
module reproduces the distribution layer:

* **hash routing** — task id -> shard via the same modulo family the
  WorkQueue already uses for partitions. With ``W = S * L`` global workers
  (S shards x L local partitions), shard ``(tid % W) // L`` and local
  partition ``tid % L`` compose to the exact global partition ``tid % W``
  a single W-worker primary would assign, which is what makes the
  single-primary oracle comparisons in ``benchmarks/simkit.run_sharded``
  exact rather than statistical.
* **full primaries** — each shard owns a private ``ColumnStore`` +
  ``TxnLog`` and (optionally) a replicator from the existing
  :func:`~repro.core.replication.make_replicator` factory, so compaction,
  wire shipping, and fan-out all work per shard unchanged.
* **scatter-gather steering** — :meth:`run_all` pins one snapshot per
  shard (a *version vector*), computes per-shard partial aggregates with
  the same bincount/segment reductions as
  :class:`~repro.core.steering.SteeringEngine`, and merges them into
  results bit-identical to a single primary at the same data (Q7's
  provenance walk crosses shards through an id -> (shard, row) map).
* **cross-shard work stealing** — when a shard's incremental READY counts
  drain, :meth:`rebalance` pulls a batch from the richest sibling over a
  real ``Transport`` endpoint pair; the victim logs a prune and the thief
  logs a NORMAL insert (original task ids preserved), so each shard's
  replicas replay to bit-parity without any new log record type. The
  hand-off is two-phase: the victim's prune is PROVISIONAL until the
  thief's insert acks, and a transport death mid-steal rolls the chunk
  back as a logged re-insert — no task is ever lost to a dead wire.
* **shard-primary failover** — :meth:`fail_shard` marks a primary dead
  (it stops serving claims/inserts/steals; the other shards keep
  claiming), and :meth:`promote_shard` elects its most-caught-up replica
  via the existing ``Replicator.promote()``, drains the surviving log
  tail, requeues RUNNING rows, rebuilds the shard's WorkQueue around the
  promoted store, re-registers a fresh replicator, and re-arms the
  per-shard supervision (:meth:`attach_supervision`) with a bumped
  generation — not one committed transaction on any shard is lost.

Float caveat for bit-parity: merged Q6/Q7 means add per-shard partial sums
in shard order while the oracle sums in row order. For workloads whose
times are exactly representable (the drills use dyadic clocks) the results
are bit-identical; for arbitrary floats they agree to ulp-level
reassociation error.
"""
from __future__ import annotations

import concurrent.futures
import pickle
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.schema import Status
from repro.core.steering import Q7_ACT_A, sweep_partials
from repro.core.store import SnapshotView
from repro.core.transport import TCPTransport
from repro.core.workqueue import WorkQueue

_OPEN = (int(Status.READY), int(Status.RUNNING), int(Status.BLOCKED))

# steal batches cross the wire in bounded frames with a strict
# send -> recv alternation, so an in-process endpoint pair (socketpair)
# can never deadlock on a kernel buffer, whatever the batch size
_STEAL_CHUNK_ROWS = 256


class UnrecoverableShardError(RuntimeError):
    """A failed shard primary cannot be promoted: it has no replicator, or
    every replica in its group is dead too. The shard's committed state is
    only reachable through a durable checkpoint at this point."""


class DeadShardError(RuntimeError):
    """A remote sweep targeted a failed shard primary. A merged Q1-Q7
    result that silently excluded a shard would misreport global state, so
    the scatter refuses instead: ``promote_shard`` the dead primary first,
    or run :meth:`ShardRouter.run_all` over explicitly pinned snapshots of
    the frozen stores."""


def merge_partials(partials: Iterable[Dict[str, object]]
                   ) -> Dict[str, object]:
    """Combine per-shard :func:`~repro.core.steering.sweep_partials` into
    the single-primary Q1-Q7 result shape — the pure merge half of the
    distributed sweep.

    Shard index is list position; worker slabs land in disjoint global
    slots (``lo = sum of preceding shards' n_workers``), Q5/Q6 segment
    partials add in shard order (bit-stable for dyadic times), Q6 maxima
    combine by elementwise max, and Q7 filters each shard's candidate
    hits against the GLOBAL duration mean before the cross-shard parent
    walk. ``q7`` holds sorted global task ids and ``version`` the version
    vector, exactly as :meth:`ShardRouter.run_all` documents.
    """
    partials = list(partials)
    if not partials:
        raise ValueError("merge_partials needs at least one partial")
    sizes = [int(p["n_workers"]) for p in partials]
    W = sum(sizes)
    started = np.zeros(W, np.int64)
    finished = np.zeros(W, np.int64)
    failures = np.zeros(W, np.int64)
    fail_counts = np.zeros(W, np.int64)
    q4 = 0
    q5_counts = np.zeros(1, np.int64)
    q6_cnt = np.zeros(1, np.int64)
    q6_sum = np.zeros(1, np.float64)
    q6_max = np.full(1, -np.inf)
    q6_open: set = set()
    q7_sum, q7_cnt, q7_any = 0.0, 0, False

    def grow(arr, n, fill=0):
        if n <= arr.size:
            return arr
        out = np.full(n, fill, arr.dtype)
        out[:arr.size] = arr
        return out

    lo = 0
    for p, L in zip(partials, sizes):
        started[lo:lo + L] += p["started"]
        finished[lo:lo + L] += p["finished"]
        failures[lo:lo + L] += p["failures"]
        fail_counts[lo:lo + L] += p["fail_counts"]
        lo += L
        q4 += int(p["q4"])
        bc = p["q5_counts"]
        if bc.size:
            q5_counts = grow(q5_counts, bc.size)
            q5_counts[:bc.size] += bc
        q6_open.update(np.asarray(p["q6_open"]).tolist())
        n_act = p["q6_cnt"].size
        if n_act:
            q6_cnt = grow(q6_cnt, n_act)
            q6_sum = grow(q6_sum, n_act)
            q6_max = grow(q6_max, n_act, -np.inf)
            q6_cnt[:n_act] += p["q6_cnt"]
            q6_sum[:n_act] += p["q6_sum"]
            q6_max[:n_act] = np.maximum(q6_max[:n_act], p["q6_max"])
        if p["q7_any"]:
            q7_any = True
            q7_sum += float(p["q7_sum"])
            q7_cnt += int(p["q7_cnt"])

    q1 = {int(w): {"started": int(started[w]),
                   "finished": int(finished[w]),
                   "failures": int(failures[w])}
          for w in np.nonzero(started)[0]}
    q3 = (np.nonzero(fail_counts == fail_counts.max())[0].tolist()
          if fail_counts.any() else [])
    q5 = ((int(np.argmax(q5_counts)), int(q5_counts.max()))
          if q5_counts.any() else (-1, 0))
    q6 = {}
    if q6_cnt.any() and q6_open:
        for a in np.nonzero(q6_cnt)[0]:
            if int(a) in q6_open:
                q6[int(a)] = (float(q6_sum[a] / q6_cnt[a]),
                              float(q6_max[a]))
        q6 = dict(sorted(q6.items(), key=lambda kv: -kv[1][0]))
    q7 = _merge_q7(partials, q7_any, q7_sum, q7_cnt)
    return {"q1": q1, "q3": q3, "q4": q4, "q5": q5, "q6": q6, "q7": q7,
            "version": [int(p["version"]) for p in partials]}


def _merge_q7(partials: Sequence[Dict[str, object]], any_fin_b: bool,
              dsum: float, dcnt: int) -> List[int]:
    """Cross-shard provenance walk over the partials' compact ancestry
    arrays: per-shard candidate hits filtered against the GLOBAL mean,
    then parent edges chased through an id -> (shard, compact row) map
    (live copies shadow PRUNED tombstones). Returns sorted task ids —
    the multiset a single primary's row-index result maps to."""
    if not any_fin_b or dcnt == 0:
        return []
    mean = dsum / dcnt
    max_id = -1
    for p in partials:
        if p["anc_ids"].size:
            max_id = max(max_id, int(p["anc_ids"].max()))
    if max_id < 0:
        return []
    shard_of = np.full(max_id + 1, -1, np.int32)
    row_of = np.full(max_id + 1, -1, np.int64)
    for prefer_live in (False, True):       # live rows overwrite PRUNED
        for s, p in enumerate(partials):
            ids = p["anc_ids"]
            if prefer_live:
                keep = ~p["anc_pruned"]
                r = np.nonzero(keep)[0]
                ids = ids[keep]
            else:
                r = np.arange(ids.size, dtype=np.int64)
            shard_of[ids] = s
            row_of[ids] = r
    hits_s, hits_r = [], []
    for s, p in enumerate(partials):
        h = p["hit_idx"][p["hit_dur"] > mean]
        hits_s.append(np.full(len(h), s, np.int32))
        hits_r.append(h.astype(np.int64))
    cur_s = np.concatenate(hits_s)
    cur_r = np.concatenate(hits_r)
    if not len(cur_r):
        return []
    acts = [p["anc_act"] for p in partials]
    parents = [p["anc_parent"] for p in partials]
    while True:
        a = np.full(len(cur_r), -1, np.int64)
        pp = np.full(len(cur_r), -1, np.int64)
        for s in range(len(partials)):
            m = (cur_r >= 0) & (cur_s == s)
            if m.any():
                a[m] = acts[s][cur_r[m]]
                pp[m] = parents[s][cur_r[m]]
        walk = (cur_r >= 0) & (a > Q7_ACT_A) & (pp >= 0)
        if not walk.any():
            break
        pid = pp[walk]
        inb = pid <= max_id
        pid_c = np.minimum(pid, max_id)
        ns = np.where(inb, shard_of[pid_c], -1)
        nr = np.where(inb & (ns >= 0), row_of[pid_c], -1)
        cur_s[walk] = ns.astype(np.int32)
        cur_r[walk] = nr
    out = []
    for s, p in enumerate(partials):
        m = (cur_r >= 0) & (cur_s == s)
        if m.any():
            rows = cur_r[m]
            ok = acts[s][rows] == Q7_ACT_A
            out.append(p["anc_ids"][rows[ok]])
    if not out:
        return []
    return np.sort(np.concatenate(out)).tolist()


@dataclass
class Shard:
    """One primary: private queue (own store + txn log) + its replicator.

    ``alive`` is the serving flag — a dead shard keeps its (frozen) store
    and txn log in place as the WAL a promoted replica drains, but stops
    taking claims, inserts, reaps, and steals until :meth:`ShardRouter.
    promote_shard` swaps in the recovered WorkQueue. ``supervisor`` /
    ``secondary`` are the per-shard expansion pair installed by
    :meth:`ShardRouter.attach_supervision`; the secondary survives the
    primary's death and is promoted (generation bumped) with the shard.
    """
    index: int
    wq: WorkQueue
    replicator: Optional[object] = None
    steals_in: int = 0
    steals_out: int = 0
    alive: bool = True
    supervisor: Optional[object] = None
    secondary: Optional[object] = None


@dataclass
class StealStats:
    batches: int = 0
    tasks: int = 0
    wire_bytes: int = 0
    # two-phase hand-off: chunks whose transport died before the thief's
    # insert ack, rolled back on the victim as a logged re-insert
    rollbacks: int = 0
    rolled_back_tasks: int = 0
    per_shard_in: Dict[int, int] = field(default_factory=dict)


class ShardRouter:
    """Route a W-worker workload across ``num_shards`` full primaries."""

    def __init__(self, num_shards: int, workers_per_shard: int, *,
                 capacity: int = 1 << 16,
                 replicate: Optional[str] = None,
                 replicas: int = 1,
                 sync_every: int = 64,
                 transport: Optional[str] = None,
                 device_claim: Optional[bool] = None,
                 lease_s: Optional[float] = None,
                 steal_recv_timeout: Optional[float] = 30.0):
        if num_shards < 1:
            raise ValueError("num_shards must be >= 1")
        if workers_per_shard < 1:
            raise ValueError("workers_per_shard must be >= 1")
        self.num_shards = num_shards
        self.workers_per_shard = workers_per_shard
        self.num_global_workers = num_shards * workers_per_shard
        self._next_task_id = 0
        # replication policy, kept so promote_shard / from_checkpoint can
        # re-arm a shard's replicator identically after a failover/restore
        self._capacity = capacity
        self._replicate = replicate
        self._replicas = replicas
        self._sync_every = sync_every
        self._transport = transport
        self._device_claim = device_claim
        self.shards: List[Shard] = []
        for s in range(num_shards):
            wq = WorkQueue(num_workers=workers_per_shard, capacity=capacity,
                           device_claim=device_claim, lease_s=lease_s)
            rep = None
            if replicate is not None:
                from repro.core.replication import make_replicator
                rep = make_replicator(wq, replicate, replicas=replicas,
                                      sync_every=sync_every,
                                      transport=transport,
                                      account_encoded=False)
            self.shards.append(Shard(index=s, wq=wq, replicator=rep))
        # the steal hop: one connected endpoint pair shared by all shards
        # (in-process stand-in for the victim->thief socket; the frames on
        # it are the real wire payloads). The recv deadline turns a wedged
        # sibling into a TransportError — which _pull's two-phase rollback
        # already handles — instead of a rebalance hung in recv forever.
        self._steal_tx, self._steal_rx = TCPTransport.pair(
            recv_timeout=steal_recv_timeout)
        self.steal_stats = StealStats()
        # persistent scatter pool: remote_sweep / sync_replicas /
        # replica_vector issue their per-shard requests concurrently, so
        # the analyst wall tracks max(shard), not the serial sum (the
        # ReplicaGroup fan-out pattern, one level up)
        self._scatter: Optional[concurrent.futures.ThreadPoolExecutor] = \
            concurrent.futures.ThreadPoolExecutor(
                max_workers=num_shards,
                thread_name_prefix="shard-scatter") \
            if num_shards > 1 else None
        self.last_scatter_wall_s: List[float] = [0.0] * num_shards
        self.last_scatter_total_s = 0.0
        self._closed = False

    # ------------------------------------------------------------- routing
    def shard_of(self, task_ids: np.ndarray) -> np.ndarray:
        """Owning shard per task id (hash routing)."""
        ids = np.asarray(task_ids, np.int64)
        return (ids % self.num_global_workers) // self.workers_per_shard

    def global_worker(self, shard: int, local_worker) -> np.ndarray:
        """Local partition id -> global worker id (the bijection that makes
        merged Q1/Q3 keys comparable with a single W-worker primary)."""
        return shard * self.workers_per_shard + np.asarray(local_worker)

    # ------------------------------------------------------------- inserts
    def add_tasks(self, activity_id: int, n: int, *,
                  status: Status = Status.READY,
                  duration_est=0.0,
                  domain_in: Optional[np.ndarray] = None,
                  parent_task: Optional[np.ndarray] = None,
                  now: float = 0.0) -> np.ndarray:
        """Insert ``n`` tasks with GLOBALLY unique ids, scattered to their
        owning shards (each shard insert is one normal logged txn)."""
        ids = np.arange(self._next_task_id, self._next_task_id + n,
                        dtype=np.int64)
        self._next_task_id += n
        dur = np.asarray(duration_est, np.float64)
        owner = self.shard_of(ids)
        for s, sh in enumerate(self.shards):
            m = owner == s
            cnt = int(m.sum())
            if not cnt:
                continue
            if not sh.alive:
                raise RuntimeError(
                    f"shard {s} is down (failed primary, not yet "
                    f"promoted) — cannot insert {cnt} tasks it owns")
            sh.wq.add_tasks(
                activity_id, cnt, status=status,
                duration_est=(float(dur) if dur.ndim == 0 else dur[m]),
                domain_in=None if domain_in is None else domain_in[m],
                parent_task=None if parent_task is None else
                np.asarray(parent_task)[m],
                now=now, task_ids=ids[m])
        return ids

    # -------------------------------------------------------------- claims
    def claim_all(self, k: int = 1, *, now: float = 0.0, steal: bool = True
                  ) -> Dict[int, Tuple[int, np.ndarray]]:
        """Batched claim on every shard: {global_worker: (shard, rows)}.

        ``rows`` index into that shard's store; ``steal`` here is the
        INTRA-shard redistribution the WorkQueue already does — cross-shard
        stealing is :meth:`rebalance`. Dead shards are skipped: the
        survivors' claim loops never stall on a failed sibling.
        """
        out: Dict[int, Tuple[int, np.ndarray]] = {}
        for s, sh in enumerate(self.shards):
            if not sh.alive:
                continue
            got = sh.wq.claim_all(k=k, now=now, steal=steal)
            for lw, rows in got.items():
                out[int(self.global_worker(s, lw))] = (s, rows)
        return out

    def ready_counts(self) -> np.ndarray:
        """Global READY-per-partition vector (length S*L): the concatenation
        of every shard's incremental counts."""
        return np.concatenate([sh.wq.ready_counts() for sh in self.shards])

    def tasks_left(self) -> int:
        """Q4 over the union of shards (the executor's termination check)."""
        return int(sum(
            np.isin(sh.wq.store.col("status"), _OPEN).sum()
            for sh in self.shards))

    def live_task_ids(self) -> np.ndarray:
        """Sorted ids of every materialized, non-PRUNED task across shards —
        the conservation invariant cross-shard stealing must preserve."""
        parts = []
        for sh in self.shards:
            st = sh.wq.store.col("status")
            keep = (st != int(Status.EMPTY)) & (st != int(Status.PRUNED))
            parts.append(sh.wq.store.col("task_id")[keep])
        return np.sort(np.concatenate(parts)) if parts \
            else np.empty(0, np.int64)

    # --------------------------------------------------------------- leases
    def reap_expired(self, *, now: float = 0.0, max_trials: int = 3) -> int:
        """Run the stale-claim reaper on every shard (an ordinary logged
        transaction per shard, so per-shard replicas replay it like any
        other record). Reaped rows re-enter their owning shard's READY
        counts, which is exactly what :meth:`rebalance` keys drained-shard
        stealing off — dead-worker backlog becomes stealable cross-shard
        with no extra wiring. Dead shards are skipped (their frozen state
        is recovered wholesale at promote). Returns total rows reaped."""
        return sum(sh.wq.reap_expired(now=now, max_trials=max_trials)
                   for sh in self.shards if sh.alive)

    def autoscale_signals(self, *, now: float = 0.0) -> Dict[str, float]:
        """Union autoscaling signals: counts sum across shards; ages and
        latencies take the max (the pool must cover the worst shard)."""
        sigs = [sh.wq.autoscale_signals(now=now) for sh in self.shards]
        return {
            "pending": float(sum(s["pending"] for s in sigs)),
            "backlog_age_s": max(s["backlog_age_s"] for s in sigs),
            "claim_p95_s": max(s["claim_p95_s"] for s in sigs),
            "running": float(sum(s["running"] for s in sigs)),
        }

    # ------------------------------------------------- cross-shard stealing
    def rebalance(self, *, now: float = 0.0,
                  max_batch: Optional[int] = None) -> int:
        """Cross-shard work stealing: every DRAINED shard (zero READY rows)
        pulls half the richest sibling's READY backlog over the transport.

        The victim's half is marked PRUNED in a logged transaction and the
        thief re-inserts the identical tasks (original ids, original inputs)
        as a NORMAL logged insert — both shards' replicas replay their own
        log to bit-parity, no new record type needed. The prune is only
        PROVISIONAL until the thief's insert acks: if the transport dies
        mid-steal the chunk is rolled back on the victim as a logged
        re-insert (see :meth:`_pull`), so a wire failure can delay a
        migration but never lose a task. Returns tasks moved.

        Migration resets a task's retry counter and submit time (only READY
        rows travel, so no start/end history is lost); the victim keeps a
        PRUNED tombstone row under the same id — :meth:`live_task_ids`
        resolves ids to their live copy.
        """
        # dead shards neither steal nor get robbed: -1 keeps them out of
        # both the drained test and the richest-victim argmax
        totals = [int(sh.wq.ready_counts().sum()) if sh.alive else -1
                  for sh in self.shards]
        moved = 0
        for s, sh in enumerate(self.shards):
            if not sh.alive or totals[s] > 0:
                continue
            victim = int(np.argmax(totals))
            if victim == s or totals[victim] < 2:
                continue
            batch = totals[victim] // 2
            if max_batch is not None:
                batch = min(batch, max_batch)
            got = self._pull(self.shards[victim], sh, batch, now)
            totals[victim] -= got
            totals[s] += got
            moved += got
        return moved

    def _pull(self, victim: Shard, thief: Shard, batch: int,
              now: float) -> int:
        vst = victim.wq.store
        rows = np.nonzero(vst.col("status") == int(Status.READY))[0][:batch]
        if not len(rows):
            return 0
        in_cols = sorted(
            (c for c in vst.cols
             if c.startswith("in") and c[2:].isdigit()),
            key=lambda c: int(c[2:]))
        moved = 0
        for lo in range(0, len(rows), _STEAL_CHUNK_ROWS):
            chunk = rows[lo:lo + _STEAL_CHUNK_ROWS]
            payload = {
                "ids": vst.col("task_id")[chunk],
                "act": vst.col("activity_id")[chunk],
                "parent": vst.col("parent_task")[chunk],
                "dur": vst.col("duration_est")[chunk],
                "dom": np.stack([vst.col(c)[chunk] for c in in_cols], 1)
                if in_cols else None,
            }
            # phase 1 — tombstone the victim's copy (logged) BEFORE the
            # ship, so a task is never claimable on two shards at once.
            # The tombstone is provisional: it only sticks once phase 2
            # (the thief's insert) has the payload in hand.
            victim.wq.prune(chunk)
            try:
                buf = pickle.dumps(payload,
                                   protocol=pickle.HIGHEST_PROTOCOL)
                self._steal_tx.send_bytes(buf)
                wire = self._steal_rx.recv_bytes()
            except (OSError, EOFError):
                # the wire died before the thief acked this chunk: roll
                # the provisional prune back as a NORMAL logged re-insert
                # (same ids, same inputs), so the victim's replicas replay
                # prune+insert to the same live rows and the chunk stays
                # claimable where it was. Remaining chunks are abandoned —
                # the transport is gone.
                self._reinsert(victim, payload, now)
                self.steal_stats.rollbacks += 1
                self.steal_stats.rolled_back_tasks += len(chunk)
                break
            self.steal_stats.wire_bytes += len(wire)
            p = pickle.loads(wire)
            # phase 2 — the thief's insert is the ack that commits the move
            self._reinsert(thief, p, now)
            moved += len(chunk)
        if moved:
            victim.steals_out += 1
            thief.steals_in += 1
            self.steal_stats.batches += 1
            self.steal_stats.tasks += moved
            self.steal_stats.per_shard_in[thief.index] = \
                self.steal_stats.per_shard_in.get(thief.index, 0) + moved
        return moved

    @staticmethod
    def _reinsert(shard: Shard, payload: Dict, now: float) -> None:
        """Materialize a steal payload on ``shard`` as normal logged
        inserts (original ids preserved) — the thief's commit on success,
        the victim's rollback on a dead transport."""
        for a in np.unique(payload["act"]):
            m = payload["act"] == a
            shard.wq.add_tasks(
                int(a), int(m.sum()),
                duration_est=payload["dur"][m],
                domain_in=None if payload["dom"] is None
                else payload["dom"][m],
                parent_task=payload["parent"][m],
                now=now, task_ids=payload["ids"][m])

    # -------------------------------------------------- snapshots / replicas
    def version_vector(self) -> Tuple[int, ...]:
        return tuple(sh.wq.store.version for sh in self.shards)

    def snapshot_vector(self) -> Tuple[SnapshotView, ...]:
        """One immutable snapshot per shard — the consistent cut every
        scatter-gather sweep pins (the distributed analogue of
        ``SteeringEngine.snapshot_scope``)."""
        return tuple(sh.wq.store.snapshot_view() for sh in self.shards)

    def _scatter_map(self, fn: Callable[[int], object],
                     concurrent_scatter: bool = True) -> List[object]:
        """Run ``fn(shard_index)`` for every shard — on the persistent
        scatter pool when available (wall ≈ max(shard)), else serially.
        The caller blocks until every shard returned, so per-shard log
        staging on pool threads happens while the producer thread is
        parked — the TxnLog single-producer contract holds per shard."""
        idxs = range(self.num_shards)
        if self._scatter is None or not concurrent_scatter:
            return [fn(s) for s in idxs]
        return list(self._scatter.map(fn, idxs))

    def replica_vector(self, *, concurrent_scatter: bool = True
                       ) -> Tuple[SnapshotView, ...]:
        """Snapshot vector cut from the per-shard REPLICAS (analyst-side
        HTAP: sweeps run off the primaries' claim path). The per-shard
        sync+snapshot requests scatter concurrently — independent
        replicators, disjoint logs."""
        def one(s: int) -> SnapshotView:
            sh = self.shards[s]
            if sh.replicator is None:
                raise ValueError("shard has no replicator "
                                 "(construct with replicate=...)")
            sh.replicator.sync()
            return sh.replicator.snapshot_view()
        return tuple(self._scatter_map(one, concurrent_scatter))

    def sync_replicas(self, *, concurrent_scatter: bool = True
                      ) -> Tuple[int, ...]:
        """Catch every live shard's replicas up CONCURRENTLY, pinned at
        the version vector cut on the calling thread before the scatter.
        Returns that vector — the consistent cut a subsequent
        ``remote_sweep(..., versions=vec, sync=False)`` analyzes (how the
        executor splits the producer-thread sync from the analyst-thread
        scatter). Dead shards are skipped exactly as :meth:`compact`
        skips them (their frozen log is the promote WAL), but keep their
        version entry."""
        versions = self.version_vector()

        def one(s: int) -> None:
            sh = self.shards[s]
            if sh.alive and sh.replicator is not None:
                sh.replicator.sync(upto_version=versions[s])
        self._scatter_map(one, concurrent_scatter)
        return versions

    def compact(self) -> int:
        """Per-shard log compaction (each shard's consumer floor governs).
        A dead shard's log is its WAL — frozen until promote drains it —
        so compaction only runs on live shards."""
        return sum(sh.wq.compact_log() for sh in self.shards if sh.alive)

    def consumer_lags(self) -> Dict[str, int]:
        """Union of per-shard consumer lags, keys namespaced by shard."""
        out: Dict[str, int] = {}
        for s, sh in enumerate(self.shards):
            for name, lag in sh.wq.consumer_lags().items():
                out[f"shard{s}:{name}"] = lag
        return out

    # ------------------------------------------------- supervision / failover
    def attach_supervision(self, workflow, *, fanout: int = 1) -> None:
        """Install a Supervisor + SecondarySupervisor pair on every shard,
        so expansion state survives a primary promote (the ``expanded``
        column rides the shard store, hence the replica, hence the
        promoted WorkQueue — ``SecondarySupervisor.promote(wq)`` is exact).

        Call :meth:`sync_secondaries` on the driving cadence so the shadow
        cursors track the primaries. Cross-shard caveat: ``Supervisor``
        allocates ids from the SHARD-LOCAL counter, which breaks global
        hash routing for seeding and for multi-activity expansion — seed
        through :meth:`add_tasks` and keep sharded workflows
        single-activity (:meth:`expand_all` enforces this; cross-shard
        child routing is a documented ROADMAP residual)."""
        from repro.core.supervisor import SecondarySupervisor, Supervisor
        for sh in self.shards:
            sh.supervisor = Supervisor(sh.wq, workflow, fanout=fanout)
            sh.secondary = SecondarySupervisor(sh.supervisor)

    def sync_secondaries(self) -> None:
        """Refresh every live shard's shadow supervisor state."""
        for sh in self.shards:
            if sh.alive and sh.secondary is not None:
                sh.secondary.sync()

    def expand_all(self, *, now: float = 0.0) -> int:
        """Run dependency expansion on every live shard's supervisor."""
        total = 0
        for sh in self.shards:
            if not sh.alive or sh.supervisor is None:
                continue
            if sh.supervisor.workflow.num_activities > 1:
                raise ValueError(
                    "per-shard expansion requires a single-activity "
                    "workflow: Supervisor.expand allocates child ids from "
                    "the shard-local counter, which breaks global hash "
                    "routing — route children through ShardRouter."
                    "add_tasks instead")
            total += sh.supervisor.expand(now=now)
        return total

    def fail_shard(self, shard: int) -> None:
        """Simulate shard ``shard``'s primary dying: the node stops serving
        claims, inserts, reaps, steals, and replica syncs. Its in-memory
        store is considered LOST; what survives is the txn log tail (the
        node's WAL) and the replica state — exactly what
        :meth:`promote_shard` recovers from. Its supervisor dies with it
        (the secondary shadow survives). Idempotent; the other shards'
        claim loops are untouched."""
        sh = self.shards[shard]
        sh.alive = False
        if sh.supervisor is not None:
            sh.supervisor.crash()

    def promote_shard(self, shard: int) -> WorkQueue:
        """Fail the shard over onto its most-caught-up replica: elect via
        the existing ``Replicator.promote()`` (which drains the surviving
        log tail, so not one committed transaction is lost, and requeues
        RUNNING rows — their workers died with the primary), rebuild the
        shard's WorkQueue around the promoted store, re-register a fresh
        replicator from the router's replication policy, and promote the
        shard's SecondarySupervisor (generation bumped) onto the new
        queue. Returns the promoted WorkQueue; the shard is serving again
        when this returns.

        Raises :class:`UnrecoverableShardError` when there is nothing to
        promote — no replicator, or every replica in the group is dead
        (``AllReplicasDeadError``); a durable checkpoint is the only way
        back at that point."""
        from repro.core.replication import AllReplicasDeadError
        sh = self.shards[shard]
        if sh.replicator is None:
            raise UnrecoverableShardError(
                f"shard {shard} has no replicator to promote "
                "(construct the router with replicate=...)")
        try:
            new_wq = sh.replicator.promote()
        except AllReplicasDeadError as e:
            raise UnrecoverableShardError(
                f"shard {shard} lost its primary and every replica — "
                f"restore from a checkpoint: {e}") from e
        sh.replicator = None          # promote() already closed it
        self._adopt(sh, new_wq)
        return new_wq

    def _adopt(self, sh: Shard, wq: WorkQueue) -> None:
        """Swap a shard's primary for a promoted/restored WorkQueue:
        re-arm its replicator from the router's replication policy and
        promote its secondary supervisor onto the new queue."""
        if sh.replicator is not None:
            sh.replicator.close()
        sh.wq = wq
        sh.replicator = None
        if self._replicate is not None:
            from repro.core.replication import make_replicator
            sh.replicator = make_replicator(
                wq, self._replicate, replicas=self._replicas,
                sync_every=self._sync_every, transport=self._transport,
                account_encoded=False)
        sh.alive = True
        if sh.secondary is not None:
            from repro.core.supervisor import SecondarySupervisor
            sh.supervisor = sh.secondary.promote(wq)
            sh.secondary = SecondarySupervisor(sh.supervisor)

    @classmethod
    def from_checkpoint(cls, shard_states, *,
                        replicate: Optional[str] = None,
                        replicas: int = 1,
                        sync_every: int = 64,
                        transport: Optional[str] = None,
                        device_claim: Optional[bool] = None,
                        capacity: int = 1 << 16) -> "ShardRouter":
        """Rebuild a router from per-shard restored state, in shard order:
        ``shard_states`` is one ``(store, meta)`` pair per shard as cut by
        ``Checkpointer.save`` (meta carries ``num_workers`` / ``version`` /
        ``log_len``). Each shard's WorkQueue resumes with its log offset
        and compaction horizon pinned at the checkpoint's version vector,
        and replicators are re-armed from the given policy — the restored
        run's scatter-gather sweeps are bit-identical to the pre-crash cut.
        """
        if not shard_states:
            raise ValueError("from_checkpoint needs at least one shard")
        wps = int(shard_states[0][1]["num_workers"])
        r = cls(len(shard_states), wps, capacity=capacity,
                replicate=None, device_claim=device_claim)
        r._replicate = replicate
        r._replicas = replicas
        r._sync_every = sync_every
        r._transport = transport
        next_id = 0
        for sh, (store, meta) in zip(r.shards, shard_states):
            if int(meta["num_workers"]) != wps:
                raise ValueError("shards disagree on workers_per_shard")
            wq = WorkQueue(wps, store=store, device_claim=device_claim)
            used = store.col("status") != int(Status.EMPTY)
            if used.any():
                mx = int(store.col("task_id")[used].max())
                wq._next_task_id = mx + 1
                next_id = max(next_id, mx + 1)
            wq.log.base = int(meta["log_len"])
            wq.log.horizon_version = int(meta["version"])
            r._adopt(sh, wq)
        r._next_task_id = next_id
        return r

    # ------------------------------------------------ scatter-gather sweep
    def run_all(self, now: float,
                views: Optional[Sequence[SnapshotView]] = None,
                horizon: float = 60.0) -> Dict[str, object]:
        """Distributed Q1-Q7 sweep: per-shard partial aggregates merged into
        the single-primary result shape.

        ``views`` pins the sweep at an explicit version vector (default: cut
        one now). Differences from ``SteeringEngine.run_all``: ``q7`` holds
        global TASK IDS (sorted) rather than store rows — rows are
        shard-local and meaningless globally — and ``version`` is the
        version vector (a list). Everything else is bit-identical to a
        W-worker single primary over the same data.

        The reduction is split into two PURE pieces so the per-shard half
        can run anywhere (an analyst thread here, or inside a replica
        process via :meth:`remote_sweep`):
        :func:`repro.core.steering.sweep_partials` per view, then
        :func:`merge_partials` over the results.
        """
        if views is None:
            views = self.snapshot_vector()
        if len(views) != self.num_shards:
            raise ValueError(f"version vector has {len(views)} entries, "
                             f"expected {self.num_shards}")
        return merge_partials(
            sweep_partials(v, self.workers_per_shard, now, horizon)
            for v in views)

    @staticmethod
    def comparable(result: Dict[str, object]) -> Dict[str, object]:
        """Strip the version field (scalar vs vector) for sweep parity
        fingerprints."""
        return {k: v for k, v in result.items() if k != "version"}

    @staticmethod
    def oracle_normalize(result: Dict[str, object],
                         view: SnapshotView) -> Dict[str, object]:
        """Map a single-primary ``SteeringEngine.run_all`` result into the
        router's shape: q7 store rows -> sorted global task ids."""
        out = ShardRouter.comparable(result)
        rows = np.asarray(out.get("q7", []), np.int64)
        out["q7"] = np.sort(view.col("task_id")[rows]).tolist()
        return out

    # ----------------------------------------------------- remote analysts
    def remote_sweep(self, now: float, *, horizon: float = 60.0,
                     versions: Optional[Sequence[int]] = None,
                     sync: bool = True,
                     concurrent_scatter: bool = True,
                     shard_delay_s: Optional[Sequence[float]] = None
                     ) -> Dict[str, object]:
        """Concurrent scatter-gather of the FULL Q1-Q7 sweep through the
        per-shard replica processes: each shard's replicator runs
        :func:`~repro.core.steering.sweep_partials` INSIDE its replica
        process and ships back only the partial aggregates;
        :func:`merge_partials` combines them here into a result
        bit-identical to :meth:`run_all` (and hence to a single-primary
        oracle) at the same version vector.

        ``sync=True`` (default) pins ``versions`` to the current version
        vector and catches each shard's replica up to it inside the
        scatter. Callers that must keep log staging on the producer
        thread (the executor's analyst pool) pass the vector returned by
        :meth:`sync_replicas` with ``sync=False`` — the scatter then only
        issues the log-free partial-sweep requests. Each partial's view
        version is hard-checked against the pinned vector. Per-shard
        walls land in ``last_scatter_wall_s`` (straggler spread via
        :meth:`scatter_spread_s`); ``concurrent_scatter=False`` is the
        serial baseline arm the e_sharded benchmark compares against.

        ``shard_delay_s`` injects a per-shard modeled data-node RPC
        latency, slept inside each replica process before its sweep —
        the latency-regime knob of the e_sharded fan-out benchmark
        (same role as ``run_baseline``'s ``access_latency_s``: the
        paper's shards are separate hosts behind a NIC) and a straggler
        injector for spread measurements. ``None`` (production) injects
        nothing.

        Raises :class:`DeadShardError` when any shard is down — a merged
        result silently missing a shard would misreport global state —
        and ``ValueError`` when a shard's replicator cannot run remote
        partial sweeps (requires ``replicate='remote'`` or
        ``'shipped'``)."""
        for s, sh in enumerate(self.shards):
            if not sh.alive:
                raise DeadShardError(
                    f"shard {s} is down (failed primary, not yet "
                    f"promoted) — promote_shard({s}) before sweeping, or "
                    "run_all over pinned snapshots of the frozen stores")
            if sh.replicator is None or not hasattr(
                    sh.replicator, "remote_sweep_partials"):
                raise ValueError(
                    "remote_sweep requires replicate='remote' (or "
                    "'shipped'): the partial sweeps run inside per-shard "
                    "replica processes")
        if versions is None:
            versions = self.version_vector()

        def one(s: int) -> Tuple[Dict[str, object], float]:
            t0 = time.perf_counter()
            sh = self.shards[s]
            if sync:
                sh.replicator.sync(upto_version=versions[s])
            part = sh.replicator.remote_sweep_partials(
                now, horizon=horizon,
                delay_s=0.0 if shard_delay_s is None
                else float(shard_delay_s[s]))
            return part, time.perf_counter() - t0
        t0 = time.perf_counter()
        results = self._scatter_map(one, concurrent_scatter)
        self.last_scatter_total_s = time.perf_counter() - t0
        self.last_scatter_wall_s = [w for _, w in results]
        parts = [p for p, _ in results]
        for s, p in enumerate(parts):
            if int(p["version"]) != int(versions[s]):
                raise RuntimeError(
                    f"shard {s} replica answered the partial sweep at "
                    f"v{p['version']}, expected pinned v{versions[s]}")
        return merge_partials(parts)

    def scatter_spread_s(self) -> float:
        """Straggler signal of the last remote scatter: slowest minus
        fastest per-shard wall (the shard-level analogue of
        ``ReplicaGroup.member_spread_s``)."""
        return (max(self.last_scatter_wall_s)
                - min(self.last_scatter_wall_s))

    # -------------------------------------------------------------- teardown
    def check_invariants(self) -> None:
        for sh in self.shards:
            sh.wq.check_invariants()
        live = self.live_task_ids()
        if len(np.unique(live)) != len(live):
            raise AssertionError("task id owned live by two shards")

    def close(self) -> None:
        """Release every shard's replicator, the scatter pool, and the
        steal endpoints. Idempotent — a second close is a no-op — and
        safe after :meth:`fail_shard`/:meth:`promote_shard` (promote
        releases the old replicator and re-arms a fresh one; each armed
        replicator is detached before its single close, so nothing is
        double-closed)."""
        if self._closed:
            return
        self._closed = True
        for sh in self.shards:
            rep, sh.replicator = sh.replicator, None
            if rep is not None:
                rep.close()
        if self._scatter is not None:
            self._scatter.shutdown(wait=False)
            self._scatter = None
        self._steal_tx.close()
        self._steal_rx.close()
