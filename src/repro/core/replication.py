"""Delta replication: replica catch-up by txn-log replay (paper Section 3.2).

The paper keeps one replica per partition so a data-node crash loses nothing,
and reports tens-of-MB metadata for 100k-task workloads — small enough to
ship incrementally. :class:`DeltaReplicator` implements exactly that: the
replica is a mutable store restored from a ``snapshot_view()`` once, then
caught up by replaying ``TxnLog.tail_for_version`` records — apply-ops for
every op the WorkQueue emits (insert/add_tasks, claim, claim_all, finish,
fail, requeue_worker, resize, steering patches/prunes). ``sync`` cost is
O(delta records), independent of store size; the old full-snapshot copy is
preserved as :class:`FullCopyReplica`, the O(store) baseline the
``e_replica_lag`` benchmark measures against.

Because the store is append-only (rows are never deleted or compacted),
primary row indices are valid verbatim on any replica that replayed the same
log prefix — payload row indices ARE the replica addresses, no id remapping.
Replayed record versions pin ``store.version`` to the primary's committed
version, so a caught-up replica at version v is bit-identical to a primary
``snapshot_view()`` at v (sweep parity is asserted in tests and the
e_replica_lag experiment).

Batched replay
--------------
Real logs are dominated by long runs of same-op records (claims and finishes
— the paper's Experiment 6 op inventory). :func:`replay` coalesces each
consecutive same-op run into ONE vectorized ``store.update`` (rows
concatenated, per-record scalars repeated per row), so replay cost scales
with the number of RUNS, not records. Safe because within a run the touched
rows are disjoint by the status machine (a row cannot be claimed/finished/
failed twice without an intervening record of a different op), and NumPy
fancy-index assignment applies duplicates last-wins in log order anyway.
:func:`replay_reference` keeps the record-at-a-time loop as the equivalence
oracle (property-tested bit-identical, and the denominator of the
bench-trajectory replay-throughput gate).

The raw-pointer side table (``store.blobs``) is copied at restore time but
NOT delta-shipped: like the paper, raw files stay out of the DBMS and out of
the replication stream.

Replicas are registered txn-log CONSUMERS: every ``sync`` acks the consumed
offset, so ``TxnLog.truncate`` can drop the prefix all replicas (and the
checkpointer) are past — bounding long-run log memory without ever dropping
a record a lagging replica still needs.
"""
from __future__ import annotations

import itertools
import weakref
from operator import attrgetter, itemgetter
from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

from repro.core.schema import Status
from repro.core.store import ColumnStore
from repro.core.transactions import LogCompactedError, Txn
from repro.core.workqueue import WorkQueue


# --------------------------------------------------------------- apply ops
def _apply_insert(store: ColumnStore, p: Dict) -> None:
    idx = store.insert(p["rows"])
    # append-only determinism: replayed rows must land exactly where the
    # primary put them, else every later payload's row indices are garbage
    if len(idx) and int(idx[0]) != int(p["row_idx"][0]):
        raise RuntimeError(
            f"replica diverged: insert replayed at row {int(idx[0])}, "
            f"primary committed at {int(p['row_idx'][0])}")
    exp = p.get("expanded_rows")
    if exp is not None and len(exp):
        store.update(exp, expanded=1)


def _apply_claim(store: ColumnStore, p: Dict) -> None:
    w = int(p["worker"])
    store.update(p["rows"], status=int(Status.RUNNING), start_time=p["now"],
                 worker_id=w, core_id=w)


def _apply_claim_all(store: ColumnStore, p: Dict) -> None:
    store.update(p["rows"], status=int(Status.RUNNING), start_time=p["now"])


def _apply_finish(store: ColumnStore, p: Dict) -> None:
    store.update(p["rows"], status=int(Status.FINISHED), end_time=p["now"])
    dom = p.get("domain_out")
    if dom is not None:
        store.update(p["rows"], **{f"out{i}": dom[:, i]
                                   for i in range(dom.shape[1])})


def _apply_fail(store: ColumnStore, p: Dict) -> None:
    store.update(p["rows"], fail_trials=p["trials"])
    if len(p["retry"]):
        store.update(p["retry"], status=int(Status.READY))
    if len(p["dead"]):
        store.update(p["dead"], status=int(Status.FAILED),
                     end_time=p["now"])


def _apply_requeue(store: ColumnStore, p: Dict) -> None:
    store.update(p["rows"], status=int(Status.READY),
                 fail_trials=p["trials"], worker_id=p["new_worker"])


def _apply_resize(store: ColumnStore, p: Dict) -> None:
    if len(p["rows"]):
        store.update(p["rows"], worker_id=p["assign"])


def _apply_steer_patch(store: ColumnStore, p: Dict) -> None:
    store.update(p["rows"], **{p["col"]: p["value"]})


def _apply_steer_prune(store: ColumnStore, p: Dict) -> None:
    store.update(p["rows"], status=int(Status.PRUNED))


_APPLY = {
    "insert": _apply_insert,
    "claim": _apply_claim,
    "claim_all": _apply_claim_all,
    "finish": _apply_finish,
    "fail": _apply_fail,
    "requeue_worker": _apply_requeue,
    "resize": _apply_resize,
    "steer_patch": _apply_steer_patch,
    "steer_prune": _apply_steer_prune,
}


# --------------------------------------------------------------- batch ops
# Builders are deliberately lean: payload row arrays are concatenated as-is
# (they are frozen int64 ndarrays by construction — _freeze copies, never
# re-types), per-record scalars stream through np.fromiter, and the repeat
# out to row counts collapses to the scalar vector itself when every record
# in the run wrote one row (per-worker claims, per-task finishes — the
# dominant shape). Per-record Python cost is what the >=10x replay gate
# measures, so every avoidable per-record allocation here is load-bearing.
def _scalar_per_row(ps: Sequence[Dict], key: str, dtype,
                    lens: Optional[np.ndarray]) -> np.ndarray:
    vals = np.fromiter(map(itemgetter(key), ps), dtype, len(ps))
    # lens is None for all-single-row runs (the dominant shape): the scalar
    # vector IS the per-row vector, no repeat needed
    return vals if lens is None else np.repeat(vals, lens)


def _run_rows(ps: Sequence[Dict], key: str = "rows"):
    """(concatenated row indices, per-record lengths) for one same-op run.

    Returns ``lens=None`` when every record wrote exactly one row, the
    common case for per-worker claims / per-task finishes — callers then
    skip the repeat entirely. The check is exact: empty records make
    ``rows.size == len(ps)`` alias, so the per-record lengths are compared,
    not the total.
    """
    rows_list = list(map(itemgetter(key), ps))
    lens = np.fromiter(map(len, rows_list), np.int64, len(rows_list))
    if bool(np.all(lens == 1)):
        return np.fromiter(map(itemgetter(0), rows_list), np.int64,
                           len(rows_list)), None
    return np.concatenate(rows_list), lens


def _batch_claim(store: ColumnStore, ps: Sequence[Dict]) -> None:
    rows, lens = _run_rows(ps)
    now = _scalar_per_row(ps, "now", np.float64, lens)
    w = _scalar_per_row(ps, "worker", np.int32, lens)
    store.update(rows, status=int(Status.RUNNING), start_time=now,
                 worker_id=w, core_id=w)


def _batch_claim_all(store: ColumnStore, ps: Sequence[Dict]) -> None:
    rows, lens = _run_rows(ps)
    now = _scalar_per_row(ps, "now", np.float64, lens)
    store.update(rows, status=int(Status.RUNNING), start_time=now)


def _batch_finish(store: ColumnStore, ps: Sequence[Dict]) -> None:
    rows, lens = _run_rows(ps)
    now = _scalar_per_row(ps, "now", np.float64, lens)
    store.update(rows, status=int(Status.FINISHED), end_time=now)
    dom_ps = [p for p in ps if p.get("domain_out") is not None]
    if dom_ps:
        width = dom_ps[0]["domain_out"].shape[1]
        if all(p["domain_out"].shape[1] == width for p in dom_ps):
            drows, _ = _run_rows(dom_ps)
            dom = np.concatenate(list(map(itemgetter("domain_out"), dom_ps)))
            store.update(drows, **{f"out{i}": dom[:, i]
                                   for i in range(dom.shape[1])})
        else:
            # mixed output widths across the run: concatenation would raise,
            # so the (disjoint) dom sub-updates apply record by record
            for p in dom_ps:
                d = p["domain_out"]
                store.update(p["rows"], **{f"out{i}": d[:, i]
                                           for i in range(d.shape[1])})


def _batch_fail(store: ColumnStore, ps: Sequence[Dict]) -> None:
    rows, _ = _run_rows(ps)
    trials = np.concatenate(list(map(itemgetter("trials"), ps)))
    store.update(rows, fail_trials=trials)
    retry = np.concatenate(list(map(itemgetter("retry"), ps)))
    if retry.size:
        store.update(retry, status=int(Status.READY))
    dead_ps = [p for p in ps if len(p["dead"])]
    if dead_ps:
        dead, dlens = _run_rows(dead_ps, "dead")
        now = _scalar_per_row(dead_ps, "now", np.float64, dlens)
        store.update(dead, status=int(Status.FAILED), end_time=now)


def _batch_steer_prune(store: ColumnStore, ps: Sequence[Dict]) -> None:
    store.update(np.concatenate([p["rows"] for p in ps]),
                 status=int(Status.PRUNED))


# Ops whose consecutive runs coalesce into one vectorized update. insert
# keeps its per-record row-alignment check; steer_patch records can target
# different columns; requeue/resize are rare — all stay record-at-a-time.
_BATCH = {
    "claim": _batch_claim,
    "claim_all": _batch_claim_all,
    "finish": _batch_finish,
    "fail": _batch_fail,
    "steer_prune": _batch_steer_prune,
}


# --------------------------------------------------------- hot-plane slices
# The TxnLog accumulates claims/claim_alls/finishes into columnar planes at
# append time (_HotPlane), so a consecutive run replays as O(1) array
# slices: zero per-record payload reconstruction — the per-record Python
# toll the dict-extraction batchers above still pay.
def _plane_run(recs: Sequence[Txn]):
    """(plane, lo, hi) when the whole run lives contiguously in one plane.

    Records held by a caller across a ``TxnLog.truncate`` may predate the
    plane's base — their plane entries are gone, so they must route to the
    dict-payload fallback (their frozen payloads are intact); a negative
    offset here would silently slice the wrong retained entries.
    """
    first, last = recs[0], recs[-1]
    plane = first.plane
    if plane is None or last.plane is not plane \
            or last.pidx - first.pidx + 1 != len(recs) \
            or first.pidx < plane.base:
        return None
    return plane, first.pidx - plane.base, last.pidx + 1 - plane.base


def _plane_fields(plane, lo: int, hi: int):
    off = plane.off.view(lo, hi + 1)
    rows = plane.rows.view(int(off[0]), int(off[-1]))
    lens = np.diff(off)
    nowv = plane.now.view(lo, hi)
    single = bool(np.all(lens == 1))
    return rows, lens, (nowv if single else np.repeat(nowv, lens)), single


def _plane_claim(store: ColumnStore, plane, lo: int, hi: int) -> None:
    rows, lens, now, single = _plane_fields(plane, lo, hi)
    wv = plane.worker.view(lo, hi)
    w = wv if single else np.repeat(wv, lens)
    store.update(rows, status=int(Status.RUNNING), start_time=now,
                 worker_id=w, core_id=w)


def _plane_claim_all(store: ColumnStore, plane, lo: int, hi: int) -> None:
    rows, _, now, _ = _plane_fields(plane, lo, hi)
    store.update(rows, status=int(Status.RUNNING), start_time=now)


def _plane_finish(store: ColumnStore, plane, lo: int, hi: int) -> bool:
    """Returns False when the dom sub-update can't be served off the plane
    (mixed dom/no-dom rows, or width-drifted carriers whose dom rows never
    entered the buffer) — caller falls back for THIS run only."""
    doff = plane.dom_off.view(lo, hi + 1)
    d0, d1 = int(doff[0]), int(doff[-1])
    rows, _, now, _ = _plane_fields(plane, lo, hi)
    if d1 > d0:
        if d1 - d0 != rows.size:          # mixed dom/no-dom rows in the run
            return False
    elif int(plane.dom_flag.view(lo, hi).sum()):
        return False                      # carriers hidden by width drift
    store.update(rows, status=int(Status.FINISHED), end_time=now)
    if d1 > d0:         # every written row carries domain outputs
        dom = plane.dom.view(d0, d1)
        store.update(rows, **{f"out{i}": dom[:, i]
                              for i in range(dom.shape[1])})
    return True


def _run_via_plane(store: ColumnStore, op: str, recs: Sequence[Txn]) -> bool:
    sl = _plane_run(recs)
    if sl is None:
        return False
    plane, lo, hi = sl
    if op == "claim":
        _plane_claim(store, plane, lo, hi)
    elif op == "claim_all":
        _plane_claim_all(store, plane, lo, hi)
    elif op == "finish":
        return _plane_finish(store, plane, lo, hi)
    else:
        return False
    return True


def replay_reference(store: ColumnStore, records: Iterable[Txn]) -> int:
    """Record-at-a-time replay — the equivalence ORACLE for :func:`replay`.

    After each record the store's committed version is pinned to the
    record's ``store_version`` — multi-write ops bump the replica's counter
    differently than the primary's, and the pin re-aligns them.
    Returns the number of records applied.
    """
    n = 0
    for rec in records:
        try:
            op = _APPLY[rec.op]
        except KeyError:
            raise ValueError(f"no apply-op for txn log record {rec.op!r}; "
                             "DeltaReplicator cannot replay it") from None
        op(store, rec.payload)
        store.set_version(rec.store_version)
        n += 1
    return n


def replay(store: ColumnStore, records: Iterable[Txn]) -> int:
    """Apply a txn-log delta onto a (restored) store, in log order, with
    consecutive same-op runs coalesced into one vectorized update each.

    Bit-identical to :func:`replay_reference` (property-tested): within a
    run the status machine guarantees disjoint rows, and duplicate indices
    would apply last-wins in log order regardless. The version pin lands on
    the LAST record of each run — intermediate versions are unobservable
    inside a single replay call. Returns the number of records applied.
    """
    n = 0
    for op, run in itertools.groupby(records, key=attrgetter("op")):
        recs = list(run)
        batch = _BATCH.get(op)
        if batch is not None and len(recs) > 1:
            # hot planes first (O(1) slices of the log's columnar buffers);
            # dict-payload extraction covers everything the planes can't
            if not _run_via_plane(store, op, recs):
                batch(store, list(map(attrgetter("payload"), recs)))
        else:
            try:
                fn = _APPLY[op]
            except KeyError:
                raise ValueError(
                    f"no apply-op for txn log record {op!r}; "
                    "DeltaReplicator cannot replay it") from None
            for rec in recs:
                fn(store, rec.payload)
        store.set_version(recs[-1].store_version)
        n += len(recs)
    return n


_replica_seq = itertools.count()


class DeltaReplicator:
    """Replica catch-up by incremental txn-log replay.

    Restores a mutable shadow store from one ``snapshot_view()`` at
    construction, then every ``sync`` replays only the log tail appended
    since — O(delta), not O(store). ``recover`` rebuilds a consistent
    WorkQueue after primary loss (RUNNING tasks return to READY, their
    workers are presumed dead — the same semantics as requeue).

    Accounting for the e_replica_lag experiment: ``delta_bytes`` sums the
    payload wire sizes actually shipped; ``full_copy_bytes`` sums what a
    full-snapshot sync at each of the same sync points would have shipped
    (n_rows x row_nbytes), the baseline cost this subsystem removes.
    """

    def __init__(self, wq: WorkQueue, sync_every: int = 64):
        self.wq = wq
        self.sync_every = sync_every
        view = wq.store.snapshot_view()
        self.store = ColumnStore.from_view(view, wq.store.schema)
        self.store.blobs = dict(wq.store.blobs)     # side table: restore-only
        self.offset = wq.log.index_after_version(view.version)
        # registered consumer: truncate() keeps every record >= our acked
        # offset, so a lagging replica can always catch up after compaction.
        # The finalizer unregisters on GC — a dropped replica must not pin
        # the compaction floor forever (close() does it deterministically).
        self.consumer = f"replica-{next(_replica_seq)}"
        wq.log.register_consumer(self.consumer, self.offset)
        self._unregister = weakref.finalize(
            self, wq.log.unregister_consumer, self.consumer)
        self.num_workers = wq.num_workers
        self.records_applied = 0
        self.sync_count = 0
        self.delta_bytes = 0
        self.full_copy_bytes = 0

    # --------------------------------------------------------------- lag
    def lag(self) -> int:
        """Log records the replica is behind the primary."""
        return len(self.wq.log) - self.offset

    def maybe_sync(self) -> bool:
        if self.lag() >= self.sync_every:
            self.sync()
            return True
        return False

    # -------------------------------------------------------------- sync
    def sync(self, upto_version: Optional[int] = None) -> int:
        """Catch the replica up by replaying the unconsumed log tail.

        With ``upto_version`` the replay stops at that committed store
        version (bisected, not scanned) — used to align the replica with a
        specific primary ``snapshot_view()`` for version-exact reads.
        Replication only moves FORWARD: an ``upto_version`` the replica has
        already passed is a no-op (the consumed-log cursor and the replica
        version never rewind — rewinding would re-apply records on the next
        sync). Historical reads are ``SteeringEngine.at_version``'s job.
        Returns the number of records applied.
        """
        log = self.wq.log
        if upto_version is None:
            hi = len(log)
        else:
            try:
                hi = max(log.index_after_version(upto_version), self.offset)
            except LogCompactedError:
                # the target version predates the compaction horizon, which
                # the consumer floor guarantees we are already past: the
                # forward-only clamp would have produced a no-op anyway
                hi = self.offset
        recs = log.slice(self.offset, hi)
        applied = replay(self.store, recs)
        self.offset = hi
        log.ack(self.consumer, hi)
        for r in recs:
            if r.op == "resize":                # topology rides the log too
                self.num_workers = int(r.payload["workers"])
            self.delta_bytes += r.payload_nbytes()
        if upto_version is not None and upto_version > self.store.version:
            # caller vouches the log is complete through upto_version (all
            # writes used the logged API); pin even if the last record
            # committed earlier, so view.version == primary snapshot version
            # (forward only — never rewind past already-applied state)
            self.store.set_version(upto_version)
        self.records_applied += applied
        self.sync_count += 1
        self.full_copy_bytes += self.store.n_rows * self.store.row_nbytes()
        return applied

    def snapshot_view(self):
        """Immutable view of the replica at its caught-up version — what an
        analyst thread hands to ``SteeringEngine.run_all`` so analytical
        sweeps never touch the primary's arrays at all."""
        return self.store.snapshot_view()

    def close(self) -> None:
        """Drop the consumer registration so the log may compact past us."""
        self._unregister()       # idempotent; detaches the GC finalizer too

    # ----------------------------------------------------------- recovery
    def recover(self) -> WorkQueue:
        """Rebuild a WorkQueue from the replica after primary loss: catch up
        on the surviving log tail, return RUNNING tasks to READY (their
        workers are presumed lost) — same semantics as requeue after node
        failure. The replica store BECOMES the new primary store."""
        self.sync()
        store = self.store
        st = store.col("status")
        running = np.nonzero(st == int(Status.RUNNING))[0]
        if len(running):
            store.update(running, status=int(Status.READY))
        wq = WorkQueue(self.num_workers, store=store)
        wq._next_task_id = int(store.col("task_id").max() + 1) \
            if store.n_rows else 0
        return wq


# Backwards-compatible name: the per-partition replica of PR 0/1, now
# delta-fed. Callers that used ReplicaSet(wq).sync()/recover() keep working
# with sync cost dropped from O(store) to O(delta).
ReplicaSet = DeltaReplicator


class FullCopyReplica:
    """The pre-delta baseline: every sync deep-copies the whole store.

    Kept ONLY as the comparison arm of the e_replica_lag experiment (sync
    cost grows with store size, not delta size). Not for production use.
    """

    def __init__(self, wq: WorkQueue, sync_every: int = 64):
        self.wq = wq
        self.sync_every = sync_every
        self.snapshot = wq.store.snapshot()
        self.offset = len(wq.log)
        self.sync_count = 0
        self.copy_bytes = 0

    def lag(self) -> int:
        return len(self.wq.log) - self.offset

    def maybe_sync(self) -> bool:
        if self.lag() >= self.sync_every:
            self.sync()
            return True
        return False

    def sync(self) -> int:
        applied = self.lag()
        self.snapshot = self.wq.store.snapshot()
        self.offset = len(self.wq.log)
        self.sync_count += 1
        self.copy_bytes += (self.snapshot["n_rows"]
                            * self.wq.store.row_nbytes())
        return applied

    def recover(self) -> WorkQueue:
        store = ColumnStore.restore(self.snapshot)
        st = store.col("status")
        running = np.nonzero(st == int(Status.RUNNING))[0]
        if len(running):
            store.update(running, status=int(Status.READY))
        wq = WorkQueue(self.wq.num_workers, store=store)
        wq._next_task_id = int(store.col("task_id").max() + 1) \
            if store.n_rows else 0
        return wq
