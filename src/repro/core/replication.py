"""Delta replication: replica catch-up by txn-log replay (paper Section 3.2).

The paper keeps one replica per partition so a data-node crash loses nothing,
and reports tens-of-MB metadata for 100k-task workloads — small enough to
ship incrementally. :class:`DeltaReplicator` implements exactly that: the
replica is a mutable store restored from a ``snapshot_view()`` once, then
caught up by replaying ``TxnLog.tail_for_version`` records — apply-ops for
every op the WorkQueue emits (insert/add_tasks, claim, claim_all, finish,
fail, requeue_worker, resize, steering patches/prunes). ``sync`` cost is
O(delta records), independent of store size; the old full-snapshot copy is
preserved as :class:`FullCopyReplica`, the O(store) baseline the
``e_replica_lag`` benchmark measures against.

Because the store is append-only (rows are never deleted or compacted),
primary row indices are valid verbatim on any replica that replayed the same
log prefix — payload row indices ARE the replica addresses, no id remapping.
Replayed record versions pin ``store.version`` to the primary's committed
version, so a caught-up replica at version v is bit-identical to a primary
``snapshot_view()`` at v (sweep parity is asserted in tests and the
e_replica_lag experiment).

The raw-pointer side table (``store.blobs``) is copied at restore time but
NOT delta-shipped: like the paper, raw files stay out of the DBMS and out of
the replication stream.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional

import numpy as np

from repro.core.schema import Status
from repro.core.store import ColumnStore
from repro.core.transactions import Txn
from repro.core.workqueue import WorkQueue


# --------------------------------------------------------------- apply ops
def _apply_insert(store: ColumnStore, p: Dict) -> None:
    idx = store.insert(p["rows"])
    # append-only determinism: replayed rows must land exactly where the
    # primary put them, else every later payload's row indices are garbage
    if len(idx) and int(idx[0]) != int(p["row_idx"][0]):
        raise RuntimeError(
            f"replica diverged: insert replayed at row {int(idx[0])}, "
            f"primary committed at {int(p['row_idx'][0])}")
    exp = p.get("expanded_rows")
    if exp is not None and len(exp):
        store.update(exp, expanded=1)


def _apply_claim(store: ColumnStore, p: Dict) -> None:
    w = int(p["worker"])
    store.update(p["rows"], status=int(Status.RUNNING), start_time=p["now"],
                 worker_id=w, core_id=w)


def _apply_claim_all(store: ColumnStore, p: Dict) -> None:
    store.update(p["rows"], status=int(Status.RUNNING), start_time=p["now"])


def _apply_finish(store: ColumnStore, p: Dict) -> None:
    store.update(p["rows"], status=int(Status.FINISHED), end_time=p["now"])
    dom = p.get("domain_out")
    if dom is not None:
        store.update(p["rows"], **{f"out{i}": dom[:, i]
                                   for i in range(dom.shape[1])})


def _apply_fail(store: ColumnStore, p: Dict) -> None:
    store.update(p["rows"], fail_trials=p["trials"])
    if len(p["retry"]):
        store.update(p["retry"], status=int(Status.READY))
    if len(p["dead"]):
        store.update(p["dead"], status=int(Status.FAILED),
                     end_time=p["now"])


def _apply_requeue(store: ColumnStore, p: Dict) -> None:
    store.update(p["rows"], status=int(Status.READY),
                 fail_trials=p["trials"], worker_id=p["new_worker"])


def _apply_resize(store: ColumnStore, p: Dict) -> None:
    if len(p["rows"]):
        store.update(p["rows"], worker_id=p["assign"])


def _apply_steer_patch(store: ColumnStore, p: Dict) -> None:
    store.update(p["rows"], **{p["col"]: p["value"]})


def _apply_steer_prune(store: ColumnStore, p: Dict) -> None:
    store.update(p["rows"], status=int(Status.PRUNED))


_APPLY = {
    "insert": _apply_insert,
    "claim": _apply_claim,
    "claim_all": _apply_claim_all,
    "finish": _apply_finish,
    "fail": _apply_fail,
    "requeue_worker": _apply_requeue,
    "resize": _apply_resize,
    "steer_patch": _apply_steer_patch,
    "steer_prune": _apply_steer_prune,
}


def replay(store: ColumnStore, records: Iterable[Txn]) -> int:
    """Apply a txn-log delta onto a (restored) store, in log order.

    After each record the store's committed version is pinned to the
    record's ``store_version`` — multi-write ops bump the replica's counter
    differently than the primary's, and the pin re-aligns them.
    Returns the number of records applied.
    """
    n = 0
    for rec in records:
        try:
            op = _APPLY[rec.op]
        except KeyError:
            raise ValueError(f"no apply-op for txn log record {rec.op!r}; "
                             "DeltaReplicator cannot replay it") from None
        op(store, rec.payload)
        store.set_version(rec.store_version)
        n += 1
    return n


class DeltaReplicator:
    """Replica catch-up by incremental txn-log replay.

    Restores a mutable shadow store from one ``snapshot_view()`` at
    construction, then every ``sync`` replays only the log tail appended
    since — O(delta), not O(store). ``recover`` rebuilds a consistent
    WorkQueue after primary loss (RUNNING tasks return to READY, their
    workers are presumed dead — the same semantics as requeue).

    Accounting for the e_replica_lag experiment: ``delta_bytes`` sums the
    payload wire sizes actually shipped; ``full_copy_bytes`` sums what a
    full-snapshot sync at each of the same sync points would have shipped
    (n_rows x row_nbytes), the baseline cost this subsystem removes.
    """

    def __init__(self, wq: WorkQueue, sync_every: int = 64):
        self.wq = wq
        self.sync_every = sync_every
        view = wq.store.snapshot_view()
        self.store = ColumnStore.from_view(view, wq.store.schema)
        self.store.blobs = dict(wq.store.blobs)     # side table: restore-only
        self.offset = wq.log.index_after_version(view.version)
        self.num_workers = wq.num_workers
        self.records_applied = 0
        self.sync_count = 0
        self.delta_bytes = 0
        self.full_copy_bytes = 0

    # --------------------------------------------------------------- lag
    def lag(self) -> int:
        """Log records the replica is behind the primary."""
        return len(self.wq.log) - self.offset

    def maybe_sync(self) -> bool:
        if self.lag() >= self.sync_every:
            self.sync()
            return True
        return False

    # -------------------------------------------------------------- sync
    def sync(self, upto_version: Optional[int] = None) -> int:
        """Catch the replica up by replaying the unconsumed log tail.

        With ``upto_version`` the replay stops at that committed store
        version (bisected, not scanned) — used to align the replica with a
        specific primary ``snapshot_view()`` for version-exact reads.
        Replication only moves FORWARD: an ``upto_version`` the replica has
        already passed is a no-op (the consumed-log cursor and the replica
        version never rewind — rewinding would re-apply records on the next
        sync). Historical reads are ``SteeringEngine.at_version``'s job.
        Returns the number of records applied.
        """
        log = self.wq.log
        hi = len(log) if upto_version is None \
            else max(log.index_after_version(upto_version), self.offset)
        recs = log.records[self.offset:hi]
        applied = replay(self.store, recs)
        self.offset = hi
        for r in recs:
            if r.op == "resize":                # topology rides the log too
                self.num_workers = int(r.payload["workers"])
            self.delta_bytes += r.payload_nbytes()
        if upto_version is not None and upto_version > self.store.version:
            # caller vouches the log is complete through upto_version (all
            # writes used the logged API); pin even if the last record
            # committed earlier, so view.version == primary snapshot version
            # (forward only — never rewind past already-applied state)
            self.store.set_version(upto_version)
        self.records_applied += applied
        self.sync_count += 1
        self.full_copy_bytes += self.store.n_rows * self.store.row_nbytes()
        return applied

    def snapshot_view(self):
        """Immutable view of the replica at its caught-up version — what an
        analyst thread hands to ``SteeringEngine.run_all`` so analytical
        sweeps never touch the primary's arrays at all."""
        return self.store.snapshot_view()

    # ----------------------------------------------------------- recovery
    def recover(self) -> WorkQueue:
        """Rebuild a WorkQueue from the replica after primary loss: catch up
        on the surviving log tail, return RUNNING tasks to READY (their
        workers are presumed lost) — same semantics as requeue after node
        failure. The replica store BECOMES the new primary store."""
        self.sync()
        store = self.store
        st = store.col("status")
        running = np.nonzero(st == int(Status.RUNNING))[0]
        if len(running):
            store.update(running, status=int(Status.READY))
        wq = WorkQueue(self.num_workers, store=store)
        wq._next_task_id = int(store.col("task_id").max() + 1) \
            if store.n_rows else 0
        return wq


# Backwards-compatible name: the per-partition replica of PR 0/1, now
# delta-fed. Callers that used ReplicaSet(wq).sync()/recover() keep working
# with sync cost dropped from O(store) to O(delta).
ReplicaSet = DeltaReplicator


class FullCopyReplica:
    """The pre-delta baseline: every sync deep-copies the whole store.

    Kept ONLY as the comparison arm of the e_replica_lag experiment (sync
    cost grows with store size, not delta size). Not for production use.
    """

    def __init__(self, wq: WorkQueue, sync_every: int = 64):
        self.wq = wq
        self.sync_every = sync_every
        self.snapshot = wq.store.snapshot()
        self.offset = len(wq.log)
        self.sync_count = 0
        self.copy_bytes = 0

    def lag(self) -> int:
        return len(self.wq.log) - self.offset

    def maybe_sync(self) -> bool:
        if self.lag() >= self.sync_every:
            self.sync()
            return True
        return False

    def sync(self) -> int:
        applied = self.lag()
        self.snapshot = self.wq.store.snapshot()
        self.offset = len(self.wq.log)
        self.sync_count += 1
        self.copy_bytes += (self.snapshot["n_rows"]
                            * self.wq.store.row_nbytes())
        return applied

    def recover(self) -> WorkQueue:
        store = ColumnStore.restore(self.snapshot)
        st = store.col("status")
        running = np.nonzero(st == int(Status.RUNNING))[0]
        if len(running):
            store.update(running, status=int(Status.READY))
        wq = WorkQueue(self.wq.num_workers, store=store)
        wq._next_task_id = int(store.col("task_id").max() + 1) \
            if store.n_rows else 0
        return wq
