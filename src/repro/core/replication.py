"""Delta replication: replica catch-up by txn-log replay (paper Section 3.2).

The paper keeps one replica per partition so a data-node crash loses nothing,
and reports tens-of-MB metadata for 100k-task workloads — small enough to
ship incrementally. :class:`DeltaReplicator` implements exactly that: the
replica is a mutable store restored from a ``snapshot_view()`` once, then
caught up by replaying ``TxnLog.tail_for_version`` records — apply-ops for
every op the WorkQueue emits (insert/add_tasks, claim, claim_all, finish,
fail, requeue_worker, resize, steering patches/prunes). ``sync`` cost is
O(delta records), independent of store size; the old full-snapshot copy is
preserved as :class:`FullCopyReplica`, the O(store) baseline the
``e_replica_lag`` benchmark measures against.

Because the store is append-only (rows are never deleted or compacted),
primary row indices are valid verbatim on any replica that replayed the same
log prefix — payload row indices ARE the replica addresses, no id remapping.
Replayed record versions pin ``store.version`` to the primary's committed
version, so a caught-up replica at version v is bit-identical to a primary
``snapshot_view()`` at v (sweep parity is asserted in tests and the
e_replica_lag experiment).

Batched replay
--------------
Real logs are dominated by long runs of same-op records (claims and finishes
— the paper's Experiment 6 op inventory). :func:`replay` coalesces each
consecutive same-op run into ONE vectorized ``store.update`` (rows
concatenated, per-record scalars repeated per row), so replay cost scales
with the number of RUNS, not records. Safe because within a run the touched
rows are disjoint by the status machine (a row cannot be claimed/finished/
failed twice without an intervening record of a different op), and NumPy
fancy-index assignment applies duplicates last-wins in log order anyway.
:func:`replay_reference` keeps the record-at-a-time loop as the equivalence
oracle (property-tested bit-identical, and the denominator of the
bench-trajectory replay-throughput gate).

The raw-pointer side table (``store.blobs``) is copied at restore time but
NOT delta-shipped: like the paper, raw files stay out of the DBMS and out of
the replication stream.

Replicas are registered txn-log CONSUMERS: every ``sync`` acks the consumed
offset, so ``TxnLog.truncate`` can drop the prefix all replicas (and the
checkpointer) are past — bounding long-run log memory without ever dropping
a record a lagging replica still needs.
"""
from __future__ import annotations

import abc
import concurrent.futures
import itertools
import multiprocessing
import os
import pickle
import queue
import struct
import threading
import time
import traceback
import weakref
from collections import deque
from operator import attrgetter, itemgetter
from typing import Callable, Dict, Iterable, List, Optional, Sequence

import numpy as np

from repro.core import transport as transport_mod
from repro.core import wire
from repro.core.schema import Status
from repro.core.store import ColumnStore
from repro.core.transactions import LogCompactedError, Txn, plane_run
from repro.core.workqueue import WorkQueue


# --------------------------------------------------------------- apply ops
def _apply_insert(store: ColumnStore, p: Dict) -> None:
    idx = store.insert(p["rows"])
    # append-only determinism: replayed rows must land exactly where the
    # primary put them, else every later payload's row indices are garbage
    if len(idx) and int(idx[0]) != int(p["row_idx"][0]):
        raise RuntimeError(
            f"replica diverged: insert replayed at row {int(idx[0])}, "
            f"primary committed at {int(p['row_idx'][0])}")
    exp = p.get("expanded_rows")
    if exp is not None and len(exp):
        store.update(exp, expanded=1)


def _apply_claim(store: ColumnStore, p: Dict) -> None:
    # lease stamps are DERIVED, not shipped: expires_at = now + the lease
    # duration carried on the restored store snapshot, the same float64 op
    # the primary ran — so lease columns stay bit-identical with zero new
    # wire fields (claim frames still carry only rows/now/worker)
    w = int(p["worker"])
    store.update(p["rows"], status=int(Status.RUNNING), start_time=p["now"],
                 worker_id=w, core_id=w, claimed_at=p["now"],
                 heartbeat_at=p["now"],
                 expires_at=p["now"] + store.lease_s)


def _apply_claim_all(store: ColumnStore, p: Dict) -> None:
    store.update(p["rows"], status=int(Status.RUNNING), start_time=p["now"],
                 claimed_at=p["now"], heartbeat_at=p["now"],
                 expires_at=p["now"] + store.lease_s)


def _apply_finish(store: ColumnStore, p: Dict) -> None:
    store.update(p["rows"], status=int(Status.FINISHED), end_time=p["now"],
                 heartbeat_at=p["now"])
    dom = p.get("domain_out")
    if dom is not None:
        store.update(p["rows"], **{f"out{i}": dom[:, i]
                                   for i in range(dom.shape[1])})


def _apply_fail(store: ColumnStore, p: Dict) -> None:
    store.update(p["rows"], fail_trials=p["trials"])
    if len(p["retry"]):
        store.update(p["retry"], status=int(Status.READY))
    if len(p["dead"]):
        store.update(p["dead"], status=int(Status.FAILED),
                     end_time=p["now"])


def _apply_requeue(store: ColumnStore, p: Dict) -> None:
    store.update(p["rows"], status=int(Status.READY),
                 fail_trials=p["trials"], worker_id=p["new_worker"])


def _apply_resize(store: ColumnStore, p: Dict) -> None:
    if len(p["rows"]):
        store.update(p["rows"], worker_id=p["assign"])


def _apply_steer_patch(store: ColumnStore, p: Dict) -> None:
    store.update(p["rows"], **{p["col"]: p["value"]})


def _apply_steer_prune(store: ColumnStore, p: Dict) -> None:
    store.update(p["rows"], status=int(Status.PRUNED))


def _apply_reap(store: ColumnStore, p: Dict) -> None:
    store.update(p["rows"], fail_trials=p["trials"])
    if len(p["retry"]):
        store.update(p["retry"], status=int(Status.READY),
                     claimed_at=np.nan, heartbeat_at=np.nan,
                     expires_at=np.nan)
        # reaped retries are rehashed onto the CURRENT partition map (the
        # reaper may run after a resize); older logs lack the key
        new_worker = p.get("new_worker")
        if new_worker is not None:
            store.update(p["retry"], worker_id=new_worker)
    if len(p["dead"]):
        store.update(p["dead"], status=int(Status.FAILED),
                     end_time=p["now"])


def _apply_lease_renew(store: ColumnStore, p: Dict) -> None:
    store.update(p["rows"], heartbeat_at=p["now"],
                 expires_at=p["now"] + store.lease_s)


_APPLY = {
    "insert": _apply_insert,
    "claim": _apply_claim,
    "claim_all": _apply_claim_all,
    "finish": _apply_finish,
    "fail": _apply_fail,
    "requeue_worker": _apply_requeue,
    "resize": _apply_resize,
    "steer_patch": _apply_steer_patch,
    "steer_prune": _apply_steer_prune,
    # lease ops are rare (one reap per expiry sweep, renewals batched per
    # heartbeat tick): cold-path records, no plane/batch fast path needed
    "reap": _apply_reap,
    "lease_renew": _apply_lease_renew,
}


# --------------------------------------------------------------- batch ops
# Builders are deliberately lean: payload row arrays are concatenated as-is
# (they are frozen int64 ndarrays by construction — _freeze copies, never
# re-types), per-record scalars stream through np.fromiter, and the repeat
# out to row counts collapses to the scalar vector itself when every record
# in the run wrote one row (per-worker claims, per-task finishes — the
# dominant shape). Per-record Python cost is what the >=10x replay gate
# measures, so every avoidable per-record allocation here is load-bearing.
def _scalar_per_row(ps: Sequence[Dict], key: str, dtype,
                    lens: Optional[np.ndarray]) -> np.ndarray:
    vals = np.fromiter(map(itemgetter(key), ps), dtype, len(ps))
    # lens is None for all-single-row runs (the dominant shape): the scalar
    # vector IS the per-row vector, no repeat needed
    return vals if lens is None else np.repeat(vals, lens)


def _run_rows(ps: Sequence[Dict], key: str = "rows"):
    """(concatenated row indices, per-record lengths) for one same-op run.

    Returns ``lens=None`` when every record wrote exactly one row, the
    common case for per-worker claims / per-task finishes — callers then
    skip the repeat entirely. The check is exact: empty records make
    ``rows.size == len(ps)`` alias, so the per-record lengths are compared,
    not the total.
    """
    rows_list = list(map(itemgetter(key), ps))
    lens = np.fromiter(map(len, rows_list), np.int64, len(rows_list))
    if bool(np.all(lens == 1)):
        return np.fromiter(map(itemgetter(0), rows_list), np.int64,
                           len(rows_list)), None
    return np.concatenate(rows_list), lens


def _batch_claim(store: ColumnStore, ps: Sequence[Dict]) -> None:
    rows, lens = _run_rows(ps)
    now = _scalar_per_row(ps, "now", np.float64, lens)
    w = _scalar_per_row(ps, "worker", np.int32, lens)
    store.update(rows, status=int(Status.RUNNING), start_time=now,
                 worker_id=w, core_id=w, claimed_at=now, heartbeat_at=now,
                 expires_at=now + store.lease_s)


def _batch_claim_all(store: ColumnStore, ps: Sequence[Dict]) -> None:
    rows, lens = _run_rows(ps)
    now = _scalar_per_row(ps, "now", np.float64, lens)
    store.update(rows, status=int(Status.RUNNING), start_time=now,
                 claimed_at=now, heartbeat_at=now,
                 expires_at=now + store.lease_s)


def _batch_finish(store: ColumnStore, ps: Sequence[Dict]) -> None:
    rows, lens = _run_rows(ps)
    now = _scalar_per_row(ps, "now", np.float64, lens)
    store.update(rows, status=int(Status.FINISHED), end_time=now,
                 heartbeat_at=now)
    dom_ps = [p for p in ps if p.get("domain_out") is not None]
    if dom_ps:
        width = dom_ps[0]["domain_out"].shape[1]
        if all(p["domain_out"].shape[1] == width for p in dom_ps):
            drows, _ = _run_rows(dom_ps)
            dom = np.concatenate(list(map(itemgetter("domain_out"), dom_ps)))
            store.update(drows, **{f"out{i}": dom[:, i]
                                   for i in range(dom.shape[1])})
        else:
            # mixed output widths across the run: concatenation would raise,
            # so the (disjoint) dom sub-updates apply record by record
            for p in dom_ps:
                d = p["domain_out"]
                store.update(p["rows"], **{f"out{i}": d[:, i]
                                           for i in range(d.shape[1])})


def _batch_fail(store: ColumnStore, ps: Sequence[Dict]) -> None:
    rows, _ = _run_rows(ps)
    trials = np.concatenate(list(map(itemgetter("trials"), ps)))
    store.update(rows, fail_trials=trials)
    retry = np.concatenate(list(map(itemgetter("retry"), ps)))
    if retry.size:
        store.update(retry, status=int(Status.READY))
    dead_ps = [p for p in ps if len(p["dead"])]
    if dead_ps:
        dead, dlens = _run_rows(dead_ps, "dead")
        now = _scalar_per_row(dead_ps, "now", np.float64, dlens)
        store.update(dead, status=int(Status.FAILED), end_time=now)


def _batch_steer_prune(store: ColumnStore, ps: Sequence[Dict]) -> None:
    store.update(np.concatenate([p["rows"] for p in ps]),
                 status=int(Status.PRUNED))


# Ops whose consecutive runs coalesce into one vectorized update. insert
# keeps its per-record row-alignment check; steer_patch records can target
# different columns; requeue/resize are rare — all stay record-at-a-time.
_BATCH = {
    "claim": _batch_claim,
    "claim_all": _batch_claim_all,
    "finish": _batch_finish,
    "fail": _batch_fail,
    "steer_prune": _batch_steer_prune,
}


# --------------------------------------------------------- hot-plane slices
# The TxnLog accumulates claims/claim_alls/finishes into columnar planes at
# append time (_HotPlane), so a consecutive run replays as O(1) array
# slices: zero per-record payload reconstruction — the per-record Python
# toll the dict-extraction batchers above still pay. Run eligibility
# (contiguity, truncation survival) is transactions.plane_run, shared with
# the wire codec so replay and shipping route runs identically.
def _plane_fields(plane, lo: int, hi: int):
    off = plane.off.view(lo, hi + 1)
    rows = plane.rows.view(int(off[0]), int(off[-1]))
    lens = np.diff(off)
    nowv = plane.now.view(lo, hi)
    single = bool(np.all(lens == 1))
    return rows, lens, (nowv if single else np.repeat(nowv, lens)), single


def _plane_claim(store: ColumnStore, plane, lo: int, hi: int) -> None:
    rows, lens, now, single = _plane_fields(plane, lo, hi)
    wv = plane.worker.view(lo, hi)
    w = wv if single else np.repeat(wv, lens)
    store.update(rows, status=int(Status.RUNNING), start_time=now,
                 worker_id=w, core_id=w, claimed_at=now, heartbeat_at=now,
                 expires_at=now + store.lease_s)


def _plane_claim_all(store: ColumnStore, plane, lo: int, hi: int) -> None:
    rows, _, now, _ = _plane_fields(plane, lo, hi)
    store.update(rows, status=int(Status.RUNNING), start_time=now,
                 claimed_at=now, heartbeat_at=now,
                 expires_at=now + store.lease_s)


def _plane_finish(store: ColumnStore, plane, lo: int, hi: int) -> bool:
    """Returns False when the dom sub-update can't be served off the plane
    (mixed dom/no-dom rows, or width-drifted carriers whose dom rows never
    entered the buffer) — caller falls back for THIS run only."""
    doff = plane.dom_off.view(lo, hi + 1)
    d0, d1 = int(doff[0]), int(doff[-1])
    rows, _, now, _ = _plane_fields(plane, lo, hi)
    if d1 > d0:
        if d1 - d0 != rows.size:          # mixed dom/no-dom rows in the run
            return False
    elif int(plane.dom_flag.view(lo, hi).sum()):
        return False                      # carriers hidden by width drift
    store.update(rows, status=int(Status.FINISHED), end_time=now,
                 heartbeat_at=now)
    if d1 > d0:         # every written row carries domain outputs
        dom = plane.dom.view(d0, d1)
        store.update(rows, **{f"out{i}": dom[:, i]
                              for i in range(dom.shape[1])})
    return True


def _apply_plane(store: ColumnStore, op: str, plane, lo: int,
                 hi: int) -> bool:
    if op == "claim":
        _plane_claim(store, plane, lo, hi)
    elif op == "claim_all":
        _plane_claim_all(store, plane, lo, hi)
    elif op == "finish":
        return _plane_finish(store, plane, lo, hi)
    else:
        return False
    return True


def _run_via_plane(store: ColumnStore, op: str, recs: Sequence[Txn]) -> bool:
    sl = plane_run(recs)
    if sl is None:
        return False
    plane, lo, hi = sl
    return _apply_plane(store, op, plane, lo, hi)


def replay_reference(store: ColumnStore, records: Iterable[Txn]) -> int:
    """Record-at-a-time replay — the equivalence ORACLE for :func:`replay`.

    After each record the store's committed version is pinned to the
    record's ``store_version`` — multi-write ops bump the replica's counter
    differently than the primary's, and the pin re-aligns them.
    Returns the number of records applied.
    """
    n = 0
    for rec in records:
        try:
            op = _APPLY[rec.op]
        except KeyError:
            raise ValueError(f"no apply-op for txn log record {rec.op!r}; "
                             "DeltaReplicator cannot replay it") from None
        op(store, rec.payload)
        store.set_version(rec.store_version)
        n += 1
    return n


def replay(store: ColumnStore, records: Iterable[Txn],
           progress: Optional[Callable[[Sequence[Txn]], None]] = None) -> int:
    """Apply a txn-log delta onto a (restored) store, in log order, with
    consecutive same-op runs coalesced into one vectorized update each.

    Bit-identical to :func:`replay_reference` (property-tested): within a
    run the status machine guarantees disjoint rows, and duplicate indices
    would apply last-wins in log order regardless. The version pin lands on
    the LAST record of each run — intermediate versions are unobservable
    inside a single replay call. Returns the number of records applied.

    ``progress`` (when given) is invoked with each applied-and-version-
    pinned batch of records — per run on the vectorized path, per record on
    the fallback path. It is the commit hook consumers use to keep their
    offset/bytes accounting TRANSACTIONAL with the applied prefix: if a
    later record raises, everything already passed to ``progress`` is
    durably applied and must not be replayed (or re-counted) on retry.
    """
    n = 0
    for op, run in itertools.groupby(records, key=attrgetter("op")):
        recs = list(run)
        batch = _BATCH.get(op)
        if batch is not None and len(recs) > 1:
            # hot planes first (O(1) slices of the log's columnar buffers);
            # dict-payload extraction covers everything the planes can't
            if not _run_via_plane(store, op, recs):
                batch(store, list(map(attrgetter("payload"), recs)))
            store.set_version(recs[-1].store_version)
            n += len(recs)
            if progress is not None:
                progress(recs)
        else:
            try:
                fn = _APPLY[op]
            except KeyError:
                raise ValueError(
                    f"no apply-op for txn log record {op!r}; "
                    "DeltaReplicator cannot replay it") from None
            for rec in recs:
                fn(store, rec.payload)
                store.set_version(rec.store_version)
                n += 1
                if progress is not None:
                    progress((rec,))
    return n


def replay_runs(store: ColumnStore, runs) -> int:
    """Run-level replay of :func:`repro.core.wire.decode_delta_runs`
    output — the replica child's D-message hot path.

    Bit-identical to ``replay(store, decode_delta(buf))`` (shared plane
    serving, property-tested parity): hot frames apply straight off their
    receive plane with NO per-record object materialization — the
    dominant decode+replay cost on bulk catch-ups — and fall back to the
    record paths only for the shapes the plane cannot serve (single
    records, non-servable finish runs, cold frames)."""
    n = 0
    for dr in runs:
        if dr.plane is not None and dr.n > 1:
            if not _apply_plane(store, dr.op, dr.plane, 0, dr.n):
                _BATCH[dr.op](store,
                              [r.payload for r in dr.materialize()])
            store.set_version(dr.last_version)
            n += dr.n
        else:
            for rec in (dr.recs if dr.recs is not None
                        else dr.materialize()):
                try:
                    fn = _APPLY[rec.op]
                except KeyError:
                    raise ValueError(
                        f"no apply-op for txn log record {rec.op!r}; "
                        "DeltaReplicator cannot replay it") from None
                fn(store, rec.payload)
                store.set_version(rec.store_version)
                n += 1
    return n


_replica_seq = itertools.count()


class AllReplicasDeadError(RuntimeError):
    """Raised by :meth:`ReplicaGroup.elect` / :meth:`ReplicaGroup.promote`
    when every member's process is dead: there is no survivor whose live
    state can be trusted past its last ack, so election would crown a
    corpse. Callers that CAN restart from a durable snapshot should do so
    explicitly (Checkpointer.restore), not through promote()."""


class Replicator(abc.ABC):
    """The one replication surface the executor (and everything above it)
    programs against — the API consolidation of the four arms that accreted
    over PRs 2-5: :class:`DeltaReplicator`, :class:`ShippedDeltaReplicator`,
    :class:`ReplicaGroup`, :class:`FullCopyReplica`.

    Contract:

    * ``sync(upto_version=None)`` catches the replica up, forward-only;
      with ``upto_version`` the replica lands exactly AT that committed
      store version when the call returns. Pipelined arms may return at
      ENQUEUE for the plain ``sync()`` — ``sync(upto_version=...)`` and
      :meth:`flush` are the barriers.
    * ``lag()`` / ``maybe_sync()`` — records behind, and the cadence
      helper bounding it by ``sync_every``.
    * ``recover()`` materializes a consistent :class:`WorkQueue` after
      primary loss; ``promote()`` is recover + release.
    * ``close()`` releases everything (consumer registrations, replica
      processes, shipper threads). Idempotent; never hangs; never raises.
    * ``stats()`` is the uniform observability dict benchmarks read.

    Construct concrete replicators through :func:`make_replicator`; only
    tests and benchmarks reach for the classes directly.
    """

    sync_every: int = 64

    @abc.abstractmethod
    def sync(self, upto_version: Optional[int] = None) -> int:
        """Catch up; returns records shipped/applied/staged this call."""

    @abc.abstractmethod
    def lag(self) -> int:
        """Log records the replica is behind the primary."""

    @abc.abstractmethod
    def recover(self) -> WorkQueue:
        """Materialize a consistent WorkQueue from the replica."""

    @abc.abstractmethod
    def close(self) -> None:
        """Release replica resources. Idempotent; never hangs."""

    def maybe_sync(self) -> bool:
        """Sync when lag reached ``sync_every`` — the cadence helper."""
        if self.lag() >= self.sync_every:
            self.sync()
            return True
        return False

    def flush(self) -> None:
        """Barrier for pipelined arms: returns once every enqueued delta
        is shipped AND acked, re-raising any background ship error.
        Synchronous arms are always flushed — the default is a no-op."""

    def promote(self) -> WorkQueue:
        """Failover: the recovered WorkQueue becomes the primary and the
        replica's resources are released."""
        wq = self.recover()
        self.close()
        return wq

    def stats(self) -> Dict[str, float]:
        """Uniform observability counters (benchmark/operator surface)."""
        return {
            "records_applied": int(getattr(self, "records_applied", 0)),
            "encoded_bytes": int(getattr(self, "encoded_bytes", 0)),
            "sync_count": int(getattr(self, "sync_count", 0)),
            "lag": int(self.lag()),
            "fanout_lag_s": 0.0,
        }


class DeltaReplicator(Replicator):
    """Replica catch-up by incremental txn-log replay.

    Restores a mutable shadow store from one ``snapshot_view()`` at
    construction, then every ``sync`` replays only the log tail appended
    since — O(delta), not O(store). ``recover`` rebuilds a consistent
    WorkQueue after primary loss (RUNNING tasks return to READY, their
    workers are presumed dead — the same semantics as requeue).

    Accounting for the e_replica_lag experiment: ``delta_bytes`` sums the
    payload sizes of the applied records (the in-memory cost model);
    ``encoded_bytes`` sums their exact wire-codec frame sizes (what a NIC
    would carry — :func:`repro.core.wire.frames_nbytes`); ``full_copy_bytes``
    sums what a full-snapshot sync at each of the same sync points would
    have shipped (n_rows x row_nbytes), the baseline cost this subsystem
    removes. All three advance TRANSACTIONALLY with the consumed offset
    (via replay's progress hook): a sync that raises mid-tail has counted
    exactly the records it durably applied, so a retry resumes at the
    failure point instead of re-applying — and re-counting — the prefix.
    """

    def __init__(self, wq: WorkQueue, sync_every: int = 64,
                 account_encoded: bool = True):
        self.wq = wq
        self.sync_every = sync_every
        # encoded_bytes is a benchmark-facing metric (what shipping the
        # applied delta would put on a NIC); sizing it pays pickle cost for
        # cold runs, so callers that never ship (the executor's in-process
        # analyst) opt out and keep the sync hot path free of it
        self.account_encoded = account_encoded
        view = wq.store.snapshot_view()
        self.store = ColumnStore.from_view(view, wq.store.schema)
        self.store.blobs = dict(wq.store.blobs)     # side table: restore-only
        self.offset = wq.log.index_after_version(view.version)
        # registered consumer: truncate() keeps every record >= our acked
        # offset, so a lagging replica can always catch up after compaction.
        # The finalizer unregisters on GC — a dropped replica must not pin
        # the compaction floor forever (close() does it deterministically).
        self.consumer = f"replica-{next(_replica_seq)}"
        wq.log.register_consumer(self.consumer, self.offset)
        self._unregister = weakref.finalize(
            self, wq.log.unregister_consumer, self.consumer)
        self.num_workers = wq.num_workers
        self.records_applied = 0
        self.sync_count = 0
        self.delta_bytes = 0
        self.encoded_bytes = 0
        self.full_copy_bytes = 0

    # --------------------------------------------------------------- lag
    def lag(self) -> int:
        """Log records the replica is behind the primary."""
        return len(self.wq.log) - self.offset

    # -------------------------------------------------------------- sync
    def sync(self, upto_version: Optional[int] = None) -> int:
        """Catch the replica up by replaying the unconsumed log tail.

        With ``upto_version`` the replay stops at that committed store
        version (bisected, not scanned) — used to align the replica with a
        specific primary ``snapshot_view()`` for version-exact reads.
        Replication only moves FORWARD: an ``upto_version`` the replica has
        already passed is a no-op (the consumed-log cursor and the replica
        version never rewind — rewinding would re-apply records on the next
        sync). Historical reads are ``SteeringEngine.at_version``'s job.
        Returns the number of records applied.
        """
        log = self.wq.log
        if upto_version is None:
            hi = len(log)
        else:
            try:
                hi = max(log.index_after_version(upto_version), self.offset)
            except LogCompactedError:
                # the target version predates the compaction horizon, which
                # the consumer floor guarantees we are already past: the
                # forward-only clamp would have produced a no-op anyway
                hi = self.offset
        recs = log.slice(self.offset, hi)
        applied_recs: List[Txn] = []

        def committed(run: Sequence[Txn]) -> None:
            # replay's commit hook: these records are durably applied, so
            # the consumed offset and the bytes counters advance together —
            # a raise later in the tail leaves them counted exactly once,
            # and the retry's log.slice starts past them (the regression
            # the old post-replay accounting loop double-paid)
            self.offset += len(run)
            applied_recs.extend(run)
            for r in run:
                if r.op == "resize":            # topology rides the log too
                    self.num_workers = int(r.payload["workers"])
                self.delta_bytes += r.payload_nbytes()
            self.records_applied += len(run)

        try:
            applied = replay(self.store, recs, progress=committed)
        finally:
            # ack whatever prefix was applied even on a mid-tail raise:
            # compaction may safely drop records this replica consumed.
            # Encoded bytes are sized over the whole applied prefix at once
            # so cold runs frame exactly as the encoder would ship them
            # (per-callback sizing would charge one frame per record)
            if self.account_encoded:
                self.encoded_bytes += wire.frames_nbytes(applied_recs)
            log.ack(self.consumer, self.offset)
        if upto_version is not None and upto_version > self.store.version:
            # caller vouches the log is complete through upto_version (all
            # writes used the logged API); pin even if the last record
            # committed earlier, so view.version == primary snapshot version
            # (forward only — never rewind past already-applied state)
            self.store.set_version(upto_version)
        self.sync_count += 1
        self.full_copy_bytes += self.store.n_rows * self.store.row_nbytes()
        return applied

    def snapshot_view(self):
        """Immutable view of the replica at its caught-up version — what an
        analyst thread hands to ``SteeringEngine.run_all`` so analytical
        sweeps never touch the primary's arrays at all."""
        return self.store.snapshot_view()

    def close(self) -> None:
        """Drop the consumer registration so the log may compact past us."""
        self._unregister()       # idempotent; detaches the GC finalizer too

    # ----------------------------------------------------------- recovery
    def recover(self) -> WorkQueue:
        """Rebuild a WorkQueue from the replica after primary loss: catch up
        on the surviving log tail, return RUNNING tasks to READY (their
        workers are presumed lost) — same semantics as requeue after node
        failure. The replica store BECOMES the new primary store."""
        self.sync()
        store = self.store
        st = store.col("status")
        running = np.nonzero(st == int(Status.RUNNING))[0]
        if len(running):
            store.update(running, status=int(Status.READY))
        wq = WorkQueue(self.num_workers, store=store)
        wq._next_task_id = int(store.col("task_id").max() + 1) \
            if store.n_rows else 0
        return wq


# Backwards-compatible name: the per-partition replica of PR 0/1, now
# delta-fed. Callers that used ReplicaSet(wq).sync()/recover() keep working
# with sync cost dropped from O(store) to O(delta).
ReplicaSet = DeltaReplicator


# ------------------------------------------------------- cross-process wire
# Control tags of the replica wire protocol. Every parent request gets
# exactly one reply; deltas are the only bulk payload and ship as wire
# frames (repro.core.wire), not pickles. The protocol is TRANSPORT-
# AGNOSTIC: it needs only the framed send/recv of
# :class:`repro.core.transport.Transport`, so the same replica process
# serves over a multiprocessing pipe or a TCP socket (another host)
# unchanged.
#   parent -> child:  I init (snapshot + hello features)   D delta frames
#                     S sweep request   G partial-sweep request
#                     X state fetch   P promote/recover   Q quit
#   child -> parent:  A ack(offset, version)[+ accepted features on init]
#                     R sweep result   H sweep partials (columnar)
#                     Y state   W recovered snapshot   E error (traceback)
_PIN_NONE = -(1 << 62)
_DHDR = struct.Struct("<qqq")            # lo offset, hi offset, version pin
_ACK = struct.Struct("<qq")              # absolute offset, store version

# Pipelined-shipper tuning: sentinel that stops the shipper thread, and the
# coalescing target — consecutive staged chunks merge into one D message
# until its encoded size reaches this, so tiny per-sync deltas stop paying
# one round trip each (the ship_mbps_incremental collapse of PR 5). The
# target is deliberately SMALLER than one staged chunk's encoded size on
# bulk catch-ups: big backlogs then split into several in-flight messages,
# and the remote's decode+replay of message k overlaps the encode and ack
# accounting of k+1 — one round trip per ~64 KiB costs ~nothing, while the
# overlap is where the pipelined bulk throughput comes from.
_SHIP_QUIT = object()
_COALESCE_TARGET_BYTES = 64 << 10


def _shipped_replica_main(spec) -> None:
    """Entry point of the replica OS process.

    Owns a private :class:`ColumnStore` restored from the primary's
    snapshot, applies decoded wire deltas with the same :func:`replay` the
    in-process replicator uses, and acks the ABSOLUTE log offset after each
    apply — the primary forwards that ack into ``TxnLog``'s consumer-floor
    machinery, so compaction semantics are identical across the process
    boundary. Steering sweeps (``S``) run HERE, against this process's
    store: the analyst never touches a primary array, not even a
    copy-on-write one.

    ``spec`` is the picklable transport spec (``("pipe", conn)`` or
    ``("tcp", host, port)``); the init exchange doubles as the HELLO:
    the primary offers its codec list, the reply carries the one this
    process accepted (wire frames self-describe, so decode needs no state
    — the negotiation pins what the SENDER may emit).
    """
    try:
        conn = transport_mod.child_endpoint(spec)
    except (OSError, EOFError):
        return                           # primary gone before we connected
    store: Optional[ColumnStore] = None
    num_workers = 1
    offset = 0
    # sweep wrapper cached across requests (its construction recounts READY
    # rows, O(store)); rebuilt only when the store or topology changes —
    # run_all itself reads nothing but the pinned snapshot view
    engine = None
    while True:
        try:
            msg = conn.recv_bytes()
        except (EOFError, OSError):
            return                       # primary gone: nothing to serve
        tag, body = msg[:1], msg[1:]
        try:
            if tag == b"Q":
                return
            if tag == b"I":
                snap, num_workers, offset, hello = pickle.loads(body)
                store = ColumnStore.restore(snap)
                engine = None
                accepted = wire.negotiate(hello.get("codecs", ("raw",)))
                conn.send_bytes(b"A" + _ACK.pack(offset, store.version)
                                + pickle.dumps({"codec": accepted}))
            elif tag == b"D":
                lo, hi, pin = _DHDR.unpack_from(body)
                runs = wire.decode_delta_runs(body[_DHDR.size:])
                replay_runs(store, runs)
                for dr in runs:
                    # resize is a cold op: only cold frames carry records
                    for r in (dr.recs or ()):
                        if r.op == "resize":  # topology rides the log too
                            num_workers = int(r.payload["workers"])
                            engine = None
                if pin != _PIN_NONE and pin > store.version:
                    store.set_version(pin)
                offset = hi
                conn.send_bytes(b"A" + _ACK.pack(offset, store.version))
            elif tag == b"S":
                (now,) = struct.unpack_from("<d", body)
                if engine is None:
                    from repro.core.steering import SteeringEngine
                    engine = SteeringEngine(
                        WorkQueue(num_workers, store=store))
                res = engine.run_all(now, view=store.snapshot_view())
                conn.send_bytes(b"R" + pickle.dumps(
                    res, protocol=pickle.HIGHEST_PROTOCOL))
            elif tag == b"G":
                # partial sweep: reduce HERE, ship only the aggregates.
                # The shard merge (sharding_router.merge_partials) happens
                # on the caller across every shard's reply. delay_s models
                # the data-node RPC latency of the paper's multi-host
                # regime (same role as run_baseline's access_latency_s) —
                # slept HERE so concurrent scatters genuinely overlap it
                # and a serial shard loop genuinely pays it per shard;
                # 0.0 (the production value) is a no-op.
                now_, horizon_, delay_ = struct.unpack_from("<ddd", body)
                if delay_ > 0.0:
                    time.sleep(delay_)
                from repro.core.steering import sweep_partials
                part = sweep_partials(store.snapshot_view(), num_workers,
                                      now_, horizon_)
                conn.send_bytes(b"H" + wire.encode_sweep_partial(part))
            elif tag == b"X":
                conn.send_bytes(b"Y" + pickle.dumps(
                    {"snapshot": store.snapshot(), "pid": os.getpid(),
                     "num_workers": num_workers, "offset": offset},
                    protocol=pickle.HIGHEST_PROTOCOL))
            elif tag == b"P":
                st = store.col("status")
                running = np.nonzero(st == int(Status.RUNNING))[0]
                if len(running):             # workers presumed dead with
                    store.update(running,    # the primary: requeue
                                 status=int(Status.READY))
                conn.send_bytes(b"W" + pickle.dumps(
                    (store.snapshot(), num_workers),
                    protocol=pickle.HIGHEST_PROTOCOL))
            else:
                raise ValueError(f"unknown wire control tag {tag!r}")
        except Exception:                                 # noqa: BLE001
            try:
                conn.send_bytes(b"E" + pickle.dumps(traceback.format_exc()))
            except Exception:                             # noqa: BLE001
                return


class ShippedDeltaReplicator(Replicator):
    """Delta replication across a REAL process boundary.

    The replica is a separate OS process (``spawn`` by default: a fresh
    interpreter, no shared address space) fed over a
    :class:`repro.core.transport.Transport`: every ``sync`` encodes the
    unconsumed log tail with the wire codec the hello exchange negotiated
    (varint-compressed hot frames by default, raw as the fallback), ships
    the frames, and advances its consumer offset only when the remote acks
    the absolute offset back — so ``TxnLog.truncate``'s consumer-floor
    machinery bounds log memory EXACTLY as it does for in-process replicas,
    and a replica that dies mid-ship re-syncs from its last acked offset
    (respawn restores from a fresh primary snapshot, which the floor
    guarantees is at or past every un-acked record) without parity loss.

    ``transport="pipe"`` is the same-host default; ``transport="tcp"``
    runs the identical protocol over a TCP socket — loopback in tests/CI,
    any host:port in a real deployment (the ``REPRO_WIRE_TRANSPORT`` env
    var flips the default, which is how CI exercises the socket path).

    ``remote_sweep`` runs a full Q1-Q7 steering sweep inside the replica
    process and ships the result back — the executor's ``analyst="remote"``
    mode, the paper's decoupled offline-analysis path made structural.
    ``recover``/``promote`` perform failover on the remote side (RUNNING
    tasks requeue THERE) and materialize the recovered WorkQueue locally.
    :class:`ReplicaGroup` broadcasts to N of these — this class IS the
    group's N=1 special case.

    Pipelined mode (``pipelined=True``, the factory default): ``sync()``
    stages the tail (captures the log records and their hot-plane column
    views on the CALLER's thread — the log's producer thread, per the
    TxnLog threading contract) and returns at ENQUEUE; a daemon shipper
    thread encodes (once, via a shareable :class:`repro.core.wire.
    DeltaEncoder`), ships with a bounded unacked window, and harvests acks
    — encode overlaps the remote's decode+replay instead of serializing
    with it. The transactional semantics are unchanged: consumer offset,
    ``log.ack`` (the compaction floor), and every byte counter advance
    ONLY on ack; the bounded queue blocks the producer when full so the
    replica lag stays bounded; ``flush()``/``sync(upto_version=...)`` are
    the barriers and the error surface (a background ship failure re-raises
    there, or on the next ``sync``). ``close``/``recover``/``promote``
    drain the queue first. Staging must stay single-producer (the same
    thread that appends to the log) — which TxnLog already requires.

    Thread contract: all wire I/O serializes on one internal lock, so the
    executor's analyst thread (sweeps) and scheduler thread (syncs) can
    share the replicator; the child services one request at a time. The
    shipper holds the lock for a whole burst, so foreign requests always
    see a clean channel between bursts.
    """

    def __init__(self, wq: WorkQueue, sync_every: int = 64,
                 start_method: str = "spawn",
                 transport: Optional[str] = None,
                 codec: Optional[wire.CodecLike] = None,
                 pipelined: bool = False, queue_depth: int = 16,
                 chunk_records: int = 2048, window: int = 4,
                 encoder: Optional[wire.DeltaEncoder] = None):
        self.wq = wq
        self.sync_every = sync_every
        self.transport = transport if transport is not None \
            else os.environ.get("REPRO_WIRE_TRANSPORT", "pipe")
        if self.transport not in ("pipe", "tcp"):
            raise ValueError(f"unknown transport {self.transport!r}")
        # what the hello OFFERS; the child's negotiate() picks the codec
        name = codec if codec is None or isinstance(codec, str) \
            else codec.name
        self._offer = list(wire.CODECS) if name is None else [name, "raw"]
        self.codec = "raw"               # negotiated name; hello fills it
        self._codec: wire.Codec = wire.as_codec("raw")
        self.consumer = f"replica-{next(_replica_seq)}"
        self._ctx = multiprocessing.get_context(start_method)
        self._mu = threading.Lock()
        self.process: Optional[multiprocessing.Process] = None
        self.tr: Optional[transport_mod.Transport] = None
        self.offset = 0
        self.replica_version = -1
        self.num_workers = wq.num_workers
        self.records_applied = 0
        self.sync_count = 0
        self.spawn_count = 0
        self.delta_bytes = 0             # payload cost model (payload_nbytes)
        self.encoded_bytes = 0           # exact bytes that crossed the wire
        self.encode_wall_s = 0.0
        self.ship_wall_s = 0.0           # send + remote decode/apply + ack
        self.pipelined = bool(pipelined)
        self.chunk_records = int(chunk_records)
        self.window = max(1, int(window))
        self.encoder = encoder if encoder is not None \
            else wire.DeltaEncoder()
        self.enq_offset = 0              # producer cursor: staged-through
        self.messages_sent = 0           # D messages (>=1 chunk coalesced)
        self._shipq: Optional[queue.Queue] = None
        self._ship_thread: Optional[threading.Thread] = None
        self._ship_error: Optional[BaseException] = None
        self._closed = False
        wq.log.register_consumer(self.consumer, 0)
        self._unregister = weakref.finalize(
            self, wq.log.unregister_consumer, self.consumer)
        with self._mu:
            self._spawn()
        self.enq_offset = self.offset
        if self.pipelined:
            self._shipq = queue.Queue(maxsize=max(2, int(queue_depth)))
            self._ship_thread = threading.Thread(
                target=self._ship_loop, name=f"{self.consumer}-shipper",
                daemon=True)
            self._ship_thread.start()

    # ------------------------------------------------------------ process
    def _spawn(self) -> None:
        """(Re)start the replica process from a fresh primary snapshot.

        The new consumer offset is the log index right after the snapshot
        version — never below the last remote ack (the snapshot is newer by
        construction), so compaction already performed against that ack
        stays sound.
        """
        snap = self.wq.store.snapshot()
        self.offset = max(self.offset,
                          self.wq.log.index_after_version(snap["version"]))
        listener = None
        if self.transport == "tcp":
            listener = transport_mod.TCPListener()
            spec = ("tcp",) + listener.address
        else:
            parent_conn, child_conn = self._ctx.Pipe()
            spec = ("pipe", child_conn)
        self.process = self._ctx.Process(
            target=_shipped_replica_main, args=(spec,),
            daemon=True, name=f"{self.consumer}-remote")
        try:
            self.process.start()
            if listener is not None:
                self.tr = listener.accept(timeout=60)
            else:
                child_conn.close()
                self.tr = transport_mod.PipeTransport(parent_conn)
        finally:
            if listener is not None:
                listener.close()
        self.spawn_count += 1
        reply = self._request(b"I" + pickle.dumps(
            (snap, self.wq.num_workers, self.offset,
             {"codecs": self._offer}),
            protocol=pickle.HIGHEST_PROTOCOL))
        _, self.replica_version = _ACK.unpack_from(reply, 1)
        hello = pickle.loads(reply[1 + _ACK.size:]) \
            if len(reply) > 1 + _ACK.size else {}
        self.codec = hello.get("codec", "raw")
        # the Codec OBJECT is resolved exactly once, here at hello time —
        # everything downstream (sync, shipper thread) holds the object,
        # not the string (satellite: no more codec= string threading)
        self._codec = wire.as_codec(self.codec)
        self.num_workers = self.wq.num_workers
        self.wq.log.ack(self.consumer, self.offset)

    def _kill(self, graceful: bool = False) -> None:
        p, t = self.process, self.tr
        self.process = None
        self.tr = None
        if t is not None:
            if graceful and p is not None and p.is_alive():
                # bounded best-effort: a dead or wedged child must never
                # hang close()/__del__ on a full pipe or closed socket
                t.try_send(b"Q", timeout=1.0)
            t.close()
        if p is not None:
            p.join(timeout=5)
            if p.is_alive():
                p.terminate()
                p.join(timeout=5)

    def _recv_reply(self, timeout: float = 120.0) -> bytes:
        """Receive one reply frame. ``E`` replies kill the child (its
        store may hold a partial apply) and surface the remote traceback.
        Split out of :meth:`_request` so the pipelined shipper can harvest
        acks for frames it sent a window ago."""
        if not self.tr.poll(timeout):
            self._kill()
            raise TimeoutError(
                f"remote replica silent for {timeout}s; killed")
        reply = self.tr.recv_bytes()
        if reply[:1] == b"E":
            detail = pickle.loads(reply[1:])
            self._kill()
            raise RuntimeError(f"remote replica failed:\n{detail}")
        return reply

    def _request(self, msg: bytes, timeout: float = 120.0) -> bytes:
        """One lockstep request/reply round trip."""
        self.tr.send_bytes(msg)
        return self._recv_reply(timeout)

    @property
    def remote_pid(self) -> Optional[int]:
        return self.process.pid if self.process is not None else None

    # --------------------------------------------------------------- lag
    def lag(self) -> int:
        """Log records the replica is behind the primary (acked, not
        merely enqueued — the pipelined cursor is ``enq_offset``)."""
        return len(self.wq.log) - self.offset

    # -------------------------------------------------------------- sync
    def sync(self, upto_version: Optional[int] = None) -> int:
        """Ship the unconsumed tail; returns #records shipped (synchronous
        mode) or staged+enqueued (pipelined mode).

        Semantics match :meth:`DeltaReplicator.sync` (forward-only,
        ``upto_version`` bisected and pinned remotely) with one addition:
        the consumer offset, byte counters, and ``log.ack`` advance only
        after the remote acks the absolute offset — accounting is
        transactional with what the replica durably consumed. A dead child
        triggers respawn-from-snapshot (the snapshot is taken after every
        staged record was appended, so it covers all of them).

        Pipelined: a plain ``sync()`` returns at enqueue (backpressure
        blocks when the bounded queue is full); ``sync(upto_version=...)``
        additionally drains the pipeline so the replica is AT the version
        when the call returns. A background ship error re-raises here.
        """
        if not self.pipelined:
            with self._mu:
                return self._sync_locked(upto_version)
        self._raise_ship_error()
        log = self.wq.log
        lo = max(self.enq_offset, self.offset)
        if upto_version is None:
            hi = len(log)
        else:
            try:
                hi = max(log.index_after_version(upto_version), lo)
            except LogCompactedError:
                hi = lo                  # already past it (consumer floor)
        n = hi - lo
        if n:
            # ONE queue item per sync: the shipper sees the whole staged
            # span in a single burst, so its unacked window pipelines
            # across every chunk instead of draining at chunk boundaries
            self._shipq.put(wire.stage_delta(
                log.slice(lo, hi), lo,
                chunk_records=self.chunk_records))  # full q -> block
            self.enq_offset = hi
        if upto_version is not None:
            # version-exact callers need the replica AT the version when
            # sync returns: drain the pipeline, then let the synchronous
            # path settle the pin-only edge under the lock
            self.flush()
            with self._mu:
                self._sync_locked(upto_version)
        return n

    # ----------------------------------------------------- pipelined shipper
    def _raise_ship_error(self) -> None:
        err, self._ship_error = self._ship_error, None
        if err is not None:
            raise err

    def flush(self) -> None:
        """Block until every enqueued chunk is shipped AND acked; this is
        the pipelined error surface (a background failure re-raises here).
        Synchronous mode is always flushed — no-op."""
        if not self.pipelined or self._shipq is None:
            return
        self._shipq.join()
        self._raise_ship_error()

    def _join_queue(self, timeout: float) -> bool:
        """``Queue.join`` with a deadline — close()'s bounded drain."""
        q = self._shipq
        deadline = time.monotonic() + timeout
        with q.all_tasks_done:
            while q.unfinished_tasks:
                left = deadline - time.monotonic()
                if left <= 0:
                    return False
                q.all_tasks_done.wait(left)
        return True

    def _ship_loop(self) -> None:
        """Daemon shipper: dequeue staged syncs (each item is the chunk
        list of ONE sync call), coalesce a burst, encode once (shared
        :class:`wire.DeltaEncoder`), ship with a bounded unacked window,
        harvest acks. Every dequeued item is task_done'd exactly once —
        on success, error, or after close — so ``flush()``/``close()``
        can never hang on a lost item."""
        q = self._shipq
        while True:
            item = q.get()
            if item is _SHIP_QUIT:
                q.task_done()
                return
            burst = [item]
            quit_seen = False
            while len(burst) < 64:
                try:
                    nxt = q.get_nowait()
                except queue.Empty:
                    break
                if nxt is _SHIP_QUIT:
                    quit_seen = True
                    break
                burst.append(nxt)
            try:
                if not self._closed:
                    self._ship_burst([c for item in burst for c in item])
            except Exception as e:                        # noqa: BLE001
                if self._ship_error is None:
                    self._ship_error = e   # flush()/next sync re-raises
            finally:
                for _ in burst:
                    q.task_done()
            if quit_seen:
                q.task_done()
                return

    def _ship_burst(self, chunks: Sequence) -> None:
        """Ship one burst under the wire lock — foreign requests (sweeps,
        fetches, recover) always see a clean channel between bursts."""
        with self._mu:
            if self.process is None or not self.process.is_alive():
                self._spawn()
            # the respawn snapshot is taken AFTER every staged record was
            # appended, so its log index is >= every enqueued hi: chunks
            # the snapshot already covers drop out here, never partially
            todo = [c for c in chunks if c.hi > self.offset]
            if not todo:
                return
            try:
                self._ship_window(todo)
            except (BrokenPipeError, EOFError, OSError):
                # died mid-ship: nothing past the last ack was consumed;
                # respawn from a fresh snapshot — the rest of this burst
                # (and the whole backlog) is inside it and will be skipped
                # by the offset filter above on the next burst
                self._kill()
                self._spawn()

    def _ship_window(self, todo: Sequence) -> None:
        """Encode-and-send with a bounded unacked window. Small consecutive
        chunks coalesce into one D message until ~_COALESCE_TARGET_BYTES of
        encoded payload (tiny per-sync deltas stop paying one round trip
        each); up to ``window`` messages ride the wire unacked, and acks
        harvest opportunistically while the next message encodes."""
        t0 = time.perf_counter()
        enc_wall = 0.0
        outstanding: deque = deque()
        i = 0
        while i < len(todo):
            group: List = []
            bufs: List = []
            g_bytes = 0
            while i < len(todo) and (not group
                                     or g_bytes < _COALESCE_TARGET_BYTES):
                c = todo[i]
                e0 = time.perf_counter()
                bufs.append(self.encoder.encode_staged(c, self._codec))
                enc_wall += time.perf_counter() - e0
                g_bytes += len(bufs[-1])
                group.append(c)
                i += 1
            lo, hi = group[0].lo, group[-1].hi
            self.tr.send_chunks(
                [b"D" + _DHDR.pack(lo, hi, _PIN_NONE)] + bufs)
            self.messages_sent += 1
            outstanding.append((hi, g_bytes, group))
            while outstanding and (len(outstanding) >= self.window
                                   or self.tr.poll(0)):
                self._harvest_one(outstanding)
        while outstanding:
            self._harvest_one(outstanding)
        self.encode_wall_s += enc_wall
        self.ship_wall_s += max(time.perf_counter() - t0 - enc_wall, 0.0)

    def _harvest_one(self, outstanding: deque) -> None:
        """Consume one ack and advance the transactional state: offset,
        compaction floor (``log.ack`` — the one TxnLog entry point that is
        cross-thread safe by contract), and the byte counters move together
        and only here."""
        hi, g_bytes, group = outstanding.popleft()
        reply = self._recv_reply()
        off, self.replica_version = _ACK.unpack_from(reply, 1)
        if off != hi:
            raise RuntimeError(
                f"remote replica acked offset {off}, expected {hi}")
        self.offset = hi
        self.wq.log.ack(self.consumer, hi)
        self.encoded_bytes += g_bytes
        n = 0
        for c in group:
            for run in c.runs:
                if run.op == "resize":   # topology rides the log too
                    self.num_workers = int(run.recs[-1].payload["workers"])
                self.delta_bytes += wire.staged_payload_nbytes(run)
                n += len(run.recs)
        self.records_applied += n
        self.sync_count += 1

    def _sync_locked(self, upto_version: Optional[int],
                     _retry: bool = True) -> int:
        log = self.wq.log
        if self.process is None or not self.process.is_alive():
            self._spawn()
        if upto_version is None:
            hi = len(log)
        else:
            try:
                hi = max(log.index_after_version(upto_version), self.offset)
            except LogCompactedError:
                hi = self.offset         # already past it (consumer floor)
        pin = _PIN_NONE
        if upto_version is not None and upto_version > self.replica_version:
            pin = int(upto_version)
        if hi == self.offset and pin == _PIN_NONE:
            return 0
        recs = log.slice(self.offset, hi)
        t0 = time.perf_counter()
        buf = self.encoder.encode_records(self.offset, hi, recs, self._codec)
        t1 = time.perf_counter()
        try:
            reply = self._request(
                b"D" + _DHDR.pack(self.offset, hi, pin) + buf)
        except (BrokenPipeError, EOFError, OSError):
            # died mid-ship: nothing past the last ack was consumed; the
            # respawn snapshot covers every un-acked record, so parity is
            # preserved — re-issue against the new offset
            if not _retry:
                raise
            self._kill()
            self._spawn()
            return self._sync_locked(upto_version, _retry=False)
        t2 = time.perf_counter()
        off, self.replica_version = _ACK.unpack_from(reply, 1)
        if off != hi:
            raise RuntimeError(
                f"remote replica acked offset {off}, expected {hi}")
        self.offset = hi
        log.ack(self.consumer, hi)
        self.encode_wall_s += t1 - t0
        self.ship_wall_s += t2 - t1
        self.encoded_bytes += len(buf)
        for r in recs:
            if r.op == "resize":
                self.num_workers = int(r.payload["workers"])
            self.delta_bytes += r.payload_nbytes()
        self.records_applied += len(recs)
        self.sync_count += 1
        return len(recs)

    # ------------------------------------------------------------ analyst
    def remote_sweep(self, now: float) -> Dict[str, object]:
        """Run a full Q1-Q7 steering sweep IN the replica process (against
        its own store at its caught-up version) and return the result.
        Pipelined shippers drain first — the sweep sees every delta that
        was enqueued before this call."""
        self.flush()
        with self._mu:
            if self.process is None or not self.process.is_alive():
                self._spawn()
            reply = self._request(b"S" + struct.pack("<d", float(now)))
            return pickle.loads(reply[1:])

    def remote_sweep_partials(self, now: float, horizon: float = 60.0,
                              delay_s: float = 0.0) -> Dict[str, object]:
        """Run `steering.sweep_partials` IN the replica process and return
        the decoded partial aggregates (bincount slabs + scalars + compact
        ancestry columns) — the shard-parallel steering plane's unit of
        work, merged across shards by `sharding_router.merge_partials`.
        Pipelined shippers drain first, so the partial is pinned at the
        last synced version (the caller hard-checks it). ``delay_s`` is
        slept remotely before the sweep — modeled data-node RPC latency
        for the latency-regime benchmarks; leave 0 in production."""
        self.flush()
        with self._mu:
            if self.process is None or not self.process.is_alive():
                self._spawn()
            reply = self._request(
                b"G" + struct.pack("<ddd", float(now), float(horizon),
                                   float(delay_s)))
            return wire.decode_sweep_partial(reply[1:])

    def fetch_remote_state(self) -> Dict[str, object]:
        """{snapshot, pid, num_workers, offset} straight from the replica
        process — the bit-parity and process-isolation evidence the
        e_wire_ship experiment hard-checks. Pipelined shippers drain
        first."""
        self.flush()
        with self._mu:
            if self.process is None or not self.process.is_alive():
                self._spawn()
            reply = self._request(b"X")
            return pickle.loads(reply[1:])

    # ----------------------------------------------------------- failover
    def recover(self) -> WorkQueue:
        """Failover: drain the surviving log tail into the replica, requeue
        its RUNNING tasks remotely, and materialize the recovered WorkQueue
        here (the replica store BECOMES the new primary store). Pipelined
        shippers drain their queue first (no enqueued record may be lost
        to the failover)."""
        if self.pipelined:
            self.sync()                  # stage whatever tail remains
            self.flush()                 # ship + ack everything enqueued
        with self._mu:
            self._sync_locked(None)      # stragglers; no-op when drained
            reply = self._request(b"P")
            snap, num_workers = pickle.loads(reply[1:])
        store = ColumnStore.restore(snap)
        wq = WorkQueue(num_workers, store=store)
        wq._next_task_id = int(store.col("task_id").max() + 1) \
            if store.n_rows else 0
        return wq

    def close(self) -> None:
        """Quit the replica process and stop pinning the compaction floor.

        Pipelined: the queued backlog drains (ships) first with a BOUNDED
        wait, then the shipper thread stops — close never hangs on a
        wedged child and never raises (a pending background ship error is
        discarded: the replica is being released anyway). Idempotent, and
        safe after a child crash: the graceful quit is a bounded
        ``try_send`` (never blocks on a dead or full pipe), kills fall
        back to terminate, and a second close is a no-op.
        """
        t, self._ship_thread = self._ship_thread, None
        if t is not None:
            if t.is_alive():
                self._join_queue(timeout=60.0)       # bounded drain
            self._closed = True          # shipper skips anything left
            try:
                self._shipq.put(_SHIP_QUIT, timeout=5.0)
            except queue.Full:
                pass
            t.join(timeout=10.0)
            self._ship_error = None      # close never raises
        with self._mu:
            self._kill(graceful=True)
        self._unregister()       # idempotent; detaches the GC finalizer too

    def stats(self) -> Dict[str, float]:
        s = super().stats()
        s.update(encode_wall_s=self.encode_wall_s,
                 ship_wall_s=self.ship_wall_s,
                 spawn_count=self.spawn_count,
                 messages_sent=self.messages_sent,
                 pipelined=float(self.pipelined))
        return s

    def __del__(self):
        # last-resort cleanup: must never raise or hang, even mid-interpreter
        # shutdown or after __init__ died before the process came up
        try:
            self.close()
        except Exception:                                 # noqa: BLE001
            pass


class ReplicaGroup(Replicator):
    """N-replica fan-out per partition: the paper's availability story at
    cluster scale (§4 — replica placement owned by the DBMS, one consumer
    group per partition), built by BROADCASTING the same wire deltas to N
    independent :class:`ShippedDeltaReplicator` members.

    The broadcast is ENCODE-ONCE and CONCURRENT: every member shares one
    :class:`repro.core.wire.DeltaEncoder`, so a delta chunk is encoded by
    whichever member gets there first and the other N-1 ship the cached
    bytes; ``sync`` fans out on a thread pool (one thread per member), so
    broadcast wall is ~max(member), not the serial sum.

    Every member is its own registered ``TxnLog`` consumer with its own
    acked offset, so the compaction floor is min-over-group BY CONSTRUCTION
    (``TxnLog.truncate`` already takes the min across registered
    consumers): a lagging member pins exactly the prefix it still needs,
    and nothing else. ``remote_sweep`` round-robins steering sweeps across
    members (the executor's ``analyst="remote"`` load-balancing);
    ``promote`` elects the most-caught-up LIVE member (highest acked
    offset; liveness first — a dead leader's ack is still durable via the
    consumer floor, but electing it would pay a respawn) and releases the
    rest.

    With ``n_replicas=1`` this is exactly one ShippedDeltaReplicator plus
    a method veneer — the N=1 special case every pre-fabric caller keeps.
    """

    def __init__(self, wq: WorkQueue, n_replicas: int = 1,
                 sync_every: int = 64, start_method: str = "spawn",
                 transport: Optional[str] = None,
                 codec: Optional[wire.CodecLike] = None,
                 pipelined: bool = False, queue_depth: int = 16,
                 chunk_records: int = 2048, window: int = 4):
        if n_replicas < 1:
            raise ValueError("a replica group needs at least one member")
        self.wq = wq
        self.sync_every = sync_every
        # ONE encoder for the whole group: each delta chunk is encoded
        # once, every member broadcasts the same bytes
        self.encoder = wire.DeltaEncoder(max_entries=max(32, 4 * n_replicas))
        self.members: List[ShippedDeltaReplicator] = []
        self._pool: Optional[concurrent.futures.ThreadPoolExecutor] = None
        try:
            for _ in range(n_replicas):
                self.members.append(ShippedDeltaReplicator(
                    wq, sync_every=sync_every, start_method=start_method,
                    transport=transport, codec=codec, pipelined=pipelined,
                    queue_depth=queue_depth, chunk_records=chunk_records,
                    window=window, encoder=self.encoder))
            if n_replicas > 1:
                self._pool = concurrent.futures.ThreadPoolExecutor(
                    max_workers=n_replicas, thread_name_prefix="fanout")
        except Exception:
            self.close()                 # no half-built group leaks processes
            raise
        self._rr = 0
        self.last_sync_wall_s: List[float] = [0.0] * n_replicas
        self.last_broadcast_wall_s = 0.0

    # N=1 veneer: callers written against ShippedDeltaReplicator (the
    # executor gotchas, notebooks) keep reading the same surface off a
    # group — per-member figures aggregate conservatively.
    @property
    def remote_pid(self) -> Optional[int]:
        """Pid of the first live member's process (see ``remote_pids``)."""
        pids = self.remote_pids
        return pids[0] if pids else None

    @property
    def remote_pids(self) -> List[int]:
        return [m.remote_pid for m in self.members
                if m.remote_pid is not None]

    @property
    def records_applied(self) -> int:
        """Records every member has durably applied (min over the group —
        the fan-out is only as caught up as its laggard)."""
        return min(m.records_applied for m in self.members)

    @property
    def encoded_bytes(self) -> int:
        """Total bytes the fan-out put on the wire (sum over members —
        a broadcast pays the delta once per replica)."""
        return sum(m.encoded_bytes for m in self.members)

    @property
    def codec(self) -> str:
        return self.members[0].codec

    # --------------------------------------------------------------- lag
    def lag(self) -> int:
        """Records the LAGGIEST member is behind (what maybe_sync bounds)."""
        return max(m.lag() for m in self.members)

    def lags(self) -> List[int]:
        """Per-member lag in log records (index-aligned with members)."""
        return [m.lag() for m in self.members]

    def fanout_lag_s(self) -> float:
        """End-to-end wall of the last broadcast ``sync`` — with the
        concurrent fan-out this is ~max(member wall), not the serial sum
        the member-by-member loop used to pay. The straggler signal
        (slowest minus fastest member) is :meth:`member_spread_s`."""
        return self.last_broadcast_wall_s

    def member_spread_s(self) -> float:
        """Slowest minus fastest member in the last broadcast — what an
        operator watches for a straggling replica."""
        return max(self.last_sync_wall_s) - min(self.last_sync_wall_s)

    # -------------------------------------------------------------- sync
    def sync(self, upto_version: Optional[int] = None) -> int:
        """Broadcast the unconsumed tail to every member CONCURRENTLY (one
        pool thread per member); returns the max records applied by any
        member (they may start at different acked offsets after respawns).
        Ack/floor semantics are per member — ``TxnLog.truncate`` keeps
        everything the slowest one still needs. The caller blocks until
        every member returned, so member-side staging reads of the log
        happen while the producer thread is parked — the TxnLog
        single-producer contract holds.
        """
        def timed(m: ShippedDeltaReplicator):
            t0 = time.perf_counter()
            n = m.sync(upto_version)
            return n, time.perf_counter() - t0
        b0 = time.perf_counter()
        if self._pool is None:
            results = [timed(m) for m in self.members]
        else:
            results = list(self._pool.map(timed, self.members))
        self.last_broadcast_wall_s = time.perf_counter() - b0
        self.last_sync_wall_s = [w for _, w in results]
        return max(n for n, _ in results)

    def flush(self) -> None:
        """Drain every member's pipeline (concurrently when pooled)."""
        if self._pool is None:
            for m in self.members:
                m.flush()
        else:
            list(self._pool.map(ShippedDeltaReplicator.flush, self.members))

    # ------------------------------------------------------------ analyst
    def remote_sweep(self, now: float) -> Dict[str, object]:
        """Q1-Q7 sweep on the next member, round-robin — N analysts share
        the steering load and no single replica process becomes the
        analytical hot spot."""
        m = self.members[self._rr % len(self.members)]
        self._rr += 1
        return m.remote_sweep(now)

    def remote_sweep_partials(self, now: float, horizon: float = 60.0,
                              delay_s: float = 0.0) -> Dict[str, object]:
        """Partial sweep on the next member, round-robin — same analyst
        load-spreading as :meth:`remote_sweep`, shipping only the partial
        aggregates (the sharded steering plane merges them)."""
        m = self.members[self._rr % len(self.members)]
        self._rr += 1
        return m.remote_sweep_partials(now, horizon, delay_s)

    # ----------------------------------------------------------- failover
    def elect(self) -> ShippedDeltaReplicator:
        """The member ``promote`` would crown: most-caught-up (highest
        acked offset, then replica version) among LIVE processes. When
        every process is dead there is no electable member — a corpse's
        store may trail its last ack arbitrarily — so this raises
        :class:`AllReplicasDeadError` instead of crowning one."""
        def key(m: ShippedDeltaReplicator):
            alive = m.process is not None and m.process.is_alive()
            return (alive, m.offset, m.replica_version)
        leader = max(self.members, key=key)
        if not (leader.process is not None and leader.process.is_alive()):
            raise AllReplicasDeadError(
                f"all {len(self.members)} replica processes are dead; "
                "nothing to promote — restore from a checkpoint instead")
        return leader

    def recover(self) -> WorkQueue:
        """Failover WITHOUT releasing the group: the elected member drains
        the surviving tail and materializes the recovered WorkQueue."""
        return self.elect().recover()

    def promote(self) -> WorkQueue:
        """Failover: promote the elected member (its replica store becomes
        the new primary) and release every other member's process."""
        leader = self.elect()
        for m in self.members:
            if m is not leader:
                m.close()
        wq = leader.promote()
        self.close()
        return wq

    def close(self) -> None:
        for m in self.members:
            m.close()
        if self._pool is not None:
            self._pool.shutdown(wait=False)
            self._pool = None

    def stats(self) -> Dict[str, float]:
        s = super().stats()
        s["fanout_lag_s"] = self.fanout_lag_s()
        s["member_spread_s"] = self.member_spread_s()
        s.update(self.encoder.stats())
        return s


# The fabric is the group plus the transport/codec policy baked into its
# members — one name for callers that think in topology terms.
ReplicationFabric = ReplicaGroup


class FullCopyReplica(Replicator):
    """The pre-delta baseline: every sync deep-copies the whole store.

    Kept ONLY as the comparison arm of the e_replica_lag experiment (sync
    cost grows with store size, not delta size). Not for production use.
    """

    def __init__(self, wq: WorkQueue, sync_every: int = 64):
        self.wq = wq
        self.sync_every = sync_every
        self.snapshot = wq.store.snapshot()
        self.offset = len(wq.log)
        self.sync_count = 0
        self.copy_bytes = 0

    def lag(self) -> int:
        return len(self.wq.log) - self.offset

    def sync(self, upto_version: Optional[int] = None) -> int:
        # ``upto_version`` accepted for Replicator-API parity: a full copy
        # is always at the primary's CURRENT version, which is >= any
        # committed upto_version a caller could name (forward-only holds)
        applied = self.lag()
        self.snapshot = self.wq.store.snapshot()
        self.offset = len(self.wq.log)
        self.sync_count += 1
        self.copy_bytes += (self.snapshot["n_rows"]
                            * self.wq.store.row_nbytes())
        return applied

    def recover(self) -> WorkQueue:
        store = ColumnStore.restore(self.snapshot)
        st = store.col("status")
        running = np.nonzero(st == int(Status.RUNNING))[0]
        if len(running):
            store.update(running, status=int(Status.READY))
        wq = WorkQueue(self.wq.num_workers, store=store)
        wq._next_task_id = int(store.col("task_id").max() + 1) \
            if store.n_rows else 0
        return wq

    def close(self) -> None:
        """Nothing to release: the baseline registers no log consumer and
        owns no processes — present for Replicator-API parity."""

    def stats(self) -> Dict[str, float]:
        s = super().stats()
        s["copy_bytes"] = int(self.copy_bytes)
        return s


# ------------------------------------------------------------------ factory
def make_replicator(wq: WorkQueue, mode: str = "delta", *,
                    replicas: int = 1, sync_every: int = 64,
                    transport: Optional[str] = None,
                    codec: Optional[wire.CodecLike] = None,
                    pipelined: Optional[bool] = None,
                    start_method: str = "spawn",
                    account_encoded: bool = True) -> Replicator:
    """The one construction site for replicators — everything above the
    core (the executor's ``analyst=`` modes, benchmarks, notebooks) asks
    for a replication POLICY by name instead of hand-wiring classes.

    Modes (aliases in parentheses):

    * ``"delta"`` (``"local"``, ``"replica"``) — in-process
      :class:`DeltaReplicator`: shadow store in the same address space.
    * ``"shipped"`` — one :class:`ShippedDeltaReplicator` process;
      PIPELINED by default (pass ``pipelined=False`` for lockstep
      request/reply shipping).
    * ``"remote"`` (``"group"``, ``"fabric"``) — a :class:`ReplicaGroup`
      of ``replicas`` members; pipelined by default.
    * ``"full"`` — the :class:`FullCopyReplica` baseline (benchmark arm).

    ``transport`` ("pipe"/"tcp") and ``codec`` thread through to the
    shipped modes; ``codec`` accepts a name ("adaptive"/"varint"/"raw")
    or a :class:`repro.core.wire.Codec` instance.
    """
    m = {"local": "delta", "replica": "delta",
         "group": "remote", "fabric": "remote"}.get(mode, mode)
    if m in ("delta", "full", "shipped") and replicas != 1:
        raise ValueError(
            f"mode {mode!r} is single-replica; got replicas={replicas} "
            "(use mode='remote' for a fan-out group)")
    if m == "delta":
        return DeltaReplicator(wq, sync_every=sync_every,
                               account_encoded=account_encoded)
    if m == "full":
        return FullCopyReplica(wq, sync_every=sync_every)
    if m == "shipped":
        return ShippedDeltaReplicator(
            wq, sync_every=sync_every, start_method=start_method,
            transport=transport, codec=codec,
            pipelined=True if pipelined is None else pipelined)
    if m == "remote":
        return ReplicaGroup(
            wq, n_replicas=replicas, sync_every=sync_every,
            start_method=start_method, transport=transport, codec=codec,
            pipelined=True if pipelined is None else pipelined)
    raise ValueError(f"unknown replicator mode {mode!r}")
