"""Replication: one replica per partition (paper Section 3.2, replication
factor 1) fed by the transaction log; partition recovery after data-node loss.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.core.schema import Status
from repro.core.store import ColumnStore
from repro.core.workqueue import WorkQueue


class ReplicaSet:
    """Maintains a shadow snapshot + consumed-log offset per data node.

    In the paper, MySQL Cluster keeps one replica per partition so a data
    node crash loses nothing. Here the replica is a snapshot + txn-log tail:
    ``sync`` consumes new log records cheaply (metadata sizes: the paper
    measured tens of MB for 100k-task workloads), ``recover`` rebuilds a
    consistent store after the primary is lost.
    """

    def __init__(self, wq: WorkQueue, sync_every: int = 64):
        self.wq = wq
        self.sync_every = sync_every
        self.snapshot = wq.store.snapshot()
        self.offset = len(wq.log)

    def maybe_sync(self) -> bool:
        if len(self.wq.log) - self.offset >= self.sync_every:
            self.sync()
            return True
        return False

    def sync(self) -> None:
        self.snapshot = self.wq.store.snapshot()
        self.offset = len(self.wq.log)

    def recover(self) -> WorkQueue:
        """Rebuild a WorkQueue from the replica snapshot. Tasks that were
        RUNNING at snapshot time are returned to READY (their workers are
        presumed lost) — same semantics as requeue after node failure."""
        store = ColumnStore.restore(self.snapshot)
        st = store.col("status")
        running = np.nonzero(st == int(Status.RUNNING))[0]
        if len(running):
            store.update(running, status=int(Status.READY))
        wq = WorkQueue(self.wq.num_workers, store=store)
        wq._next_task_id = int(store.col("task_id").max() + 1) \
            if store.n_rows else 0
        return wq
