"""Supervisor: task generation + activity-dependency expansion + failover.

The supervisor is the only component that INSERTS tasks (paper Fig. 2); it
never sits in the claim path. A secondary supervisor keeps a shadow of the
expansion cursor + txn-log offset and can be promoted at any time (removes
the paper's single point of failure).

Workflow model: a chain of activities (the Risers pipeline is 7 linked
activities); finishing a task of activity k spawns its dependent task of
activity k+1 (1:1 pipeline, matching the paper's synthetic workloads), with
optional fan-out. Domain outputs of the parent seed the child's inputs —
that is the dataflow the provenance queries (Q7/Q8) traverse.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from repro.configs.risers_workflow import WorkflowConfig
from repro.core.schema import Status
from repro.core.workqueue import WorkQueue


@dataclass
class SupervisorState:
    expanded_upto: Dict[int, int] = field(default_factory=dict)
    log_offset: int = 0
    generation: int = 0          # bumped on promote (fencing token)


class Supervisor:
    def __init__(self, wq: WorkQueue, workflow: WorkflowConfig,
                 fanout: int = 1):
        self.wq = wq
        self.workflow = workflow
        self.fanout = fanout
        self.state = SupervisorState()
        self.alive = True

    # ------------------------------------------------------------- seeding
    def seed(self, n_tasks: int, *, duration_s: float, rng: np.random.Generator,
             now: float = 0.0) -> np.ndarray:
        """Insert the activity-0 tasks with synthetic domain params."""
        lo, hi = self.workflow.param_low, self.workflow.param_high
        dom = rng.uniform(lo, hi, size=(n_tasks, 3))
        # controlled synthetic durations (the paper repeats runs to <1% std;
        # a heavy-tailed distribution would measure tail effects instead of
        # scheduler behavior)
        dur = rng.normal(duration_s, 0.1 * duration_s, n_tasks).clip(
            duration_s * 0.5, duration_s * 2.0)
        # durations go through add_tasks (one logged insert) so replicas
        # replaying the txn log reproduce them exactly
        return self.wq.add_tasks(0, n_tasks, domain_in=dom, now=now,
                                 duration_est=dur)

    # ------------------------------------------------------------ expansion
    def expand(self, now: float = 0.0) -> int:
        """Spawn activity-(k+1) tasks for newly FINISHED activity-k tasks.

        Dedup is carried by the store's ``expanded`` column, flipped in the
        SAME transaction/log record that inserts the children: correct under
        out-of-order finishes (a task finishing after a higher row index has
        already been expanded still gets its children), and a supervisor
        promoted onto a recovered replica resumes exactly — no duplicate and
        no lost expansions, because the watermark replicates with the data.
        """
        if not self.alive:
            return 0
        n_new = 0
        store = self.wq.store
        for k in range(self.workflow.num_activities - 1):
            st = store.col("status")
            act = store.col("activity_id")
            exp = store.col("expanded")
            rows = np.nonzero((st == int(Status.FINISHED)) & (act == k)
                              & (exp == 0))[0]
            if len(rows) == 0:
                continue
            parents = store.col("task_id")[rows]
            # child inputs = parent outputs (dataflow provenance edge)
            dom = np.stack([store.col(f"out{i}")[rows] for i in range(3)],
                           axis=1)
            dom = np.nan_to_num(dom, nan=0.0)
            dur = store.col("duration_est")[rows]
            ids = self.wq.add_tasks(k + 1, len(rows) * self.fanout,
                                    domain_in=np.repeat(dom, self.fanout, 0),
                                    parent_task=np.repeat(parents,
                                                          self.fanout),
                                    duration_est=np.repeat(dur, self.fanout),
                                    now=now,
                                    mark_expanded=rows)
            self.state.expanded_upto[k] = \
                self.state.expanded_upto.get(k, 0) + len(rows)
            n_new += len(ids)
        return n_new

    def done(self) -> bool:
        c = self.wq.counts()
        return (c["READY"] == 0 and c["RUNNING"] == 0
                and c["BLOCKED"] == 0)

    # -------------------------------------------------------------- failover
    def crash(self):
        self.alive = False


class SecondarySupervisor:
    """Shadow: tracks the primary's state via the txn log; promote() yields a
    fully functional Supervisor that resumes expansion exactly where the
    primary stopped (dedup via the expansion cursor)."""

    def __init__(self, primary: Supervisor):
        self.primary = primary
        self.shadow = SupervisorState()

    def sync(self):
        self.shadow.expanded_upto = dict(self.primary.state.expanded_upto)
        self.shadow.log_offset = len(self.primary.wq.log)

    def promote(self, wq: Optional[WorkQueue] = None) -> Supervisor:
        """Promote onto the primary's WQ, or — after data-node loss — onto a
        WorkQueue recovered from a replica (``DeltaReplicator.recover()``).

        The expansion watermark is the store's ``expanded`` column, so the
        promoted supervisor needs no cursor handoff: it derives exactly
        which FINISHED tasks still lack children from the recovered data
        itself. The shadow cursor is kept as an observability counter.
        """
        target = wq if wq is not None else self.primary.wq
        sup = Supervisor(target, self.primary.workflow, self.primary.fanout)
        sup.state = SupervisorState(
            expanded_upto=dict(self.shadow.expanded_upto),
            log_offset=self.shadow.log_offset,
            generation=self.primary.state.generation + 1)
        return sup
