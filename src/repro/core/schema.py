"""Work-Queue relation schema (paper Fig. 3) + task status machine.

The WQ relation holds execution data (scheduling), domain data (task
parameters/results) and provenance links in ONE store — the paper's central
design decision (Section 2: storing them separately causes redundancy and
blocks runtime analysis).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import numpy as np


class Status(enum.IntEnum):
    EMPTY = 0        # unallocated row
    BLOCKED = 1      # waiting on dependency (upstream activity)
    READY = 2
    RUNNING = 3
    FINISHED = 4
    FAILED = 5       # exhausted fail_trials
    PRUNED = 6       # removed by user steering (paper's data reduction / Q8)


# legal transitions of the task state machine
TRANSITIONS: Dict[int, Tuple[int, ...]] = {
    Status.EMPTY: (Status.BLOCKED, Status.READY),
    Status.BLOCKED: (Status.READY, Status.PRUNED),
    Status.READY: (Status.RUNNING, Status.PRUNED),
    Status.RUNNING: (Status.FINISHED, Status.READY, Status.FAILED),
    # RUNNING->READY = retry after worker failure (fail_trials += 1)
    Status.FINISHED: (),
    Status.FAILED: (),
    Status.PRUNED: (),
}


def _transition_matrix() -> np.ndarray:
    m = np.zeros((int(max(Status)) + 1, int(max(Status)) + 1), bool)
    for frm, tos in TRANSITIONS.items():
        for to in tos:
            m[int(frm), int(to)] = True
    return m


# boolean legality matrix indexed [current_status, to]: lets the WorkQueue
# validate a whole batch with one gather instead of a per-status Python loop
LEGAL_TRANSITIONS = _transition_matrix()


@dataclass(frozen=True)
class Column:
    name: str
    dtype: np.dtype
    default: float = 0


# Fig. 3 columns (Task Id, Act Id, Worker Id, Core, Fail.Trials, Start/End
# Time, Status) + provenance (parent task) + generic domain slots. Command
# line / stdout raw strings live in the side table (store.py blobs), exactly
# like the paper keeps raw files out of the DBMS and pointers inside.
def wq_schema(num_domain_in: int = 3, num_domain_out: int = 3
              ) -> List[Column]:
    cols = [
        Column("task_id", np.dtype(np.int64), -1),
        Column("activity_id", np.dtype(np.int32), -1),
        Column("worker_id", np.dtype(np.int32), -1),
        Column("core_id", np.dtype(np.int32), -1),
        Column("status", np.dtype(np.int32), int(Status.EMPTY)),
        Column("fail_trials", np.dtype(np.int32), 0),
        Column("submit_time", np.dtype(np.float64), np.nan),
        Column("start_time", np.dtype(np.float64), np.nan),
        Column("end_time", np.dtype(np.float64), np.nan),
        # Work Claim Pattern lease columns: a claim stamps claimed_at /
        # heartbeat_at and an expiry deadline in the SAME transaction as the
        # RUNNING flip, so worker liveness lives in the relation itself —
        # an expired lease is reaped as a data-plane event (reap_expired),
        # no supervisor round-trip needed. NaN = row holds no lease.
        Column("claimed_at", np.dtype(np.float64), np.nan),
        Column("heartbeat_at", np.dtype(np.float64), np.nan),
        Column("expires_at", np.dtype(np.float64), np.nan),
        Column("duration_est", np.dtype(np.float64), 0.0),  # simulated cost
        Column("parent_task", np.dtype(np.int64), -1),      # provenance edge
        # dependency-expansion watermark: 1 once the supervisor has spawned
        # this FINISHED task's children. Lives IN the relation (not in
        # supervisor memory) so failover dedup survives data-node loss: a
        # promoted supervisor on a recovered replica derives exactly which
        # parents still need expansion from the store itself.
        Column("expanded", np.dtype(np.int32), 0),
        Column("bytes_in", np.dtype(np.int64), 0),
        Column("bytes_out", np.dtype(np.int64), 0),
    ]
    for i in range(num_domain_in):
        cols.append(Column(f"in{i}", np.dtype(np.float64), np.nan))
    for i in range(num_domain_out):
        cols.append(Column(f"out{i}", np.dtype(np.float64), np.nan))
    return cols


TERMINAL = (Status.FINISHED, Status.FAILED, Status.PRUNED)
