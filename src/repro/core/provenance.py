"""W3C PROV-style provenance capture over the shared store.

The store already holds the provenance *relation* (task rows with
parent_task edges, domain inputs/outputs, timings, agents=workers); this
module materializes PROV-DM terms from it: Entity (data values / artifacts),
Activity (task executions), Agent (workers), and the used / wasGeneratedBy /
wasAssociatedWith / wasDerivedFrom relations. Matches the paper's claim that
WQ data *is* provenance data — written once, queried at runtime.

Document construction is column-oriented: the occupied/finished/derived row
sets come from vectorized masks, per-agent association counts from ONE
bincount segment reduction over worker ids (the same reduction shape as the
steering engine's Q1), and the per-row dictionaries are built from
pre-gathered arrays — no per-row column access, no per-worker re-masking.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterator, List

import numpy as np

from repro.core.schema import Status
from repro.core.workqueue import WorkQueue


def prov_document(wq: WorkQueue, workflow_name: str = "workflow"
                  ) -> Dict[str, Any]:
    store = wq.store
    st = store.col("status")
    doc: Dict[str, Any] = {
        "prefix": {"repro": "urn:repro:", "prov": "http://www.w3.org/ns/prov#"},
        "activity": {}, "entity": {}, "agent": {},
        "used": [], "wasGeneratedBy": [], "wasAssociatedWith": [],
        "wasDerivedFrom": [],
    }
    tid = store.col("task_id")
    act = store.col("activity_id")
    wid = store.col("worker_id")
    t0 = store.col("start_time")
    t1 = store.col("end_time")
    parent = store.col("parent_task")
    ins = np.stack([store.col(f"in{j}") for j in range(3)], axis=1)
    outs = np.stack([store.col(f"out{j}") for j in range(3)], axis=1)

    occ = np.nonzero(st != int(Status.EMPTY))[0]
    # agents + association counts in one segment reduction over worker ids
    # (Q1-style bincount: no per-worker pass, idle workers read count 0)
    rw = wid[occ]
    assoc = np.bincount(rw[rw >= 0].astype(np.int64),
                        minlength=wq.num_workers) if occ.size \
        else np.zeros(wq.num_workers, np.int64)
    for w, c in enumerate(assoc):
        doc["agent"][f"repro:worker_{w}"] = {
            "prov:type": "prov:SoftwareAgent",
            "repro:tasksAssociated": int(c),
        }

    fin = st[occ] == int(Status.FINISHED)
    for i, a_name in zip(occ, (f"repro:task_{t}" for t in tid[occ])):
        doc["activity"][a_name] = {
            "prov:type": f"repro:activity_{act[i]}",
            "prov:startTime": None if np.isnan(t0[i]) else float(t0[i]),
            "prov:endTime": None if np.isnan(t1[i]) else float(t1[i]),
            "repro:status": Status(int(st[i])).name,
        }
        ein = f"repro:input_{tid[i]}"
        doc["entity"][ein] = {
            f"repro:in{j}": float(ins[i, j]) for j in range(3)
            if not np.isnan(ins[i, j])}
        doc["used"].append({"prov:activity": a_name, "prov:entity": ein})
        doc["wasAssociatedWith"].append(
            {"prov:activity": a_name, "prov:agent": f"repro:worker_{wid[i]}"})
    for i in occ[fin]:
        a_name = f"repro:task_{tid[i]}"
        eout = f"repro:output_{tid[i]}"
        doc["entity"][eout] = {
            f"repro:out{j}": float(outs[i, j]) for j in range(3)
            if not np.isnan(outs[i, j])}
        doc["wasGeneratedBy"].append(
            {"prov:entity": eout, "prov:activity": a_name})
        if parent[i] >= 0:
            doc["wasDerivedFrom"].append(
                {"prov:generatedEntity": eout,
                 "prov:usedEntity": f"repro:output_{parent[i]}"})
    return doc


def export_provenance(wq: WorkQueue, path: str,
                      workflow_name: str = "workflow") -> None:
    with open(path, "w") as f:
        json.dump(prov_document(wq, workflow_name), f, indent=1)


def derivation_path(wq: WorkQueue, task_id: int) -> List[int]:
    """Walk wasDerivedFrom edges back to the source activity."""
    store = wq.store
    parent = store.col("parent_task")
    id_to_row = store.id_index()          # cached task_id -> row gather table

    def row_of(t: int) -> int:
        return int(id_to_row[t]) if 0 <= t < id_to_row.shape[0] else -1

    path = [task_id]
    row = row_of(task_id)
    while row >= 0 and parent[row] >= 0:
        path.append(int(parent[row]))
        row = row_of(int(parent[row]))
    return path
