"""Zero-copy columnar wire codec for txn-log delta shipping.

The paper's replicas live on OTHER hosts: the transaction-log delta must
actually cross a NIC, and the cost of crossing it is the cost of the bytes
ON THE WIRE, not of the Python objects in memory. This codec turns a log
tail into length-prefixed frames a socket/pipe can ship:

HOT frames (claim / claim_all / finish — the ops that dominate real logs)
ship a *plane slice*: the txn log already accumulates these ops into
columnar hot planes at append time (:class:`~repro.core.transactions._HotPlane`),
so a consecutive same-op run encodes as a handful of contiguous typed
buffers (row indices, per-record scalars, domain outputs) framed verbatim —
no per-record dict traversal, no pickling on the hot path. Decoding is
``np.frombuffer`` over the received buffer: the arrays alias the wire bytes
(zero-copy), and the decoded records carry a receive-side plane so
:func:`repro.core.replication.replay` takes its O(1)-slice fast path on the
replica too.

COLD frames cover everything else (inserts, fails, steering, resizes, runs
whose plane entries were dropped by a ``TxnLog.truncate``): self-describing
pickled ``(op, store_version, payload)`` triples. Cold ops are rare by the
paper's op inventory (Fig. 12), so the fallback's per-record cost never
sits on the replication hot path.

Compressed hot frames (codec ``"varint"``)
------------------------------------------
The integer planes of a hot run are nearly-free to shrink before they hit
a NIC: ``store_version`` increments by ~1 per record, row indices within a
run are nearly sorted, per-record row counts are tiny, and ``now``
timestamps form near-arithmetic sequences. ``HOTC`` frames therefore ship
delta + zigzag + varint streams (first value absolute, then diffs) for
``versions``/``rows``/``worker``, plain varints for the per-record lengths
(``off`` re-derives by cumsum), and a double-delta varint of the raw IEEE
bit patterns for ``now`` (arithmetic timestamp sequences collapse to
1-byte records; arbitrary floats degrade gracefully to <= 10 bytes).
Domain outputs stay raw ``f64`` — simulation results don't varint. All
encode/decode paths are vectorized NumPy (no per-record Python), decode is
bit-exact vs the raw codec (the parity oracle, property-tested), and the
codec is negotiated PER CONNECTION in the replication hello exchange —
``raw`` stays the universal fallback.

Frame layout (all little-endian)::

    header  : magic u16 | ftype u8 | opcode u8 | n_records u32 | body u64
    HOT body: versions i64[n] | off i64[n+1] (re-based, off[0]==0)
              | rows i64[off[n]] | now f64[n]
              | claim only:  worker i32[n]
              | finish only: has_dom u8 | width u32
                             | dom f64[off[n] * width]  (has_dom == 1 only)
    HOTC body: versions dzv[n] | lens v[n] | rows dzv[off[n]]
              | now ddv[n]
              | claim only:  worker dzv[n]
              | finish only: has_dom u8 | width u32 | dom f64 (raw)
              (v = varint, dzv = delta+zigzag varint with absolute first
               value, ddv = double-delta varint of the u64 bit patterns)
    COLD body: pickle([(op, store_version, payload), ...])

``off`` is the cumulative per-record row count (n+1 entries), so a frame is
fully self-delimiting: every section length derives from the header and the
previously parsed sections. A hot finish frame is only emitted when the
run is *plane-servable* (every written row carries domain outputs, or none
does — the same condition replay's plane path checks); mixed or
width-drifted runs fall back to a cold frame, which preserves their frozen
payloads bit-exactly.
"""
from __future__ import annotations

import itertools
import pickle
import struct
import threading
from collections import OrderedDict
from operator import attrgetter
from typing import Any, Dict, Iterable, List, Optional, Sequence, Union

import numpy as np

from repro.core.transactions import Txn, plane_run

MAGIC = 0x5157                       # "WQ"
FT_HOT = 1
FT_COLD = 2
FT_HOTC = 3                          # varint/delta compressed hot frame

_HDR = struct.Struct("<HBBIQ")       # magic, ftype, opcode, n_records, body
_FIN = struct.Struct("<BI")          # has_dom, dom width

_OPCODES = {"claim": 1, "claim_all": 2, "finish": 3}
_OPS = {v: k for k, v in _OPCODES.items()}

# Codec names this build can ENCODE and DECODE, in preference order. The
# replication hello exchange offers the sender's list; the receiver picks
# the first it supports (negotiate). "raw" is the universal fallback and
# the bit-parity oracle the compressed paths are tested against.
CODECS = ("adaptive", "varint", "raw")


def negotiate(offered) -> str:
    """Receiver side of the hello exchange: first offered codec we speak."""
    for c in offered:
        if c in CODECS:
            return c
    return "raw"


# ------------------------------------------------------------------ codecs
class Codec:
    """Per-connection encode policy, resolved ONCE at hello time.

    Frames self-describe their encoding (``ftype``), so the decoder needs
    no codec state — a ``Codec`` only decides, per hot frame, which
    encoding the SENDER emits. ``choose`` sees the frame's shape (op,
    record count, the exact raw body size, and how much of it is the
    incompressible f64 domain block) and returns ``"raw"`` or
    ``"varint"``. The ``"raw"``/``"varint"`` string spellings remain
    accepted everywhere via :func:`as_codec` for back-compat.
    """

    name = "?"

    def choose(self, op: str, n_records: int, raw_nbytes: int,
               dom_nbytes: int) -> str:
        raise NotImplementedError

    def __repr__(self) -> str:                        # pragma: no cover
        return f"<Codec {self.name}>"


class RawCodec(Codec):
    """Always raw: the universal fallback and bit-parity oracle."""

    name = "raw"

    def choose(self, op, n_records, raw_nbytes, dom_nbytes) -> str:
        return "raw"


class VarintCodec(Codec):
    """Always varint-compress hot frames (PR 5 behavior)."""

    name = "varint"

    def choose(self, op, n_records, raw_nbytes, dom_nbytes) -> str:
        return "varint"


class AdaptiveCodec(Codec):
    """Per-frame choice: compress only where the varint planes pay.

    * Tiny frames (< ``min_records``) ship raw — the encode setup cost
      exceeds the handful of bytes saved, and short alternating runs are
      exactly the incremental-sync shape whose throughput collapsed when
      every frame paid the varint toll.
    * Dom-heavy finish frames ship raw: domain outputs are f64 simulation
      results that do not varint, so when they are >= ``dom_cutoff`` of
      the raw body, the int-plane savings cannot reach 1 - dom_cutoff of
      the frame — not worth the encode wall.
    * Everything else (claim/claim_all runs, narrow-dom finishes — the
      ops that dominate real logs) compresses ~3-6x and ships varint.
    """

    name = "adaptive"
    min_records = 4
    dom_cutoff = 2.0 / 3.0

    def choose(self, op, n_records, raw_nbytes, dom_nbytes) -> str:
        if n_records < self.min_records:
            return "raw"
        if dom_nbytes >= self.dom_cutoff * raw_nbytes:
            return "raw"
        return "varint"


_CODECS_BY_NAME: Dict[str, Codec] = {
    "raw": RawCodec(), "varint": VarintCodec(), "adaptive": AdaptiveCodec(),
}

CodecLike = Union[str, Codec]


def as_codec(codec: CodecLike) -> Codec:
    """Resolve a codec spelling (``"raw"``/``"varint"``/``"adaptive"`` or a
    :class:`Codec` instance) to the object the encode paths consume."""
    if isinstance(codec, Codec):
        return codec
    try:
        return _CODECS_BY_NAME[codec]
    except (KeyError, TypeError):
        raise ValueError(f"unknown wire codec {codec!r}") from None


class WireError(ValueError):
    """Malformed or truncated wire frame."""


# -------------------------------------------------------- varint primitives
# All vectorized: the per-record Python toll is exactly what the hot-frame
# path exists to avoid. Values are u64; signed streams go through zigzag
# first. Encoded length is <= 10 bytes/value, 1 byte for values < 128 —
# which deltas of nearly-sorted planes almost always are.
def _zigzag(v: np.ndarray) -> np.ndarray:
    """int64 -> uint64, small magnitudes (either sign) -> small codes."""
    v = np.ascontiguousarray(v, np.int64)
    return (v.astype(np.uint64) << np.uint64(1)) \
        ^ (v >> np.int64(63)).astype(np.uint64)


def _unzigzag(u: np.ndarray) -> np.ndarray:
    u = np.ascontiguousarray(u, np.uint64)
    return ((u >> np.uint64(1))
            ^ (np.uint64(0) - (u & np.uint64(1)))).view(np.int64)


def _varint_encode(u: np.ndarray) -> np.ndarray:
    """LEB128-style encode of a u64 vector -> one uint8 stream."""
    n = u.size
    if n == 0:
        return np.empty(0, np.uint8)
    u = np.ascontiguousarray(u, np.uint64)
    if int(u.max()) < 128:
        # the dominant section shape: every delta fits one byte (unit row/
        # version steps, tiny lens) — skip the whole length machinery
        return u.astype(np.uint8)
    nb = np.ones(n, np.int64)                   # bytes per value
    tmp = u >> np.uint64(7)
    while tmp.any():
        nb += tmp != 0
        tmp >>= np.uint64(7)
    ends = np.cumsum(nb)
    out = np.empty(int(ends[-1]), np.uint8)
    pos = ends - nb
    rem = u.copy()
    alive = np.arange(n)
    while alive.size:
        chunk = rem[alive]
        more = (chunk >> np.uint64(7)) != 0
        out[pos[alive]] = (chunk & np.uint64(0x7F)).astype(np.uint8) \
            | (more.astype(np.uint8) << 7)
        rem[alive] >>= np.uint64(7)
        pos[alive] += 1
        alive = alive[more]
    return out


def _varint_decode(body: np.ndarray, count: int, cur: int):
    """Decode ``count`` varints from ``body`` (uint8) starting at ``cur``.
    Returns (uint64 values, cursor after the last consumed byte)."""
    if count == 0:
        return np.empty(0, np.uint64), cur
    # a u64 varint is <= 10 bytes, so the section lives entirely within
    # the next 10*count bytes — bounding the terminator scan keeps decode
    # O(section), not O(sections x frame) (the raw f64 dom block trailing
    # a finish frame would otherwise be re-scanned once per section)
    b = body[cur: cur + 10 * count]
    term = np.nonzero(b < 0x80)[0]
    if term.size < count:
        raise WireError("truncated varint section")
    ends = term[:count]
    starts = np.empty(count, np.int64)
    starts[0] = 0
    starts[1:] = ends[:-1] + 1
    lens = ends - starts + 1
    max_len = int(lens.max())
    if max_len > 10:
        raise WireError(f"varint of {max_len} bytes exceeds u64")
    vals = np.zeros(count, np.uint64)
    for j in range(max_len):
        sel = lens > j
        vals[sel] |= (b[starts[sel] + j].astype(np.uint64)
                      & np.uint64(0x7F)) << np.uint64(7 * j)
    return vals, cur + int(ends[-1]) + 1


def _enc_delta_i64(vals: np.ndarray) -> np.ndarray:
    """delta + zigzag + varint of an i64 vector (first value absolute)."""
    vals = np.ascontiguousarray(vals, np.int64)
    if vals.size == 0:
        return np.empty(0, np.uint8)
    d = np.empty(vals.size, np.int64)
    d[0] = vals[0]
    np.subtract(vals[1:], vals[:-1], out=d[1:])
    return _varint_encode(_zigzag(d))


def _dec_delta_i64(body: np.ndarray, count: int, cur: int):
    u, cur = _varint_decode(body, count, cur)
    return np.cumsum(_unzigzag(u), dtype=np.int64), cur


def _enc_f64_dd(vals: np.ndarray) -> np.ndarray:
    """Double-delta varint of the raw u64 bit patterns of an f64 vector.

    Near-arithmetic timestamp sequences have near-constant bit-pattern
    first differences within a binade, so the second difference is ~0 and
    each record costs ~1 byte; the stream is exact for ANY floats (bit
    patterns round-trip, NaN payloads included) — just not always small.
    Layout: varint(bits[0]) | zz(d[0]) | zz(dd...), diffs modular in u64.
    """
    bits = np.ascontiguousarray(vals, np.float64).view(np.uint64)
    n = bits.size
    if n == 0:
        return np.empty(0, np.uint8)
    stream = np.empty(n, np.uint64)
    stream[0] = bits[0]
    if n > 1:
        d = np.diff(bits)                       # modular u64
        stream[1] = _zigzag(d[:1].view(np.int64))[0]
        if n > 2:
            stream[2:] = _zigzag(np.diff(d).view(np.int64))
    return _varint_encode(stream)


def _dec_f64_dd(body: np.ndarray, count: int, cur: int):
    u, cur = _varint_decode(body, count, cur)
    if count == 0:
        return np.empty(0, np.float64), cur
    bits = np.empty(count, np.uint64)
    bits[0] = u[0]
    if count > 1:
        dd = np.ascontiguousarray(_unzigzag(u[1:])).view(np.uint64)
        d = np.cumsum(dd, dtype=np.uint64)      # [d0, dd...] -> first diffs
        bits[1:] = bits[0] + np.cumsum(d, dtype=np.uint64)
    return bits.view(np.float64), cur


def _mv(arr: np.ndarray):
    """Byte view of a contiguous array — what the frame ships verbatim.
    (Zero-size arrays — e.g. a width-0 domain block — have no castable
    buffer; they contribute zero bytes.)"""
    if arr.size == 0:
        return b""
    return memoryview(np.ascontiguousarray(arr)).cast("B")


def _dom_servable(fields: Dict[str, Any], n_rows: int) -> Optional[bool]:
    """Whether a finish run's dom sub-update is wire-servable as one block.

    Returns True (every row carries dom), False (no row does), or None —
    mixed / width-drifted runs that must ship as a cold frame (mirrors the
    conditions of the replay plane path).
    """
    doff = fields["dom_off"]
    d0, d1 = int(doff[0]), int(doff[-1])
    if d1 > d0:
        return True if d1 - d0 == n_rows else None
    return None if int(fields["dom_flag"].sum()) else False


# ------------------------------------------------------------------ encode
def _hot_frame_fields(op: str, recs: Sequence[Txn], f: Dict[str, Any],
                      codec: Codec) -> Optional[List[Any]]:
    """Frame chunks for one hot run from ALREADY-CAPTURED plane fields.

    ``f`` is a ``slice_fields`` capture: the pipelined shipper stages it on
    the producer thread (same thread as plane compaction, so the capture
    is race-free) and encodes HERE from the staged views on its own thread
    — compaction re-bases into fresh buffers, so the old views stay
    frozen. Returns None when the run's dom sub-update is not servable
    (ships cold instead).
    """
    n = len(recs)
    off = f["off"].astype(np.int64)          # re-based copy: off[0] == 0
    off -= off[0]
    n_rows = int(off[-1])
    # dom servability + size first: it gates the frame entirely, and the
    # per-frame codec choice needs to see the incompressible dom fraction
    dom = None
    if op == "finish":
        servable = _dom_servable(f, n_rows)
        if servable is None:
            return None
        if servable:
            dom = f["dom"]
    dom_nbytes = 8 * n_rows * dom.shape[1] if dom is not None else 0
    raw_nbytes = 8 * n + 8 * (n + 1) + 8 * n_rows + 8 * n \
        + (4 * n if op == "claim" else 0) \
        + (_FIN.size + dom_nbytes if op == "finish" else 0)
    enc = codec.choose(op, n, raw_nbytes, dom_nbytes)
    versions = np.fromiter(map(attrgetter("store_version"), recs),
                           np.int64, n)
    if enc == "varint":
        chunks: List[Any] = [
            None,                            # header patched in below
            _mv(_enc_delta_i64(versions)),
            _mv(_varint_encode(np.diff(off).astype(np.uint64))),
            _mv(_enc_delta_i64(f["rows"])),
            _mv(_enc_f64_dd(f["now"])),
        ]
        if op == "claim":
            chunks.append(_mv(_enc_delta_i64(f["worker"])))
    elif enc == "raw":
        chunks = [
            None,
            _mv(versions),
            _mv(off),
            _mv(f["rows"]),
            _mv(f["now"]),
        ]
        if op == "claim":
            chunks.append(_mv(f["worker"]))
    else:
        raise ValueError(f"codec {codec.name!r} chose unknown "
                         f"encoding {enc!r}")
    if op == "finish":
        if dom is not None:
            chunks.append(_FIN.pack(1, dom.shape[1]))
            chunks.append(_mv(dom))          # sim outputs don't varint
        else:
            chunks.append(_FIN.pack(0, 0))
    body = sum(len(c) for c in chunks[1:])
    chunks[0] = _HDR.pack(MAGIC, FT_HOT if enc == "raw" else FT_HOTC,
                          _OPCODES[op], n, body)
    return chunks


def _hot_frame(op: str, recs: Sequence[Txn],
               codec: CodecLike = "raw") -> Optional[List[Any]]:
    """Frame chunks for one plane-contiguous hot run, or None when the run
    cannot be served off its plane (then it ships as a cold frame)."""
    sl = plane_run(recs)
    if sl is None:
        return None
    plane, lo, hi = sl
    return _hot_frame_fields(op, recs, plane.slice_fields(lo, hi),
                             as_codec(codec))


def _cold_frame(recs: Sequence[Txn]) -> List[Any]:
    blob = pickle.dumps(
        [(r.op, r.store_version, r.payload) for r in recs],
        protocol=pickle.HIGHEST_PROTOCOL)
    return [_HDR.pack(MAGIC, FT_COLD, 0, len(recs), len(blob)), blob]


def iter_frames(records: Iterable[Txn],
                codec: CodecLike = "raw") -> Iterable[List[Any]]:
    """Frames (each a list of bytes-like chunks) for a log delta, one frame
    per consecutive same-op run — the unit :func:`replay` coalesces."""
    codec = as_codec(codec)
    for op, run in itertools.groupby(records, key=attrgetter("op")):
        recs = list(run)
        frame = _hot_frame(op, recs, codec) if op in _OPCODES else None
        yield frame if frame is not None else _cold_frame(recs)


def delta_to_bytes(records: Iterable[Txn], codec: CodecLike = "raw") -> bytes:
    """One contiguous buffer holding every frame of the delta — what a
    ``send_bytes`` ships (a writev-style transport can send ``iter_frames``
    chunks without this join)."""
    return b"".join(c for frame in iter_frames(records, codec)
                    for c in frame)


def frames_nbytes(records: Iterable[Txn], codec: CodecLike = "raw") -> int:
    """Exact encoded wire size of a delta: ``len(delta_to_bytes(records))``.

    The raw codec is sized analytically without materializing the hot
    buffers (cold runs must still pickle — their size is not knowable
    otherwise; they are rare by construction). Varint sections only know
    their size by encoding, so other codecs sum real frames.
    """
    codec = as_codec(codec)
    if codec.name != "raw":
        return sum(len(c) for frame in iter_frames(records, codec)
                   for c in frame)
    total = 0
    for op, run in itertools.groupby(records, key=attrgetter("op")):
        recs = list(run)
        n = len(recs)
        sl = plane_run(recs) if op in _OPCODES else None
        if sl is not None:
            plane, lo, hi = sl
            f = plane.slice_fields(lo, hi)
            n_rows = int(f["off"][-1] - f["off"][0])
            servable = _dom_servable(f, n_rows) if op == "finish" else False
            if op != "finish" or servable is not None:
                total += _HDR.size + 8 * n + 8 * (n + 1) + 8 * n_rows + 8 * n
                if op == "claim":
                    total += 4 * n
                elif op == "finish":
                    total += _FIN.size
                    if servable:
                        total += 8 * n_rows * f["dom"].shape[1]
                continue
        total += _HDR.size + len(pickle.dumps(
            [(r.op, r.store_version, r.payload) for r in recs],
            protocol=pickle.HIGHEST_PROTOCOL))
    return total


def frames_nbytes_detail(records: Iterable[Txn],
                         codec: CodecLike = "raw") -> Dict[str, int]:
    """Encoded size split into hot and cold frame bytes.

    Cold frames are byte-identical across codecs (pickles don't
    re-encode), so ``hot`` is the comparable base for compression ratios:
    ``frames_nbytes_detail(recs, "raw")["hot"] /
    frames_nbytes_detail(recs, "varint")["hot"]`` is what the varint codec
    saves on the planes it actually touches.
    """
    hot = cold = 0
    for frame in iter_frames(records, codec):
        size = sum(len(c) for c in frame)
        if frame[0][2] == FT_COLD:            # header byte 2 is ftype
            cold += size
        else:
            hot += size
    return {"total": hot + cold, "hot": hot, "cold": cold}


# ----------------------------------------------------------------- staging
# The pipelined shipper's producer/consumer split of the encode path:
# stage_delta runs on the PRODUCER thread (the only thread allowed to
# touch the log's planes — TxnLog's threading contract) and captures, per
# same-op run, the plane views the frame will encode from; encode_staged
# runs later on the shipper thread against those frozen captures only.
# Compaction between the two is safe by construction: _GrowBuf.trim_front
# re-bases into FRESH buffers, so a staged view keeps aliasing the old
# (immutable) allocation, and appends only ever write past the captured
# range (growth reallocates).
class StagedRun:
    """One same-op run of a staged chunk: records plus their plane capture
    (``fields`` is None for cold runs — they encode from frozen payloads,
    which are immutable and thread-safe by construction)."""

    __slots__ = ("op", "recs", "fields")

    def __init__(self, op: str, recs: Sequence[Txn],
                 fields: Optional[Dict[str, Any]]):
        self.op = op
        self.recs = recs
        self.fields = fields


class StagedChunk:
    """A contiguous span [lo, hi) of log records captured for deferred
    encoding. Chunks are the shipper's queue items AND its encode units:
    bounded size keeps encode/ship overlapped (chunk i+1 encodes while the
    remote still replays chunk i) and bounds staged-view memory."""

    __slots__ = ("lo", "hi", "runs")

    def __init__(self, lo: int, hi: int, runs: List[StagedRun]):
        self.lo = lo
        self.hi = hi
        self.runs = runs

    @property
    def n_records(self) -> int:
        return self.hi - self.lo


def stage_delta(records: Sequence[Txn], lo: int,
                chunk_records: int = 2048) -> List[StagedChunk]:
    """Split a log tail starting at absolute offset ``lo`` into staged
    chunks of <= ``chunk_records`` records each, capturing every hot run's
    plane views NOW (producer thread). Splitting a long run across chunks
    is legal — each sub-run is still plane-contiguous and decodes to the
    same replay — and is exactly what lets encode overlap shipping."""
    out: List[StagedChunk] = []
    for start in range(0, len(records), max(chunk_records, 1)):
        sub = records[start: start + chunk_records]
        runs: List[StagedRun] = []
        for op, run in itertools.groupby(sub, key=attrgetter("op")):
            recs = list(run)
            fields = None
            if op in _OPCODES:
                sl = plane_run(recs)
                if sl is not None:
                    plane, plo, phi = sl
                    fields = plane.slice_fields(plo, phi)
            runs.append(StagedRun(op, recs, fields))
        out.append(StagedChunk(lo + start, lo + start + len(sub), runs))
    return out


# Exact per-record payload_nbytes() totals for the hot-op payload layouts
# (claim: worker/rows/now/ids, claim_all: n/rows/now, finish: ids/rows/now
# + optional domain_out): fixed charge per record + 8 bytes per i64 row
# entry. Lets replicator ack accounting stay O(runs), not O(records).
_PAYLOAD_FIXED = {"claim": 16, "claim_all": 16, "finish": 8}
_PAYLOAD_PER_ROW = {"claim": 16, "claim_all": 8, "finish": 16}


def staged_payload_nbytes(run: StagedRun) -> int:
    """Sum of ``payload_nbytes()`` over the run's records — computed from
    the captured plane fields in O(1) for hot runs (bit-exact vs the
    per-record sum, property-tested), per-record fallback otherwise.

    Finish runs take the fast path only when ``_dom_servable`` decides
    the capture represents the payloads exactly (every row's domain block
    captured, or none at all): mixed and width-drifted runs keep their
    ``domain_out`` only in the record payloads, so they cannot be sized
    from the capture alone — same rule as hot-frame eligibility.
    """
    f = run.fields
    fixed = _PAYLOAD_FIXED.get(run.op)
    if f is None or fixed is None:
        return sum(r.payload_nbytes() for r in run.recs)
    off = f["off"]
    n_rows = int(off[-1]) - int(off[0])
    dom_nbytes = 0
    if run.op == "finish":
        servable = _dom_servable(f, n_rows)
        if servable is None:
            return sum(r.payload_nbytes() for r in run.recs)
        if servable:
            dom_nbytes = f["dom"].nbytes
    return fixed * len(run.recs) + _PAYLOAD_PER_ROW[run.op] * n_rows \
        + dom_nbytes


def encode_staged(chunk: StagedChunk, codec: CodecLike) -> bytes:
    """Encode one staged chunk into its frame buffer — safe on any thread
    (touches only the chunk's frozen captures, never the live planes)."""
    codec = as_codec(codec)
    parts: List[Any] = []
    for r in chunk.runs:
        frame = None
        if r.fields is not None:
            frame = _hot_frame_fields(r.op, r.recs, r.fields, codec)
        if frame is None:
            frame = _cold_frame(r.recs)
        parts.extend(frame)
    return b"".join(parts)


class DeltaEncoder:
    """Encode-once cache for broadcast fan-out.

    A :class:`~repro.core.replication.ReplicaGroup` ships the SAME log
    span to every member; pre-PR 6 each member re-encoded it. Members now
    share one encoder: the first caller for a ``(lo, hi, codec)`` span
    pays the encode, concurrent and later callers get the identical bytes
    back (``hits``). Entries are LRU-bounded — the broadcast consumes an
    entry within one sync, so a handful of chunks of history suffices.

    Thread-safe: concurrent requests for the same key block on the owning
    encoder's completion instead of duplicating work; if the owner fails,
    waiters fall back to encoding themselves.
    """

    def __init__(self, max_entries: int = 32):
        self._mu = threading.Lock()
        self._entries: "OrderedDict[tuple, bytes]" = OrderedDict()
        self._inflight: Dict[tuple, threading.Event] = {}
        self.max_entries = max_entries
        self.encodes = 0
        self.hits = 0

    def _get_or_encode(self, key, thunk) -> bytes:
        while True:
            with self._mu:
                buf = self._entries.get(key)
                if buf is not None:
                    self.hits += 1
                    self._entries.move_to_end(key)
                    return buf
                ev = self._inflight.get(key)
                if ev is None:
                    ev = self._inflight[key] = threading.Event()
                    break                     # we own the encode
            ev.wait(timeout=120.0)            # another thread is encoding
            with self._mu:
                buf = self._entries.get(key)
                if buf is not None:
                    self.hits += 1
                    return buf
                if key not in self._inflight:
                    # owner failed and cleared the slot without publishing:
                    # loop back and claim the encode ourselves
                    continue
        try:
            buf = thunk()
            with self._mu:
                self._entries[key] = buf
                self.encodes += 1
                while len(self._entries) > self.max_entries:
                    self._entries.popitem(last=False)
            return buf
        finally:
            with self._mu:
                self._inflight.pop(key, None)
            ev.set()

    def encode_staged(self, chunk: StagedChunk, codec: CodecLike) -> bytes:
        codec = as_codec(codec)
        return self._get_or_encode(
            (chunk.lo, chunk.hi, codec.name),
            lambda: encode_staged(chunk, codec))

    def encode_records(self, lo: int, hi: int, records: Sequence[Txn],
                       codec: CodecLike) -> bytes:
        """Synchronous-path entry: same cache key space as staged chunks
        (identical span + codec => identical bytes), so pipelined and
        synchronous members of one group still share encodes."""
        codec = as_codec(codec)
        return self._get_or_encode(
            (lo, hi, codec.name),
            lambda: delta_to_bytes(records, codec))

    def stats(self) -> Dict[str, int]:
        return {"encodes": self.encodes, "hits": self.hits,
                "entries": len(self._entries)}


# ------------------------------------------------------------------ decode
class _RxField:
    """Receive-side buffer with the ``.view(lo, hi)`` surface the replay
    plane path slices — backed directly by the wire bytes (zero-copy)."""

    __slots__ = ("data",)

    def __init__(self, data: np.ndarray):
        self.data = data

    def view(self, lo: int, hi: int) -> np.ndarray:
        return self.data[lo:hi]


class _RxPlane:
    """Decoded hot frame, shaped like the sender's ``_HotPlane`` slice so
    ``replay`` serves the run as O(1) views of the received buffer."""

    __slots__ = ("base", "n", "off", "rows", "now", "worker",
                 "dom_off", "dom", "dom_flag")

    def __init__(self, n: int, off, rows, now, worker=None,
                 dom=None, has_dom: bool = False):
        self.base = 0
        self.n = n
        self.off = _RxField(off)
        self.rows = _RxField(rows)
        self.now = _RxField(now)
        self.worker = _RxField(worker) if worker is not None else None
        # reconstructed dom locator: a servable finish frame has dom rows
        # exactly aligned with its written rows (dom_off == off, every flag
        # set) or none at all — the only two shapes hot frames ship
        self.dom_off = _RxField(off if has_dom
                                else np.zeros(n + 1, np.int64))
        self.dom = _RxField(dom) if dom is not None else None
        self.dom_flag = _RxField(
            np.ones(n, np.int8) if has_dom else np.zeros(n, np.int8))

    def record_payload(self, i: int, op: str) -> Dict[str, Any]:
        """Materialize one record's payload dict (replay's single-record and
        dict-batch fallbacks need it; plane-path runs never call this)."""
        lo, hi = int(self.off.data[i]), int(self.off.data[i + 1])
        p: Dict[str, Any] = {"rows": self.rows.data[lo:hi],
                             "now": float(self.now.data[i])}
        if self.worker is not None:
            p["worker"] = int(self.worker.data[i])
        if op == "finish" and self.dom is not None:
            p["domain_out"] = self.dom.data[lo:hi]
        return p


class WireTxn:
    """Decoded log record: replayable via :func:`repro.core.replication.replay`
    (op/store_version/plane/pidx drive the plane fast path; ``payload``
    materializes lazily from the received buffers when a fallback needs it)."""

    __slots__ = ("op", "store_version", "plane", "pidx", "_payload")

    def __init__(self, op: str, store_version: int, plane: Optional[_RxPlane],
                 pidx: int, payload: Optional[Dict[str, Any]] = None):
        self.op = op
        self.store_version = store_version
        self.plane = plane
        self.pidx = pidx
        self._payload = payload

    @property
    def payload(self) -> Dict[str, Any]:
        if self._payload is None:
            self._payload = self.plane.record_payload(self.pidx, self.op)
        return self._payload

    def __repr__(self) -> str:                        # pragma: no cover
        return f"WireTxn({self.op!r}, v={self.store_version})"


class DecodedRun:
    """One decoded frame as a run-level replay unit.

    Hot frames carry their receive plane plus the per-record version
    column — NO per-record objects (materializing one ``WireTxn`` per
    record is the dominant decode cost on large frames, and batched
    replay only ever looks at the run's endpoints). Cold frames keep
    their per-record ``WireTxn`` list (``recs``), mixed ops included.
    """

    __slots__ = ("op", "plane", "versions", "recs")

    def __init__(self, op: Optional[str], plane: Optional[_RxPlane],
                 versions: Optional[np.ndarray],
                 recs: Optional[List[WireTxn]] = None):
        self.op = op
        self.plane = plane
        self.versions = versions
        self.recs = recs

    @property
    def n(self) -> int:
        return len(self.recs) if self.recs is not None \
            else int(self.versions.size)

    @property
    def last_version(self) -> int:
        return int(self.versions[-1])

    def materialize(self) -> List[WireTxn]:
        """Per-record view of the run — the replay fallback paths (and
        :func:`decode_delta`) still speak records."""
        if self.recs is not None:
            return self.recs
        return list(map(WireTxn, itertools.repeat(self.op),
                        self.versions.tolist(),
                        itertools.repeat(self.plane), range(self.n)))


def _parse_frames(buf) -> List[DecodedRun]:
    """Parse a frame buffer into run-level decode units, in log order.

    Hot frames decode as ``np.frombuffer`` views of ``buf`` — no copies of
    the row/scalar/domain sections; cold frames unpickle their payloads.
    """
    out: List[DecodedRun] = []
    pos, end_all = 0, len(buf)
    while pos < end_all:
        if pos + _HDR.size > end_all:
            raise WireError("truncated frame header")
        magic, ftype, opcode, n, body = _HDR.unpack_from(buf, pos)
        if magic != MAGIC:
            raise WireError(f"bad frame magic {magic:#x} at offset {pos}")
        pos += _HDR.size
        end = pos + body
        if end > end_all:
            raise WireError("truncated frame body")
        if ftype == FT_COLD:
            out.append(DecodedRun(None, None, None, [
                WireTxn(op, sv, None, -1, payload)
                for op, sv, payload in pickle.loads(buf[pos:end])]))
        elif ftype == FT_HOTC:
            op = _OPS.get(opcode)
            if op is None:
                raise WireError(f"unknown hot opcode {opcode}")
            body_u8 = np.frombuffer(buf, np.uint8, body, pos)
            cur = 0
            versions, cur = _dec_delta_i64(body_u8, n, cur)
            lens, cur = _varint_decode(body_u8, n, cur)
            off = np.zeros(n + 1, np.int64)
            np.cumsum(lens.astype(np.int64), out=off[1:])
            n_rows = int(off[-1])
            rows, cur = _dec_delta_i64(body_u8, n_rows, cur)
            now, cur = _dec_f64_dd(body_u8, n, cur)
            worker = dom = None
            has_dom = False
            if op == "claim":
                w64, cur = _dec_delta_i64(body_u8, n, cur)
                worker = w64.astype(np.int32)
            elif op == "finish":
                flag, width = _FIN.unpack_from(buf, pos + cur)
                cur += _FIN.size
                has_dom = bool(flag)
                if has_dom:
                    dom = np.frombuffer(
                        buf, np.float64, n_rows * width, pos + cur
                    ).reshape(n_rows, width) if width else \
                        np.empty((n_rows, 0), np.float64)
                    cur += 8 * n_rows * width
            if cur != body:
                raise WireError(
                    f"compressed hot frame body mismatch: "
                    f"parsed {cur} != {body}")
            plane = _RxPlane(n, off, rows, now, worker, dom, has_dom)
            out.append(DecodedRun(op, plane, versions))
        elif ftype == FT_HOT:
            op = _OPS.get(opcode)
            if op is None:
                raise WireError(f"unknown hot opcode {opcode}")
            versions = np.frombuffer(buf, np.int64, n, pos)
            pos += 8 * n
            off = np.frombuffer(buf, np.int64, n + 1, pos)
            pos += 8 * (n + 1)
            n_rows = int(off[-1])
            rows = np.frombuffer(buf, np.int64, n_rows, pos)
            pos += 8 * n_rows
            now = np.frombuffer(buf, np.float64, n, pos)
            pos += 8 * n
            worker = dom = None
            has_dom = False
            if op == "claim":
                worker = np.frombuffer(buf, np.int32, n, pos)
                pos += 4 * n
            elif op == "finish":
                flag, width = _FIN.unpack_from(buf, pos)
                pos += _FIN.size
                has_dom = bool(flag)
                if has_dom:
                    # width 0 is legal (a domain_out with no columns):
                    # frombuffer of zero elements cannot infer the row
                    # count, so shape it explicitly
                    dom = np.frombuffer(
                        buf, np.float64, n_rows * width, pos
                    ).reshape(n_rows, width) if width else \
                        np.empty((n_rows, 0), np.float64)
                    pos += 8 * n_rows * width
            if pos != end:
                # the parsed sections must consume the body EXACTLY: a
                # mismatch means n_records/off disagree with the header,
                # and frombuffer would have read misaligned garbage
                raise WireError(
                    f"hot frame body mismatch: parsed {pos} != {end}")
            plane = _RxPlane(n, off, rows, now, worker, dom, has_dom)
            out.append(DecodedRun(op, plane, versions))
        else:
            raise WireError(f"unknown frame type {ftype}")
        pos = end
    return out


def decode_delta_runs(buf) -> List[DecodedRun]:
    """Run-level decode — the replica child's fast path: one
    :class:`DecodedRun` per frame, records materialized only where a
    fallback needs them (see ``repro.core.replication.replay_runs``)."""
    return _parse_frames(buf)


def decode_delta(buf) -> List[WireTxn]:
    """Parse a frame buffer back into replayable records, in log order
    (the record-level surface ``replay``/tests consume)."""
    out: List[WireTxn] = []
    for run in _parse_frames(buf):
        out.extend(run.recs if run.recs is not None else run.materialize())
    return out


# ---------------------------------------------------------------------------
# Sweep-partial codec (PR 10): ship per-shard steering partials, not views
# ---------------------------------------------------------------------------
#
# The remote steering op (`G` request in repro.core.replication) runs
# `steering.sweep_partials` INSIDE the replica process and ships back only
# the partial aggregates — bincount slabs, a few scalars, and compact
# ancestry columns — instead of a whole snapshot or a pickled result dict.
# Layout: `u32 header_len | pickle((meta, descs)) | raw array bytes...`
# where `meta` holds the scalar fields and `descs` is a list of
# `(key, dtype_str, shape)` for each ndarray field, whose C-contiguous
# bytes follow in order. Decode is `np.frombuffer` over the received
# buffer — the arrays alias the wire bytes (zero-copy), same discipline as
# the hot-frame codec above. The merge (`sharding_router.merge_partials`)
# only reads the arrays, so aliasing read-only wire memory is safe.

_PARTIAL_HDR = struct.Struct("<I")


def encode_sweep_partial(partial: Dict[str, Any]) -> bytes:
    """Serialize a `steering.sweep_partials` dict into one wire buffer."""
    meta: Dict[str, Any] = {}
    descs: List[Any] = []
    chunks: List[bytes] = []
    for key, val in partial.items():
        if isinstance(val, np.ndarray):
            arr = np.ascontiguousarray(val)
            descs.append((key, arr.dtype.str, arr.shape))
            chunks.append(arr.tobytes())
        else:
            meta[key] = val
    head = pickle.dumps((meta, descs), protocol=pickle.HIGHEST_PROTOCOL)
    return b"".join([_PARTIAL_HDR.pack(len(head)), head] + chunks)


def decode_sweep_partial(buf) -> Dict[str, Any]:
    """Inverse of :func:`encode_sweep_partial`; arrays alias ``buf``."""
    mv = memoryview(buf)
    (head_len,) = _PARTIAL_HDR.unpack_from(mv, 0)
    pos = _PARTIAL_HDR.size
    meta, descs = pickle.loads(mv[pos:pos + head_len])
    pos += head_len
    out: Dict[str, Any] = dict(meta)
    for key, dtype_str, shape in descs:
        dt = np.dtype(dtype_str)
        n = int(np.prod(shape)) if shape else 1
        nbytes = dt.itemsize * n
        out[key] = np.frombuffer(mv, dtype=dt, count=n,
                                 offset=pos).reshape(shape)
        pos += nbytes
    if pos != len(mv):
        raise WireError(
            f"sweep partial body mismatch: parsed {pos} != {len(mv)}")
    return out
