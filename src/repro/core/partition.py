"""WQ partitioning: hash by worker id (paper Section 3.2).

The supervisor assigns ``worker_id = task_id % W`` round-robin ("the
supervisor circularly assigns a worker id to each task"), which yields
balanced partitions for uniform workloads. ``rehash`` supports elastic
W -> W' re-partitioning (only rows whose assignment changes move — stable
task ids).
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np


def assign_workers(task_ids: np.ndarray, num_workers: int) -> np.ndarray:
    return (task_ids % num_workers).astype(np.int32)


def rehash(worker_ids: np.ndarray, task_ids: np.ndarray, new_workers: int,
           only_statuses: np.ndarray = None) -> Tuple[np.ndarray, int]:
    """Re-partition to ``new_workers``; returns (new_assignment, n_moved)."""
    new = assign_workers(task_ids, new_workers)
    moved = int(np.sum(new != worker_ids))
    return new, moved


def partition_sizes(worker_ids: np.ndarray, num_workers: int) -> np.ndarray:
    return np.bincount(worker_ids[worker_ids >= 0], minlength=num_workers)


def imbalance(worker_ids: np.ndarray, num_workers: int) -> float:
    sizes = partition_sizes(worker_ids, num_workers)
    if sizes.sum() == 0:
        return 0.0
    return float(sizes.max() / max(sizes.mean(), 1e-9) - 1.0)
