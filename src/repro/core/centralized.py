"""Chiron-style centralized execution control — the Experiment 8 baseline.

Centralized design (paper Fig. 6-B): every worker request hops through ONE
master; the master serializes access to ONE unpartitioned queue; each claim
scans the whole queue; an extra acknowledgement message closes the loop.
We model the per-request costs the paper identifies: (1) request queueing at
the master, (2) serialized full-queue scan, (3) ack round-trip.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

from repro.core.schema import Status
from repro.core.store import ColumnStore


class CentralizedMaster:
    def __init__(self, store: Optional[ColumnStore] = None,
                 capacity: int = 1 << 16):
        self.store = store or ColumnStore(capacity=capacity)
        self._next_task_id = 0
        self.total_messages = 0      # request + reply + ack per claim
        self.busy_s = 0.0            # serialized master occupancy

    def add_tasks(self, activity_id: int, n: int, *, now: float = 0.0
                  ) -> np.ndarray:
        ids = np.arange(self._next_task_id, self._next_task_id + n,
                        dtype=np.int64)
        self._next_task_id += n
        self.store.insert({
            "task_id": ids,
            "activity_id": np.full(n, activity_id, np.int32),
            "worker_id": np.full(n, -1, np.int32),   # assigned at claim time
            "status": np.full(n, int(Status.READY), np.int32),
            "submit_time": np.full(n, now, np.float64),
        })
        return ids

    def claim(self, worker_id: int, k: int = 1, *, now: float = 0.0
              ) -> np.ndarray:
        """One serialized master transaction: full-queue scan + assignment.

        Returns claimed rows; the caller accounts the wall time of this call
        as master occupancy (no two claims overlap — that is the bottleneck
        the paper measures two orders of magnitude of).
        """
        t0 = time.perf_counter()
        status = self.store.col("status")              # full scan
        idx = np.nonzero(status == int(Status.READY))[0][:k]
        if len(idx):
            self.store.update(idx, status=int(Status.RUNNING),
                              worker_id=worker_id, start_time=now)
        self.total_messages += 3    # request, reply, ack (Fig. 6-B)
        self.busy_s += time.perf_counter() - t0
        return idx

    def finish(self, idx: np.ndarray, *, now: float = 0.0) -> None:
        t0 = time.perf_counter()
        self.store.update(np.asarray(idx), status=int(Status.FINISHED),
                          end_time=now)
        self.total_messages += 2    # completion + ack
        self.busy_s += time.perf_counter() - t0
