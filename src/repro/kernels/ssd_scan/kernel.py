"""Mamba2 SSD chunked kernel (state-space duality, TPU-native form).

Per (batch, head) the sequence is processed chunk-by-chunk on the innermost
(sequential) grid axis with the running state S [P, N] in VMEM scratch:

  intra-chunk (MXU):  scores = C B^T ; y_diag = (scores * L) (dt * x)
  inter-chunk (MXU):  y_off = C S_prev^T * decay_in ; S = g S + (dt B d_end)^T x

ops.py precomputes the elementwise decay terms (da cumsums) — cheap VPU work
kept outside so the kernel feeds the MXU with clean [Q,N]x[N,P] matmuls.
Chunk layout: Q = chunk length (256), N = state dim, P = head dim.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, b_ref, c_ref, dt_ref, dacum_ref, o_ref, s_ref, *,
                q: int, n: int, p: int, nc: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    x = x_ref[0].astype(jnp.float32)       # [Q, P]
    bmat = b_ref[0].astype(jnp.float32)    # [Q, N]
    cmat = c_ref[0].astype(jnp.float32)    # [Q, N]
    dt = dt_ref[0].astype(jnp.float32)     # [Q, 1]
    dacum = dacum_ref[0].astype(jnp.float32)   # [Q, 1] inclusive cumsum of da

    # intra-chunk: L[i,j] = exp(dacum_i - dacum_j) for j <= i
    scores = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())))  # [Q,Q]
    li = dacum - dacum.reshape(1, q)           # [Q, Q] via broadcast
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    lmat = jnp.where(jj <= ii, jnp.exp(li), 0.0)
    att = scores * lmat                         # [Q, Q]
    y_diag = jax.lax.dot_general(att, dt * x, (((1,), (0,)), ((), ())))

    # inter-chunk: contribution of the incoming state
    decay_in = jnp.exp(dacum)                   # [Q, 1]
    y_off = decay_in * jax.lax.dot_general(
        cmat, s_ref[...], (((1,), (1,)), ((), ())))     # [Q,N]x[P,N]->[Q,P]

    o_ref[0] = (y_diag + y_off).astype(o_ref.dtype)

    # state update: S' = g * S + sum_k dt_k decay(end,k) x_k B_k^T
    g = jnp.exp(dacum[q - 1, 0])
    w = dt * jnp.exp(dacum[q - 1, 0] - dacum)   # [Q,1] dt * decay-to-end
    s_new = jax.lax.dot_general(w * x, bmat, (((0,), (0,)), ((), ())))
    s_ref[...] = g * s_ref[...] + s_new         # [P, N]


def ssd_scan_fwd(x, bmat, cmat, dt, dacum, *, chunk: int = 256,
                 interpret: bool = False):
    """x: [BH, S, P]; bmat/cmat: [BH, S, N]; dt/dacum: [BH, S, 1].

    dacum = per-(b,h) inclusive cumsum of da = dt*a RESET per chunk
    (ops.py computes it). Returns y: [BH, S, P].
    """
    bh, s, p = x.shape
    n = bmat.shape[-1]
    q = min(chunk, s)
    nc = s // q
    kernel = functools.partial(_ssd_kernel, q=q, n=n, p=p, nc=nc)
    return pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, q, p), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, q, n), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, q, n), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, q, 1), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, q, 1), lambda h, c: (h, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, p), lambda h, c: (h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, bmat, cmat, dt, dacum)
