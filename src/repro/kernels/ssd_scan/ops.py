"""Dispatcher: precompute decay cumsums, call the SSD kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_fwd
from repro.kernels.ssd_scan.ref import ssd_scan_ref


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, bmat, cmat, dt, da, *, chunk: int = 256,
             interpret: bool = False):
    """Same signature as the oracle. da: [BH,S,1] log-decay (dt*a).

    Computes the per-chunk inclusive cumsum of da (the only sequential
    elementwise prep) and runs the chunked dual-form kernel.

    NOTE kernel state carry: state entering chunk c is decayed by the chunk's
    OWN cumulative decay inside the kernel (decay_in) — so dacum must reset
    at chunk boundaries, and the cross-chunk decay g is exp(dacum[-1]).
    """
    bh, s, _ = x.shape
    q = min(chunk, s)
    nc = s // q
    dac = da.reshape(bh, nc, q)
    dacum = jnp.cumsum(dac, axis=-1).reshape(bh, s, 1)
    return ssd_scan_fwd(x, bmat, cmat, dt, dacum, chunk=chunk,
                        interpret=interpret)
