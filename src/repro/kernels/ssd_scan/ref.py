"""Pure-jnp oracle: sequential SSM recurrence (the 'linear form' of SSD)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(x, bmat, cmat, dt, da):
    """x: [BH,S,P]; bmat/cmat: [BH,S,N]; dt/da: [BH,S,1] (da = dt * a <= 0).

    h_t = exp(da_t) h_{t-1} + dt_t x_t B_t^T ;  y_t = h_t C_t
    """
    def step(h, args):
        xt, bt, ct, dtt, dat = args
        h = jnp.exp(dat)[..., None] * h \
            + (dtt * xt)[..., :, None] * bt[..., None, :]   # [BH,P,N]
        y = jnp.einsum("bpn,bn->bp", h, ct)
        return h, y

    bh, s, p = x.shape
    n = bmat.shape[-1]
    h0 = jnp.zeros((bh, p, n), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(bmat, 1, 0).astype(jnp.float32),
          jnp.moveaxis(cmat, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(da, 1, 0).astype(jnp.float32))
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)
