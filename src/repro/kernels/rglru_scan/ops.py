"""Dispatcher for the RG-LRU recurrence kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rglru_scan.kernel import rglru_scan_fwd
from repro.kernels.rglru_scan.ref import rglru_scan_ref


@functools.partial(jax.jit, static_argnames=("interpret",))
def rglru_scan(a, u, *, interpret: bool = False):
    return rglru_scan_fwd(a, u, interpret=interpret)
