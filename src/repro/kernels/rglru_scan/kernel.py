"""RG-LRU linear-recurrence kernel (RecurrentGemma mixer).

h_t = a_t * h_{t-1} + u_t, elementwise over channels. Grid = (batch,
channel_blocks, time_blocks) with time innermost/sequential; the carry h
[1, CB] lives in VMEM scratch. Inside a block the recurrence runs as an
unrolled loop over the block's TB steps — pure VPU work on [1, CB] vectors
(channels on the 128-lane axis), which is the TPU-native layout for a
first-order scan: lanes carry independent recurrences.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, u_ref, o_ref, h_ref, *, tb: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)      # [TB, CB]
    u = u_ref[0].astype(jnp.float32)
    h = h_ref[...]                        # [1, CB]
    out = jnp.zeros_like(a)
    for t in range(tb):                   # unrolled in-block scan (VPU)
        h = a[t:t + 1] * h + u[t:t + 1]
        out = jax.lax.dynamic_update_slice(out, h, (t, 0))
    h_ref[...] = h
    o_ref[0] = out.astype(o_ref.dtype)


def rglru_scan_fwd(a: jax.Array, u: jax.Array, *, time_block: int = 128,
                   ch_block: int = 512, interpret: bool = False) -> jax.Array:
    """a, u: [B, S, C] -> h: [B, S, C] (first-order linear recurrence)."""
    b, s, c = a.shape
    tb = min(time_block, s)
    cb = min(ch_block, c)
    nt, ncb = s // tb, c // cb
    kernel = functools.partial(_rglru_kernel, tb=tb)
    return pl.pallas_call(
        kernel,
        grid=(b, ncb, nt),
        in_specs=[
            pl.BlockSpec((1, tb, cb), lambda bi, ci, ti: (bi, ti, ci)),
            pl.BlockSpec((1, tb, cb), lambda bi, ci, ti: (bi, ti, ci)),
        ],
        out_specs=pl.BlockSpec((1, tb, cb), lambda bi, ci, ti: (bi, ti, ci)),
        out_shape=jax.ShapeDtypeStruct((b, s, c), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, cb), jnp.float32)],
        interpret=interpret,
    )(a, u)
