"""Pure-jnp oracle for the RG-LRU recurrence (associative scan form)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(a, u):
    """a, u: [B,S,C]; h_t = a_t h_{t-1} + u_t, h_0 = 0."""
    def combine(e1, e2):
        a1, u1 = e1
        a2, u2 = e2
        return a1 * a2, a2 * u1 + u2
    _, h = jax.lax.associative_scan(
        combine, (a.astype(jnp.float32), u.astype(jnp.float32)), axis=1)
    return h.astype(a.dtype)
