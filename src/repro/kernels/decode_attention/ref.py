"""Pure-jnp oracle for decode attention (1 token vs cache of kv_len)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k, v, kv_len, *, sm_scale=None):
    b, _, hq, dh = q.shape
    smax, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = sm_scale if sm_scale is not None else dh ** -0.5
    qg = q.reshape(b, 1, hkv, g, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = jnp.arange(smax)[None, None, None, None, :] < kv_len
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(b, 1, hq, dh).astype(q.dtype)
