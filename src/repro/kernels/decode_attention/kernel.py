"""Decode attention kernel: 1 query token against a long KV cache.

Memory-bound: the job is to stream K/V blocks HBM->VMEM exactly once while
the online-softmax state rides in VMEM scratch. Grid = (batch*q_heads,
num_kv_blocks), kv innermost/sequential. The valid cache length (kv_len) is a
scalar-prefetch operand (SMEM) used to mask the tail block — this is what the
serving path uses where caches fill incrementally.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _dec_kernel(kvlen_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                *, kb: int, scale: float, nk: int):
    ki = pl.program_id(1)
    kv_len = kvlen_ref[0]

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k_start = ki * kb

    @pl.when(k_start < kv_len)
    def _compute():
        q = q_ref[0].astype(jnp.float32)               # [1, DH]
        k = k_ref[0].astype(jnp.float32)               # [KB, DH]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, kb), 1)
        s = jnp.where(kpos < kv_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def decode_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array,
                         kv_len: jax.Array, *, sm_scale: float = None,
                         kv_block: int = 512,
                         interpret: bool = False) -> jax.Array:
    """q: [B,1,Hq,DH]; k/v: [B,Smax,Hkv,DH]; kv_len: scalar int32."""
    b, _, hq, dh = q.shape
    smax, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    kb = min(kv_block, smax)
    nk = smax // kb
    scale = sm_scale if sm_scale is not None else dh ** -0.5

    qr = q.reshape(b, hq, dh).reshape(b * hq, 1, dh)
    kr = k.transpose(0, 2, 1, 3).reshape(b * hkv, smax, dh)
    vr = v.transpose(0, 2, 1, 3).reshape(b * hkv, smax, dh)
    kvl = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (1,))

    kernel = functools.partial(_dec_kernel, kb=kb, scale=scale, nk=nk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b * hq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, dh), lambda h, j, *_: (h, 0, 0)),
            pl.BlockSpec((1, kb, dh), lambda h, j, *_, g=g: (h // g, j, 0)),
            pl.BlockSpec((1, kb, dh), lambda h, j, *_, g=g: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, dh), lambda h, j, *_: (h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * hq, 1, dh), q.dtype),
        interpret=interpret,
    )(kvl, qr, kr, vr)
    return out.reshape(b, hq, 1, dh).transpose(0, 2, 1, 3)
