"""Jit'd dispatcher for decode attention."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_fwd
from repro.kernels.decode_attention.ref import decode_attention_ref


def _pad_dh(x, target):
    pad = target - x.shape[-1]
    if pad == 0:
        return x
    return jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, pad)))


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_attention(q, k, v, *, kv_len=None, interpret: bool = False):
    dh = q.shape[-1]
    if kv_len is None:
        kv_len = k.shape[1]
    target = max(128, ((dh + 127) // 128) * 128)
    scale = dh ** -0.5
    qp, kp, vp = (_pad_dh(t, target) for t in (q, k, v))
    out = decode_attention_fwd(qp, kp, vp, jnp.asarray(kv_len, jnp.int32),
                               sm_scale=scale, interpret=interpret)
    return out[..., :dh]
