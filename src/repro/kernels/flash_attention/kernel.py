"""Flash attention forward kernel (TPU, pl.pallas_call + BlockSpec).

Tiling: grid = (batch*q_heads, num_q_blocks, num_kv_blocks); the kv dim is the
innermost (sequential) grid axis, so the online-softmax state (m, l, acc)
lives in VMEM scratch and persists across kv steps. Block shapes keep the MXU
fed: q block [QB, DH], kv block [KB, DH] with DH padded to a multiple of 128
lanes by ops.py (the softmax scale uses the TRUE head dim). GQA is handled in
the index map: q head h reads kv head h // (Hq // Hkv).

Causal/window masking is per-element inside a block; fully-masked kv blocks
are skipped with @pl.when (no MXU work issued for the upper triangle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               causal: bool, window: int, qb: int, kb: int, scale: float,
               nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * qb
    k_start = ki * kb
    run = jnp.bool_(True)
    if causal:                       # skip blocks above the diagonal
        run = jnp.logical_and(run, k_start <= q_start + qb - 1)
    if window:                       # skip blocks left of the window
        run = jnp.logical_and(run, k_start + kb - 1 > q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)              # [QB, DH]
        k = k_ref[0].astype(jnp.float32)              # [KB, DH]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 1)
        mask = jnp.ones((qb, kb), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                            # [QB, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        sm_scale: float = None,
                        q_block: int = 256, kv_block: int = 256,
                        interpret: bool = False) -> jax.Array:
    """q: [B,S,Hq,DH]; k/v: [B,Skv,Hkv,DH]; DH 128-aligned (ops.py pads)."""
    b, sq, hq, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qb = min(q_block, sq)
    kb = min(kv_block, skv)
    nq, nk = sq // qb, skv // kb
    scale = sm_scale if sm_scale is not None else dh ** -0.5

    # layout: fold heads into the leading grid dim
    qr = q.transpose(0, 2, 1, 3).reshape(b * hq, sq, dh)
    kr = k.transpose(0, 2, 1, 3).reshape(b * hkv, skv, dh)
    vr = v.transpose(0, 2, 1, 3).reshape(b * hkv, skv, dh)

    kernel = functools.partial(_fa_kernel, causal=causal, window=window,
                               qb=qb, kb=kb, scale=scale, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(b * hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, qb, dh), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, kb, dh), lambda h, i, j, g=g: (h // g, j, 0)),
            pl.BlockSpec((1, kb, dh), lambda h, i, j, g=g: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, qb, dh), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb, 1), jnp.float32),   # m
            pltpu.VMEM((qb, 1), jnp.float32),   # l
            pltpu.VMEM((qb, dh), jnp.float32),  # acc
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hq, sq, dh).transpose(0, 2, 1, 3)
