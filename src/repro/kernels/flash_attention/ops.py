"""Jit'd dispatcher for flash attention: head-dim padding + kernel call."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import flash_attention_ref


def _pad_dh(x, target):
    pad = target - x.shape[-1]
    if pad == 0:
        return x
    return jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, pad)))


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    interpret: bool = False):
    """q: [B,S,Hq,DH]; pads DH up to a 128 multiple (zero pads are exact:
    extra q/k lanes contribute 0 to logits, extra v lanes are sliced off)."""
    dh = q.shape[-1]
    target = max(128, ((dh + 127) // 128) * 128)
    scale = dh ** -0.5
    qp, kp, vp = (_pad_dh(t, target) for t in (q, k, v))
    out = flash_attention_fwd(qp, kp, vp, causal=causal, window=window,
                              sm_scale=scale, interpret=interpret)
    return out[..., :dh]
