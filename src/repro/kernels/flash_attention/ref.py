"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax.numpy as jnp
import jax


NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=0, sm_scale=None):
    b, sq, hq, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = sm_scale if sm_scale is not None else dh ** -0.5
    qg = q.reshape(b, sq, hkv, g, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, dh).astype(q.dtype)
