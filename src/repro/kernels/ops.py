"""Jit'd dispatch wrappers for the Pallas kernels.

``pallas_enabled()`` gates kernel use: on TPU backends kernels run compiled;
on CPU they run ``interpret=True`` (used by the test suite); models default to
the reference/chunked paths unless ``cfg.attn_impl == "pallas"``.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax

_FORCE = os.environ.get("REPRO_PALLAS", "")


def backend() -> str:
    return jax.default_backend()


def pallas_enabled() -> bool:
    if _FORCE == "0":
        return False
    return _FORCE == "1" or backend() == "tpu"


def interpret_mode() -> bool:
    """Run kernels in interpret mode (CPU correctness validation)."""
    return backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0):
    from repro.kernels.flash_attention.ops import flash_attention as fa
    return fa(q, k, v, causal=causal, window=window,
              interpret=interpret_mode())


def decode_attention(q, k, v, *, kv_len=None, window: int = 0):
    from repro.kernels.decode_attention.ops import decode_attention as da
    return da(q, k, v, kv_len=kv_len, interpret=interpret_mode())
