"""Dispatcher for the work-queue claim kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.wq_claim.kernel import wq_claim_fwd
from repro.kernels.wq_claim.ref import wq_claim_ref


@functools.partial(jax.jit,
                   static_argnames=("num_workers", "k", "interpret"))
def wq_claim(status, worker, *, num_workers: int, k: int = 1,
             interpret: bool = False):
    n = status.shape[0]
    pad = (-n) % 1024 if n > 1024 else 0
    if pad:
        status = jnp.pad(status, (0, pad))          # pads are EMPTY(0)
        worker = jnp.pad(worker, (0, pad), constant_values=-1)
    new_status, claimed = wq_claim_fwd(
        status, worker, num_workers=num_workers, k=k,
        row_block=min(1024, status.shape[0]), interpret=interpret)
    return new_status[:n], claimed[:n]
