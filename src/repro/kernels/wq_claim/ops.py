"""Dispatcher for the work-queue claim kernel."""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.wq_claim.kernel import wq_claim_fwd
from repro.kernels.wq_claim.ref import wq_claim_ref


@functools.partial(jax.jit,
                   static_argnames=("num_workers", "k", "interpret"))
def wq_claim(status, worker, *, num_workers: int, k: int = 1,
             interpret: bool = False):
    n = status.shape[0]
    pad = (-n) % 1024 if n > 1024 else 0
    if pad:
        status = jnp.pad(status, (0, pad))          # pads are EMPTY(0)
        worker = jnp.pad(worker, (0, pad), constant_values=-1)
    new_status, claimed = wq_claim_fwd(
        status, worker, num_workers=num_workers, k=k,
        row_block=min(1024, status.shape[0]), interpret=interpret)
    return new_status[:n], claimed[:n]


def wq_claim_columns(status: np.ndarray, worker: np.ndarray, *,
                     num_workers: int, k: int = 1,
                     interpret: bool = None
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Host-facing bridge for WorkQueue's device claim path.

    Takes the store's numpy status/worker columns, runs the Pallas claim op
    (interpret mode automatically off-TPU), and returns numpy
    ``(claim_mask [N] bool, new_status [N] int32)`` for the control plane to
    apply to the authoritative host store.
    """
    if status.size == 0:
        return (np.zeros(0, bool), np.zeros(0, np.int32))
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    new_status, claimed = wq_claim(
        jnp.asarray(np.ascontiguousarray(status), jnp.int32),
        jnp.asarray(np.ascontiguousarray(worker), jnp.int32),
        num_workers=num_workers, k=k, interpret=bool(interpret))
    return (np.asarray(claimed).astype(bool),
            np.asarray(new_status, np.int32))
