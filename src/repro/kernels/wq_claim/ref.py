"""Pure-jnp oracle for the work-queue claim op."""
from __future__ import annotations

import jax
import jax.numpy as jnp

READY = 2
RUNNING = 3


def wq_claim_ref(status, worker, *, num_workers: int, k: int):
    """For each worker: claim its first k READY rows (by row order)."""
    ready = status == READY
    onehot = jax.nn.one_hot(worker, num_workers, dtype=jnp.int32) \
        * ready[:, None].astype(jnp.int32)
    rank = jnp.cumsum(onehot, axis=0) - onehot           # exclusive per worker
    myrank = jnp.sum(rank * onehot, axis=1)
    claim = ready & (myrank < k)
    return jnp.where(claim, RUNNING, status), claim.astype(jnp.int32)
