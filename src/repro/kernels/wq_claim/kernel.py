"""Work-queue claim kernel — the paper's measured hot spot, TPU-native.

The paper's Experiment 6 shows getREADYtasks (SELECT next READY tasks WHERE
worker_id = w) + the RUNNING-status update are >40% + ~53% of all DBMS time.
SchalaDB's insight is that partition-private access needs no locks; on TPU
that becomes: every worker's claim is computed in ONE data-parallel pass over
the store columns, and the status flip is a masked vector write — no
conflicts are possible because the (status, worker) masks are disjoint by
construction (hash partitioning by worker id).

Inputs (columns of the WQ relation, int32):
  status [N], worker [N]  — plus scalars W (workers), K (claim budget)
Grid = (num_row_blocks,) sequential; scratch carries the per-worker running
counts [1, W]. For each row block: mask = READY & (rank within its worker's
READY sequence < K); claimed rows flip to RUNNING in-place (aliased output)
and a claim flag row is emitted. Ranks are computed with a per-worker
one-hot cumulative sum — [RB, W] VPU work, no atomics, no locks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

READY = 2
RUNNING = 3


def _claim_kernel(status_ref, worker_ref, out_status_ref, claimed_ref,
                  counts_ref, *, rb: int, w: int, k: int):
    bi = pl.program_id(0)

    @pl.when(bi == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    status = status_ref[...]                   # [RB]
    worker = worker_ref[...]                   # [RB]
    ready = status == READY
    onehot = (worker[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (rb, w), 1)) & ready[:, None]          # [RB, W]
    oh = onehot.astype(jnp.int32)
    within = jnp.cumsum(oh, axis=0) - oh                   # exclusive
    rank = jnp.sum(within * oh, axis=1) + jnp.sum(
        counts_ref[0][None, :] * oh, axis=1)               # [RB]
    claim = ready & (rank < k)
    out_status_ref[...] = jnp.where(claim, RUNNING, status)
    claimed_ref[...] = claim.astype(jnp.int32)
    counts_ref[...] = counts_ref[...] + jnp.sum(oh, axis=0)[None, :]


def wq_claim_fwd(status: jax.Array, worker: jax.Array, *, num_workers: int,
                 k: int, row_block: int = 1024,
                 interpret: bool = False):
    """status/worker: [N] int32. Returns (new_status [N], claimed [N] int32).

    claimed[i] == 1 iff row i was claimed this round (its worker's rank
    budget k not yet exhausted). One pass, no locks — the TPU analogue of
    the partition-private SELECT ... FOR UPDATE.
    """
    n = status.shape[0]
    rb = min(row_block, n)
    nb = n // rb
    kernel = functools.partial(_claim_kernel, rb=rb, w=num_workers, k=k)
    new_status, claimed = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((rb,), lambda i: (i,)),
            pl.BlockSpec((rb,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((rb,), lambda i: (i,)),
            pl.BlockSpec((rb,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((1, num_workers), jnp.int32)],
        interpret=interpret,
    )(status, worker)
    return new_status, claimed
