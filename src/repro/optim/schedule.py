"""LR schedules (cosine with linear warmup)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_warmup(step, *, peak_lr: float = 3e-4, warmup: int = 100,
                  total: int = 10_000, min_ratio: float = 0.1):
    stepf = jnp.asarray(step, jnp.float32)
    warm = stepf / jnp.maximum(warmup, 1)
    prog = jnp.clip((stepf - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return peak_lr * jnp.where(stepf < warmup, warm, cos)
