"""Adafactor (Shazeer & Stern, 2018): factored second moment, no momentum.

Memory per parameter matrix [R, C]: R + C floats instead of R*C — this is
what lets the 104B/1T archs fit 16 GB/chip HBM (see DESIGN.md §4). Updates
run in f32 and cast back to the (possibly bf16) param dtype. Stacked
per-layer leaves are updated via ``lax.map`` over the layer dim so the f32
temporaries are single-layer sized (full-stack temporaries measured ~100 GiB
on kimi-k2; see EXPERIMENTS §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

DECAY = 0.8
EPS1 = 1e-30
EPS2 = 1e-3
CLIP = 1.0
_STACK_MAP_MIN = 1 << 22


def _factored(p) -> bool:
    return p.ndim >= 2 and p.shape[-1] >= 2 and p.shape[-2] >= 2


def adafactor_init(params):
    def init(p):
        if _factored(p):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return jax.tree.map(init, params)


def _update_one(p, g, s, beta, lr, gscale):
    g = g.astype(jnp.float32) * gscale
    g2 = g * g + EPS1
    if _factored(p):
        vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
        vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
        denom = (vr[..., None] * vc[..., None, :]
                 / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                               EPS1)[..., None])
        u = g * jax.lax.rsqrt(jnp.maximum(denom, EPS1))
        new_s = {"vr": vr, "vc": vc}
    else:
        v = beta * s["v"] + (1 - beta) * g2
        u = g * jax.lax.rsqrt(jnp.maximum(v, EPS1))
        new_s = {"v": v}
    rms = jnp.sqrt(jnp.mean(u * u) + EPS1)
    u = u / jnp.maximum(1.0, rms / CLIP)
    scale = jnp.maximum(EPS2, jnp.sqrt(jnp.mean(
        jnp.square(p.astype(jnp.float32)))))
    return (p.astype(jnp.float32) - lr * scale * u).astype(p.dtype), new_s


def adafactor_update(params, grads, state, step, lr, gscale=1.0):
    stepf = step.astype(jnp.float32)
    beta = 1.0 - stepf ** (-DECAY)

    pflat = jax.tree_util.tree_flatten_with_path(params)[0]
    tree = jax.tree_util.tree_structure(params)
    gflat = jax.tree_util.tree_leaves(grads)

    def state_at(path):
        node = state
        for k in path:
            node = node[k.key if hasattr(k, "key") else k.idx]
        return node

    outs = []
    for (path, p), g in zip(pflat, gflat):
        s = state_at(path)
        if p.ndim >= 3 and p.size >= _STACK_MAP_MIN and _factored(p):
            newp, news = jax.lax.map(
                lambda a: _update_one(a[0], a[1], {"vr": a[2], "vc": a[3]},
                                      beta, lr, gscale),
                (p, g, s["vr"], s["vc"]))
            outs.append((newp, {"vr": news["vr"], "vc": news["vc"]}))
        else:
            outs.append(_update_one(p, g, s, beta, lr, gscale))
    new_params = jax.tree_util.tree_unflatten(tree, [o[0] for o in outs])
    new_state = jax.tree_util.tree_unflatten(tree, [o[1] for o in outs])
    return new_params, new_state, {}
