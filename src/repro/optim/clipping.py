"""Global-norm gradient clipping."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _sumsq(x: jax.Array) -> jax.Array:
    if x.ndim >= 3 and x.size >= (1 << 22):
        # layer-stacked leaf: reduce per layer so the f32 upcast temporary is
        # single-layer sized, not full-stack sized
        return jnp.sum(jax.lax.map(
            lambda s: jnp.sum(jnp.square(s.astype(jnp.float32))), x))
    return jnp.sum(jnp.square(x.astype(jnp.float32)))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(_sumsq(x) for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float = 1.0):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm
