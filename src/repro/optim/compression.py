"""Gradient compression with error feedback (distributed-optimization trick).

int8 per-tensor symmetric quantization applied to gradients *before* the
cross-replica mean: on TPU this halves/quarters the all-reduce bytes over ICI
(the all-reduce then runs on the int8/bf16 payload; GSPMD keeps the reduction
in the compressed dtype and we rescale after). Error feedback accumulates the
quantization residual locally so the compression is unbiased over time
(Seide et al., 2014; Karimireddy et al., 2019).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads(grads, error):
    """Returns (compressed-and-restored grads, new error feedback)."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = quantize_int8(gf)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), gf - deq
    out = jax.tree.map(one, grads, error)
    newg = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    newe = jax.tree.map(lambda t: t[1], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    return newg, newe


def init_error(grads_shape):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_shape)
