from repro.optim.api import OptState, init_opt, apply_updates  # noqa: F401
