"""Optimizer API: AdamW and Adafactor (factored, for the >100B archs).

Pure-functional: ``init_opt(cfg, params)`` -> state; ``apply_updates`` ->
(new_params, new_state, stats). The optimizer kind is carried by the config
(static), so the state is a pure array pytree (jit/sharding friendly).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.adafactor import adafactor_init, adafactor_update

OptState = Dict[str, Any]   # {"step": i32[], "inner": pytree}


def init_opt(cfg: ModelConfig, params) -> OptState:
    if cfg.optimizer == "adafactor":
        inner = adafactor_init(params)
    else:
        inner = adamw_init(params)
    return {"step": jnp.zeros((), jnp.int32), "inner": inner}


def apply_updates(cfg: ModelConfig, params, grads, state: OptState, lr,
                  gscale=1.0) -> Tuple[Any, OptState, Dict[str, Any]]:
    """gscale folds gradient clipping/averaging into the (layer-scanned)
    update so no scaled copy of the gradient tree is materialized."""
    step = state["step"] + 1
    if cfg.optimizer == "adafactor":
        new_params, inner, stats = adafactor_update(
            params, grads, state["inner"], step, lr, gscale)
    else:
        new_params, inner, stats = adamw_update(
            params, grads, state["inner"], step, lr, gscale)
    return new_params, {"step": step, "inner": inner}, stats
