"""AdamW with f32 moments (params may be bf16; update math runs in f32).

Stacked per-layer leaves ([L, ...], ndim>=3) are updated via ``lax.map`` over
the layer dim: the elementwise update math then materializes [1-layer] f32
temporaries instead of full-stack ones (measured 5 GiB x ~20 live buffers on
kimi-k2 before this; see EXPERIMENTS §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

B1, B2, EPS, WD = 0.9, 0.95, 1e-8, 0.1
_STACK_MAP_MIN = 1 << 22      # map leaves bigger than 4M elements


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params)}


def _update_one(p, g, m, v, lr, bc1, bc2, gscale):
    g = g.astype(jnp.float32) * gscale
    m = B1 * m + (1 - B1) * g
    v = B2 * v + (1 - B2) * g * g
    u = (m / bc1) / (jnp.sqrt(v / bc2) + EPS)
    u = u + WD * p.astype(jnp.float32)
    return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v


def adamw_update(params, grads, state, step, lr, gscale=1.0):
    stepf = step.astype(jnp.float32)
    bc1 = 1.0 - B1 ** stepf
    bc2 = 1.0 - B2 ** stepf

    def upd(p, g, m, v):
        if p.ndim >= 3 and p.size >= _STACK_MAP_MIN:
            return jax.lax.map(
                lambda a: _update_one(*a, lr, bc1, bc2, gscale), (p, g, m, v))
        return _update_one(p, g, m, v, lr, bc1, bc2, gscale)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
    return pick(0), {"m": pick(1), "v": pick(2)}, {}
