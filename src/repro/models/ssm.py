"""Mamba2 SSD (state-space duality) mixer.

Chunked dual form (Dao & Gu, 2024): within a chunk the recurrence is evaluated
as a masked quadratic attention-like matmul (MXU-friendly); across chunks the
state is carried by an associative scan. ``jax.lax.associative_scan`` keeps the
HLO a log-depth tree so compiled FLOP accounting stays faithful (a sequential
while-loop would hide the cost from `cost_analysis`).

Decode keeps an O(1) recurrent state per layer: {"conv": [B,W-1,Cin],
"ssm": [B,H,P,N]} — this is what makes mamba2 runnable at long_500k.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.sharding import shard

Params = Dict[str, Any]


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nheads = d_in // s.head_dim
    conv_ch = d_in + 2 * s.state_dim     # x, B, C go through the conv
    return s, d_in, nheads, conv_ch


def ssd_init(rng, cfg: ModelConfig, dtype) -> Params:
    s, d_in, nh, conv_ch = _dims(cfg)
    d = cfg.d_model
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    proj_out = 2 * d_in + 2 * s.state_dim + nh   # z, x, B, C, dt
    return {
        "in_proj": L.dense_init(k1, d, proj_out, dtype),
        "conv_w": jax.random.normal(k2, (s.conv_width, conv_ch), dtype) * 0.2,
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": L.norm_init(d_in, "rmsnorm", dtype),
        "out_proj": L.dense_init(k3, d_in, d, dtype),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    s, d_in, nh, _ = _dims(cfg)
    z, x, bmat, cmat, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + s.state_dim,
                 2 * d_in + 2 * s.state_dim], axis=-1)
    return z, x, bmat, cmat, dt


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d. x: [B,S,C]; w: [W,C]. Returns (y, new_state)."""
    wlen = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], wlen - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i: i + x.shape[1]] * w[i] for i in range(wlen)) + b
    return jax.nn.silu(y), xp[:, -(wlen - 1):]


def _segsum(da: jax.Array) -> jax.Array:
    """Stable 'segment-sum' trick: [..., Q] -> [..., Q, Q] lower-tri cumulative
    sums L[i,j] = sum(da[j+1..i]) for j < i, -inf above diagonal."""
    q = da.shape[-1]
    cs = jnp.cumsum(da, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, bmat: jax.Array,
                cmat: jax.Array, chunk: int,
                init_state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """SSD dual form.

    x: [B,S,H,P]; dt: [B,S,H] (post-softplus); a: [H] (negative);
    bmat/cmat: [B,S,N]. Returns (y [B,S,H,P], final_state [B,H,P,N]).
    """
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    nc = s // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    bc = bmat.reshape(b, nc, chunk, n)
    cc = cmat.reshape(b, nc, chunk, n)
    da = dtc * a[None, None, None, :]                    # [B,NC,Q,H] log-decay

    # --- intra-chunk (quadratic, masked) ---
    lmat = jnp.exp(_segsum(jnp.moveaxis(da, -1, 2)))     # [B,NC,H,Q,Q]
    scores = jnp.einsum("bcqn,bckn->bcqk", cc, bc)       # [B,NC,Q,Q]
    att = scores[:, :, None] * lmat                      # [B,NC,H,Q,Q]
    y_diag = jnp.einsum("bchqk,bckh,bckhp->bcqhp", att, dtc, xc)

    # --- chunk states ---
    dacum = jnp.cumsum(da, axis=2)                       # [B,NC,Q,H]
    decay_to_end = jnp.exp(dacum[:, :, -1:, :] - dacum)  # [B,NC,Q,H]
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchpn",
                        bc, dtc * decay_to_end, xc)      # [B,NC,H,P,N]

    # --- inter-chunk associative scan: S_c = G_c * S_{c-1} + states_c ---
    gc = jnp.exp(dacum[:, :, -1, :])                     # [B,NC,H] chunk decay

    def combine(e1, e2):
        g1, s1 = e1
        g2, s2 = e2
        return g1 * g2, s2 + g2[..., None, None] * s1

    gs, ss = jax.lax.associative_scan(combine, (gc, states), axis=1)
    prev = jnp.concatenate([jnp.zeros_like(ss[:, :1]), ss[:, :-1]], axis=1)
    final_state = ss[:, -1]                              # [B,H,P,N]
    if init_state is not None:
        # decayed initial state enters every chunk: prod of g over chunks < c
        gprod = jnp.concatenate([jnp.ones_like(gs[:, :1]), gs[:, :-1]], axis=1)
        prev = prev + gprod[..., None, None] * init_state[:, None]
        final_state = final_state + gs[:, -1][..., None, None] * init_state

    # --- inter-chunk contribution to outputs ---
    decay_from_start = jnp.exp(dacum)                    # [B,NC,Q,H]
    y_off = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", cc, decay_from_start, prev)

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final_state


def ssd_mixer(p: Params, x: jax.Array, cfg: ModelConfig,
              state: Optional[Dict[str, jax.Array]] = None
              ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Full Mamba2 block mixer. x: [B,S,D].

    state=None: train/prefill (chunked dual form), returns final state dict.
    state given: S must be 1 (decode); sequential update.
    """
    s, d_in, nh, conv_ch = _dims(cfg)
    zxbcdt = L.dense(p["in_proj"], x)
    z, xi, bmat, cmat, dt = _split_proj(cfg, zxbcdt)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # [B,S,H]
    a = -jnp.exp(p["A_log"])                                      # [H]

    conv_in = jnp.concatenate([xi, bmat, cmat], axis=-1)
    conv_state = None if state is None else state["conv"]
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], p["conv_b"],
                                      conv_state)
    xi, bmat, cmat = jnp.split(conv_out, [d_in, d_in + s.state_dim], axis=-1)
    xh = xi.reshape(xi.shape[0], xi.shape[1], nh, s.head_dim)
    xh = shard(xh, "batch", None, "model_heads")

    if state is None:
        seq = xh.shape[1]
        pad = (-seq) % s.chunk
        xf = xh.astype(jnp.float32)
        bf, cf = bmat.astype(jnp.float32), cmat.astype(jnp.float32)
        if pad:
            # zero-dt padding is a no-op on the state: decay exp(0)=1, inc=0
            xf = jnp.pad(xf, ((0, 0), (0, pad), (0, 0), (0, 0)))
            bf = jnp.pad(bf, ((0, 0), (0, pad), (0, 0)))
            cf = jnp.pad(cf, ((0, 0), (0, pad), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        y, fin = ssd_chunked(xf, dt, a, bf, cf, s.chunk)
        if pad:
            y = y[:, :seq]
        new_state = {"conv": new_conv, "ssm": fin}
    else:
        # decode: h' = exp(dt*a)*h + dt*B (x) ; y = C.h
        h0 = state["ssm"]                                         # [B,H,P,N]
        dt1 = dt[:, 0]                                            # [B,H]
        da = jnp.exp(dt1 * a[None, :])                            # [B,H]
        inc = jnp.einsum("bh,bn,bhp->bhpn", dt1,
                         bmat[:, 0].astype(jnp.float32),
                         xh[:, 0].astype(jnp.float32))
        h1 = h0 * da[..., None, None] + inc
        y = jnp.einsum("bn,bhpn->bhp", cmat[:, 0].astype(jnp.float32), h1)
        y = y[:, None]                                            # [B,1,H,P]
        new_state = {"conv": new_conv, "ssm": h1}

    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(x.shape[0], x.shape[1], d_in).astype(x.dtype)
    y = L.apply_norm(p["norm"], y * jax.nn.silu(z), "rmsnorm")    # gated norm
    return L.dense(p["out_proj"], y), new_state


def init_ssm_state(cfg: ModelConfig, batch: int, layers: int, dtype
                   ) -> Dict[str, jax.Array]:
    s, d_in, nh, conv_ch = _dims(cfg)
    return {
        "conv": jnp.zeros((layers, batch, s.conv_width - 1, conv_ch), dtype),
        "ssm": jnp.zeros((layers, batch, nh, s.head_dim, s.state_dim),
                         jnp.float32),
    }
