"""Shared neural layers: norms, MLPs, embeddings, RoPE / M-RoPE.

Functional style: params are plain dict pytrees; every layer is
``f(params, x, ...) -> y``. Initializers return the param subtree.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding import shard

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------
def dense_init(rng, in_dim: int, out_dim: int, dtype, bias: bool = False,
               scale: Optional[float] = None) -> Params:
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    p = {"w": jax.random.normal(rng, (in_dim, out_dim), dtype) * jnp.asarray(scale, dtype)}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def dense(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def norm_init(dim: int, kind: str, dtype) -> Params:
    p = {"scale": jnp.ones((dim,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((dim,), dtype)
    return p


def apply_norm(p: Params, x: jax.Array, kind: str, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# MLP (gated or plain)
# ---------------------------------------------------------------------------
def mlp_init(rng, d_model: int, d_ff: int, glu: bool, dtype) -> Params:
    ks = jax.random.split(rng, 3)
    p: Params = {"up": dense_init(ks[0], d_model, d_ff, dtype),
                 "down": dense_init(ks[1], d_ff, d_model, dtype)}
    if glu:
        p["gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def mlp(p: Params, x: jax.Array, act: str, glu: bool) -> jax.Array:
    h = dense(p["up"], x)
    if glu:
        h = act_fn(act)(dense(p["gate"], x)) * h
    else:
        h = act_fn(act)(h)
    h = shard(h, "batch", None, "model_ff")
    return dense(p["down"], h)


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------
def embed_init(rng, vocab: int, d_model: int, dtype) -> Params:
    return {"table": jax.random.normal(rng, (vocab, d_model), dtype) * 0.02}


def embed(p: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["table"], tokens, axis=0)


def unembed(p: Params, x: jax.Array) -> jax.Array:
    """Logits; used with tied or untied head table."""
    return x @ p["table"].T


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, Dh]; positions: broadcastable to [..., S]."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                           # [Dh/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, Dh/2]
    angles = angles[..., None, :]                            # [..., S, 1, Dh/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., : dh // 2], x[..., dh // 2:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions_3d: jax.Array, theta: float,
                sections: Tuple[int, int, int] = (16, 24, 24)) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    x: [B, S, H, Dh]; positions_3d: [3, B, S] (t/h/w position ids). The rotary
    half-dim is split into ``sections`` (t,h,w); each section rotates with its
    own position stream. sections must sum to Dh/2.
    """
    dh = x.shape[-1]
    assert sum(sections) == dh // 2, (sections, dh)
    freqs = rope_freqs(dh, theta)                           # [Dh/2]
    # pick, per frequency slot, which of the 3 position streams drives it
    sel = jnp.repeat(jnp.arange(3), jnp.asarray(sections),
                     total_repeat_length=dh // 2)           # [Dh/2]
    pos = jnp.take_along_axis(
        positions_3d.astype(jnp.float32),                   # [3,B,S]
        sel[:, None, None] * jnp.ones((1,) + positions_3d.shape[1:], jnp.int32),
        axis=0)                                             # [Dh/2,B,S]
    angles = jnp.moveaxis(pos, 0, -1) * freqs               # [B,S,Dh/2]
    angles = angles[..., None, :]                           # [B,S,1,Dh/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., : dh // 2], x[..., dh // 2:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------
def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token cross-entropy; logits [..., V] fp32-stable."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
