"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Recurrence (per channel): r_t = sigmoid(W_a x_t + b_a); i_t = sigmoid(W_x x_t + b_x)
  a_t = exp(c * softplus(Lambda) * (-r_t))        (c = 8)
  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train/prefill uses ``jax.lax.associative_scan`` (log-depth; FLOP-faithful HLO
and the TPU-parallel form). Decode is the O(1) sequential update — this is what
makes recurrentgemma runnable at long_500k.

The recurrent *block* (Griffin): y = W_out[ GeLU(W_gate x) * RGLRU(conv4(W_in x)) ].
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.ssm import _causal_conv
from repro.sharding import shard

Params = Dict[str, Any]
_C = 8.0


def _nblocks(cfg: ModelConfig) -> int:
    return cfg.num_heads


def _blockdiag_init(rng, lw: int, nb: int, dtype) -> Params:
    c = lw // nb
    return {"w": jax.random.normal(rng, (nb, c, c), dtype) * (c ** -0.5),
            "b": jnp.zeros((nb, c), dtype)}


def _blockdiag(p: Params, x: jax.Array) -> jax.Array:
    """Block-diagonal linear (Griffin's BlockDiagonalLinear): gates are
    computed per channel block — parameter-efficient AND tensor-parallel
    local (a full [lw,lw] gate matmul would all-gather the lw-sharded
    branch every layer: measured 70 x 1 GiB f32 AGs on recurrentgemma-9b
    train_4k, see EXPERIMENTS §Perf)."""
    b, s, lw = x.shape
    nb, c, _ = p["w"].shape
    xr = x.reshape(b, s, nb, c)
    y = jnp.einsum("bsnc,ncd->bsnd", xr, p["w"]) + p["b"]
    return y.reshape(b, s, lw)


def rglru_init(rng, cfg: ModelConfig, dtype) -> Params:
    r = cfg.rglru
    lw = r.lru_width or cfg.d_model
    d = cfg.d_model
    nb = _nblocks(cfg)
    k1, k2, k3, k4, k5, k6 = jax.random.split(rng, 6)
    return {
        "in": L.dense_init(k1, d, lw, dtype),
        "gate": L.dense_init(k2, d, lw, dtype),
        "out": L.dense_init(k3, lw, d, dtype),
        "conv_w": jax.random.normal(k4, (r.conv_width, lw), dtype) * 0.2,
        "conv_b": jnp.zeros((lw,), dtype),
        "wa": _blockdiag_init(k5, lw, nb, dtype),
        "wx": _blockdiag_init(k6, lw, nb, dtype),
        # Lambda init so a^c in (0.9, 0.999) at r=1 (Griffin appendix)
        "lam": jnp.log(jnp.expm1(-jnp.log(
            jnp.linspace(0.9, 0.999, lw).astype(jnp.float32)) / _C)),
    }


def _rglru_core(p: Params, x: jax.Array,
                h0: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """x: [B,S,W] -> (y [B,S,W], h_final [B,W])."""
    rgate = jax.nn.sigmoid(_blockdiag(p["wa"], x).astype(jnp.float32))
    igate = jax.nn.sigmoid(_blockdiag(p["wx"], x).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * rgate          # [B,S,W] (<=0)
    a = jnp.exp(log_a)
    gated = igate * x.astype(jnp.float32)
    # multiply by sqrt(1-a^2) (input normalization, stable form)
    beta = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12, 1.0))
    u = beta * gated

    if x.shape[1] == 1 and h0 is not None:
        h = a[:, 0] * h0 + u[:, 0]
        return h[:, None].astype(x.dtype), h

    def combine(e1, e2):
        a1, u1 = e1
        a2, u2 = e2
        return a1 * a2, a2 * u1 + u2

    a_s, h_s = jax.lax.associative_scan(combine, (a, u), axis=1)
    if h0 is not None:
        h_s = h_s + a_s * h0[:, None]
    return h_s.astype(x.dtype), h_s[:, -1]


def rglru_block(p: Params, x: jax.Array, cfg: ModelConfig,
                state: Optional[Dict[str, jax.Array]] = None
                ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Griffin recurrent block. x: [B,S,D]."""
    branch = L.dense(p["in"], x)
    branch = shard(branch, "batch", None, "model_ff")
    conv_state = None if state is None else state["conv"]
    h0 = None if state is None else state["lru"]
    branch, new_conv = _causal_conv(branch, p["conv_w"], p["conv_b"],
                                    conv_state)
    rec, h_fin = _rglru_core(p, branch, h0)
    gate = jax.nn.gelu(L.dense(p["gate"], x))
    y = L.dense(p["out"], gate * rec)
    new_state = {"conv": new_conv, "lru": h_fin}
    return y, new_state


def init_rglru_state(cfg: ModelConfig, batch: int, n_rec_layers: int, dtype
                     ) -> Dict[str, jax.Array]:
    r = cfg.rglru
    lw = r.lru_width or cfg.d_model
    return {
        "conv": jnp.zeros((n_rec_layers, batch, r.conv_width - 1, lw), dtype),
        "lru": jnp.zeros((n_rec_layers, batch, lw), jnp.float32),
    }
