"""GQA attention with KV cache, causal / sliding-window / cross variants.

The compute core dispatches to the Pallas flash/decode kernels when
``repro.kernels.ops.pallas_enabled()`` (TPU target, or interpret mode in
tests); otherwise to the pure-jnp reference (identical math).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.sharding import shard

Params = Dict[str, Any]
KVCache = Dict[str, jax.Array]   # {"k": [B,Smax,Hkv,Dh], "v": ..., "idx": scalar}

NEG_INF = -1e30


def attn_init(rng, cfg: ModelConfig, dtype, cross: bool = False) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(rng, 4)
    return {
        "q": L.dense_init(kq, d, cfg.num_heads * hd, dtype, bias=cfg.qkv_bias),
        "k": L.dense_init(kk, d, cfg.num_kv_heads * hd, dtype, bias=cfg.qkv_bias),
        "v": L.dense_init(kv, d, cfg.num_kv_heads * hd, dtype, bias=cfg.qkv_bias),
        "o": L.dense_init(ko, cfg.num_heads * hd, d, dtype),
    }


def _split_heads(x: jax.Array, n: int) -> jax.Array:
    return x.reshape(x.shape[:-1] + (n, x.shape[-1] // n))


def _merge_heads(x: jax.Array) -> jax.Array:
    return x.reshape(x.shape[:-2] + (x.shape[-2] * x.shape[-1],))


def sdpa_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
             causal: bool, window: int = 0,
             q_offset: jax.Array | int = 0,
             kv_len: Optional[jax.Array] = None) -> jax.Array:
    """Reference scaled-dot-product attention with GQA.

    q: [B,Sq,Hq,Dh], k/v: [B,Skv,Hkv,Dh]. ``q_offset`` is the absolute position
    of q[0] (for decode). ``kv_len`` masks positions >= kv_len (cache tail).
    ``window > 0`` restricts attention to the last ``window`` positions.
    """
    b, sq, hq, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, dh)
    scale = dh ** -0.5
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)[:, None] + q_offset          # [Sq,1]
    kpos = jnp.arange(skv)[None, :]                    # [1,Skv]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    if kv_len is not None:
        mask &= kpos < kv_len
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, dh).astype(q.dtype)


def _sdpa(q, k, v, *, cfg: ModelConfig, causal, window=0, q_offset=0,
          kv_len=None):
    impl = cfg.attn_impl
    if impl == "pallas":
        from repro.kernels import ops as kops  # late import: optional dep
        if q.shape[1] == 1:                # decode: 1 query token
            return kops.decode_attention(q, k, v, kv_len=kv_len, window=window)
        if kv_len is None and isinstance(q_offset, int) and q_offset == 0:
            return kops.flash_attention(q, k, v, causal=causal, window=window)
        impl = "chunked"                   # kernel has no cache-tail variant
    if impl == "chunked" and q.shape[1] > 1 and kv_len is None \
            and isinstance(q_offset, int) and q_offset == 0:
        from repro.models.chunked_attn import chunked_sdpa
        return chunked_sdpa(q, k, v, causal=causal, window=window,
                            q_chunk=cfg.q_chunk, packed=cfg.packed_causal)
    return sdpa_ref(q, k, v, causal=causal, window=window, q_offset=q_offset,
                    kv_len=kv_len)


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype,
                  layers: int) -> KVCache:
    hd = cfg.resolved_head_dim
    shape = (layers, batch, max_len, cfg.num_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "idx": jnp.zeros((), jnp.int32)}


def attention(p: Params, x: jax.Array, cfg: ModelConfig, *,
              positions: jax.Array,
              causal: bool = True,
              window: int = 0,
              cache_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
              cache_idx: Optional[jax.Array] = None,
              mrope_positions: Optional[jax.Array] = None,
              ) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """Self-attention over x: [B,S,D].

    Training/prefill: cache_kv=None -> attends within x (returns fresh K/V so
    prefill can populate the cache).
    Decode: cache_kv=(k,v) [B,Smax,Hkv,Dh] and cache_idx = #valid entries;
    x is the new token(s); returns updated (k, v).
    """
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = _split_heads(L.dense(p["q"], x), hq)
    k = _split_heads(L.dense(p["k"], x), hkv)
    v = _split_heads(L.dense(p["v"], x), hkv)
    q = shard(q, "batch", None, "model_heads")
    k = shard(k, "batch", None, "model_kv")
    v = shard(v, "batch", None, "model_kv")
    if mrope_positions is not None:
        dh = q.shape[-1]
        sec = (dh // 2 - 2 * (dh // 6), dh // 6, dh // 6)
        q = L.apply_mrope(q, mrope_positions, cfg.rope_theta, sec)
        k = L.apply_mrope(k, mrope_positions, cfg.rope_theta, sec)
    elif cfg.rope_theta > 0:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)

    if cache_kv is None:
        out = _sdpa(q, k, v, cfg=cfg, causal=causal, window=window)
        new_kv = (k, v)
    elif window and cache_kv[0].shape[1] == window:
        # rotating ring-buffer cache for sliding-window decode (bounded memory
        # at long_500k): slot s holds absolute position p(s) = t - ((t-s) % W)
        ck, cv = cache_kv
        t = cache_idx                       # absolute position of the new token
        slot = jnp.mod(t, window)
        ck = jax.lax.dynamic_update_slice(ck, k, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v, (0, slot, 0, 0))
        slots = jnp.arange(window)
        valid = (t >= window) | (slots <= t)           # unwritten slots masked
        logits_mask = jnp.where(valid, 0.0, NEG_INF)[None, None, None, None, :]
        b, hkv_, dh_ = ck.shape[0], ck.shape[2], ck.shape[3]
        g = hq // hkv_
        qg = q.reshape(b, 1, hkv_, g, dh_)
        lg = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        ck.astype(jnp.float32)) * (dh_ ** -0.5)
        lg = lg + logits_mask.reshape(1, 1, 1, 1, window)
        w = jax.nn.softmax(lg, axis=-1)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", w, cv.astype(jnp.float32))
        out = out.reshape(b, 1, hq, dh_).astype(q.dtype)
        new_kv = (ck, cv)
    else:
        ck, cv = cache_kv
        ck = jax.lax.dynamic_update_slice(ck, k, (0, cache_idx, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v, (0, cache_idx, 0, 0))
        kv_len = cache_idx + x.shape[1]
        out = _sdpa(q, ck, cv, cfg=cfg, causal=causal, window=window,
                    q_offset=cache_idx, kv_len=kv_len)
        new_kv = (ck, cv)
    out = _merge_heads(out)
    out = L.dense(p["o"], out)
    return shard(out, "batch", None, None), new_kv


def cross_attention(p: Params, x: jax.Array, enc_kv: Tuple[jax.Array, jax.Array],
                    cfg: ModelConfig) -> jax.Array:
    """Decoder cross-attention over precomputed encoder K/V."""
    hq, hd = cfg.num_heads, cfg.resolved_head_dim
    q = _split_heads(L.dense(p["q"], x), hq)
    k, v = enc_kv
    out = _sdpa(q, k, v, cfg=cfg, causal=False)
    return L.dense(p["o"], _merge_heads(out))


def encode_cross_kv(p: Params, enc_out: jax.Array, cfg: ModelConfig
                    ) -> Tuple[jax.Array, jax.Array]:
    k = _split_heads(L.dense(p["k"], enc_out), cfg.num_kv_heads)
    v = _split_heads(L.dense(p["v"], enc_out), cfg.num_kv_heads)
    return k, v
