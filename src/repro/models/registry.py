"""Model registry: uniform build API + dry-run input specs per (arch, shape).

``build_model(cfg)`` returns a ``Model`` bundle of pure functions; the launch
layer jits them with shardings, the executor invokes them per task.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FAMILY_ENCDEC, ModelConfig, ShapeConfig
from repro.models import encdec as ED
from repro.models import transformer as T


class Model(NamedTuple):
    cfg: ModelConfig
    init: Callable[[jax.Array], Any]
    train_loss: Callable[[Any, Dict[str, Any]], Tuple[jax.Array, Dict]]
    prefill: Callable[[Any, Dict[str, Any], int], Tuple[jax.Array, Any]]
    decode_step: Callable[[Any, jax.Array, Any], Tuple[jax.Array, Any]]
    init_cache: Callable[[int, int], Any]


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == FAMILY_ENCDEC:
        return Model(
            cfg=cfg,
            init=lambda rng: ED.init_params(cfg, rng),
            train_loss=lambda p, b: ED.train_loss(cfg, p, b),
            prefill=lambda p, b, m: ED.prefill(cfg, p, b, m),
            decode_step=lambda p, t, c: ED.decode_step(cfg, p, t, c),
            init_cache=lambda b, m: ED.init_cache(cfg, b, m),
        )
    return Model(
        cfg=cfg,
        init=lambda rng: T.init_params(cfg, rng),
        train_loss=lambda p, b: T.train_loss(cfg, p, b),
        prefill=lambda p, b, m: T.prefill(cfg, p, b, m),
        decode_step=lambda p, t, c: T.decode_step(cfg, p, t, c),
        init_cache=lambda b, m: T.init_cache(cfg, b, m),
    )


# ---------------------------------------------------------------------------
# dry-run input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------
def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)
    sds = jax.ShapeDtypeStruct
    if cfg.family == FAMILY_ENCDEC:
        # enc frames seq = s; decoder tokens = s // 8 (speech:text ratio)
        dec = max(cfg.loss_chunk, s // 8)
        return {"frames": sds((b, s, cfg.d_model), dt),
                "tokens": sds((b, dec), i32),
                "labels": sds((b, dec), i32)}
    batch: Dict[str, Any] = {"labels": sds((b, s), i32)}
    if cfg.embed_stub:
        batch["embeds"] = sds((b, s, cfg.d_model), dt)
    else:
        batch["tokens"] = sds((b, s), i32)
    if cfg.mrope:
        batch["mrope_positions"] = sds((3, b, s), i32)
    return batch


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Specs for serve_step: one new token given a cache of seq_len."""
    b = shape.global_batch
    sds = jax.ShapeDtypeStruct
    tokens = sds((b, 1), jnp.int32)
    cache = jax.eval_shape(
        lambda: (ED.init_cache(cfg, b, shape.seq_len)
                 if cfg.family == FAMILY_ENCDEC
                 else T.init_cache(cfg, b, shape.seq_len)))
    return {"tokens": tokens, "cache": cache}


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    sds = jax.ShapeDtypeStruct
    if cfg.family == FAMILY_ENCDEC:
        return {"frames": sds((b, s, cfg.d_model), dt),
                "tokens": sds((b, max(64, s // 8)), jnp.int32)}
    batch: Dict[str, Any] = {}
    if cfg.embed_stub:
        batch["embeds"] = sds((b, s, cfg.d_model), dt)
    else:
        batch["tokens"] = sds((b, s), jnp.int32)
    if cfg.mrope:
        batch["mrope_positions"] = sds((3, b, s), jnp.int32)
    return batch
