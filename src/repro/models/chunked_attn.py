"""Memory-sane chunked attention (pure JAX "flash" — scan over query chunks).

Used for large shapes (train_4k .. prefill_32k) where materializing [B,H,S,S]
logits is infeasible. The scan keeps the HLO small and the peak memory bounded
by one (q_chunk x S_kv) logits block per head shard.

Baseline schedule is *rectangular*: every q-chunk scans the full KV with causal
masking (2x FLOP waste on causal attention). The *triangle-packed* schedule
(``packed=True``) pairs q-chunk i with q-chunk N-1-i so each pair covers a
constant number of KV chunks — exact causal FLOPs with static shapes. The
packed schedule is a §Perf hillclimb deliverable; both are kept selectable.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

from repro.flags import scan as _flags_scan
import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _attend_block(qg, k, v, *, scale, mask):
    """qg: [B,Q,Hkv,G,Dh]; k/v: [B,K,Hkv,Dh]; mask: [Q,K] bool.
    Returns (out_unnorm [B,Q,Hkv,G,Dh] f32, lse-parts (m, l))."""
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    m = jnp.max(logits, axis=-1)                              # [B,H,G,Q]
    p = jnp.exp(logits - m[..., None])
    l = jnp.sum(p, axis=-1)                                   # [B,H,G,Q]
    out = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return out, m, l


def chunked_sdpa(q: jax.Array, k: jax.Array, v: jax.Array, *,
                 causal: bool, window: int = 0, q_chunk: int = 1024,
                 packed: bool = False) -> jax.Array:
    """q: [B,Sq,Hq,Dh]; k/v: [B,Skv,Hkv,Dh]; Sq == Skv (train/prefill)."""
    if packed and causal and not window:
        return _packed_causal(q, k, v, q_chunk=q_chunk)
    b, sq, hq, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = dh ** -0.5
    q_chunk = min(q_chunk, sq)
    nq = sq // q_chunk
    qg = q.reshape(b, nq, q_chunk, hkv, g, dh)

    kpos = jnp.arange(skv)

    def body(_, args):
        qi, i = args
        qpos = i * q_chunk + jnp.arange(q_chunk)
        mask = jnp.ones((q_chunk, skv), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window:
            mask &= kpos[None, :] > qpos[:, None] - window
        out, m, l = _attend_block(qi, k, v, scale=scale, mask=mask)
        out = out / jnp.maximum(l, 1e-30)[..., None]
        # [B,H,G,Q,D] -> [B,Q,H,G,D]
        return None, jnp.moveaxis(out, 3, 1)

    # flash-attention backward semantics: recompute the chunk's logits in the
    # backward pass instead of saving [B,H,Q,Skv] softmax residuals per chunk
    _, outs = _flags_scan(jax.checkpoint(body), None,
                           (jnp.moveaxis(qg, 1, 0), jnp.arange(nq)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, hq, dh)
    return out.astype(q.dtype)


def _packed_causal(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   q_chunk: int) -> jax.Array:
    """Triangle-packed causal schedule.

    Pair q-chunk i (needs kv[0:(i+1)c]) with q-chunk n-1-i (needs kv[0:(n-i)c]).
    Each pair is served from a single KV slab kv[0:(n-i)c], statically padded to
    the worst case but *masked per pair*, then the scan carries only the pair
    index — XLA sees (n/2) x (2 q-chunks x full-slab) rectangles whose total
    masked-out fraction is ~0 instead of ~1/2.

    Exactness: both chunks use per-element causal masks; packing changes only
    the iteration space. FLOPs halve because the slab for pair i is sliced to
    length (n-i)c — the dominant (early-i) slabs pair a short row with a long
    row. Static shape: we keep the full slab but split it in two halves and
    skip the second half for the short row via a zero-multiplier — see below.
    """
    b, sq, hq, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = dh ** -0.5
    q_chunk = min(q_chunk, sq)
    n = sq // q_chunk
    if n % 2 != 0:
        return chunked_sdpa(q, k, v, causal=True, q_chunk=q_chunk)
    qg = q.reshape(b, n, q_chunk, hkv, g, dh)
    half = skv // 2
    kpos_lo, kpos_hi = jnp.arange(half), half + jnp.arange(half)
    k_lo, v_lo = k[:, :half], v[:, :half]
    k_hi, v_hi = k[:, half:], v[:, half:]

    def pair_body(_, i):
        j = n - 1 - i
        qi = jax.lax.dynamic_index_in_dim(qg, i, 1, keepdims=False)
        qj = jax.lax.dynamic_index_in_dim(qg, j, 1, keepdims=False)
        qpos_i = i * q_chunk + jnp.arange(q_chunk)
        qpos_j = j * q_chunk + jnp.arange(q_chunk)
        # low half serves both rows; high half serves only the long row j
        qc = jnp.concatenate([qi, qj], axis=1)             # [B,2Q,H,G,D]
        qpos = jnp.concatenate([qpos_i, qpos_j])
        mask_lo = kpos_lo[None, :] <= qpos[:, None]
        out_lo, m_lo, l_lo = _attend_block(qc, k_lo, v_lo, scale=scale,
                                           mask=mask_lo)
        mask_hi = kpos_hi[None, :] <= qpos_j[:, None]
        out_hi, m_hi, l_hi = _attend_block(qj, k_hi, v_hi, scale=scale,
                                           mask=mask_hi)
        # combine row j (softmax merge of two partials)
        m_lo_j = m_lo[..., q_chunk:]
        l_lo_j = l_lo[..., q_chunk:]
        out_lo_j = out_lo[..., q_chunk:, :]
        m_j = jnp.maximum(m_lo_j, m_hi)
        a1 = jnp.exp(m_lo_j - m_j)[..., None]
        a2 = jnp.exp(m_hi - m_j)[..., None]
        out_j = (out_lo_j * a1 + out_hi * a2)
        l_j = l_lo_j * a1[..., 0] + l_hi * a2[..., 0]
        out_i = out_lo[..., :q_chunk, :] / jnp.maximum(
            l_lo[..., :q_chunk], 1e-30)[..., None]
        out_j = out_j / jnp.maximum(l_j, 1e-30)[..., None]
        # [B,H,G,Q,D] -> [B,Q,H,G,D]
        return None, (jnp.moveaxis(out_i, 3, 1), jnp.moveaxis(out_j, 3, 1),
                      i, j)

    _, (outs_i, outs_j, idx_i, idx_j) = _flags_scan(
        jax.checkpoint(pair_body), None, jnp.arange(n // 2))
    # stitch chunks back into order
    outs = jnp.concatenate([outs_i, outs_j], axis=0)       # [n, B,Q,H,G,D]
    order = jnp.concatenate([idx_i, idx_j])
    inv = jnp.argsort(order)
    outs = outs[inv]
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, hq, dh)
    return out.astype(q.dtype)
