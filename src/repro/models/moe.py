"""Mixture-of-Experts FFN: top-k router + three dispatch paths.

- "dense": all-experts einsum oracle (exact, FLOP-wasteful x E/top_k). Tests.
- "sort": capacity-bounded sort-based dispatch, single-device reference of the
  production algorithm.
- EP (automatic when a mesh rule set is active and the "model" axis >1):
  ``shard_map`` expert parallelism with *local* dispatch — routing runs under
  GSPMD, token->expert scatter happens per data shard against the local expert
  slab, partial outputs are psum'd over the "model" axis. This avoids the
  GSPMD failure mode where the [T*k, D] dispatch gather is replicated per
  device (measured: 1.17 TB/device temp on kimi-k2 train_4k; see EXPERIMENTS
  §Perf) and is the TPU-native analogue of all-to-all MoE dispatch.

Expert weights are stored padded to a multiple of EP_SHARDS (=16, the "model"
axis of the production mesh) so the expert dim always shards evenly; padding
experts receive no routing mass (router emits only the true E logits).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.sharding import current_rules, shard

Params = Dict[str, Any]

# jax.shard_map(check_vma=) landed in jax 0.5; on older jaxlibs the API lives
# in jax.experimental with the check_rep= spelling
if hasattr(jax, "shard_map"):
    _shard_map = functools.partial(jax.shard_map, check_vma=False)
else:
    from jax.experimental.shard_map import shard_map as _shard_map_impl
    _shard_map = functools.partial(_shard_map_impl, check_rep=False)

EP_SHARDS = 16          # production "model" axis size; expert-dim padding unit
CAPACITY_FACTOR = 1.25


def _epad(e: int) -> int:
    return ((e + EP_SHARDS - 1) // EP_SHARDS) * EP_SHARDS


def moe_init(rng, cfg: ModelConfig, dtype) -> Params:
    m = cfg.moe
    d, f, e = cfg.d_model, m.expert_ff, m.num_experts
    ep = _epad(e)
    kr, ku, kg, kd = jax.random.split(rng, 4)
    scale = d ** -0.5
    p: Params = {
        "router": jax.random.normal(kr, (d, e), jnp.float32) * scale,
        "up": jax.random.normal(ku, (ep, d, f), dtype) * scale,
        "down": jax.random.normal(kd, (ep, f, d), dtype) * (f ** -0.5),
    }
    if cfg.glu:
        p["gate"] = jax.random.normal(kg, (ep, d, f), dtype) * scale
    return p


def route(p: Params, x2d: jax.Array, cfg: ModelConfig
          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Router: returns (weights [T,k], expert_idx [T,k], aux_loss scalar)."""
    m = cfg.moe
    logits = (x2d.astype(jnp.float32) @ p["router"])          # [T,E]
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, m.top_k)                     # [T,k]
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    # load-balancing aux loss (Switch-style): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)                               # mean prob per e
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, m.num_experts, dtype=jnp.float32), axis=1),
        axis=0)                                                # frac routed per e
    aux = m.num_experts * jnp.sum(me * ce)
    return w.astype(x2d.dtype), idx, aux


def _expert_ffn(p: Params, buf: jax.Array, cfg: ModelConfig,
                annotate: bool = True) -> jax.Array:
    """buf: [E(,loc), C, D] -> same, via per-expert batched matmuls."""
    up, gate, down = p["up"], p.get("gate"), p["down"]
    h = jnp.einsum("ecd,edf->ecf", buf, up)
    if cfg.glu:
        h = L.act_fn(cfg.act)(jnp.einsum("ecd,edf->ecf", buf, gate)) * h
    else:
        h = L.act_fn(cfg.act)(h)
    if annotate:
        h = shard(h, "model_expert", None, None)
    return jnp.einsum("ecf,efd->ecd", h, down)


def _rank_in_expert(ek: jax.Array, counts: jax.Array, num_e: int
                    ) -> Tuple[jax.Array, jax.Array]:
    """Per-token rank among same-expert assignments + updated counts.

    ek: [T] expert ids for this top-k slot; counts: [E] running totals from
    earlier slots. All O(T) / O(E) memory (no [T,E] one-hots).
    """
    t = ek.shape[0]
    order = jnp.argsort(ek)
    sorted_e = ek[order]
    cnt = jax.ops.segment_sum(jnp.ones((t,), jnp.int32), sorted_e,
                              num_segments=num_e)
    starts = jnp.cumsum(cnt) - cnt
    rank_sorted = jnp.arange(t, dtype=jnp.int32) - starts[sorted_e]
    rank = jnp.zeros((t,), jnp.int32).at[order].set(rank_sorted)
    return rank + counts[ek], counts + cnt


def _dispatch_compute(p_local: Params, x2d: jax.Array, idx: jax.Array,
                      w: jax.Array, cfg: ModelConfig, *, e_base,
                      e_loc: int, cap: int) -> jax.Array:
    """Scatter tokens to the local expert slab, run FFN, gather back.

    x2d: [T,D]; idx/w: [T,k]; expert slab covers [e_base, e_base+e_loc).
    Loops over the k slots so no [T*k, D] intermediate is ever built.
    """
    m = cfg.moe
    t, d = x2d.shape
    counts = jnp.zeros((m.num_experts,), jnp.int32)
    buf = jnp.zeros((e_loc * cap + 1, d), x2d.dtype)
    dests = []
    for kk in range(m.top_k):
        ek = idx[:, kk]
        rank, counts = _rank_in_expert(ek, counts, m.num_experts)
        loc = ek - e_base
        keep = (loc >= 0) & (loc < e_loc) & (rank < cap)
        dest = jnp.where(keep, loc * cap + rank, e_loc * cap)
        buf = buf.at[dest].add(x2d * keep[:, None].astype(x2d.dtype))
        dests.append((dest, keep))
    out_buf = _expert_ffn(p_local, buf[:-1].reshape(e_loc, cap, d), cfg,
                          annotate=False)
    out_buf = jnp.concatenate([out_buf.reshape(e_loc * cap, d),
                               jnp.zeros((1, d), x2d.dtype)], axis=0)
    out2d = jnp.zeros((t, d), x2d.dtype)
    for kk, (dest, keep) in enumerate(dests):
        gk = w[:, kk] * keep.astype(x2d.dtype)
        out2d = out2d + out_buf[dest] * gk[:, None]
    return out2d


def moe_ffn_sort(p: Params, x: jax.Array, cfg: ModelConfig,
                 capacity_factor: float = CAPACITY_FACTOR
                 ) -> Tuple[jax.Array, jax.Array]:
    """Single-device reference of the capacity dispatch. x: [B,S,D]."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    x2d = x.reshape(t, d)
    w, idx, aux = route(p, x2d, cfg)
    cap = int(max(1, (t * m.top_k * capacity_factor) // m.num_experts))
    out2d = _dispatch_compute(p, x2d, idx, w, cfg, e_base=0,
                              e_loc=_epad(m.num_experts), cap=cap)
    return out2d.reshape(b, s, d), aux


def moe_ffn_ep(p: Params, x: jax.Array, cfg: ModelConfig,
               capacity_factor: float = CAPACITY_FACTOR
               ) -> Tuple[jax.Array, jax.Array]:
    """shard_map expert-parallel path (see module docstring)."""
    rules = current_rules()
    mesh = rules.mesh
    m = cfg.moe
    b, s, d = x.shape
    x = shard(x, "batch", None, None)
    w, idx, aux = route(p, x.reshape(b * s, d), cfg)
    w3 = shard(w.reshape(b, s, m.top_k), "batch", None, None)
    i3 = shard(idx.reshape(b, s, m.top_k), "batch", None, None)

    batch_phys = rules.physical("batch")
    ep = _epad(m.num_experts)
    e_loc = ep // mesh.shape["model"]
    dp = 1
    if batch_phys:
        for a in (batch_phys if isinstance(batch_phys, tuple)
                  else (batch_phys,)):
            dp *= mesh.shape[a]
    t_loc = (b // dp) * s
    cap = int(max(1, (t_loc * m.top_k * capacity_factor) // m.num_experts))

    bspec = P(batch_phys, None, None)
    wspecs = {k: P("model", None, None) for k in ("up", "gate", "down")
              if k in p}

    def local_fn(up, gate, down, xl, wl, il):
        rank_m = jax.lax.axis_index("model")
        bl, sl, dl = xl.shape
        pl = {"up": up, "down": down}
        if gate is not None:
            pl["gate"] = gate
        out2d = _dispatch_compute(
            pl, xl.reshape(bl * sl, dl), il.reshape(bl * sl, m.top_k),
            wl.reshape(bl * sl, m.top_k), cfg,
            e_base=rank_m * e_loc, e_loc=e_loc, cap=cap)
        out2d = jax.lax.psum(out2d, "model")
        return out2d.reshape(bl, sl, dl)

    fn = _shard_map(
        local_fn, mesh=mesh,
        in_specs=(P("model", None, None),
                  P("model", None, None) if "gate" in p else P(),
                  P("model", None, None), bspec, bspec, bspec),
        out_specs=bspec)
    out = fn(p["up"], p.get("gate"), p["down"], x, w3, i3)
    return out, aux


def moe_ffn_dense(p: Params, x: jax.Array, cfg: ModelConfig
                  ) -> Tuple[jax.Array, jax.Array]:
    """All-experts oracle (exact, no capacity drops)."""
    m = cfg.moe
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    w, idx, aux = route(p, x2d, cfg)
    up, down = p["up"][: m.num_experts], p["down"][: m.num_experts]
    h = jnp.einsum("td,edf->tef", x2d, up)
    if cfg.glu:
        gate = p["gate"][: m.num_experts]
        h = L.act_fn(cfg.act)(jnp.einsum("td,edf->tef", x2d, gate)) * h
    else:
        h = L.act_fn(cfg.act)(h)
    y_all = jnp.einsum("tef,efd->ted", h, down)                # [T,E,D]
    sel = jax.nn.one_hot(idx, m.num_experts, dtype=x.dtype)    # [T,k,E]
    gates = jnp.einsum("tk,tke->te", w, sel)                   # [T,E]
    out2d = jnp.einsum("te,ted->td", gates, y_all)
    return out2d.reshape(b, s, d), aux


def moe_ffn(p: Params, x: jax.Array, cfg: ModelConfig
            ) -> Tuple[jax.Array, jax.Array]:
    if cfg.moe.dispatch == "dense":
        return moe_ffn_dense(p, x, cfg)
    rules = current_rules()
    if rules is not None and "model" in rules.mesh.axis_names \
            and rules.mesh.shape["model"] > 1:
        return moe_ffn_ep(p, x, cfg)
    return moe_ffn_sort(p, x, cfg)
