"""Encoder-decoder backbone (SeamlessM4T-v2 style) with audio-frame stub.

Encoder: bidirectional transformer over precomputed frame embeddings (the
modality frontend is a stub per the assignment). Decoder: causal self-attn +
cross-attn + FFN. Decode keeps a self-KV cache plus precomputed cross-K/V.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

from repro.flags import scan as _flags_scan
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models.transformer import _maybe_ckpt, chunked_xent
from repro.sharding import shard

Params = Dict[str, Any]


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _enc_layer_init(rng, cfg, dtype):
    k1, k2 = jax.random.split(rng)
    return {"ln1": L.norm_init(cfg.d_model, cfg.norm, dtype),
            "attn": A.attn_init(k1, cfg, dtype),
            "ln2": L.norm_init(cfg.d_model, cfg.norm, dtype),
            "mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.glu, dtype)}


def _dec_layer_init(rng, cfg, dtype):
    k1, k2, k3 = jax.random.split(rng, 3)
    return {"ln1": L.norm_init(cfg.d_model, cfg.norm, dtype),
            "self_attn": A.attn_init(k1, cfg, dtype),
            "ln_x": L.norm_init(cfg.d_model, cfg.norm, dtype),
            "cross_attn": A.attn_init(k2, cfg, dtype),
            "ln2": L.norm_init(cfg.d_model, cfg.norm, dtype),
            "mlp": L.mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.glu, dtype)}


def init_params(cfg: ModelConfig, rng) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)   # master params; steps cast to cfg.dtype
    ke, kd, kemb, kh = jax.random.split(rng, 4)
    return {
        "embed": L.embed_init(kemb, cfg.vocab_size, cfg.d_model, dtype),
        "head": L.embed_init(kh, cfg.vocab_size, cfg.d_model, dtype),
        "encoder": jax.vmap(lambda r: _enc_layer_init(r, cfg, dtype))(
            jax.random.split(ke, cfg.num_encoder_layers)),
        "decoder": jax.vmap(lambda r: _dec_layer_init(r, cfg, dtype))(
            jax.random.split(kd, cfg.num_layers)),
        "enc_norm": L.norm_init(cfg.d_model, cfg.norm, dtype),
        "final_norm": L.norm_init(cfg.d_model, cfg.norm, dtype),
    }


def encode(cfg: ModelConfig, params: Params, frames: jax.Array) -> jax.Array:
    """frames: [B,Senc,D] precomputed embeddings (stub frontend)."""
    x = shard(frames.astype(_dtype(cfg)), "batch", "seq", None)
    positions = jnp.arange(x.shape[1])[None, :]

    def body(h, lp):
        hn = L.apply_norm(lp["ln1"], h, cfg.norm)
        out, _ = A.attention(lp["attn"], hn, cfg, positions=positions,
                             causal=False)
        h = h + out
        hn = L.apply_norm(lp["ln2"], h, cfg.norm)
        return h + L.mlp(lp["mlp"], hn, cfg.act, cfg.glu), None

    x, _ = _flags_scan(_maybe_ckpt(cfg, body), x, params["encoder"])
    return L.apply_norm(params["enc_norm"], x, cfg.norm)


def _decoder_stack(cfg, params, x, enc_out, positions, caches=None, idx=None):
    with_cache = caches is not None

    def run_layer(lp, h, lc):
        hn = L.apply_norm(lp["ln1"], h, cfg.norm)
        cache = None if lc is None else (lc["k"], lc["v"])
        out, new_kv = A.attention(lp["self_attn"], hn, cfg,
                                  positions=positions, causal=True,
                                  cache_kv=cache, cache_idx=idx)
        h = h + out
        hn = L.apply_norm(lp["ln_x"], h, cfg.norm)
        enc_kv = A.encode_cross_kv(lp["cross_attn"], enc_out, cfg)
        h = h + A.cross_attention(lp["cross_attn"], hn, enc_kv, cfg)
        hn = L.apply_norm(lp["ln2"], h, cfg.norm)
        h = h + L.mlp(lp["mlp"], hn, cfg.act, cfg.glu)
        return h, new_kv

    if with_cache:
        def body(h, layer):
            lp, lc = layer
            h, kv = run_layer(lp, h, lc)
            return h, {"k": kv[0], "v": kv[1]}
        x, new_caches = _flags_scan(_maybe_ckpt(cfg, body), x,
                                     (params["decoder"], caches))
    else:
        def body(h, lp):
            h, kv = run_layer(lp, h, None)
            return h, {"k": kv[0], "v": kv[1]}
        x, new_caches = _flags_scan(_maybe_ckpt(cfg, body), x,
                                     params["decoder"])
    return x, new_caches


def train_loss(cfg: ModelConfig, params: Params, batch: Dict[str, Any]
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    enc_out = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens)
    x = shard(x, "batch", "seq", None)
    positions = jnp.arange(x.shape[1])[None, :]
    x, _ = _decoder_stack(cfg, params, x, enc_out, positions)
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    loss = chunked_xent(cfg, x, params["head"]["table"], batch["labels"])
    return loss, {"loss": loss, "aux_loss": jnp.zeros((), jnp.float32)}


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    dtype = _dtype(cfg)
    hd = cfg.resolved_head_dim
    shape = (cfg.num_layers, batch, max_len, cfg.num_kv_heads, hd)
    enc = (batch, cfg.cross_kv_len, cfg.d_model)
    return {"layers": {"k": jnp.zeros(shape, dtype),
                       "v": jnp.zeros(shape, dtype)},
            "enc_out": jnp.zeros(enc, dtype),
            "idx": jnp.zeros((), jnp.int32)}


def prefill(cfg: ModelConfig, params: Params, batch: Dict[str, Any],
            max_len: int) -> Tuple[jax.Array, Dict[str, Any]]:
    """Encode frames; prefill the decoder with the prompt tokens."""
    enc_out = encode(cfg, params, batch["frames"])
    # keep only cross_kv_len frames for decode cross-attention (fixed budget)
    enc_keep = enc_out[:, : cfg.cross_kv_len]
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = L.embed(params["embed"], tokens)
    positions = jnp.arange(s)[None, :]
    x, fresh = _decoder_stack(cfg, params, x, enc_out, positions)
    cache = init_cache(cfg, b, max_len)
    ck = jax.lax.dynamic_update_slice(cache["layers"]["k"],
                                      fresh["k"].astype(_dtype(cfg)),
                                      (0, 0, 0, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["layers"]["v"],
                                      fresh["v"].astype(_dtype(cfg)),
                                      (0, 0, 0, 0, 0))
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = x[:, -1:] @ params["head"]["table"].T
    pad = cfg.cross_kv_len - enc_keep.shape[1]
    if pad > 0:
        enc_keep = jnp.pad(enc_keep, ((0, 0), (0, pad), (0, 0)))
    return logits, {"layers": {"k": ck, "v": cv},
                    "enc_out": enc_keep.astype(_dtype(cfg)),
                    "idx": jnp.asarray(s, jnp.int32)}


def decode_step(cfg: ModelConfig, params: Params, tokens: jax.Array,
                cache: Dict[str, Any]) -> Tuple[jax.Array, Dict[str, Any]]:
    x = L.embed(params["embed"], tokens)
    idx = cache["idx"]
    positions = idx[None, None] * jnp.ones((x.shape[0], 1), jnp.int32)
    x, new_caches = _decoder_stack(cfg, params, x, cache["enc_out"],
                                   positions, caches=cache["layers"], idx=idx)
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = x[:, -1:] @ params["head"]["table"].T
    return logits, {"layers": new_caches, "enc_out": cache["enc_out"],
                    "idx": idx + 1}
