"""Decoder-only LM assembly for all decoder families (dense / moe / ssm /
hybrid / vlm), with scan-over-layers, remat, KV / recurrent caches, and
sequence-chunked cross-entropy for big vocabularies.

Params are dict pytrees whose per-layer leaves are stacked on a leading [L]
axis so the whole stack lowers as one ``lax.scan`` body (small HLO, fast
compiles at 512 devices).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

from repro.flags import scan as _flags_scan
import jax
import jax.numpy as jnp

from repro.configs.base import (FAMILY_DENSE, FAMILY_HYBRID, FAMILY_MOE,
                                FAMILY_SSM, FAMILY_VLM, ModelConfig)
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import rglru as R
from repro.models import ssm as S
from repro.sharding import shard

Params = Dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------
def _attn_block_init(rng, cfg: ModelConfig, dtype) -> Params:
    k1, k2 = jax.random.split(rng)
    p = {"ln1": L.norm_init(cfg.d_model, cfg.norm, dtype),
         "attn": A.attn_init(k1, cfg, dtype)}
    if cfg.d_ff or cfg.moe:
        p["ln2"] = L.norm_init(cfg.d_model, cfg.norm, dtype)
        if cfg.family == FAMILY_MOE:
            p["moe"] = M.moe_init(k2, cfg, dtype)
        else:
            p["mlp"] = L.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.glu, dtype)
    return p


def _ssm_block_init(rng, cfg: ModelConfig, dtype) -> Params:
    return {"ln1": L.norm_init(cfg.d_model, cfg.norm, dtype),
            "mixer": S.ssd_init(rng, cfg, dtype)}


def _rec_block_init(rng, cfg: ModelConfig, dtype) -> Params:
    k1, k2 = jax.random.split(rng)
    return {"ln1": L.norm_init(cfg.d_model, cfg.norm, dtype),
            "mixer": R.rglru_init(k1, cfg, dtype),
            "ln2": L.norm_init(cfg.d_model, cfg.norm, dtype),
            "mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.glu, dtype)}


def _stack_init(rng, n: int, init_fn) -> Params:
    return jax.vmap(init_fn)(jax.random.split(rng, n))


def _hybrid_counts(cfg: ModelConfig) -> Tuple[int, int]:
    """(n_groups, n_tail) for the (rec,rec,attn) pattern."""
    plen = len(cfg.rglru.pattern)
    return cfg.num_layers // plen, cfg.num_layers % plen


def init_params(cfg: ModelConfig, rng) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)   # master params; steps cast to cfg.dtype
    k_embed, k_layers, k_head, k_tail = jax.random.split(rng, 4)
    p: Params = {"embed": L.embed_init(k_embed, cfg.vocab_size, cfg.d_model,
                                       dtype),
                 "final_norm": L.norm_init(cfg.d_model, cfg.norm, dtype)}
    if not cfg.tie_embeddings:
        p["head"] = L.embed_init(k_head, cfg.vocab_size, cfg.d_model, dtype)

    if cfg.family in (FAMILY_DENSE, FAMILY_MOE, FAMILY_VLM):
        p["layers"] = _stack_init(
            k_layers, cfg.num_layers,
            lambda r: _attn_block_init(r, cfg, dtype))
    elif cfg.family == FAMILY_SSM:
        p["layers"] = _stack_init(
            k_layers, cfg.num_layers,
            lambda r: _ssm_block_init(r, cfg, dtype))
    elif cfg.family == FAMILY_HYBRID:
        ng, nt = _hybrid_counts(cfg)

        def group_init(r):
            ks = jax.random.split(r, len(cfg.rglru.pattern))
            g = {}
            for i, kind in enumerate(cfg.rglru.pattern):
                g[f"pos{i}"] = (_rec_block_init(ks[i], cfg, dtype)
                                if kind == "rec"
                                else _attn_block_init(ks[i], cfg, dtype))
            return g
        p["groups"] = _stack_init(k_layers, ng, group_init)
        if nt:
            p["tail"] = _stack_init(
                k_tail, nt, lambda r: _rec_block_init(r, cfg, dtype))
    else:
        raise ValueError(cfg.family)
    return p


# ---------------------------------------------------------------------------
# blocks (train/prefill: cache=None; decode: cache per layer)
# ---------------------------------------------------------------------------
def _attn_block(p, x, cfg: ModelConfig, *, positions, window=0, cache=None,
                idx=None, mrope=None):
    h = L.apply_norm(p["ln1"], x, cfg.norm)
    out, new_kv = A.attention(
        p["attn"], h, cfg, positions=positions, causal=True, window=window,
        cache_kv=cache, cache_idx=idx, mrope_positions=mrope)
    x = x + out
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        h = L.apply_norm(p["ln2"], x, cfg.norm)
        out, aux = M.moe_ffn(p["moe"], h, cfg)
        x = x + out
    elif "mlp" in p:
        h = L.apply_norm(p["ln2"], x, cfg.norm)
        x = x + L.mlp(p["mlp"], h, cfg.act, cfg.glu)
    return x, new_kv, aux


def _ssm_block(p, x, cfg: ModelConfig, *, cache=None):
    h = L.apply_norm(p["ln1"], x, cfg.norm)
    out, new_state = S.ssd_mixer(p["mixer"], h, cfg, state=cache)
    return x + out, new_state


def _rec_block(p, x, cfg: ModelConfig, *, cache=None):
    h = L.apply_norm(p["ln1"], x, cfg.norm)
    out, new_state = R.rglru_block(p["mixer"], h, cfg, state=cache)
    x = x + out
    h = L.apply_norm(p["ln2"], x, cfg.norm)
    x = x + L.mlp(p["mlp"], h, cfg.act, cfg.glu)
    return x, new_state


# ---------------------------------------------------------------------------
# stacks
# ---------------------------------------------------------------------------
def _maybe_ckpt(cfg: ModelConfig, fn):
    # prevent_cse=False: safe under scan (which already isolates iterations)
    # and lets XLA keep the bf16 carry as the saved residual instead of an
    # upcast f32 copy (halves per-layer activation stash)
    return jax.checkpoint(fn, prevent_cse=False) if cfg.remat else fn


def _run_stack(cfg: ModelConfig, params: Params, x, *, positions,
               caches=None, idx=None, mrope=None):
    """Returns (x, new_caches, total_aux)."""
    fam = cfg.family

    with_cache = caches is not None

    if fam in (FAMILY_DENSE, FAMILY_MOE, FAMILY_VLM):
        if with_cache:
            def body(carry, layer):
                h, aux = carry
                lp, lc = layer
                h, new_kv, a = _attn_block(lp, h, cfg, positions=positions,
                                           cache=(lc["k"], lc["v"]), idx=idx,
                                           mrope=mrope)
                return (h, aux + a), {"k": new_kv[0], "v": new_kv[1]}
            xs = (params["layers"], caches)
        else:
            def body(carry, lp):
                h, aux = carry
                h, _, a = _attn_block(lp, h, cfg, positions=positions,
                                      mrope=mrope)
                return (h, aux + a), None
            xs = params["layers"]
        (x, aux), new_caches = _flags_scan(_maybe_ckpt(cfg, body),
                                            (x, jnp.zeros((), jnp.float32)),
                                            xs)
        return x, new_caches, aux

    if fam == FAMILY_SSM:
        if with_cache:
            def body(h, layer):
                lp, lc = layer
                return _ssm_block(lp, h, cfg, cache=lc)
            xs = (params["layers"], caches)
        else:
            def body(h, lp):
                h, _ = _ssm_block(lp, h, cfg)
                return h, None
            xs = params["layers"]
        x, new_caches = _flags_scan(_maybe_ckpt(cfg, body), x, xs)
        return x, new_caches, jnp.zeros((), jnp.float32)

    if fam == FAMILY_HYBRID:
        pattern = cfg.rglru.pattern
        window = cfg.rglru.window

        def make_body(has_cache):
            def body(h, layer):
                lp, lc = layer if has_cache else (layer, None)
                outs = {}
                for i, kind in enumerate(pattern):
                    key = f"pos{i}"
                    c = None if lc is None else lc.get(key)
                    if kind == "rec":
                        h, st = _rec_block(lp[key], h, cfg, cache=c)
                        if has_cache:
                            outs[key] = st
                    else:
                        kv = None if c is None else (c["k"], c["v"])
                        h, new_kv, _ = _attn_block(
                            lp[key], h, cfg, positions=positions,
                            window=window, cache=kv, idx=idx)
                        if has_cache:
                            outs[key] = {"k": new_kv[0], "v": new_kv[1]}
                return h, (outs if has_cache else None)
            return body

        if with_cache:
            xs = (params["groups"], caches["groups"])
        else:
            xs = params["groups"]
        x, new_g = _flags_scan(_maybe_ckpt(cfg, make_body(with_cache)), x, xs)

        new_tail = None
        if "tail" in params:
            if with_cache:
                def tail_body(h, layer):
                    lp, lc = layer
                    return _rec_block(lp, h, cfg, cache=lc)
                xs = (params["tail"], caches["tail"])
            else:
                def tail_body(h, lp):
                    h, _ = _rec_block(lp, h, cfg)
                    return h, None
                xs = params["tail"]
            x, new_tail = _flags_scan(_maybe_ckpt(cfg, tail_body), x, xs)
        if not with_cache:
            return x, None, jnp.zeros((), jnp.float32)
        return x, {"groups": new_g, "tail": new_tail}, \
            jnp.zeros((), jnp.float32)

    raise ValueError(fam)


# ---------------------------------------------------------------------------
# losses / heads
# ---------------------------------------------------------------------------
def _head_table(cfg: ModelConfig, params: Params) -> jax.Array:
    return params["embed"]["table"] if cfg.tie_embeddings \
        else params["head"]["table"]


def chunked_xent(cfg: ModelConfig, x: jax.Array, table: jax.Array,
                 labels: jax.Array) -> jax.Array:
    """Sequence-chunked mean cross-entropy. x: [B,S,D]; labels: [B,S].

    Never materializes [B,S,V]; peak is [B,chunk,V] (sharded over model_vocab).

    The table is resharded to a VOCAB-sharded view once per step: tied archs
    store it D-sharded (cheap embedding lookups), but contracting a D-sharded
    table in the loss produces [B,chunk,V] all-reduces/gathers (measured
    4 x 32 GiB f32 AGs on recurrentgemma-9b; see EXPERIMENTS §Perf). With the
    V-sharded view each model rank computes its V/16 logit slice locally.
    """
    table = shard(table, "model_vocab", None)
    b, s, d = x.shape
    chunk = min(cfg.loss_chunk, s)
    nc = s // chunk
    xc = x.reshape(b, nc, chunk, d)
    lc = labels.reshape(b, nc, chunk)

    def body(tot, args):
        xi, li = args                       # [B,chunk,D], [B,chunk]
        logits = (xi @ table.T).astype(jnp.float32)
        logits = shard(logits, "batch", None, "model_vocab")
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(logz - gold), None

    body = jax.checkpoint(body)
    tot, _ = _flags_scan(body, jnp.zeros((), jnp.float32),
                          (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(lc, 1, 0)))
    return tot / (b * s)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------
def _embed_inputs(cfg: ModelConfig, params: Params, batch: Dict[str, Any]):
    if cfg.embed_stub:
        x = batch["embeds"].astype(_dtype(cfg))
    else:
        # gather from an explicitly replicated table view: XLA's SPMD
        # partitioner mis-partitions the gather when the table is sharded on
        # the offset dim (verifier failure: all-reduce + oversized
        # dynamic-slice at 512 devices). The forced replication costs one
        # table all-gather per microbatch — visible in the collective
        # roofline term and tracked as a §Perf hillclimb item.
        table = shard(params["embed"]["table"], None, None)
        x = jnp.take(table, batch["tokens"], axis=0)
    return shard(x, "batch", "seq", None)


def train_loss(cfg: ModelConfig, params: Params, batch: Dict[str, Any]
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    x = _embed_inputs(cfg, params, batch)
    b, s = x.shape[:2]
    positions = jnp.arange(s)[None, :]
    mrope = batch.get("mrope_positions") if cfg.mrope else None
    x, _, aux = _run_stack(cfg, params, x, positions=positions, mrope=mrope)
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    loss = chunked_xent(cfg, x, _head_table(cfg, params), batch["labels"])
    if cfg.moe is not None:
        loss = loss + cfg.moe.aux_loss_weight * aux
    return loss, {"loss": loss, "aux_loss": aux}


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    dtype = _dtype(cfg)
    fam = cfg.family
    if fam in (FAMILY_DENSE, FAMILY_MOE, FAMILY_VLM):
        cache = A.init_kv_cache(cfg, batch, max_len, dtype, cfg.num_layers)
        return {"layers": {"k": cache["k"], "v": cache["v"]},
                "idx": jnp.zeros((), jnp.int32)}
    if fam == FAMILY_SSM:
        st = S.init_ssm_state(cfg, batch, cfg.num_layers, dtype)
        return {"layers": st, "idx": jnp.zeros((), jnp.int32)}
    if fam == FAMILY_HYBRID:
        ng, nt = _hybrid_counts(cfg)
        w = min(cfg.rglru.window, max_len)
        hd = cfg.resolved_head_dim
        groups: Dict[str, Any] = {}
        for i, kind in enumerate(cfg.rglru.pattern):
            if kind == "rec":
                st = R.init_rglru_state(cfg, batch, ng, dtype)
            else:
                st = {"k": jnp.zeros((ng, batch, w, cfg.num_kv_heads, hd),
                                     dtype),
                      "v": jnp.zeros((ng, batch, w, cfg.num_kv_heads, hd),
                                     dtype)}
            groups[f"pos{i}"] = st
        tail = R.init_rglru_state(cfg, batch, nt, dtype) if nt else None
        return {"layers": {"groups": groups, "tail": tail},
                "idx": jnp.zeros((), jnp.int32)}
    raise ValueError(fam)


def prefill(cfg: ModelConfig, params: Params, batch: Dict[str, Any],
            max_len: int) -> Tuple[jax.Array, Dict[str, Any]]:
    """Run the prompt, build the decode cache, return last-position logits."""
    x = _embed_inputs(cfg, params, batch)
    b, s = x.shape[:2]
    positions = jnp.arange(s)[None, :]
    mrope = batch.get("mrope_positions") if cfg.mrope else None
    cache = init_cache(cfg, b, max_len)
    fam = cfg.family

    if fam in (FAMILY_DENSE, FAMILY_MOE, FAMILY_VLM):
        # run without cache, then scatter fresh K/V into the cache
        def body(carry, lp):
            h, aux = carry
            h, kv, a = _attn_block(lp, h, cfg, positions=positions,
                                   mrope=mrope)
            return (h, aux + a), {"k": kv[0], "v": kv[1]}
        (x, _), fresh = _flags_scan(_maybe_ckpt(cfg, body),
                                     (x, jnp.zeros((), jnp.float32)),
                                     params["layers"])
        ck = jax.lax.dynamic_update_slice(
            cache["layers"]["k"], fresh["k"].astype(_dtype(cfg)),
            (0, 0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["layers"]["v"], fresh["v"].astype(_dtype(cfg)),
            (0, 0, 0, 0, 0))
        cache = {"layers": {"k": ck, "v": cv},
                 "idx": jnp.asarray(s, jnp.int32)}
    elif fam == FAMILY_SSM:
        def body(carry, layer):
            h = carry
            lp = layer
            hn = L.apply_norm(lp["ln1"], h, cfg.norm)
            out, st = S.ssd_mixer(lp["mixer"], hn, cfg, state=None)
            # recover final conv state from the tail of the conv input
            return h + out, st
        # For prefill we recompute states via the chunked form; conv state is
        # the last (conv_width-1) conv inputs — handled inside ssd_mixer when
        # state propagation is requested. Simpler: run mixers individually.
        x, states = _ssm_prefill(cfg, params, x)
        cache = {"layers": states, "idx": jnp.asarray(s, jnp.int32)}
    elif fam == FAMILY_HYBRID:
        x, states = _hybrid_prefill(cfg, params, x, positions, max_len)
        cache = {"layers": states, "idx": jnp.asarray(s, jnp.int32)}
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = x[:, -1:] @ _head_table(cfg, params).T
    return logits, cache


def _ssm_prefill(cfg, params, x):
    def body(h, lp):
        hn = L.apply_norm(lp["ln1"], h, cfg.norm)
        out, st = S.ssd_mixer(lp["mixer"], hn, cfg, state=None)
        return h + out, st
    x, states = _flags_scan(_maybe_ckpt(cfg, body), x, params["layers"])
    return x, states


def _hybrid_prefill(cfg, params, x, positions, max_len):
    w = min(cfg.rglru.window, max_len)
    s = x.shape[1]

    def scatter_window(kv):
        k, v = kv
        # place the last w entries at slot = pos % w (ring layout)
        pos = jnp.arange(s - w, s) if s >= w else jnp.arange(s)
        slots = jnp.mod(pos, w)
        ck = jnp.zeros((k.shape[0], w) + k.shape[2:], k.dtype)
        ck = ck.at[:, slots].set(k[:, -len(slots):] if s >= w else k)
        cv = jnp.zeros_like(ck)
        cv = cv.at[:, slots].set(v[:, -len(slots):] if s >= w else v)
        return {"k": ck, "v": cv}

    def body(h, lp):
        outs = {}
        for i, kind in enumerate(cfg.rglru.pattern):
            key = f"pos{i}"
            if kind == "rec":
                hn = L.apply_norm(lp[key]["ln1"], h, cfg.norm)
                out, st = R.rglru_block(lp[key]["mixer"], hn, cfg, state=None)
                h = h + out
                hn = L.apply_norm(lp[key]["ln2"], h, cfg.norm)
                h = h + L.mlp(lp[key]["mlp"], hn, cfg.act, cfg.glu)
                outs[key] = st
            else:
                h, kv, _ = _attn_block(lp[key], h, cfg, positions=positions,
                                       window=cfg.rglru.window)
                outs[key] = scatter_window(kv)
        return h, outs

    x, groups = _flags_scan(_maybe_ckpt(cfg, body), x, params["groups"])
    tail = None
    if "tail" in params:
        def tail_body(h, lp):
            hn = L.apply_norm(lp["ln1"], h, cfg.norm)
            out, st = R.rglru_block(lp["mixer"], hn, cfg, state=None)
            h = h + out
            hn = L.apply_norm(lp["ln2"], h, cfg.norm)
            h = h + L.mlp(lp["mlp"], hn, cfg.act, cfg.glu)
            return h, st
        x, tail = _flags_scan(_maybe_ckpt(cfg, tail_body), x, params["tail"])
    return x, {"groups": groups, "tail": tail}


def decode_step(cfg: ModelConfig, params: Params, tokens: jax.Array,
                cache: Dict[str, Any]) -> Tuple[jax.Array, Dict[str, Any]]:
    """One decode step. tokens: [B,1] (or embeds [B,1,D] for stub archs)."""
    if cfg.embed_stub and tokens.ndim == 3:
        x = tokens.astype(_dtype(cfg))
    else:
        x = L.embed(params["embed"], tokens)
    idx = cache["idx"]
    positions = idx[None, None] * jnp.ones((x.shape[0], 1), jnp.int32)
    mrope = None
    if cfg.mrope:
        mrope = jnp.broadcast_to(positions[None], (3,) + positions.shape)
    x, new_caches, _ = _run_stack(cfg, params, x, positions=positions,
                                  caches=cache["layers"], idx=idx,
                                  mrope=mrope)
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = x[:, -1:] @ _head_table(cfg, params).T
    return logits, {"layers": new_caches, "idx": idx + 1}
