"""Roofline analysis per (arch x shape x mesh) from compiled dry-run cells.

Three terms (TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI):

  compute    = HLO_FLOPs        / (chips * peak_flops)
  memory     = HLO_bytes        / (chips * hbm_bw)
  collective = link_bytes/chip  / link_bw

FLOP/byte counting caveat + remedy: ``cost_analysis`` counts a while-loop
(scan) body ONCE regardless of trip count. We therefore run a *two-point
depth probe*: the same step is lowered at depth d1 and d2 layers with every
model scan fully unrolled (flags.unrolled_scans) and microbatches=1 (token
count — and hence FLOPs — are batch-linear, so accumulation doesn't change
totals). Then

  per_layer = (cost(d2) - cost(d1)) / (d2 - d1)
  total     = cost(d1) + per_layer * (L_real - d1)

The same scaling applies to collective bytes. The gradient all-reduce bytes
DO scale with microbatch count; we add the analytic correction
(mb-1) * grad_sync_bytes on top of the probe (documented per cell).

MODEL_FLOPS (the "useful" numerator for the efficiency ratio) is the standard
analytic count: 6*N_active*T for training (2*N_active*T forward) plus the
attention term 12*L*B*S^2*H*Dh*(0.5 causal) (4*... for forward-only), and the
family-specific mixer terms for SSD / RG-LRU.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Dict, Optional, Tuple

import numpy as np

from repro.configs import SHAPES, get_config
from repro.configs.base import (FAMILY_ENCDEC, FAMILY_HYBRID, FAMILY_MOE,
                                FAMILY_SSM, HardwareConfig, ModelConfig,
                                ShapeConfig, V5E)


# ---------------------------------------------------------------------------
# analytic MODEL_FLOPS
# ---------------------------------------------------------------------------
def analytic_model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    n_active = cfg.active_param_count
    hd = cfg.resolved_head_dim
    if shape.kind == "train":
        tokens = shape.tokens
        base = 6.0 * n_active * tokens
        attn = _attn_flops(cfg, shape.global_batch, shape.seq_len,
                           mult=12.0)
        return base + attn
    if shape.kind == "prefill":
        tokens = shape.tokens
        base = 2.0 * n_active * tokens
        attn = _attn_flops(cfg, shape.global_batch, shape.seq_len, mult=4.0)
        return base + attn
    # decode: one token per sequence
    b = shape.global_batch
    base = 2.0 * n_active * b
    # attention over the cache: 4*B*L_attn*Hq*Dh*S_kv (QK^T + PV)
    l_attn, _ = _attn_layer_count(cfg)
    skv = shape.seq_len
    if cfg.family == FAMILY_HYBRID:
        skv = min(skv, cfg.rglru.window)
    if cfg.family == FAMILY_SSM:
        attn = 2.0 * b * cfg.num_layers * _ssd_state_flops(cfg)
    else:
        attn = 4.0 * b * l_attn * cfg.num_heads * hd * skv
    if cfg.family == FAMILY_ENCDEC:
        attn += 4.0 * b * cfg.num_layers * cfg.num_heads * hd \
            * cfg.cross_kv_len
    return base + attn


def _attn_layer_count(cfg: ModelConfig) -> Tuple[int, float]:
    """(#self-attention layers, causal factor)."""
    if cfg.family == FAMILY_SSM:
        return 0, 1.0
    if cfg.family == FAMILY_HYBRID:
        plen = len(cfg.rglru.pattern)
        n_attn = (cfg.num_layers // plen) * sum(
            1 for p in cfg.rglru.pattern if p == "attn")
        return n_attn, 1.0
    if cfg.family == FAMILY_ENCDEC:
        return cfg.num_layers + cfg.num_encoder_layers, 1.0
    return cfg.num_layers, 0.5     # causal


def _attn_flops(cfg: ModelConfig, b: int, s: int, mult: float) -> float:
    l_attn, causal = _attn_layer_count(cfg)
    hd = cfg.resolved_head_dim
    if cfg.family == FAMILY_HYBRID:
        # local attention: each query sees at most `window` keys
        w = cfg.rglru.window
        span = min(w, s)
        per = mult * b * s * span * cfg.num_heads * hd * 0.5
        rec_layers = cfg.num_layers - l_attn
        ssd = 0.0
        return l_attn * per + rec_layers * mult / 2.0 * b * s \
            * (cfg.rglru.lru_width or cfg.d_model)   # recurrence ~ elementwise
    if cfg.family == FAMILY_SSM:
        return cfg.num_layers * mult / 2.0 * b * s * _ssd_chunk_flops(cfg)
    if cfg.family == FAMILY_ENCDEC:
        enc = cfg.num_encoder_layers * mult * b * s * s \
            * cfg.num_heads * hd
        dec_s = max(cfg.loss_chunk, s // 8)
        dec = cfg.num_layers * mult * b * dec_s * dec_s * cfg.num_heads \
            * hd * 0.5
        cross = cfg.num_layers * mult * b * dec_s * min(s, cfg.cross_kv_len) \
            * cfg.num_heads * hd
        return enc + dec + cross
    return l_attn * mult * b * s * s * cfg.num_heads * hd * causal


def _ssd_chunk_flops(cfg: ModelConfig) -> float:
    """Per-token SSD dual-form flops (intra-chunk quadratic + states)."""
    s_cfg = cfg.ssm
    d_in = s_cfg.expand * cfg.d_model
    nh = d_in // s_cfg.head_dim
    q = s_cfg.chunk
    n, p = s_cfg.state_dim, s_cfg.head_dim
    # per token: scores row q*n + y_diag q*p per head group + states n*p
    return nh * (q * n / nh + q * p + 2 * n * p)


def _ssd_state_flops(cfg: ModelConfig) -> float:
    s_cfg = cfg.ssm
    d_in = s_cfg.expand * cfg.d_model
    nh = d_in // s_cfg.head_dim
    return nh * s_cfg.head_dim * s_cfg.state_dim * 2


# ---------------------------------------------------------------------------
# probe
# ---------------------------------------------------------------------------
def probe_depths(cfg: ModelConfig) -> Tuple[int, int]:
    """Never probe with a trip-count-1 layer scan: GSPMD lowers single-trip
    scans with degraded (replicated) sharding, inflating per-device costs
    ~16x (measured on recurrentgemma prefill_32k)."""
    if cfg.family == FAMILY_HYBRID:
        plen = len(cfg.rglru.pattern)
        return 2 * plen, 3 * plen        # 2 and 3 pattern groups
    return 2, 3


def layer_units(cfg: ModelConfig) -> float:
    """Real depth in probe units (hybrid: groups incl. fractional tail)."""
    if cfg.family == FAMILY_HYBRID:
        plen = len(cfg.rglru.pattern)
        return cfg.num_layers / plen
    return float(cfg.num_layers)


def probe_cfg(cfg: ModelConfig, depth: int) -> ModelConfig:
    upd = dict(num_layers=depth, microbatches=1, q_chunk=2048,
               loss_chunk=2048, attn_impl="chunked")
    if cfg.family == FAMILY_ENCDEC:
        plen = 1
        upd["num_encoder_layers"] = depth
    return dataclasses.replace(cfg, **upd)


def run_probe(arch: str, shape_name: str, multi_pod: bool = False
              ) -> Dict[str, float]:
    """Lower the cell at two unrolled depths; return per-layer + base costs."""
    from repro import flags
    from repro.analysis.hlo_collectives import parse_collectives
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import shape_cells

    cfg0 = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    d1, d2 = probe_depths(cfg0)
    out: Dict[str, Dict[str, float]] = {}
    for d in (d1, d2):
        cfg = probe_cfg(cfg0, d)
        with flags.unrolled_scans(True):
            lowered = shape_cells(cfg, shape, mesh)
            compiled = lowered.compile()
        cost = compiled.cost_analysis()
        coll = parse_collectives(compiled.as_text())
        out[d] = {
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "link_bytes": coll.link_bytes(mesh.size),
        }
    units = layer_units(cfg0)
    # per-unit delta: non-hybrid probes step layers; hybrid probes step whole
    # (rec,rec,attn) groups
    plen = len(cfg0.rglru.pattern) if cfg0.family == FAMILY_HYBRID else 1
    unit_span = (d2 - d1) / plen
    per_unit = {k: (out[d2][k] - out[d1][k]) / unit_span for k in out[d1]}
    base_units = d1 / plen
    total = {k: out[d1][k] + per_unit[k] * (units - base_units)
             for k in out[d1]}
    # microbatch gradient-sync correction (train only): each extra microbatch
    # re-syncs gradients once
    mb = cfg0.microbatches
    if shape.kind == "train" and mb > 1:
        grad_bytes = cfg0.param_count * 2.0    # bf16 grads
        n = mesh.size
        total["link_bytes"] += (mb - 1) * 2.0 * grad_bytes * (n - 1) / n / n
    return {"d1": out[d1], "d2": out[d2], "per_unit": per_unit,
            "total": total, "units": units}


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------
def roofline_terms(total: Dict[str, float], n_chips: int,
                   hw: HardwareConfig = V5E) -> Dict[str, float]:
    """cost_analysis on the SPMD-partitioned module reports PER-DEVICE costs;
    link_bytes is already per-chip."""
    compute_s = total["flops"] / hw.peak_flops_bf16
    memory_s = total["bytes"] / hw.hbm_bandwidth
    coll_s = total["link_bytes"] / hw.ici_bandwidth
    dom = max(("compute", compute_s), ("memory", memory_s),
              ("collective", coll_s), key=lambda kv: kv[1])[0]
    return {"compute_s": compute_s, "memory_s": memory_s,
            "collective_s": coll_s, "bottleneck": dom}


def analyze_cell(arch: str, shape_name: str, multi_pod: bool = False,
                 hw: HardwareConfig = V5E) -> Dict[str, object]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    probe = run_probe(arch, shape_name, multi_pod)
    n_chips = 512 if multi_pod else 256
    terms = roofline_terms(probe["total"], n_chips, hw)
    model_flops = analytic_model_flops(cfg, shape)
    hlo_flops_global = probe["total"]["flops"] * n_chips
    useful = model_flops / hlo_flops_global if hlo_flops_global else 0.0
    step_s = max(terms["compute_s"], terms["memory_s"],
                 terms["collective_s"])
    mfu = (model_flops / n_chips / hw.peak_flops_bf16) / step_s \
        if step_s > 0 else 0.0
    return {
        "arch": arch, "shape": shape_name,
        "mesh": "multipod_2x16x16" if multi_pod else "pod_16x16",
        "terms": terms,
        "model_flops": model_flops,
        "hlo_flops_global": hlo_flops_global,
        "useful_ratio": useful,
        "roofline_fraction": mfu,
        "probe": probe,
    }
