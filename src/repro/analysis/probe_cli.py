import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Roofline depth-probe CLI: one (arch x shape) cell per process (single-pod
# mesh — the roofline table is single-pod per the assignment).
import argparse
import json
import pathlib
import traceback


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--out", default="results/roofline")
    args = ap.parse_args()

    from repro.analysis.roofline import analyze_cell
    from repro.configs import SHAPES, cell_status, get_config

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    mesh_name = "multipod_2x16x16" if args.multi else "pod_16x16"
    cell = f"{args.arch}__{args.shape}__{mesh_name}"
    status = cell_status(get_config(args.arch), SHAPES[args.shape])
    if status != "run":
        rec = {"arch": args.arch, "shape": args.shape, "mesh": mesh_name,
               "status": status}
    else:
        try:
            rec = analyze_cell(args.arch, args.shape, args.multi)
            rec["status"] = "ok"
            t = rec["terms"]
            print(f"[roofline] {cell}: compute {t['compute_s']*1e3:.2f}ms "
                  f"memory {t['memory_s']*1e3:.2f}ms "
                  f"collective {t['collective_s']*1e3:.2f}ms "
                  f"-> {t['bottleneck']}; "
                  f"MFU {rec['roofline_fraction']*100:.1f}% "
                  f"useful {rec['useful_ratio']*100:.1f}%")
        except Exception as e:  # noqa: BLE001
            rec = {"arch": args.arch, "shape": args.shape, "mesh": mesh_name,
                   "status": f"error: {type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-3000:]}
            print(f"[roofline] {cell}: FAILED {e}")
    (out_dir / f"{cell}.json").write_text(json.dumps(rec, indent=2))


if __name__ == "__main__":
    main()
