"""Parse collective ops + byte counts out of compiled/lowered HLO text.

``cost_analysis`` has no collective-bytes entry, so we regex the (post-SPMD)
HLO for all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops and sum their result-shape bytes.

Per-chip link-bytes model (ring algorithms on a 1D/2D torus):
  all-reduce:        2 * S * (n-1)/n   bytes through each chip
  all-gather:        S * (n-1)/n       (S = full gathered size)
  reduce-scatter:    S * (n-1)/n
  all-to-all:        S * (n-1)/n       (S = per-chip payload * n)
  collective-permute: S                (one hop)
Caveat: while-loop (scan) bodies appear ONCE in HLO text; the roofline module
scales scanned-body collectives by trip count via the two-point depth probe.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %ag = bf16[16,512,128]{2,1,0} all-gather(%x), ...
_OP_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([\d,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
# tuple-result variants: (bf16[..], bf16[..]) all-reduce(...)
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=dict)
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    total_bytes: int = 0

    def add(self, kind: str, nbytes: int):
        self.counts[kind] = self.counts.get(kind, 0) + 1
        self.bytes_by_kind[kind] = self.bytes_by_kind.get(kind, 0) + nbytes
        self.total_bytes += nbytes

    def link_bytes(self, n_devices: int) -> float:
        """Per-chip bytes through the busiest link under ring algorithms."""
        f = (n_devices - 1) / max(n_devices, 1)
        total = 0.0
        for kind, b in self.bytes_by_kind.items():
            if kind == "all-reduce":
                total += 2.0 * b * f
            elif kind == "collective-permute":
                total += float(b)
            else:
                total += b * f
        return total


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    seen_done = set()
    for line in hlo_text.splitlines():
        if "-done(" in line:
            # async pairs: count only the -start op
            continue
        m = _OP_RE.search(line)
        if m:
            dtype, dims, kind = m.groups()
            stats.add(kind, _shape_bytes(dtype, dims))
            continue
        m = _TUPLE_RE.search(line)
        if m:
            shapes, kind = m.groups()
            nbytes = sum(_shape_bytes(d, s)
                         for d, s in _SHAPE_RE.findall(shapes))
            stats.add(kind, nbytes)
    return stats
