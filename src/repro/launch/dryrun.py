import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.
#
# The two lines above MUST run before any jax import (jax locks the device
# count at first init). Each invocation handles one cell and writes a JSON
# record (memory analysis, cost analysis, collective bytes) consumed by
# EXPERIMENTS.md §Dry-run / §Roofline.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b \
#       --shape train_4k --mesh single   [--out results/dryrun]
#   PYTHONPATH=src python -m repro.launch.dryrun --all  # full grid, sequential
import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax

from repro.configs import ARCH_IDS, SHAPES, cell_status, get_config
from repro.launch.mesh import make_production_mesh


def input_specs(arch: str, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    from repro.configs import SHAPES, get_config
    from repro.models.registry import (decode_input_specs,
                                       prefill_input_specs,
                                       train_input_specs)
    cfg, shape = get_config(arch), SHAPES[shape_name]
    if shape.kind == "train":
        return train_input_specs(cfg, shape)
    if shape.kind == "decode":
        return decode_input_specs(cfg, shape)
    return prefill_input_specs(cfg, shape)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: pathlib.Path, *, packed_causal: bool = False,
             tag: str = "") -> dict:
    from repro.configs import SHAPES, get_config
    from repro.launch.steps import shape_cells

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "tag": tag, "status": None}

    status = cell_status(cfg, shape)
    if status != "run":
        rec["status"] = status
        _write(out_dir, cell_id, rec)
        return rec

    # large-shape-safe attention + loss chunking for the production lowering
    cfg = dataclasses.replace(cfg, attn_impl="chunked",
                              packed_causal=packed_causal)
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        t0 = time.time()
        lowered = shape_cells(cfg, shape, mesh)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        from repro.analysis.hlo_collectives import parse_collectives
        coll = parse_collectives(compiled.as_text())
        rec.update({
            "status": "ok",
            "lower_s": round(t1 - t0, 2),
            "compile_s": round(t2 - t1, 2),
            "memory": _mem_dict(mem),
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "transcendentals": float(cost.get("transcendentals", 0.0)),
            "collectives": {
                "counts": coll.counts,
                "bytes_by_kind": coll.bytes_by_kind,
                "total_bytes": coll.total_bytes,
                "link_bytes_per_chip": coll.link_bytes(mesh.size),
            },
            "num_devices": mesh.size,
        })
        print(f"[dryrun] {cell_id}: OK "
              f"(lower {rec['lower_s']}s compile {rec['compile_s']}s, "
              f"flops {rec['flops']:.3e})")
        print(f"[dryrun] {cell_id} memory: {rec['memory']}")
    except Exception as e:  # noqa: BLE001 — record the failure, keep the grid
        rec["status"] = f"error: {type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] {cell_id}: FAILED {type(e).__name__}: {e}")
    _write(out_dir, cell_id, rec)
    return rec


def _mem_dict(mem) -> dict:
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    if "argument_size_in_bytes" in out and "temp_size_in_bytes" in out:
        per_dev = (out["argument_size_in_bytes"] + out["temp_size_in_bytes"]
                   + out.get("output_size_in_bytes", 0)
                   - out.get("alias_size_in_bytes", 0))
        out["per_device_total"] = int(per_dev)
    return out


def _write(out_dir: pathlib.Path, cell_id: str, rec: dict):
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{cell_id}.json").write_text(json.dumps(rec, indent=2))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--packed-causal", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()
    out = pathlib.Path(args.out)

    if args.all:
        for mp in (False, True):
            for arch in ARCH_IDS:
                for shape in SHAPES:
                    run_cell(arch, shape, mp, out)
        return
    assert args.arch and args.shape, "--arch/--shape required without --all"
    run_cell(args.arch, args.shape, args.mesh == "multi", out,
             packed_causal=args.packed_causal, tag=args.tag)


if __name__ == "__main__":
    main()
