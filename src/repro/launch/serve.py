"""Serving entrypoint: WQ-driven continuous batching.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
      --requests 16
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.runtime.executor import ServeExecutor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    ex = ServeExecutor(cfg, slots=args.slots,
                       max_len=64 if args.smoke else 4096)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.requests, 8)).astype(np.int32)
    t0 = time.time()
    ex.submit(prompts, max_new=args.max_new)
    n = ex.drain()
    dt = time.time() - t0
    print(f"served {ex.wq.counts()['FINISHED']} requests in {dt:.1f}s "
          f"({args.max_new * n / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
