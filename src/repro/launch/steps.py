"""Jitted train / serve step builders with full sharding annotations.

``build_train_step`` returns (step_fn, state_shardings, batch_shardings);
``build_serve_step`` the decode equivalent. Task-level knobs (lr scale, seed,
sweep parameters from the SchalaDB work queue) enter as traced scalars so
different tasks share one executable.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

from repro.flags import scan as _flags_scan
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch import shardrules as SR
from repro.models.registry import (Model, build_model, decode_input_specs,
                                   train_input_specs)
from repro.optim import apply_updates, init_opt
from repro.optim.clipping import clip_by_global_norm, global_norm
from repro.optim.compression import compress_grads
from repro.sharding import Rules, use_rules


def _cast_tree(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating)
        else x, tree)


def _cast_params_pinned(cfg, rules, params, dtype):
    """Cast master params to compute dtype WITH sharding pinned to the
    storage sharding — forces XLA to cast-then-gather (bf16 moves over the
    wire) instead of gather-then-cast (f32 moves: 2x FSDP bytes)."""
    if rules is None:
        return _cast_tree(params, dtype)
    shardings = SR.param_shardings(cfg, rules, params)

    def one(x, sh):
        if jnp.issubdtype(x.dtype, jnp.floating):
            x = x.astype(dtype)
        return jax.lax.with_sharding_constraint(x, sh)
    return jax.tree.map(one, params, shardings)


def _split_micro(batch: Dict[str, Any], mb: int) -> Dict[str, Any]:
    """[B, ...] -> [mb, B/mb, ...] (mrope carries batch at dim 1)."""
    out = {}
    for k, x in batch.items():
        if k == "mrope_positions":        # [3,B,S] -> [mb,3,B/mb,S]
            b = x.shape[1]
            assert b % mb == 0, (k, x.shape, mb)
            out[k] = jnp.moveaxis(
                x.reshape(3, mb, b // mb, *x.shape[2:]), 1, 0)
        else:
            b = x.shape[0]
            assert b % mb == 0, (k, x.shape, mb)
            out[k] = x.reshape(mb, b // mb, *x.shape[1:])
    return out


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, rules: Optional[Rules] = None,
                    grad_compression: bool = False):
    """(state, batch, knobs) -> (state, metrics).

    state = {"params", "opt", "err"?}; knobs = {"lr": f32[]}.
    """
    model = build_model(cfg)
    dt = jnp.dtype(cfg.dtype)

    def step(state, batch, knobs):
        with use_rules(rules):
            def loss_fn(params, mbatch):
                loss, metrics = model.train_loss(
                    _cast_params_pinned(cfg, rules, params, dt), mbatch)
                return loss, metrics

            mb = max(1, cfg.microbatches)
            if mb == 1:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(state["params"], batch)
            else:
                # gradient accumulation: scan over microbatches; residual
                # activations live only for one microbatch at a time
                mbatch0 = _split_micro(batch, mb)

                def micro(acc, mbatch):
                    (l, met), g = jax.value_and_grad(
                        loss_fn, has_aux=True)(state["params"], mbatch)
                    acc = jax.tree.map(jnp.add, acc, g)
                    return acc, (l, met)

                zero = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, p.dtype), state["params"])
                grads, (losses, metrics) = _flags_scan(micro, zero, mbatch0)
                grads = jax.tree.map(lambda g: g / mb, grads)
                loss = jnp.mean(losses)
                metrics = jax.tree.map(jnp.mean, metrics)
            gnorm = global_norm(grads)
            gscale = jnp.minimum(1.0, 1.0 / jnp.maximum(gnorm, 1e-12))
            if grad_compression:
                grads, new_err = compress_grads(grads, state["err"])
            new_params, new_opt, stats = apply_updates(
                cfg, state["params"], grads, state["opt"], knobs["lr"],
                gscale=gscale)
            out = {"params": new_params, "opt": new_opt}
            if grad_compression:
                out["err"] = new_err
            metrics = dict(metrics, grad_norm=gnorm, **stats)
            return out, metrics

    return step


def init_train_state(cfg: ModelConfig, rng, grad_compression: bool = False):
    model = build_model(cfg)
    params = model.init(rng)
    state = {"params": params, "opt": init_opt(cfg, params)}
    if grad_compression:
        from repro.optim.compression import init_error
        state["err"] = init_error(params)
    return state


def train_state_shardings(cfg: ModelConfig, rules: Rules, state) -> Any:
    out = {"params": SR.param_shardings(cfg, rules, state["params"]),
           "opt": SR.opt_shardings(cfg, rules, state["params"], state["opt"])}
    if "err" in state:
        out["err"] = SR.param_shardings(cfg, rules, state["err"])
    return out


def abstract_train_state(cfg: ModelConfig, grad_compression: bool = False):
    """ShapeDtypeStructs of the train state — no allocation (dry-run path)."""
    return jax.eval_shape(
        functools.partial(init_train_state, cfg,
                          grad_compression=grad_compression),
        jax.random.PRNGKey(0))


def lower_train_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                     grad_compression: bool = False):
    """Lower (not run) the train step on the production mesh."""
    rules = SR.make_rules(cfg, shape, mesh)
    step = make_train_step(cfg, rules, grad_compression)
    state_sds = abstract_train_state(cfg, grad_compression)
    state_sh = train_state_shardings(cfg, rules, state_sds)
    batch_sds = train_input_specs(cfg, shape)
    batch_sh = SR.batch_shardings(cfg, rules, batch_sds)
    knob_sds = {"lr": jax.ShapeDtypeStruct((), jnp.float32)}
    knob_sh = {"lr": NamedSharding(mesh, P())}
    jitted = jax.jit(step,
                     in_shardings=(state_sh, batch_sh, knob_sh),
                     out_shardings=(state_sh, None),
                     donate_argnums=(0,))
    with mesh:
        lowered = jitted.lower(state_sds, batch_sds, knob_sds)
    return lowered


# ---------------------------------------------------------------------------
# serve (decode)
# ---------------------------------------------------------------------------
def make_serve_step(cfg: ModelConfig, rules: Optional[Rules] = None,
                    temperature: float = 0.0):
    """(params, tokens, cache, rng) -> (next_tokens, cache, logprobs)."""
    model = build_model(cfg)
    dt = jnp.dtype(cfg.dtype)

    def step(params, tokens, cache, rng):
        with use_rules(rules):
            logits, new_cache = model.decode_step(_cast_tree(params, dt),
                                                  tokens, cache)
            logits = logits[:, -1].astype(jnp.float32)
            if temperature > 0:
                nxt = jax.random.categorical(rng, logits / temperature)
            else:
                nxt = jnp.argmax(logits, axis=-1)
            lp = jax.nn.log_softmax(logits)
            sel = jnp.take_along_axis(lp, nxt[:, None], axis=-1)[:, 0]
            return nxt[:, None].astype(jnp.int32), new_cache, sel

    return step


def lower_serve_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    rules = SR.make_rules(cfg, shape, mesh)
    step = make_serve_step(cfg, rules)
    model = build_model(cfg)
    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    params_sh = SR.param_shardings(cfg, rules, params_sds)
    specs = decode_input_specs(cfg, shape)
    tok_sh = rules.sharding("batch", None)
    cache_sh = SR.cache_shardings(cfg, rules, specs["cache"])
    rng_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)
    jitted = jax.jit(step,
                     in_shardings=(params_sh, tok_sh, cache_sh,
                                   NamedSharding(mesh, P())),
                     out_shardings=(tok_sh, cache_sh, None),
                     donate_argnums=(2,))
    with mesh:
        lowered = jitted.lower(params_sds, specs["tokens"], specs["cache"],
                               rng_sds)
    return lowered


def shape_cells(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    """Dispatch: train shapes lower train_step; decode shapes serve_step."""
    if shape.kind == "train":
        return lower_train_step(cfg, shape, mesh)
    if shape.kind == "decode":
        return lower_serve_step(cfg, shape, mesh)
    # prefill: lower the prefill forward (serve-side compute)
    return lower_prefill_step(cfg, shape, mesh)


def make_prefill_step(cfg: ModelConfig, rules: Optional[Rules], max_len: int):
    model = build_model(cfg)
    dt = jnp.dtype(cfg.dtype)

    def step(params, batch):
        with use_rules(rules):
            logits, cache = model.prefill(_cast_tree(params, dt), batch,
                                          max_len)
            return jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32), \
                cache

    return step


def lower_prefill_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    from repro.models.registry import prefill_input_specs
    rules = SR.make_rules(cfg, shape, mesh)
    # decode cache allocated at prefill length + headroom
    max_len = shape.seq_len + 128
    step = make_prefill_step(cfg, rules, max_len)
    model = build_model(cfg)
    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    params_sh = SR.param_shardings(cfg, rules, params_sds)
    specs = prefill_input_specs(cfg, shape)
    batch_sh = SR.batch_shardings(cfg, rules, specs)
    jitted = jax.jit(step, in_shardings=(params_sh, batch_sh))
    with mesh:
        lowered = jitted.lower(params_sds, specs)
    return lowered
