"""Training entrypoint: WQ-driven trainer for any --arch.

On TPU pods this builds the production mesh, shards state per
launch/shardrules, and runs the SchalaDB executor; on CPU use --smoke for a
reduced config (the 100M+ configuration is exercised structurally by the
dry-run + smoke tests; real-silicon runs use the same code path).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
      --steps 50
"""
from __future__ import annotations

import argparse

import jax

from repro.checkpoint import Checkpointer
from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.data.pipeline import DataConfig
from repro.runtime.executor import TrainExecutor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU)")
    ap.add_argument("--seq-len", type=int, default=0)
    ap.add_argument("--batch", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    seq = args.seq_len or (64 if args.smoke else 4096)
    batch = args.batch or (8 if args.smoke else 256)
    ck = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    ex = TrainExecutor(cfg, num_workers=args.workers, base_lr=args.lr,
                       checkpointer=ck, checkpoint_every=50,
                       data_cfg=DataConfig(vocab_size=cfg.vocab_size,
                                           seq_len=seq, batch_size=batch))
    if args.resume and ck and ck.latest_step() is not None:
        step, state, wq = ck.restore(jax.device_get(ex.state))
        ex.state, ex.step = state, step
        if wq is not None:
            ex.wq = wq
        print(f"resumed from step {step}")
    ex.submit_steps(args.steps)
    hist = ex.run()
    if hist:
        print(f"trained {len(hist)} steps; "
              f"loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")
    if ck:
        ck.save(ex.step, ex.state, ex.wq)
        ck.wait()


if __name__ == "__main__":
    main()
