"""Per-(arch x shape x mesh) sharding strategy.

Strategy selection (DESIGN.md §4):
- <2B dense-ish archs: pure DP — params replicated, batch over every divisible
  axis; ZeRO-1 shards optimizer moments over spare axes.
- >=2B: TP over "model" (Megatron col/row pairs), DP batch over ("pod","data").
- fsdp archs (>=9B): params additionally sharded over "data" (ZeRO-3 by GSPMD).
- MoE: experts over "model" (EP); kimi additionally FSDP on the expert matrices.
- KV heads: sharded over "model" only when divisible; otherwise replicated
  (GQA-TP practice: KV weights are small, Q/O carry the TP split).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import (FAMILY_ENCDEC, FAMILY_MOE, FAMILY_SSM,
                                ModelConfig, ShapeConfig)
from repro.sharding import Rules


@dataclasses.dataclass(frozen=True)
class Strategy:
    tp: bool
    fsdp: bool
    ep: bool
    dp_only: bool

    @staticmethod
    def for_arch(cfg: ModelConfig) -> "Strategy":
        big = cfg.param_count >= 2e9
        ep = cfg.moe is not None
        # §Perf iteration (granite): hypothesis was that TP of attention would
        # cut the 48.6 s memory term (idle "model" axis). REFUTED: measured
        # terms identical — the bytes come from the MoE dispatch
        # scatter/gather path, which TP does not touch (see EXPERIMENTS
        # §Perf). TP kept on: it shards attention params at zero cost.
        tp = big
        return Strategy(tp=tp, fsdp=cfg.fsdp, ep=ep,
                        dp_only=not big and not ep)


def _axis_size(mesh: Mesh, name: str) -> int:
    try:
        return mesh.shape[name]
    except (KeyError, TypeError):
        return 1


def make_rules(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> Rules:
    st = Strategy.for_arch(cfg)
    axes = mesh.axis_names
    has_pod = "pod" in axes
    dp_axes: Tuple[str, ...] = (("pod", "data") if has_pod else ("data",))
    total_dp = int(np.prod([_axis_size(mesh, a) for a in dp_axes]))
    model_size = _axis_size(mesh, "model")

    # batch mapping: fold "model" into DP when unused by TP and divisible
    batch_axes = dp_axes
    if (st.dp_only and shape.global_batch % (total_dp * model_size) == 0):
        batch_axes = dp_axes + ("model",)
    elif shape.global_batch % total_dp != 0:
        batch_axes = ("data",) if shape.global_batch % \
            _axis_size(mesh, "data") == 0 else ()

    table: Dict[str, Any] = {
        "batch": batch_axes,
        "seq": None,
        "model_ff": "model" if st.tp else None,
        "model_heads": "model" if st.tp else None,
        "model_kv": "model" if (st.tp and cfg.num_kv_heads % model_size == 0)
                    else None,
        # decode KV-cache sequence sharding: when KV heads can't split over
        # "model", split the cache on the sequence dim instead (partial-softmax
        # attention; GSPMD inserts small logit all-reduces instead of
        # replicating the multi-GB cache per chip)
        "model_kvseq": None if (st.tp and cfg.num_kv_heads % model_size == 0)
                       else "model",
        "model_vocab": "model" if (st.tp or st.dp_only is False) else None,
        # must mirror the embed-table D sharding in param_spec (embed/table)
        "model_embed": "model" if st.tp else None,
        "model_expert": "model" if st.ep else None,
        "fsdp": "data" if st.fsdp else None,
    }
    return Rules(mesh, table)


def fit_spec(mesh: Mesh, spec: P, shape: Tuple[int, ...]) -> P:
    """Drop spec axes whose dim isn't divisible by the axis-size product —
    jit in_shardings (unlike internal GSPMD propagation) require exact
    divisibility. Dropped axes mean that tensor dim stays replicated."""
    dims = list(spec) + [None] * (len(shape) - len(list(spec)))
    out = []
    for dim_size, ax in zip(shape, dims):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        prod = int(np.prod([_axis_size(mesh, a) for a in axes]))
        out.append(ax if dim_size % prod == 0 else None)
    return P(*out)


# ---------------------------------------------------------------------------
# parameter shardings (path-based)
# ---------------------------------------------------------------------------
def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
    return "/".join(parts)


def param_spec(cfg: ModelConfig, rules: Rules, path: str, leaf) -> P:
    st = Strategy.for_arch(cfg)
    mdl = rules.physical("model_ff")          # "model" or None
    fsdp = rules.physical("fsdp")             # "data" or None
    vocab = "model" if rules.physical("model_vocab") else None
    ep = rules.physical("model_expert")
    # stacked layer params carry a leading [L] (or [groups]) axis
    stacked = bool(re.match(
        r"(layers|groups|tail|encoder|decoder)(/|$)", path))
    lead: Tuple = (None,) if stacked else ()

    def spec(*dims):
        return P(*(lead + dims + (None,) * (leaf.ndim - len(lead) - len(dims))))

    if re.search(r"head/table$", path):
        # untied LM head: vocab-sharded -> loss logits stay local per shard
        return P(vocab, fsdp)
    if re.search(r"embed/table$", path):
        # d_model-sharded -> token lookup is a local gather (a vocab-sharded
        # table makes XLA all-gather all V x D bytes per microbatch: measured
        # +16.6 GB/device on command-r train_4k, see EXPERIMENTS §Perf).
        # Tied archs pay a per-chunk logit all-reduce instead (hillclimb item).
        return P(fsdp, mdl)
    if re.search(r"moe/router$", path):
        return spec(None, None)
    if re.search(r"moe/(up|gate)$", path):
        return spec(ep, fsdp, None)
    if re.search(r"moe/down$", path):
        return spec(ep, None, fsdp)
    if re.search(r"(attn|self_attn|cross_attn)/(q|k|v)/w$", path):
        kv = re.search(r"/(k|v)/w$", path) and rules.physical("model_kv") is None
        return spec(fsdp, None if kv else mdl)
    if re.search(r"(attn|self_attn|cross_attn)/(q|k|v)/b$", path):
        kv = re.search(r"/(k|v)/b$", path) and rules.physical("model_kv") is None
        return spec(None if kv else mdl)
    if re.search(r"(attn|self_attn|cross_attn)/o/w$", path):
        return spec(mdl, fsdp)
    if re.search(r"mlp/(up|gate)/w$", path):
        return spec(fsdp, mdl)
    if re.search(r"mlp/down/w$", path):
        return spec(mdl, fsdp)
    if re.search(r"mlp/(up|gate|down)/b$", path):
        return spec(mdl)
    # SSM / RG-LRU mixers
    if re.search(r"mixer/(in|gate)/w$", path):          # rglru in/gate
        return spec(fsdp, mdl)
    if re.search(r"mixer/out/w$", path):
        return spec(mdl, fsdp)
    if re.search(r"mixer/(wa|wx)/w$", path):      # block-diag [nb, c, c]
        return spec(mdl, None, None)
    if re.search(r"mixer/(wa|wx)/b$", path):      # [nb, c]
        return spec(mdl, None)
    if re.search(r"mixer/lam$", path):
        return spec(mdl)
    if re.search(r"mixer/conv_w$", path):
        return spec(None, mdl)
    if re.search(r"mixer/(in_proj|out_proj)/w$", path):  # mamba2: dp-only
        return spec(None, None)
    return spec()  # norms, scalars, biases: replicated


def param_shardings(cfg: ModelConfig, rules: Rules, params) -> Any:
    def one(path, leaf):
        spec = param_spec(cfg, rules, _path_str(path), leaf)
        return NamedSharding(rules.mesh, fit_spec(rules.mesh, spec,
                                                  leaf.shape))
    return jax.tree_util.tree_map_with_path(one, params)


def zero1_spec(rules: Rules, pspec: P, shape: Tuple[int, ...]) -> P:
    """ZeRO-1: shard large replicated optimizer moments over the data axis."""
    if any(s is not None for s in pspec) or int(np.prod(shape)) < (1 << 20):
        return pspec
    data = _axis_size(rules.mesh, "data")
    dims = list(pspec) + [None] * (len(shape) - len(pspec))
    for i, s in enumerate(shape):
        if s % data == 0:
            dims[i] = "data"
            return P(*dims)
    return pspec


def opt_shardings(cfg: ModelConfig, rules: Rules, params, opt_state) -> Any:
    """Moments follow their param's sharding (+ ZeRO-1 for replicated ones).

    State paths: adamw ``inner/{m,v}/<param-path>``; adafactor
    ``inner/<param-path>/{v,vr,vc}`` (vr drops the last dim, vc the
    second-to-last).
    """
    pshard: Dict[str, P] = {}

    def record(path, leaf):
        pshard[_path_str(path)] = param_spec(cfg, rules, _path_str(path), leaf)
        return leaf
    jax.tree_util.tree_map_with_path(record, params)

    def one(path, leaf):
        ps = _path_str(path)
        base, kind = None, None
        m = re.match(r"inner/(m|v)/(.*)$", ps)
        if m:
            base, kind = m.group(2), "moment"
        else:
            m = re.match(r"inner/(.*)/(v|vr|vc)$", ps)
            if m:
                base, kind = m.group(1), m.group(2)
        spec = pshard.get(base, P()) if base else P()
        dims = list(spec) + [None] * max(0, leaf.ndim - len(list(spec)))
        if kind == "vr":                 # [..., R] stats: drop last param dim
            dims = dims[:-1] if dims else dims
        elif kind == "vc":               # drop second-to-last param dim
            if len(dims) >= 2:
                dims = dims[:-2] + dims[-1:]
        dims = dims[: leaf.ndim] + [None] * (leaf.ndim - len(dims[: leaf.ndim]))
        spec = zero1_spec(rules, P(*dims), leaf.shape)
        dims = list(spec)[: leaf.ndim]
        dims += [None] * (leaf.ndim - len(dims))
        return NamedSharding(rules.mesh,
                             fit_spec(rules.mesh, P(*dims), leaf.shape))

    return jax.tree_util.tree_map_with_path(one, opt_state)


# ---------------------------------------------------------------------------
# batch / cache shardings
# ---------------------------------------------------------------------------
def batch_shardings(cfg: ModelConfig, rules: Rules, specs: Dict[str, Any]
                    ) -> Dict[str, Any]:
    out = {}
    for name, sds in specs.items():
        if name == "mrope_positions":          # [3,B,S]
            spec = rules.spec(None, "batch", None)
        elif name == "cache":
            out[name] = cache_shardings(cfg, rules, sds)
            continue
        else:
            spec = rules.spec(*(["batch"] + [None] * (len(sds.shape) - 1)))
        out[name] = NamedSharding(rules.mesh,
                                  fit_spec(rules.mesh, spec, sds.shape))
    return out


def cache_shardings(cfg: ModelConfig, rules: Rules, cache_spec) -> Any:
    def one(path, leaf):
        ps = _path_str(path)
        if ps.endswith("idx"):
            spec = rules.spec()
        elif re.search(r"(^|/)(k|v)$", ps):     # [L,B,S,Hkv,Dh]
            if leaf.shape[2] >= 4096:           # long cache: shard seq
                spec = rules.spec(None, "batch", "model_kvseq",
                                  "model_kv", None)
            else:
                spec = rules.spec(None, "batch", None, "model_kv", None)
        elif ps.endswith("enc_out"):            # [B,S,D]
            spec = rules.spec("batch", None, None)
        elif re.search(r"conv$", ps):           # [L,B,W,C]
            spec = rules.spec(None, "batch", None, "model_ff")
        elif re.search(r"ssm$", ps):            # [L,B,H,P,N]
            spec = rules.spec(None, "batch", "model_heads", None, None)
        elif re.search(r"lru$", ps):            # [L,B,W]
            spec = rules.spec(None, "batch", "model_ff")
        else:
            spec = P(*([None] * leaf.ndim))
        return NamedSharding(rules.mesh,
                             fit_spec(rules.mesh, spec, leaf.shape))
    return jax.tree_util.tree_map_with_path(one, cache_spec)
