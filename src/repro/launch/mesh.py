"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benches see the real single device.
"""
from __future__ import annotations

import jax

from repro.configs.base import MULTI_POD, SINGLE_POD, MeshConfig


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    return MULTI_POD if multi_pod else SINGLE_POD


def make_host_mesh() -> jax.sharding.Mesh:
    """1-device mesh for smoke runs through the same code path."""
    return jax.make_mesh((1, 1), ("data", "model"))
