"""Process-wide lowering flags.

``scan_unroll()`` — when True, every model-level ``lax.scan`` fully unrolls.
Used ONLY by the roofline depth probe: XLA's ``cost_analysis`` counts a
while-loop body ONCE regardless of trip count, so faithful FLOP/byte counts
require unrolled lowering of shallow (1-2 layer) probe configs; the roofline
module then scales per-layer deltas to the real depth (see analysis/roofline).

``wq_device_claim()`` — when True, WorkQueues CONSTRUCTED while it holds run
claim_all's primary phase through the wq_claim Pallas op on the accelerator
instead of the host numpy fast-path (the queue samples the flag once in
__init__; flip ``wq.device_claim`` to switch an existing queue). Defaults
from the REPRO_WQ_DEVICE_CLAIM env var (off unless set to 1/true/yes);
``device_claims()`` scopes the construction-time default.
"""
from __future__ import annotations

import contextlib
import contextvars
import os

_SCAN_UNROLL = contextvars.ContextVar("repro_scan_unroll", default=False)

_WQ_DEVICE_CLAIM = contextvars.ContextVar(
    "repro_wq_device_claim",
    default=os.environ.get("REPRO_WQ_DEVICE_CLAIM", "").lower()
    in ("1", "true", "yes"))


def wq_device_claim() -> bool:
    return _WQ_DEVICE_CLAIM.get()


@contextlib.contextmanager
def device_claims(on: bool = True):
    """Construction-time default for WorkQueue(device_claim=None) within the
    scope; queues built earlier keep whatever they sampled."""
    tok = _WQ_DEVICE_CLAIM.set(on)
    try:
        yield
    finally:
        _WQ_DEVICE_CLAIM.reset(tok)


def scan_unroll() -> bool:
    return _SCAN_UNROLL.get()


@contextlib.contextmanager
def unrolled_scans(on: bool = True):
    tok = _SCAN_UNROLL.set(on)
    try:
        yield
    finally:
        _SCAN_UNROLL.reset(tok)


def scan(body, init, xs, **kw):
    """lax.scan wrapper honoring the unroll flag (model code uses this)."""
    import jax
    if scan_unroll():
        kw = dict(kw, unroll=True)
    return jax.lax.scan(body, init, xs, **kw)
