"""Process-wide lowering flags.

``scan_unroll()`` — when True, every model-level ``lax.scan`` fully unrolls.
Used ONLY by the roofline depth probe: XLA's ``cost_analysis`` counts a
while-loop body ONCE regardless of trip count, so faithful FLOP/byte counts
require unrolled lowering of shallow (1-2 layer) probe configs; the roofline
module then scales per-layer deltas to the real depth (see analysis/roofline).
"""
from __future__ import annotations

import contextlib
import contextvars

_SCAN_UNROLL = contextvars.ContextVar("repro_scan_unroll", default=False)


def scan_unroll() -> bool:
    return _SCAN_UNROLL.get()


@contextlib.contextmanager
def unrolled_scans(on: bool = True):
    tok = _SCAN_UNROLL.set(on)
    try:
        yield
    finally:
        _SCAN_UNROLL.reset(tok)


def scan(body, init, xs, **kw):
    """lax.scan wrapper honoring the unroll flag (model code uses this)."""
    import jax
    if scan_unroll():
        kw = dict(kw, unroll=True)
    return jax.lax.scan(body, init, xs, **kw)
