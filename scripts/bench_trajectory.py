"""Bench-trajectory gate: measure the headline perf numbers, record them in
a committed ``BENCH_PR<n>.json`` at the repo root, and fail CI when any of
the enforced floors regresses:

- claim fast-path speedup (vectorized claim_all vs the seed loop, >=5x, at
  k=1 AND at k=4 — the segmented-argpartition batched-claim path)
- replica sweep parity after delta catch-up ACROSS a TxnLog.truncate
- batched txn-log replay speedup vs record-at-a-time (>=10x on a
  claims/finishes-heavy ~100k-record log), bit-parity enforced inside the
  experiment itself
- steering-sweep latency (full Q1-Q7 run_all on a ~100k-row snapshot,
  recorded every PR and bounded by --max-sweep-ms)
- cross-process wire shipping (e_wire_ship): a ShippedDeltaReplicator in a
  SEPARATE OS process, synced across a TxnLog.truncate, must sweep
  bit-identically to a primary snapshot (hard-checked inside the
  experiment) and sustain --min-ship-mbps of encode+ship+replay throughput
  on the bulk catch-up — shipped through the PIPELINED background shipper
  (encode of chunk k+1 overlaps the remote's decode+replay of chunk k),
  measured end-to-end enqueue-to-last-ack on the NEGOTIATED
  (varint-compressed) wire bytes; the lockstep request/reply number rides
  along as ship_mbps_bulk_sync, and the tiny-delta incremental regime as
  ship_mbps_incremental (producer-visible: sync() enqueues + final flush)
  vs ship_mbps_incremental_sync (a blocking round trip per sync)
- hot-frame compression (--min-compression): the varint codec's raw/
  compressed hot-frame byte ratio on the claims/finishes-heavy bulk log
  must hold its floor (decode bit-parity is hard-checked in the experiment
  and the wire tests)
- sharded scale-out (e_sharded): a 4-shard ShardRouter must deliver
  --min-sharded-scaleup x the single-primary claim throughput under weak
  scaling (fixed per-shard load, N-shard wall = max over independent
  shards), with scatter-gather Q1-Q7 sweeps bit-identical to a
  single-primary oracle at the same version vector and cross-shard work
  stealing conserving the live task-id multiset (both hard-checked inside
  the experiment)
- parallel steering plane (e_sharded phase D): the 4-shard remote scatter
  ships per-shard Q1-Q7 partial aggregates out of the replica PROCESSES
  (sweep_partials remotely, merge_partials on the router), hard-checked
  bit-identical to the local run_all and the single-primary oracle at the
  same pinned version vector (across a per-shard log truncate); under the
  paper's modeled per-shard data-node RPC latency the CONCURRENT scatter
  wall must beat the serial shard loop by --min-steer-fanout-speedup
  (>=2x at 4 shards) and stay under --max-steer-wall-ms, with per-shard
  walls and the straggler spread recorded
- chaos kill-drill (e_chaos): >=2 workers silently killed + the shipped
  replica process killed mid-run; lease expiry + the vectorized reaper +
  work stealing + snapshot respawn must conserve the live task-id set,
  drain every task and restore replica bit-parity (all hard-checked
  inside the experiment), with the kill-to-drained wall bounded by
  --max-recovery-s; one worker batch now dies DURING a pool resize, so
  the reaper must land requeued rows on the post-resize partition map and
  the heartbeat monitor must resync with no ghost beats
- shard-primary failover (e_shard_failover): two shard primaries killed
  mid-run with claims in flight; each promote must drain the unsynced WAL
  tail, conserve the live task-id set, keep the surviving shards claiming
  (never zero during a dead window), stay claim- and sweep-bit-identical
  to a single-primary oracle, and restore sharded checkpoints at exactly
  their persisted version vectors (all hard-checked inside the
  experiment), with the first-kill-to-drained wall bounded by
  --max-shard-failover-s
- replica fan-out (e_wire_ship's ReplicaGroup drill): every member of the
  3-replica group must sweep bit-identically after a broadcast sync, and
  promote() must elect the highest-acked survivor after the leader dies
  (hard-checked inside the experiment); the broadcast now fans out
  CONCURRENTLY, so its wall (fanout_lag_ms, bounded by
  --max-fanout-lag-ms) tracks the slowest member (fanout_member_max_ms),
  not the serial sum (fanout_member_sum_ms)

Each PR appends one snapshot file; the accumulated ``BENCH_*.json`` series
IS the performance trajectory of the repo (CI prints it on every run, so a
regression is visible as a bend in the series, not just a red X).

Usage (what the CI job runs):
    python scripts/bench_trajectory.py --pr auto --min-claim-speedup 5 \
        --min-replay-speedup 10

``--pr auto`` resolves to highest committed BENCH_PR<n>.json + 1. The
builder seeds the snapshot for the current PR by running the same command
locally and committing the resulting BENCH_PR<n>.json; CI then re-measures
against the same gates (writing its snapshot as an artifact only).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

ROOT = pathlib.Path(__file__).resolve().parent.parent


def measure(scale_claim: float, scale_replica: float) -> dict:
    sys.path.insert(0, str(ROOT))
    sys.path.insert(0, str(ROOT / "src"))
    from benchmarks import experiments as E

    claim_rows = E.exp_kernel_claim(scale_claim)
    sp_k1 = [r["speedup"] for r in claim_rows
             if r.get("impl") == "speedup" and r.get("k", 1) == 1]
    sp_kn = [r["speedup"] for r in claim_rows
             if r.get("impl") == "speedup" and r.get("k", 1) > 1]
    replay_rows = E.exp_replay_throughput(scale_claim)  # raises on mismatch
    replay = next(r for r in replay_rows if r["impl"] == "speedup")
    sweep = E.exp_steering_sweep(scale_claim)[0]
    lag_rows = E.exp_replica_lag(scale_replica)   # raises on sweep mismatch
    ratios = [r["bytes_ratio_full_over_delta"] for r in lag_rows
              if r["mode"] == "speedup"]
    truncs = [r.get("log_truncated_records", 0) for r in lag_rows
              if r["mode"] == "delta"]
    # raises unless the shipped replica lives in another process, synced
    # across a truncate, and swept bit-identically to the primary
    wire_rows = E.exp_wire_ship(scale_replica)
    # raises unless scatter-gather sweeps match the single-primary oracle
    # and cross-shard stealing conserves the live task-id multiset
    sharded = E.exp_sharded(scale_claim)[0]
    # raises unless the kill-drill conserved the task-id set, drained
    # every task on the survivors, and restored replica bit-parity
    chaos = E.exp_chaos(scale_claim)[0]
    # raises unless both shard-primary failovers conserved the task-id
    # set, kept survivors claiming, stayed oracle-bit-identical and
    # restored sharded checkpoints at their exact version vectors
    failover = E.exp_shard_failover(scale_claim)[0]
    return {
        "claim_speedup_min": min(sp_k1),
        "claim_speedup_max": max(sp_k1),
        "claim_k4_speedup_min": min(sp_kn),
        "claim_k4_speedup_max": max(sp_kn),
        "replay_speedup": replay["speedup"],
        "replay_records": replay["records"],
        "sweep_ms": sweep["ms_per_sweep"],
        "sweep_rows": sweep["rows"],
        "replica_bytes_ratio_min": min(ratios),
        "replica_sweep_equal": all(r.get("sweep_equal", True)
                                   for r in lag_rows if r["mode"] == "delta"),
        "replica_log_truncated_min": min(truncs),
        "ship_mbps": min(r["ship_mbps_bulk"] for r in wire_rows),
        "ship_mbps_bulk_sync": min(r["ship_mbps_bulk_sync"]
                                   for r in wire_rows),
        "ship_mbps_incremental": min(r["ship_mbps"] for r in wire_rows),
        "ship_mbps_incremental_sync": min(r["ship_mbps_incremental_sync"]
                                          for r in wire_rows),
        "bulk_pipeline_messages": max(r["bulk_pipeline_messages"]
                                      for r in wire_rows),
        "encoded_bytes_ratio": max(r["encoded_bytes_ratio"]
                                   for r in wire_rows),
        "wire_records_shipped": sum(r["records_shipped"] + r["bulk_records"]
                                    for r in wire_rows),
        "wire_remote_parity": all(r["cols_equal"] and r["sweep_equal"]
                                  for r in wire_rows),
        "wire_transport": wire_rows[0]["transport"],
        "wire_codec": wire_rows[0]["codec"],
        "compression_ratio": min(r["compression_ratio"] for r in wire_rows),
        "compression_ratio_total": min(r["compression_ratio_total"]
                                       for r in wire_rows),
        "fanout_n": min(r["fanout_n"] for r in wire_rows),
        "fanout_lag_ms": max(r["fanout_lag_ms"] for r in wire_rows),
        "fanout_member_max_ms": max(r["fanout_member_max_ms"]
                                    for r in wire_rows),
        "fanout_member_sum_ms": max(r["fanout_member_sum_ms"]
                                    for r in wire_rows),
        "fanout_spread_ms": max(r["fanout_spread_ms"] for r in wire_rows),
        "fanout_parity": all(r["fanout_sweep_equal"]
                             and r["fanout_elected_highest_acked"]
                             and r["fanout_promote_no_running"]
                             for r in wire_rows),
        "sharded_scaleup": sharded["scaleup"],
        "sharded_shards": sharded["shards"],
        "sharded_claims_per_s": sharded["claims_per_s_sharded"],
        "sharded_sweep_equal": (sharded["sweep_equal"]
                                and sharded["replica_sweep_equal"]
                                and sharded["claim_parity"]),
        "sharded_steal_conserved": (sharded["steal_conserved"]
                                    and sharded["steal_moved"] > 0
                                    and sharded["steal_replica_parity"]),
        "sharded_steal_moved": sharded["steal_moved"],
        "steer_fanout_speedup": sharded["steer_fanout_speedup"],
        "steer_wall_ms": round(sharded["steer_concurrent_wall_s"] * 1e3, 2),
        "steer_serial_wall_ms": round(sharded["steer_serial_wall_s"] * 1e3,
                                      2),
        "steer_shard_walls_ms": [round(w * 1e3, 2)
                                 for w in sharded["steer_shard_walls_s"]],
        "steer_spread_ms": round(sharded["steer_spread_s"] * 1e3, 2),
        "steer_rpc_delay_ms": round(sharded["steer_rpc_delay_s"] * 1e3, 2),
        "steer_rows": sharded["steer_rows"],
        "steer_remote_parity": (sharded["steer_remote_sweep_equal"]
                                and sharded["steer_remote_matches_local"]
                                and sharded["steer_scatter_equal"]
                                and sharded["steer_log_truncated"]),
        "chaos_recovery_s": max(chaos["recovery_s"],
                                chaos["sharded_recovery_s"]),
        "chaos_conserved": (chaos["conserved"]
                            and chaos["sharded_conserved"]),
        "chaos_drained": chaos["drained"] and chaos["sharded_drained"],
        "chaos_workers_killed": len(chaos["workers_killed"]),
        "chaos_replicas_killed": chaos["replicas_killed"],
        "chaos_reaped": chaos["reaped"] + chaos["sharded_reaped"],
        "chaos_replica_parity": (chaos["replica_cols_equal"]
                                 and chaos["sharded_replica_parity"]),
        "chaos_replica_respawns": chaos["replica_respawns"],
        "chaos_resize_ok": (chaos["resize_rehash_ok"]
                            and chaos["resize_no_ghost_beats"]
                            and chaos["resize_conserved"]
                            and chaos["resize_drained"]),
        "shard_failover_wall_s": failover["failover_wall_s"],
        "shard_failover_promote_s_max": failover["promote_s_max"],
        "shard_failover_survivor_min_claims":
            failover["survivor_min_claims"],
        "shard_failover_survivor_min_claims_per_s":
            failover["survivor_min_claims_per_s"],
        "shard_failover_conserved": (failover["conserved"]
                                     and failover["drained"]),
        "shard_failover_parity": (failover["claim_parity"]
                                  and failover["sweep_equal"]
                                  and failover["replica_cols_equal"]),
        "shard_failover_ckpt_ok": (failover["ckpt_vector_match"]
                                   and failover["ckpt_sweep_equal"]
                                   and failover["ckpt_pre_kill_sweep_equal"]
                                   and failover["ckpt_resumed_claims"] > 0),
        "shard_failover_log_lag_drained": failover["promote_log_lag"],
        "claim_scale": scale_claim,
        "replica_scale": scale_replica,
    }


def trajectory() -> list:
    snaps = []
    for p in sorted(ROOT.glob("BENCH_PR*.json")):
        try:
            snaps.append({"file": p.name, **json.loads(p.read_text())})
        except (OSError, json.JSONDecodeError) as e:
            print(f"warn: unreadable trajectory point {p.name}: {e}",
                  file=sys.stderr)
    return snaps


def next_pr_number() -> int:
    """Highest committed BENCH_PR<n>.json + 1 — what ``--pr auto`` resolves
    to, so CI never re-gates a stale snapshot because someone forgot to
    bump a hand-edited number."""
    import re
    nums = []
    for p in ROOT.glob("BENCH_PR*.json"):
        m = re.fullmatch(r"BENCH_PR(\d+)\.json", p.name)
        if m:
            nums.append(int(m.group(1)))
    return max(nums, default=0) + 1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pr", required=True,
                    help="PR number — writes BENCH_PR<n>.json at the root; "
                         "'auto' derives it as highest committed "
                         "BENCH_PR<n>.json + 1")
    ap.add_argument("--min-claim-speedup", type=float, default=5.0)
    ap.add_argument("--min-replay-speedup", type=float, default=10.0,
                    help="floor for batched vs record-at-a-time txn-log "
                         "replay on the claims/finishes-heavy log")
    ap.add_argument("--max-sweep-ms", type=float, default=500.0,
                    help="ceiling for one full Q1-Q7 steering sweep on the "
                         "~100k-row store (0 records without enforcing)")
    ap.add_argument("--min-ship-mbps", type=float, default=30.0,
                    help="floor for the cross-process bulk catch-up's "
                         "encode+ship+replay throughput through the "
                         "pipelined shipper (e_wire_ship, end-to-end on "
                         "the compressed wire; 0 records without "
                         "enforcing)")
    ap.add_argument("--max-fanout-lag-ms", type=float, default=50.0,
                    help="ceiling for the concurrent ReplicaGroup "
                         "broadcast wall — it must track the slowest "
                         "member, not the serial member sum (0 records "
                         "without enforcing)")
    ap.add_argument("--min-sharded-scaleup", type=float, default=3.0,
                    help="floor for e_sharded's weak-scaling aggregate "
                         "claim throughput at 4 shards vs 1 (0 records "
                         "without enforcing)")
    ap.add_argument("--min-steer-fanout-speedup", type=float, default=2.0,
                    help="floor for e_sharded's concurrent-vs-serial "
                         "remote steering scatter wall ratio at 4 shards "
                         "under the modeled per-shard RPC latency "
                         "(0 records without enforcing)")
    ap.add_argument("--max-steer-wall-ms", type=float, default=50.0,
                    help="ceiling for the concurrent remote steering "
                         "scatter wall — it must track the slowest shard "
                         "plus one modeled RPC round trip, not the serial "
                         "shard sum (0 records without enforcing)")
    ap.add_argument("--max-recovery-s", type=float, default=60.0,
                    help="ceiling for the chaos drill's kill-to-drained "
                         "wall (worst of the single-primary and sharded "
                         "phases; 0 records without enforcing)")
    ap.add_argument("--max-shard-failover-s", type=float, default=60.0,
                    help="ceiling for e_shard_failover's first-kill-to-"
                         "drained wall across two shard-primary promotes "
                         "(0 records without enforcing)")
    ap.add_argument("--min-compression", type=float, default=2.0,
                    help="floor for the varint codec's raw/compressed "
                         "hot-frame byte ratio on the bulk log "
                         "(0 records without enforcing)")
    ap.add_argument("--scale", type=float, default=1.0,
                    help="claim/replay/sweep scale (1.0 = the gated "
                         "100k-task / 100k-record runs)")
    ap.add_argument("--replica-scale", type=float, default=1.0)
    args = ap.parse_args()
    pr = next_pr_number() if args.pr == "auto" else int(args.pr)

    t0 = time.perf_counter()
    snap = measure(args.scale, args.replica_scale)
    snap["wall_s"] = round(time.perf_counter() - t0, 1)
    out = ROOT / f"BENCH_PR{pr}.json"
    out.write_text(json.dumps(snap, indent=1) + "\n")

    print("bench trajectory (committed BENCH_PR*.json + this run):")
    for pt in trajectory():
        print(f"  {pt['file']}: claim_speedup_min={pt.get('claim_speedup_min')}"
              f" claim_k4={pt.get('claim_k4_speedup_min')}"
              f" replay_speedup={pt.get('replay_speedup')}"
              f" sweep_ms={pt.get('sweep_ms')}"
              f" replica_bytes_ratio_min={pt.get('replica_bytes_ratio_min')}"
              f" ship_mbps={pt.get('ship_mbps')}"
              f" ship_inc={pt.get('ship_mbps_incremental')}"
              f" fanout_lag_ms={pt.get('fanout_lag_ms')}"
              f" compression={pt.get('compression_ratio')}"
              f" sharded_scaleup={pt.get('sharded_scaleup')}"
              f" steer_fanout={pt.get('steer_fanout_speedup')}"
              f" chaos_recovery_s={pt.get('chaos_recovery_s')}"
              f" shard_failover_s={pt.get('shard_failover_wall_s')}")

    failures = []
    if snap["claim_speedup_min"] < args.min_claim_speedup:
        failures.append(
            f"claim host speedup {snap['claim_speedup_min']}x is below the "
            f"{args.min_claim_speedup}x gate")
    if snap["claim_k4_speedup_min"] < args.min_claim_speedup:
        failures.append(
            f"k=4 claim host speedup {snap['claim_k4_speedup_min']}x "
            f"(segmented argpartition) is below the "
            f"{args.min_claim_speedup}x gate")
    if args.min_ship_mbps > 0 and snap["ship_mbps"] < args.min_ship_mbps:
        failures.append(
            f"cross-process ship throughput {snap['ship_mbps']} MB/s is "
            f"below the {args.min_ship_mbps} MB/s gate")
    if not snap["wire_remote_parity"]:
        failures.append("shipped-replica remote parity failed")
    if args.min_compression > 0 \
            and snap["compression_ratio"] < args.min_compression:
        failures.append(
            f"hot-frame compression {snap['compression_ratio']}x is below "
            f"the {args.min_compression}x gate")
    if not snap["fanout_parity"]:
        failures.append(
            "replica fan-out failed: a group member diverged or promote() "
            "elected the wrong replica after the leader died")
    if args.max_fanout_lag_ms > 0 \
            and snap["fanout_lag_ms"] > args.max_fanout_lag_ms:
        failures.append(
            f"concurrent fan-out broadcast wall {snap['fanout_lag_ms']}ms "
            f"exceeds the {args.max_fanout_lag_ms}ms gate "
            f"(slowest member {snap['fanout_member_max_ms']}ms, serial "
            f"sum would be {snap['fanout_member_sum_ms']}ms)")
    if snap["replay_speedup"] < args.min_replay_speedup:
        failures.append(
            f"batched replay speedup {snap['replay_speedup']}x is below the "
            f"{args.min_replay_speedup}x gate "
            f"({snap['replay_records']}-record log)")
    if args.max_sweep_ms > 0 and snap["sweep_ms"] > args.max_sweep_ms:
        failures.append(
            f"steering sweep {snap['sweep_ms']}ms exceeds the "
            f"{args.max_sweep_ms}ms gate at {snap['sweep_rows']} rows")
    if not snap["replica_sweep_equal"]:
        failures.append("replica sweep parity failed")
    if snap["replica_log_truncated_min"] <= 0:
        failures.append("replica parity ran without a TxnLog.truncate — "
                        "the compaction path went unexercised")
    if args.min_sharded_scaleup > 0 \
            and snap["sharded_scaleup"] < args.min_sharded_scaleup:
        failures.append(
            f"sharded claim scaleup {snap['sharded_scaleup']}x at "
            f"{snap['sharded_shards']} shards is below the "
            f"{args.min_sharded_scaleup}x gate")
    if not snap["sharded_sweep_equal"]:
        failures.append("sharded scatter-gather sweep lost parity with "
                        "the single-primary oracle")
    if not snap["sharded_steal_conserved"]:
        failures.append("cross-shard work stealing lost or duplicated "
                        "tasks (or broke replica parity)")
    if not snap["steer_remote_parity"]:
        failures.append("remote merged steering sweep lost bit-parity "
                        "with the local run_all / single-primary oracle "
                        "(or never crossed a per-shard truncate)")
    if args.min_steer_fanout_speedup > 0 \
            and snap["steer_fanout_speedup"] < args.min_steer_fanout_speedup:
        failures.append(
            f"concurrent steering scatter speedup "
            f"{snap['steer_fanout_speedup']}x at "
            f"{snap['sharded_shards']} shards is below the "
            f"{args.min_steer_fanout_speedup}x gate (serial "
            f"{snap['steer_serial_wall_ms']}ms vs concurrent "
            f"{snap['steer_wall_ms']}ms)")
    if args.max_steer_wall_ms > 0 \
            and snap["steer_wall_ms"] > args.max_steer_wall_ms:
        failures.append(
            f"concurrent steering scatter wall {snap['steer_wall_ms']}ms "
            f"exceeds the {args.max_steer_wall_ms}ms gate (per-shard "
            f"walls {snap['steer_shard_walls_ms']}ms, spread "
            f"{snap['steer_spread_ms']}ms)")
    if not (snap["chaos_conserved"] and snap["chaos_drained"]
            and snap["chaos_replica_parity"]):
        failures.append(
            f"chaos kill-drill failed: conserved={snap['chaos_conserved']}"
            f" drained={snap['chaos_drained']} "
            f"replica_parity={snap['chaos_replica_parity']}")
    if args.max_recovery_s > 0 \
            and snap["chaos_recovery_s"] > args.max_recovery_s:
        failures.append(
            f"chaos recovery took {snap['chaos_recovery_s']}s from kill "
            f"to full drain — over the {args.max_recovery_s}s gate")
    if not snap["chaos_resize_ok"]:
        failures.append(
            "kill-during-resize drill failed: reaped rows missed the "
            "post-resize partition map or the heartbeat monitor kept "
            "ghost beats")
    if not (snap["shard_failover_conserved"]
            and snap["shard_failover_parity"]
            and snap["shard_failover_ckpt_ok"]):
        failures.append(
            f"shard failover failed: conserved="
            f"{snap['shard_failover_conserved']} "
            f"parity={snap['shard_failover_parity']} "
            f"ckpt={snap['shard_failover_ckpt_ok']}")
    if snap["shard_failover_survivor_min_claims"] <= 0:
        failures.append(
            "surviving shards' claim throughput hit zero during a "
            "shard-primary dead window")
    if args.max_shard_failover_s > 0 \
            and snap["shard_failover_wall_s"] > args.max_shard_failover_s:
        failures.append(
            f"shard failover took {snap['shard_failover_wall_s']}s from "
            f"first kill to full drain — over the "
            f"{args.max_shard_failover_s}s gate")
    if failures:
        for f in failures:
            print(f"FAIL: {f}", file=sys.stderr)
        sys.exit(1)
    print(f"OK: claim_speedup_min={snap['claim_speedup_min']}x "
          f"k4={snap['claim_k4_speedup_min']}x "
          f"(gate {args.min_claim_speedup}x), "
          f"replay_speedup={snap['replay_speedup']}x "
          f"(gate {args.min_replay_speedup}x), "
          f"sweep_ms={snap['sweep_ms']} (gate {args.max_sweep_ms}ms), "
          f"replica_bytes_ratio_min={snap['replica_bytes_ratio_min']}x, "
          f"ship_mbps={snap['ship_mbps']} "
          f"(gate {args.min_ship_mbps} MB/s), "
          f"compression={snap['compression_ratio']}x "
          f"(gate {args.min_compression}x), "
          f"fanout_lag_ms={snap['fanout_lag_ms']} "
          f"(gate {args.max_fanout_lag_ms}ms, "
          f"member max {snap['fanout_member_max_ms']}ms / "
          f"sum {snap['fanout_member_sum_ms']}ms), "
          f"sharded_scaleup={snap['sharded_scaleup']}x@"
          f"{snap['sharded_shards']}shards "
          f"(gate {args.min_sharded_scaleup}x), "
          f"steer_fanout={snap['steer_fanout_speedup']}x "
          f"(gate {args.min_steer_fanout_speedup}x, concurrent "
          f"{snap['steer_wall_ms']}ms vs serial "
          f"{snap['steer_serial_wall_ms']}ms, "
          f"gate {args.max_steer_wall_ms}ms, spread "
          f"{snap['steer_spread_ms']}ms), "
          f"chaos_recovery_s={snap['chaos_recovery_s']} "
          f"(gate {args.max_recovery_s}s, "
          f"{snap['chaos_workers_killed']} workers + "
          f"{snap['chaos_replicas_killed']} replica killed, "
          f"{snap['chaos_reaped']} claims reaped), "
          f"shard_failover_s={snap['shard_failover_wall_s']} "
          f"(gate {args.max_shard_failover_s}s, "
          f"promote max {snap['shard_failover_promote_s_max']}s, "
          f"survivor min claims "
          f"{snap['shard_failover_survivor_min_claims']}, "
          f"{snap['shard_failover_log_lag_drained']} WAL records "
          f"drained) "
          f"[{snap['wire_transport']}/{snap['wire_codec']}]")


if __name__ == "__main__":
    main()
