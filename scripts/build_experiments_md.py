"""Assemble EXPERIMENTS.md from results/{dryrun,roofline,bench,perf_iter}."""
import json
import glob
import pathlib
import sys

R = pathlib.Path("results")


def load(d):
    out = {}
    for f in sorted(glob.glob(str(R / d / "*.json"))):
        rec = json.load(open(f))
        out[pathlib.Path(f).stem] = rec
    return out


def gib(b):
    return f"{b/2**30:.1f}"


def main():
    dry = load("dryrun")
    roof = load("roofline")
    bench = {pathlib.Path(f).stem: json.load(open(f))
             for f in sorted(glob.glob(str(R / "bench" / "*.json")))}

    md = []
    md.append("""# EXPERIMENTS

Paper: *Distributed In-memory Data Management for Workflow Executions*
(SchalaDB / d-Chiron), PeerJ CS 2021 — reproduced as a JAX/TPU
workflow-driven training/serving framework. See DESIGN.md for the system and
the paper->system mapping. All artifacts in `results/` are regenerable:

    bash scripts/run_dryrun_grid.sh          # §Dry-run (80 cells)
    bash scripts/run_roofline_grid.sh        # §Roofline depth probes
    PYTHONPATH=src python -m benchmarks.run  # §Benchmarks (paper E1-E8)

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI;
single pod = (data=16, model=16) = 256 chips; multi-pod = (pod=2,16,16) = 512.
This container is CPU-only: dry-run lowers/compiles with 512 host devices;
nothing here is a wall-clock TPU measurement.
""")

    # ---------------- dry-run ----------------
    md.append("""## §Dry-run (80 cells: 10 archs x 4 shapes x 2 meshes)

Every runnable cell **lowers AND compiles** (`.lower().compile()`) on both
production meshes; `long_500k` is a documented skip for the 8 full-attention
archs (sub-quadratic archs run it). Memory columns: `state` =
`argument_size_in_bytes` per device (params + optimizer + inputs — exact,
sharding-determined); `temp` = XLA-CPU temp upper bound (the CPU backend
lacks the TPU memory-aware scheduler/buffer-reuse passes, so this OVERSTATES
real HBM liveness; the §Perf log shows it being driven down where it flagged
real problems, e.g. kimi 1.17 TB -> 94 GB).

| arch | shape | mesh | status | flops/dev | state GiB | temp GiB | collectives (count) |
|---|---|---|---|---|---|---|---|""")
    for key in sorted(dry):
        r = dry[key]
        mesh = "2x16x16" if "multi" in r["mesh"] else "16x16"
        if r["status"] != "ok":
            md.append(f"| {r['arch']} | {r['shape']} | {mesh} | "
                      f"{r['status']} | – | – | – | – |")
            continue
        m = r["memory"]
        coll = r["collectives"]["counts"]
        cstr = ", ".join(f"{k}:{v}" for k, v in sorted(coll.items()))
        md.append(
            f"| {r['arch']} | {r['shape']} | {mesh} | ok | "
            f"{r['flops']:.2e} | {gib(m['argument_size_in_bytes'])} | "
            f"{gib(m['temp_size_in_bytes'])} | {cstr} |")

    n_ok = sum(1 for r in dry.values() if r["status"] == "ok")
    n_skip = sum(1 for r in dry.values()
                 if str(r["status"]).startswith("skip"))
    md.append(f"\n**{n_ok} compiled OK, {n_skip} documented skips, "
              f"{len(dry)-n_ok-n_skip} failures.** The multi-pod pass proves "
              "the `pod` axis shards (DP over pods; gradient all-reduce "
              "crosses the pod boundary hierarchically).\n")

    # ---------------- roofline ----------------
    md.append("""## §Roofline (single-pod, per assignment)

Terms from the two-point unrolled depth probe (see
`src/repro/analysis/roofline.py` docstring — `cost_analysis` counts scan
bodies once, so shallow unrolled probes are scaled to real depth; gradient
sync bytes get an analytic microbatch correction). `MODEL_FLOPS` = 6·N_active·T
(+ family attention/mixer terms); `useful` = MODEL_FLOPS / HLO_FLOPS (catches
remat/dispatch waste); `MFU` = roofline fraction = (MODEL_FLOPS/chips/peak) /
max(term).

| arch | shape | compute s | memory s | collective s | bottleneck | useful % | MFU % |
|---|---|---|---|---|---|---|---|""")
    for key in sorted(roof):
        r = roof[key]
        if r.get("status") != "ok":
            if str(r.get("status", "")).startswith("skip"):
                md.append(f"| {r['arch']} | {r['shape']} | – | – | – | "
                          f"{r['status']} | – | – |")
            continue
        t = r["terms"]
        md.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3f} | "
            f"{t['memory_s']:.3f} | {t['collective_s']:.3f} | "
            f"**{t['bottleneck']}** | {r['useful_ratio']*100:.1f} | "
            f"{r['roofline_fraction']*100:.1f} |")

    md.append("""
Reading the table: train shapes land at 5-20% MFU baseline (memory-bound —
bytes-accessed includes every HLO operand pass; the CPU backend does not
model fusion reuse, so treat as lower-bound MFU). Decode shapes are
correctly memory/collective-bound (batch-1-per-chip serving). Per-cell
one-line diagnosis + what would move the dominant term lives in §Perf for
the three hillclimbed cells; for the rest the bottleneck column is the
diagnosis (memory: raise arithmetic intensity — bigger per-chip batch or
fused kernels; collective: reshard or overlap).
""")

    # ---------------- benchmarks ----------------
    md.append("""## §Benchmarks — paper experiments E1-E8

Methodology: event-driven simulation over the REAL store (store/scheduler op
costs measured on true partition sizes; task compute is virtual time — the
paper's tasks are external simulators). `mode=paper` charges the calibrated
per-access latency of the paper's stack (MySQL Cluster over GbE, 10 ms/access
and 10 ms Chiron master RTT); `mode=adapted` charges only OUR measured
in-memory column-store ops — i.e., what the TPU adaptation actually costs.
""")
    heads = {
        "e1_strong_scaling": "E1 strong scaling (Fig 9a): near-linear to 960"
                             " cores; 48-thread oversubscription degrades",
        "e2_weak_scaling": "E2 weak scaling (Fig 9b): paper +12%/+35% off"
                           " linear at 2x/4x",
        "e3_workload_tasks": "E3 tasks scaling (Fig 10a)",
        "e4_workload_duration": "E4 duration scaling (Fig 10b)",
        "e5_dbms_overhead": "E5 DBMS overhead (Fig 11): paper regime"
                            " saturates at short tasks; adapted ~0",
        "e6_access_breakdown": "E6 access breakdown (Fig 12): getREADYtasks"
                               " dominates (paper: >40%)",
        "e7_steering_overhead": "E7 steering overhead (Fig 13): paper <5%",
        "e8_centralized_vs_distributed": "E8 Chiron vs d-Chiron (Fig 14):"
                                         " paper ~91% faster (~11x)",
        "claim_kernel": "Claim fast-path (host k=1 sort / k=4 segmented"
                        " argpartition vs seed loop; device wq_claim op)",
        "e_replica_lag": "Replica catch-up: delta txn-log replay vs"
                         " full-copy (encoded wire bytes vs payload model;"
                         " parity hard-checked across a truncate)",
        "e_wire_ship": "Cross-process wire shipping over the transport"
                       " fabric (pipe/TCP): pipelined background shipper"
                       " (bulk best-of-3 e2e + producer-visible"
                       " incremental vs blocking), adaptive varint"
                       " frames, concurrent 3-replica fan-out parity +"
                       " leader-kill election, throughput + bit-parity +"
                       " remote failover, all hard-checked",
        "e_sharded": "Sharded multi-primary scale-out (ShardRouter, 4"
                     " shards): scatter-gather Q1-Q7 parity vs a"
                     " single-primary oracle at one version vector,"
                     " cross-shard steal conservation + per-shard replica"
                     " parity (hard-checked), weak-scaling claim"
                     " throughput (the --min-sharded-scaleup gate)",
        "e_chaos": "Chaos kill-drill: >=2 workers go silent + the shipped"
                   " replica process killed mid-run; claim-lease expiry +"
                   " the vectorized reaper + work stealing + snapshot"
                   " respawn must conserve the live task-id set, drain"
                   " every task and restore bit-parity (hard-checked; the"
                   " --max-recovery-s gate)",
        "replay_throughput": "Batched hot-plane txn-log replay vs"
                             " record-at-a-time (bit-parity enforced)",
        "steering_sweep": "Full Q1-Q7 steering sweep latency on a ~100k-row"
                          " snapshot",
    }
    for name, rows in bench.items():
        md.append(f"### {heads.get(name, name)}\n")
        if not rows:
            continue
        cols = list(rows[0].keys())
        md.append("| " + " | ".join(cols) + " |")
        md.append("|" + "---|" * len(cols))
        for r in rows:
            md.append("| " + " | ".join(str(r.get(c, "")) for c in cols)
                      + " |")
        md.append("")

    md.append("""### Paper-claim scoreboard

| paper claim | our result | verdict |
|---|---|---|
| E1: near-linear strong scaling to 960 cores (12/24 threads) | efficiency 0.98 @960 cores/24t; degradation only at 48t oversubscription | reproduced |
| E2: +12% @2x, +35% @4x off linear | same direction, see table | reproduced (shape) |
| E3/E4: longer tasks => closer to linear | gap shrinks with duration in paper mode; ~0 in adapted mode | reproduced + improved |
| E5: DBMS time ~ total for <=3s tasks; negligible >=60s | paper-mode frac ~1.0 @1s -> 0.02 @60s; adapted-mode ~0.002 @1s | reproduced + improved |
| E6: getREADYtasks >40% of DBMS time | ~70% (our updates are cheaper than the paper's; reads dominate harder) | reproduced (direction) |
| E7: steering queries add <5% | paper-mode ~0% (analytics run on the store mirror, off the claim path) | reproduced + improved |
| E8: d-Chiron ~91% faster (~11x) than Chiron | paper-mode ~17x; adapted-mode 1.8x (our centralized baseline is already in-memory) | reproduced |
""")

    # ---------------- perf ----------------
    md.append(open("docs/PERF_LOG.md").read()
              if pathlib.Path("docs/PERF_LOG.md").exists() else "")
    pathlib.Path("EXPERIMENTS.md").write_text("\n".join(md))
    print(f"EXPERIMENTS.md written ({len(md)} blocks)")


if __name__ == "__main__":
    main()
